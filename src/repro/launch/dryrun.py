import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

__doc__ = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without touching real hardware:
  * the pjit/shard_map distribution config is coherent (SPMD partitioning
    succeeds for the 16×16 single-pod AND 2×16×16 multi-pod mesh);
  * the per-device memory fits (``compiled.memory_analysis()``);
  * the roofline terms (§Roofline): FLOPs/bytes from ``cost_analysis()``
    and collective bytes parsed from the post-SPMD HLO text.

Results are cached as JSON per cell under ``reports/dryrun/`` so the
80-compile sweep is resumable and parallelizable across processes:

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
        --shape train_4k --mesh single          # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all [--jobs 8]
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Dict

# TPU v5e constants (assigned)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

REPORT_DIR = "reports/dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[m.group(1)]


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in the (per-device) HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            token = f" {op}("
            if token not in line and f" {op}-start(" not in line:
                continue
            # operands appear inside the call parens
            try:
                args = line.split("(", 1)[1]
            except IndexError:
                continue
            for tok in re.findall(r"\w+\[[\d,]*\]", args):
                out[op] += _shape_bytes(tok)
            break
    return out


def run_cell(arch: str, shape: str, mesh_kind: str,
             variant: str = "base") -> Dict:
    import jax

    from .. import shardlib as sl
    from .mesh import make_production_mesh
    from .steps import build_cell, rules_for

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(mesh.devices.size)
    rules = rules_for(arch, shape, mesh)
    with sl.axis_rules(mesh, rules):
        cell = build_cell(arch, shape, smoke=False, variant=variant)
        jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate_argnums)
        lowered = jf.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    # XLA's cost_analysis counts while bodies ONCE; our analyzer multiplies
    # by trip counts (layer scans, attention chunk scans, MoE loops).
    from .hlo_analysis import analyze
    acc = analyze(hlo_text)
    coll = {k: int(v) for k, v in acc["collectives"].items()}

    flops_dev = float(acc["flops"])
    bytes_dev = float(acc["bytes"])
    coll_dev = float(sum(coll.values()))
    xla_flops_dev = float(cost.get("flops", 0.0))  # body-once reference

    # Terms per the assignment: global quantities over chips × peak.
    compute_s = flops_dev * n_chips / (n_chips * PEAK_FLOPS)
    memory_s = bytes_dev * n_chips / (n_chips * HBM_BW)
    collective_s = coll_dev * n_chips / (n_chips * ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    report = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "chips": n_chips,
        "ok": True, "variant": variant,
        "kind": cell.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "xla_body_once_flops": xla_flops_dev,
            "collective_bytes": coll_dev,
            "collectives": coll,
            "bytes_by_class": {k: int(v) for k, v in
                               acc["bytes_by_class"].items()},
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes",
                                      0)),
        },
        "roofline": {**{k: float(v) for k, v in terms.items()},
                     "dominant": dominant.replace("_s", "")},
        "model_flops": float(cell.model_flops),
        "useful_ratio": (float(cell.model_flops)
                         / max(flops_dev * n_chips, 1.0)),
    }
    return report


def cell_path(arch: str, shape: str, mesh_kind: str,
              variant: str = "base") -> str:
    suffix = "" if variant == "base" else f"__{variant}"
    return os.path.join(REPORT_DIR,
                        f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", choices=["base", "opt"], default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    args = ap.parse_args()
    os.makedirs(REPORT_DIR, exist_ok=True)

    if not args.all:
        assert args.arch and args.shape
        path = cell_path(args.arch, args.shape, args.mesh, args.variant)
        if os.path.exists(path) and not args.force:
            print(f"cached: {path}")
            return 0
        try:
            rep = run_cell(args.arch, args.shape, args.mesh, args.variant)
        except Exception as e:  # record failures too — they are bugs
            rep = {"arch": args.arch, "shape": args.shape,
                   "mesh": args.mesh, "ok": False, "error": repr(e),
                   "variant": args.variant,
                   "traceback": traceback.format_exc()}
        with open(path, "w") as f:
            json.dump(rep, f, indent=1)
        print(json.dumps({k: v for k, v in rep.items()
                          if k not in ("traceback",)}, indent=1))
        return 0 if rep.get("ok") else 1

    # --all: drive one subprocess per cell (isolates device-count init and
    # parallelizes compilation across processes).
    from ..configs import all_cells
    cells, skipped = all_cells()
    for a, s, why in skipped:
        print(f"SKIP {a} × {s}: {why}")
    jobs = []
    for mesh_kind in args.meshes.split(","):
        for a, s in cells:
            if os.path.exists(cell_path(a, s, mesh_kind)) and not args.force:
                continue
            jobs.append((a, s, mesh_kind))
    print(f"{len(jobs)} cells to compile")
    running = []
    fails = 0
    while jobs or running:
        while jobs and len(running) < args.jobs:
            a, s, mk = jobs.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", mk]
            running.append(((a, s, mk), subprocess.Popen(
                cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)))
        done = [(key, pr) for key, pr in running if pr.poll() is not None]
        running = [(key, pr) for key, pr in running if pr.poll() is None]
        for (a, s, mk), pr in done:
            ok = pr.returncode == 0
            fails += 0 if ok else 1
            print(f"{'OK  ' if ok else 'FAIL'} {a} × {s} × {mk}")
        time.sleep(1.0)
    print(f"done; {fails} failures")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
