"""Serving driver: batched SSD/SSSP queries over a HoD index (the paper's
workload) or LM decode — request batching, latency percentiles.

    PYTHONPATH=src python -m repro.launch.serve --requests 200 --batch 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core.build_fast import build_hod_fast
from ..core import (BuildConfig, QueryEngine,  grid_road_graph,
                    pack_index, power_law_digraph)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="road", choices=["road", "web"])
    ap.add_argument("--side", type=int, default=60)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--sssp", action="store_true")
    args = ap.parse_args()

    g = (grid_road_graph(args.side) if args.graph == "road"
         else power_law_digraph(args.side * args.side, 4, weighted=True))
    print(f"graph: n={g.n} m={g.m}")
    t0 = time.perf_counter()
    res = build_hod_fast(g, BuildConfig(max_core_nodes=512,
                                   max_core_edges=1 << 15))
    ix = pack_index(g, res, chunk=2048)
    print(f"index built in {time.perf_counter()-t0:.1f}s "
          f"({ix.n_levels} levels, core {ix.n_core}, "
          f"{res.stats.shortcuts_added} shortcuts)")
    eng = QueryEngine(ix)

    rng = np.random.default_rng(0)
    sources = rng.integers(0, g.n, args.requests).astype(np.int32)
    lat = []
    for lo in range(0, args.requests, args.batch):
        batch = sources[lo: lo + args.batch]
        if batch.shape[0] < args.batch:
            batch = np.pad(batch, (0, args.batch - batch.shape[0]),
                           mode="edge")
        t0 = time.perf_counter()
        if args.sssp:
            eng.sssp(batch)
        else:
            eng.ssd(batch)
        lat.append((time.perf_counter() - t0) / batch.shape[0])
    lat = np.array(lat) * 1e3
    print(f"served {args.requests} {'SSSP' if args.sssp else 'SSD'} "
          f"queries, batch={args.batch}")
    print(f"per-query latency: mean {lat.mean():.2f} ms  "
          f"p50 {np.percentile(lat, 50):.2f}  "
          f"p99 {np.percentile(lat, 99):.2f} ms")


if __name__ == "__main__":
    main()
