"""Batched HoD query serving (DESIGN.md §8, §12): async request
coalescing, fixed jit batch shapes, an LRU source-row cache, a
mixed-traffic SLO scheduler, and disk cost — modeled for in-memory
engines, *measured* for store-backed ones.

The paper's flagship workload (closeness centrality, Table 5) issues
hundreds of SSD queries; the ROADMAP north-star is the same shape at
traffic scale — many independent clients, each asking for one source.
:class:`QueryServer` sits between the two: it accepts an async request
stream, coalesces sources into fixed-size batches (padding to the jit'd
batch shape so no request triggers a recompile), answers repeats from an
LRU cache of recent source rows, and accounts each batch's index scan
through the block-I/O model (DESIGN.md §9) — one scan of F_f + core +
F_b *per batch*, which is exactly the amortization HoD's sweep
structure buys (every source in the batch shares the scan).

Mixed traffic (DESIGN.md §12): one server can admit several query
modes at once (``modes=("ssd", "p2p")``) and schedule them under
per-class latency targets.  ``scheduler="fifo"`` is the single-queue
baseline — every class shares one arrival-ordered queue, one size
trigger, and one ``max_wait_ms`` timer, so a cheap point lookup queues
behind whatever cold sweep arrived first.  ``scheduler="slo"`` gives
each class its own admission queue and flushes a batch *when the
oldest pending request's class deadline would otherwise be missed*
(deadline minus an EWMA of the class's recent batch execution time),
not only on size or a global timer.  Per-class p50/p99 and
deadline-miss counters land in the PR-8 ``obs`` registry
(``latency_ms.<mode>[.cached|.cold]``, ``slo.miss.<mode>``) and in
``ServerStats.report`` / the ``slo`` table of ``BENCH_serve.json``.

Two index residency modes (DESIGN.md §6):

* ``QueryServer(engine)`` — the classic fully-resident engine; each
  batch charges one *synthetic* sequential scan to the device;
* ``QueryServer(store_path=..., cache_bytes=...)`` — disk-resident: the
  index streams from its block store through a bounded page cache, the
  device meters *actual* block reads (cache misses), and per-batch
  real-vs-modeled I/O plus the cache hit-rate land in ``batch_io``.
  ``cache_policy`` picks the eviction policy (``"2q"`` by default —
  the scan-resistant choice for cyclic sweeps; ``"arc"``, ``"lru"``,
  ``"clock"`` also available, DESIGN.md §6).  ``--codec`` writes the
  store with a per-block segment codec (``delta``/``f16``): misses
  then read *compressed* bytes and decompress on cache fill, so
  ``store_bytes_read`` < ``store_bytes_filled``.

The CLI surface is a thin override layer over the declarative config
spine (``repro.config``, DESIGN.md §12): ``--config
configs/serve_mixed.yaml`` loads a hierarchical include-based file and
any explicitly-typed flag wins over it (precedence: built-in defaults
< include chain < file < CLI).

    PYTHONPATH=src python -m repro.launch.serve --requests 200 --batch 32
    PYTHONPATH=src python -m repro.launch.serve --store --cache-frac 0.05
    PYTHONPATH=src python -m repro.launch.serve --store --codec delta
    PYTHONPATH=src python -m repro.launch.serve --store --mode p2p
    PYTHONPATH=src python -m repro.launch.serve --mode threshold \
        --threshold 8
    PYTHONPATH=src python -m repro.launch.serve --store --mode topk --k 10
    PYTHONPATH=src python -m repro.launch.serve --store --mode knn --k 8
    PYTHONPATH=src python -m repro.launch.serve --store --queue-depth 8 \
        --decode-workers 4
    PYTHONPATH=src python -m repro.launch.serve \
        --config configs/serve_mixed.yaml
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..config import (SERVE_DEFAULTS, Config, ConfigError,
                      overrides_from_args, validate_serve)
from ..core import (BuildConfig, QueryEngine, grid_road_graph, pack_index,
                    power_law_digraph)
from ..core.build_fast import build_hod_fast
from ..core.io_sim import BlockDevice, IOStats
from ..obs.metrics import Histogram, MetricsRegistry
from ..obs.trace import span_if

__all__ = ["QueryResult", "ServerStats", "BatchIO", "ClassSLO",
           "QueryServer", "server_from_config", "mixed_request_stream"]


@dataclasses.dataclass
class QueryResult:
    """One answered request."""

    source: int
    dist: np.ndarray                    # [n] distances, original node order
    #                                     (p2p: a scalar; knn: [k] distances)
    pred: Optional[np.ndarray] = None   # [n] predecessors (SSSP mode only)
    nodes: Optional[np.ndarray] = None  # knn mode: [k] nearest node ids
    target: Optional[int] = None        # p2p mode: the other endpoint
    mode: str = ""                      # query mode that answered this
    latency_s: float = 0.0              # submit -> answer (includes waiting)
    batched_with: int = 1               # real requests sharing the batch
    cached: bool = False                # answered from the LRU cache
    io_bytes: float = 0.0               # this request's share of the scan


@dataclasses.dataclass
class ServerStats:
    requests: int = 0
    batches: int = 0
    cache_hits: int = 0                 # result-row LRU hits
    padded_slots: int = 0               # jit-shape filler rows executed
    busy_seconds: float = 0.0           # time inside the engine
    deadline_misses: int = 0            # SLO-classed answers past deadline
    page_hits: int = 0                  # store page-cache block hits
    page_misses: int = 0                # store page-cache block misses
    store_bytes_read: int = 0           # actual bytes read from segments
    #: decompressed bytes the cache was filled with; exceeds
    #: ``store_bytes_read`` on codec stores (decompress-on-fill)
    store_bytes_filled: int = 0
    # Read-pipeline overlap metrics (store-backed with prefetch):
    stall_seconds: float = 0.0          # modeled consumer wait on the device
    stall_wall_seconds: float = 0.0     # measured wait for in-flight fills
    ttfl_seconds: float = 0.0           # time-to-first-level, first sweep

    def throughput(self) -> float:
        return self.requests / self.busy_seconds if self.busy_seconds else 0.0

    def page_hit_rate(self) -> float:
        total = self.page_hits + self.page_misses
        return self.page_hits / total if total else 0.0

    def report(self, label: str = "", batch_size: Optional[int] = None,
               latency: Optional[Histogram] = None,
               slo_rows: Optional[List[dict]] = None,
               fleet_stats=None) -> str:
        """Human-readable serving summary (the CLI footer), shared with
        ``benchmarks/serve_throughput.py``.  ``latency`` is the served
        mode's ``latency_ms.*`` histogram from the server's
        :class:`~repro.obs.metrics.MetricsRegistry` — percentiles come
        from its fixed buckets, no per-request list needed.
        ``slo_rows`` (``QueryServer.slo_report()``) appends one line
        per traffic class with its deadline accounting;
        ``fleet_stats`` (``QueryServer.fleet_report()``) one line per
        serving shard."""
        extras = []
        if batch_size is not None:
            extras.append(f"batch={batch_size}")
        extras += [f"{self.cache_hits} cache hits",
                   f"{self.padded_slots} padded slots"]
        what = f"{label} requests" if label else "requests"
        lines = [f"served {self.requests} {what} in "
                 f"{self.batches} batches ({', '.join(extras)})"]
        if latency is not None and latency.count:
            s = latency.summary()
            lines.append(f"latency: mean {s['mean']:.2f} ms  "
                         f"p50 {s['p50']:.2f}  p95 {s['p95']:.2f}  "
                         f"p99 {s['p99']:.2f} ms")
        for row in slo_rows or ():
            dl = (f"deadline {row['deadline_ms']:g} ms, "
                  f"{row['deadline_misses']}/{row['requests']} missed"
                  if row.get("deadline_ms") else "no deadline")
            lines.append(
                f"class {row['cls']:<12} p50 {row['p50_ms']:.2f}  "
                f"p99 {row['p99_ms']:.2f} ms  "
                f"({row['requests']} answered, {dl})")
        if fleet_stats is not None:
            lines.append(f"fleet: {len(fleet_stats.rows)} shards, "
                         f"aggregate hit rate "
                         f"{fleet_stats.cache.hit_rate():.3f}, "
                         f"{fleet_stats.cache.bytes_read / 1e6:.1f} MB "
                         "read")
            lines.extend(fleet_stats.report_lines())
        lines.append(f"throughput: {self.throughput():.0f} queries/s "
                     "(engine-busy basis)")
        return "\n".join(lines)


@dataclasses.dataclass
class BatchIO:
    """Real-vs-modeled I/O of one executed batch (store-backed servers).
    ``page_hits / (page_hits + page_misses)`` is the batch's hit rate."""

    batch: int                          # stats.batches ordinal
    real_bytes: int                     # actual segment bytes read (misses;
    #                                     compressed bytes on codec stores)
    modeled_bytes: int                  # compact-payload scan model
    page_hits: int = 0
    page_misses: int = 0
    filled_bytes: int = 0               # decompressed bytes cached
    stall_s: float = 0.0                # modeled pipeline stall this batch


@dataclasses.dataclass(frozen=True)
class ClassSLO:
    """Latency target of one traffic class (DESIGN.md §12).

    ``deadline_ms`` is the submit→answer budget; the scheduler flushes
    the class's queue early enough that the oldest rider can still be
    executed inside it (deadline minus the class's recent batch-time
    EWMA).  ``batch`` caps how many requests one flush admits (the jit
    shape stays the server's ``batch_size`` — a smaller class batch is
    an admission cap, padded up like any partial batch)."""

    deadline_ms: float
    batch: Optional[int] = None

    def __post_init__(self):
        if not self.deadline_ms > 0:
            raise ValueError(f"deadline_ms must be > 0, "
                             f"got {self.deadline_ms!r}")
        if self.batch is not None and self.batch < 1:
            raise ValueError(f"class batch must be >= 1, "
                             f"got {self.batch!r}")


#: One queued request: (request key, future, submit time, mode).
_Pending = Tuple[object, "asyncio.Future", float, str]

#: Shared single-arrival queue key under ``scheduler="fifo"``.
_FIFO = "_fifo"


class QueryServer:
    """Coalesces HoD query requests into fixed-size batched sweeps.

    Every batch runs at exactly ``batch_size`` requests — short batches
    are padded by repeating the last request — so the engine compiles one
    batch shape once.  ``max_wait_ms`` bounds how long a lone request
    waits for co-riders before a partial batch is flushed anyway.

    ``mode`` picks the query type (DESIGN.md §7):

    * ``"ssd"`` — full single-source distances (default; also what
      ``sssp=False`` meant before modes existed);
    * ``"sssp"`` — distances + predecessors (``sssp=True`` back-compat);
    * ``"p2p"`` — point-to-point: requests are ``(source, target)``
      pairs, answers are scalar distances.  Store-backed engines run the
      meet-in-the-middle sweep, which reads strictly less than a full
      SSD scan (its ``BatchIO.modeled_bytes`` stays the full-scan model,
      so ``real_bytes`` visibly undercuts it);
    * ``"within"`` — distances clamped to the server-level ``within_d``
      threshold (labels past it are ``+inf``);
    * ``"knn"`` — the ``knn_k`` nearest nodes of each source (answers
      carry ``[k]`` node ids + distances; store-backed engines run the
      shrinking-radius bounded sweep).

    ``modes=("ssd", "p2p", ...)`` admits several query types into one
    server (mixed traffic); ``mode`` then names the *primary* class
    (what :meth:`serve_stream` and a mode-less :meth:`submit` use).
    ``scheduler`` picks the admission policy — ``"fifo"`` (one shared
    arrival queue; the single-queue coalescing baseline) or ``"slo"``
    (per-class queues with deadline-aware flushing, configured by
    ``slo={mode: ClassSLO(...)}``; classes without an SLO fall back to
    ``max_wait_ms``).  See DESIGN.md §12 for the state machine.

    Store-backed servers stream through the depth-N read pipeline:
    ``queue_depth``/``decode_workers`` size it (``None`` keeps the
    engine defaults), ``pin_frac`` sizes the page cache's pin budget,
    and ``ServerStats`` reports the overlap metrics (modeled stall
    seconds, time-to-first-level).
    """

    MODES = ("ssd", "sssp", "p2p", "within", "knn")
    SCHEDULERS = ("fifo", "slo")
    #: EWMA factor for per-class batch-execution estimates.
    EXEC_EWMA_ALPHA = 0.3
    #: Deadline headroom: flush at ``deadline - HEADROOM * exec_est``.
    #: The factor above 1 absorbs EWMA estimation error and event-loop
    #: contention (another class's batch may hold the loop when this
    #: queue comes due) — without it every deadline-flushed batch
    #: lands exactly on its deadline and jitter turns into misses.
    SLO_HEADROOM = 2.0

    def __init__(self, engine: Optional[QueryEngine] = None,
                 batch_size: int = 32,
                 max_wait_ms: float = 2.0, cache_entries: int = 1024,
                 sssp: bool = False, mode: Optional[str] = None,
                 modes: Optional[Tuple[str, ...]] = None,
                 scheduler: str = "fifo",
                 slo: Optional[Dict[str, object]] = None,
                 within_d: float = float("inf"), knn_k: int = 10,
                 device: Optional[BlockDevice] = None,
                 warm_start: bool = False,
                 store_path: Optional[str] = None,
                 cache_bytes: Optional[int] = None,
                 cache_policy: str = "2q",
                 pin_frac: Optional[float] = None,
                 queue_depth: Optional[int] = None,
                 decode_workers: Optional[int] = None,
                 shards: Optional[int] = None,
                 engine_opts: Optional[dict] = None,
                 tracer=None,
                 metrics: Optional[MetricsRegistry] = None):
        # Fail at construction with a named parameter, not deep inside
        # PageCache / asyncio (ISSUE-9 satellite).
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if not max_wait_ms >= 0:
            raise ValueError(f"max_wait_ms must be >= 0, "
                             f"got {max_wait_ms!r}")
        if cache_entries < 0:
            raise ValueError(f"cache_entries must be >= 0, "
                             f"got {cache_entries!r}")
        if not within_d > 0:
            raise ValueError(f"within_d must be > 0, got {within_d!r}")
        if knn_k < 1:
            raise ValueError(f"knn_k must be >= 1, got {knn_k!r}")
        if queue_depth is not None and queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, "
                             f"got {queue_depth!r}")
        if decode_workers is not None and decode_workers < 1:
            raise ValueError(f"decode_workers must be >= 1, "
                             f"got {decode_workers!r}")
        if pin_frac is not None and not 0.0 <= pin_frac <= 1.0:
            raise ValueError(f"pin_frac must be in [0, 1], "
                             f"got {pin_frac!r}")
        if shards is not None:
            if shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards!r}")
            if engine is not None:
                raise ValueError("shards applies to store-backed "
                                 "serving (pass store_path, not engine)")
            if device is not None:
                raise ValueError("pass device or shards, not both — "
                                 "a sharded fleet meters its own "
                                 "per-shard devices")
        if scheduler not in self.SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r} "
                             f"(one of {self.SCHEDULERS})")
        if mode is None:
            mode = ("sssp" if sssp
                    else (modes[0] if modes else "ssd"))
        elif sssp and mode != "sssp":
            raise ValueError(f"sssp=True contradicts mode={mode!r}")
        if modes is None:
            modes = (mode,)
        elif mode not in modes:
            raise ValueError(f"primary mode {mode!r} missing from "
                             f"modes={modes!r}")
        for m in modes:
            if m not in self.MODES:
                raise ValueError(f"unknown mode {m!r} "
                                 f"(one of {self.MODES})")
        if len(set(modes)) != len(modes):
            raise ValueError(f"duplicate modes in {modes!r}")
        self._slo: Dict[str, ClassSLO] = {}
        for cls_name, spec in (slo or {}).items():
            if cls_name not in modes:
                raise ValueError(f"SLO class {cls_name!r} is not an "
                                 f"admitted mode {modes!r}")
            if isinstance(spec, ClassSLO):
                self._slo[cls_name] = spec
            elif isinstance(spec, dict):
                self._slo[cls_name] = ClassSLO(
                    deadline_ms=float(spec["deadline_ms"]),
                    batch=spec.get("batch"))
            else:
                raise ValueError(f"slo[{cls_name!r}] must be a ClassSLO "
                                 f"or mapping, got {spec!r}")
        if engine is None:
            if store_path is None:
                raise ValueError("pass an engine or a store_path")
            # Store-backed serving (DESIGN.md §6): stream the index from
            # its block store under a bounded page-cache budget; the
            # device then meters *actual* block reads (cache misses),
            # so no synthetic scan charge is applied per batch.
            from ..storage import (IndexStore, PageCache,
                                   StreamingQueryEngine)
            if shards is not None:
                # Sharded fleet (DESIGN.md §13): the store's cache and
                # device are routing façades over N per-shard slices;
                # the engine below is the unchanged single-host code.
                from ..fleet import ServingFleet
                fleet = ServingFleet(
                    store_path, shards, cache_bytes=cache_bytes,
                    cache_policy=cache_policy, pin_frac=pin_frac,
                    decode_workers=(decode_workers
                                    if decode_workers is not None
                                    else 2))
                store = fleet.store
            else:
                cache = PageCache(cache_bytes, policy=cache_policy,
                                  pin_frac=pin_frac)
                store = IndexStore(store_path, device=device,
                                   cache=cache)
            device = store.device
            opts = dict(engine_opts or {})
            if queue_depth is not None:
                opts.setdefault("queue_depth", queue_depth)
            if decode_workers is not None:
                opts.setdefault("decode_workers", decode_workers)
            try:
                engine = StreamingQueryEngine(store, **opts)
            except Exception:
                store.close()   # don't leak the opened segments
                raise
        elif store_path is not None:
            raise ValueError("pass either an engine or a store_path, "
                             "not both")
        self.engine = engine
        self.store = getattr(engine, "store", None)   # None = in-memory
        self.fleet = getattr(engine, "fleet", None)   # None = unsharded
        # Observability (DESIGN.md §11): the tracer threads down through
        # the engine into pipeline/cache/device hooks; the registry
        # collects per-mode latency histograms + server counters.  Both
        # are optional — tracer=None keeps every hook inert, and an
        # unshared registry is created so histograms always exist.
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if tracer is not None:
            if hasattr(engine, "set_tracer"):
                engine.set_tracer(tracer)
            else:
                engine.tracer = tracer
        pipe = getattr(engine, "_pipe", None)
        if pipe is not None:
            self.metrics.gauge("pipeline.queue_depth").set(
                pipe.queue_depth)
        self.batch_size = int(batch_size)
        self.max_wait_ms = float(max_wait_ms)
        self.cache_entries = int(cache_entries)
        self.mode = mode
        self.modes = tuple(modes)
        self.scheduler = scheduler
        self.sssp = mode == "sssp"
        self.within_d = float(within_d)
        self.knn_k = int(knn_k)
        self.device = device or BlockDevice()
        self.stats = ServerStats()
        self.batch_io: List[BatchIO] = []
        # Cache / pending keys are ints (one source) or (source, target)
        # tuples (p2p), namespaced by mode *and* the mode's parameters
        # (ISSUE-9 staleness fix — see _cache_key).
        self._cache: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        # Admission queues (DESIGN.md §12): one shared arrival queue
        # under "fifo", one queue per class under "slo".
        self._queues: Dict[str, List[_Pending]] = {}
        self._timer: Optional[asyncio.Task] = None
        #: Absolute flush-by time the armed timer targets (perf_counter
        #: seconds) — exposed for the fake-clock regression tests.
        self._timer_deadline: Optional[float] = None
        #: Per-class EWMA of batch execution seconds (deadline headroom).
        self._exec_ewma: Dict[str, float] = {}
        self._last_batch_bytes = 0.0    # real (store) or modeled (in-mem)

        # One query's disk cost = one sequential scan of the index "files"
        # (paper §5: traversal order == file order); a batch shares it.
        # The executor scans the persisted SweepPlans, so those are the
        # bytes charged (assoc slots only when SSSP reconstruction runs).
        # The core search reads the dense closure OR the raw CSR, never
        # both — charge whichever this engine's core_mode actually scans.
        # Store-backed servers keep this as the *model* to compare real
        # reads against; only in-memory engines charge it to the device.
        self._mode_sweep_bytes: Dict[str, int] = {}
        for m in self.modes:
            m_sssp = m == "sssp"
            if self.store is not None:
                self._mode_sweep_bytes[m] = self.store.scan_bytes(
                    sssp=m_sssp, core_mode=engine.core_mode)
            else:
                from ..core.index import core_scan_bytes
                ix = engine.index
                self._mode_sweep_bytes[m] = (
                    ix.plan_f.scan_bytes(include_assoc=m_sssp)
                    + ix.plan_b.scan_bytes(include_assoc=m_sssp)
                    + (ix.plan_core.scan_bytes(True) if m_sssp else 0)
                    + core_scan_bytes(ix, engine.core_mode))
        self._sweep_bytes = self._mode_sweep_bytes[self.mode]
        if warm_start:
            # Compile the batch shape at construction (server startup),
            # off the first request's latency path.
            self.warmup()

    # ------------------------------------------------------------- internals
    def _now(self) -> float:
        """Monotonic clock — a seam the fake-clock tests patch."""
        return time.perf_counter()

    def _keys(self, requests: np.ndarray) -> List:
        """Hashable request identities: ints, or (source, target) pairs."""
        if requests.ndim == 2:
            return [(int(s), int(t)) for s, t in requests]
        return [int(s) for s in requests]

    def _cache_key(self, req, mode: Optional[str] = None) -> tuple:
        """LRU namespace: mode *plus the parameters that shape its
        answer*.  ``within`` rows depend on the threshold and ``knn``
        rows on k, so reconfiguring a live server (or serving two
        parameterizations) must never replay rows computed under the
        old parameter (ISSUE-9 cache-staleness fix)."""
        mode = mode or self.mode
        if mode == "within":
            return (mode, self.within_d, req)
        if mode == "knn":
            return (mode, self.knn_k, req)
        return (mode, None, req)

    def _cache_get(self, req, mode: Optional[str] = None):
        key = self._cache_key(req, mode)
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, req, row: tuple,
                   mode: Optional[str] = None) -> None:
        if self.cache_entries <= 0:
            return
        key = self._cache_key(req, mode)
        self._cache[key] = row
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_entries:
            self._cache.popitem(last=False)

    def _execute(self, requests: np.ndarray,
                 mode: Optional[str] = None) -> List[tuple]:
        """Run one padded batch; returns one (dist, pred) row per request
        (``requests`` is ``[B]`` sources, or ``[B, 2]`` pairs in p2p)."""
        mode = mode or self.mode
        fill = requests.shape[0]
        batch = requests
        if fill < self.batch_size:     # pad to the compiled shape
            pad = ((0, self.batch_size - fill),) + ((0, 0),) * (
                requests.ndim - 1)
            batch = np.pad(requests, pad, mode="edge")
        before = (self.store.cache.stats.snapshot()
                  if self.store is not None else None)
        pstats = (self.engine.pipeline_stats()
                  if hasattr(self.engine, "pipeline_stats") else None)
        pbefore = pstats.snapshot() if pstats is not None else None
        t0 = time.perf_counter()
        with span_if(self.tracer, f"query.{mode}",
                     batch=self.stats.batches + 1, fill=fill), \
             span_if(self.tracer, "jit.dispatch", mode=mode):
            if mode == "sssp":
                dist, pred = self.engine.sssp(batch)
            elif mode == "p2p":
                dist, pred = (self.engine.p2p(batch[:, 0], batch[:, 1]),
                              None)
            elif mode == "within":
                dist, pred = (self.engine.ssd_within(batch,
                                                     self.within_d), None)
            elif mode == "knn":
                # rows carry (distances, node ids); _row_fields unpacks
                nodes, dist = self.engine.knn(batch, self.knn_k)
                pred = nodes
            else:
                dist, pred = self.engine.ssd(batch), None
        busy = time.perf_counter() - t0
        self.stats.busy_seconds += busy
        # Per-class execution estimate (deadline headroom, DESIGN.md
        # §12): EWMA so one slow cold batch doesn't lock in forever.
        prev = self._exec_ewma.get(mode)
        a = self.EXEC_EWMA_ALPHA
        self._exec_ewma[mode] = (busy if prev is None
                                 else (1 - a) * prev + a * busy)
        pdelta = (pstats - pbefore) if pstats is not None else None
        if pdelta is not None:
            self.stats.stall_seconds += pdelta.stall_model_s
            self.stats.stall_wall_seconds += pdelta.stall_wall_s
            if self.stats.ttfl_seconds == 0.0:
                self.stats.ttfl_seconds = pdelta.ttfl_s
        self.stats.batches += 1
        self.stats.padded_slots += self.batch_size - fill
        m = self.metrics
        m.counter("server.batches").inc()
        m.counter(f"server.batches.{mode}").inc()
        m.counter("server.padded_slots").inc(self.batch_size - fill)
        m.counter("server.busy_seconds").inc(busy)
        if pdelta is not None:
            m.counter("pipeline.stall_seconds").inc(pdelta.stall_model_s)
        if self.store is None:
            # In-memory engine: no real reads happen, charge the modeled
            # sequential scan so I/O reporting stays meaningful.
            self.device.sequential(self._mode_sweep_bytes[mode])
            self._last_batch_bytes = float(self._mode_sweep_bytes[mode])
        else:
            # Store-backed: the page cache already metered every actual
            # block read (miss) through the device — record the delta.
            delta = self.store.cache.stats - before
            self.stats.page_hits += delta.hits
            self.stats.page_misses += delta.misses
            self.stats.store_bytes_read += delta.bytes_read
            self.stats.store_bytes_filled += delta.bytes_filled
            self.batch_io.append(BatchIO(
                batch=self.stats.batches, real_bytes=delta.bytes_read,
                modeled_bytes=self._mode_sweep_bytes[mode],
                page_hits=delta.hits,
                page_misses=delta.misses,
                filled_bytes=delta.bytes_filled,
                stall_s=pdelta.stall_model_s if pdelta else 0.0))
            self._last_batch_bytes = float(delta.bytes_read)
            m.counter("page_cache.hits").inc(delta.hits)
            m.counter("page_cache.misses").inc(delta.misses)
            m.counter("store.bytes_read").inc(delta.bytes_read)
            m.counter("store.bytes_filled").inc(delta.bytes_filled)
            m.gauge("page_cache.hit_rate").set(
                self.stats.page_hit_rate())
        rows = []
        for i, req in enumerate(self._keys(requests)):
            if mode == "p2p":          # scalar answer per pair
                row = (np.float32(dist[i]), None)
            else:
                row = (dist[i].copy(),
                       None if pred is None else pred[i].copy())
            self._cache_put(req, row, mode)
            rows.append(row)
        return rows

    def _observe(self, latency_s: float, cached: bool,
                 mode: Optional[str] = None) -> None:
        """Per-request metrics: request counters, the per-mode and
        per-class (``.cached`` / ``.cold``) latency histograms the p99
        bench gate reads back (DESIGN.md §11), and — when the class
        has an SLO — deadline-miss accounting (§12)."""
        mode = mode or self.mode
        m = self.metrics
        m.counter("server.requests").inc()
        ms = latency_s * 1e3
        m.histogram(f"latency_ms.{mode}").observe(ms)
        if cached:
            m.counter("server.result_cache_hits").inc()
            m.histogram(f"latency_ms.{mode}.cached").observe(ms)
        else:
            m.histogram(f"latency_ms.{mode}.cold").observe(ms)
        cls = self._slo.get(mode)
        if cls is not None:
            m.counter(f"slo.requests.{mode}").inc()
            if ms > cls.deadline_ms:
                m.counter(f"slo.miss.{mode}").inc()
                self.stats.deadline_misses += 1

    def _row_fields(self, row: tuple, mode: Optional[str] = None) -> tuple:
        """Split a cached row into ``(dist, pred, nodes)`` — knn rows
        carry node ids in the second slot, SSSP rows predecessors."""
        if (mode or self.mode) == "knn":
            return row[0], None, row[1]
        return row[0], row[1], None

    # ------------------------------------------------------------- sync path
    def warmup(self) -> None:
        """Trigger the one-and-only jit compile outside the latency path
        — once per admitted mode — and seed the per-class execution
        estimates the deadline scheduler subtracts from its budgets."""
        for m in self.modes:
            shape = (1, 2) if m == "p2p" else (1,)
            self._execute(np.zeros(shape, dtype=np.int32), mode=m)
        # Seed the per-class execution estimates from a second,
        # post-compile pass: the compile-time figures are orders of
        # magnitude above steady state and would make the deadline
        # scheduler flush every early batch immediately.
        self._exec_ewma.clear()
        for m in self.modes:
            shape = (1, 2) if m == "p2p" else (1,)
            self._execute(np.zeros(shape, dtype=np.int32), mode=m)
        self.stats = ServerStats()
        self.batch_io.clear()
        self._cache.clear()   # the warmup row must not count as a hit
        ps = (self.engine.pipeline_stats()
              if hasattr(self.engine, "pipeline_stats") else None)
        if self.store is not None:
            # Zero the page-cache counters — warmed *blocks* stay
            # resident (that is what a real warm start buys) — and the
            # device + pipeline counters under the SAME cache lock:
            # every fill charges cache and device inside that lock, so
            # the compound reset cannot interleave with a half-charged
            # fill (ISSUE-8 reset-race fix).
            also = [self.device.reset]
            if ps is not None:
                also.append(ps.reset)  # no stall/ttfl from warmup sweeps
            self.store.cache.reset_stats(also=also)
        else:
            self.device.reset()
            if ps is not None:
                ps.reset()
        self.metrics.reset()
        if self.tracer is not None:
            # Compile-time spans must not pollute the served trace.
            self.tracer.clear()

    def serve_stream(self, requests: np.ndarray,
                     mode: Optional[str] = None) -> List[QueryResult]:
        """Closed-loop driver: answer a request list in arrival order.

        ``requests`` is ``[N]`` sources — or ``[N, 2]`` (source, target)
        rows in p2p mode.  All requests of a chunk arrive together, so
        each one's ``latency_s`` is the full chunk wall time (submit →
        answer, same semantics as the async path) — divide by
        ``batched_with`` for the amortized per-query cost.
        """
        mode = mode or self.mode
        if mode not in self.modes:
            raise ValueError(f"mode {mode!r} not admitted "
                             f"(modes={self.modes!r})")
        requests = np.asarray(requests, dtype=np.int32)
        if (requests.ndim == 2) != (mode == "p2p"):
            raise ValueError("p2p mode takes [N, 2] (source, target) "
                             "rows; other modes take [N] sources")
        out: List[QueryResult] = []
        for lo in range(0, requests.shape[0], self.batch_size):
            chunk = requests[lo: lo + self.batch_size]
            t0 = time.perf_counter()
            misses = sorted({k for k in self._keys(chunk)
                             if self._cache_get(k, mode) is None})
            miss_rows: Dict[object, tuple] = {}
            if misses:
                uniq = np.asarray(misses, dtype=np.int32)
                for k, row in zip(misses, self._execute(uniq, mode)):
                    miss_rows[k] = row
            lat = time.perf_counter() - t0
            share = self._last_batch_bytes / len(misses) if misses else 0.0
            charged = set()   # charge each missed request's share once
            for k in self._keys(chunk):
                cached = k not in miss_rows
                row = miss_rows.get(k) or self._cache_get(k, mode)
                self.stats.requests += 1
                self.stats.cache_hits += cached
                self._observe(lat, cached, mode)
                src, tgt = k if isinstance(k, tuple) else (k, None)
                d, p, nd = self._row_fields(row, mode)
                out.append(QueryResult(
                    source=src, target=tgt, dist=d, pred=p, nodes=nd,
                    mode=mode, latency_s=lat, batched_with=chunk.shape[0],
                    cached=cached,
                    io_bytes=0.0 if (cached or k in charged) else share))
                charged.add(k)
        return out

    # ------------------------------------------------------------ async path
    async def submit(self, source: int,
                     target: Optional[int] = None,
                     mode: Optional[str] = None) -> QueryResult:
        """Enqueue one request; resolves when its batch executes (or on a
        cache hit, immediately).  p2p mode requires ``target``;
        ``mode`` (default: the server's primary) must be admitted."""
        mode = mode or self.mode
        if mode not in self.modes:
            raise ValueError(f"mode {mode!r} not admitted "
                             f"(modes={self.modes!r})")
        if (target is not None) != (mode == "p2p"):
            raise ValueError("target is required in p2p mode and "
                             "meaningless otherwise")
        req = ((int(source), int(target)) if target is not None
               else int(source))
        t0 = self._now()
        hit = self._cache_get(req, mode)
        if hit is not None:
            self.stats.requests += 1
            self.stats.cache_hits += 1
            lat = self._now() - t0
            self._observe(lat, cached=True, mode=mode)
            d, p, nd = self._row_fields(hit, mode)
            return QueryResult(source=int(source), target=target,
                               dist=d, pred=p, nodes=nd, mode=mode,
                               latency_s=lat, cached=True)
        fut = asyncio.get_running_loop().create_future()
        qkey = _FIFO if self.scheduler == "fifo" else mode
        self._queues.setdefault(qkey, []).append((req, fut, t0, mode))
        if len(self._queues[qkey]) >= self._take_size(qkey):
            self._flush_queue(qkey, partial=False)
        # Deterministic re-arm (ISSUE-9 double-wait fix): the timer is
        # ALWAYS re-derived from the oldest pending deadlines after any
        # queue mutation — a straggler left over by a full-size flush
        # keeps its own submit-time budget instead of waiting for the
        # next arrival (or a fresh full max_wait) to re-arm it.
        self._arm_timer()
        return await fut

    # --------------------------------------------------- scheduler internals
    def _take_size(self, qkey: str) -> int:
        """Size trigger / flush width of one queue (per-class caps)."""
        cls = self._slo.get(qkey)
        if cls is not None and cls.batch is not None:
            return min(cls.batch, self.batch_size)
        return self.batch_size

    def _flush_by(self, entry: _Pending) -> float:
        """Absolute time this entry's queue must flush by (DESIGN.md
        §12 deadline accounting): its class deadline minus
        ``SLO_HEADROOM`` times the class's batch-execution EWMA
        (clamped at the submit time, so an already-hopeless deadline
        still flushes immediately rather than never).  Classes without
        an SLO use ``max_wait_ms``."""
        _, _, t0, mode = entry
        cls = self._slo.get(mode) if self.scheduler == "slo" else None
        if cls is None:
            return t0 + self.max_wait_ms / 1e3
        est = self._exec_ewma.get(mode, 0.0)
        return max(t0, t0 + cls.deadline_ms / 1e3
                   - self.SLO_HEADROOM * est)

    def _earliest_flush_by(self) -> Optional[float]:
        cands = [self._flush_by(q[0])
                 for q in self._queues.values() if q]
        return min(cands) if cands else None

    def _arm_timer(self) -> None:
        """(Re)arm the single flush timer at the earliest flush-by time
        over every queue; disarm when nothing is pending.  Called after
        every queue mutation, so the timer deadline is always a pure
        function of the pending set — no path leaves a straggler
        waiting on the *next* submit to start its clock."""
        earliest = self._earliest_flush_by()
        if earliest is None:
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None
            self._timer_deadline = None
            return
        if (self._timer is not None
                and self._timer_deadline is not None
                and abs(self._timer_deadline - earliest) < 1e-9):
            return   # already armed for exactly this deadline
        if self._timer is not None:
            self._timer.cancel()
        self._timer_deadline = earliest
        delay = max(0.0, earliest - self._now())
        self._timer = asyncio.create_task(self._flush_later(delay))

    async def _flush_later(self, delay: float) -> None:
        await asyncio.sleep(delay)
        self._timer = None
        self._timer_deadline = None
        self._flush_due()

    def _flush_due(self) -> None:
        """Timer body: flush every queue whose oldest rider is due (or
        that reached its size trigger), most-urgent class first, then
        re-arm for whatever is left."""
        while True:
            now = self._now()
            due = [(self._flush_by(q[0]), qkey)
                   for qkey, q in self._queues.items()
                   if q and (len(q) >= self._take_size(qkey)
                             or self._flush_by(q[0]) <= now)]
            if not due:
                break
            due.sort()
            for _, qkey in due:
                self._flush_queue(qkey, partial=True, only_due=True)
        self._arm_timer()

    def _flush_queue(self, qkey: str, partial: bool = True,
                     only_due: bool = False) -> None:
        """Flush one admission queue: full takes always, a trailing
        partial take when ``partial`` (and, under ``only_due``, only
        while its oldest rider is actually due)."""
        q = self._queues.get(qkey)
        while q:
            width = self._take_size(qkey)
            if len(q) < width:
                if not partial:
                    break
                if only_due and self._flush_by(q[0]) > self._now():
                    break
            take, self._queues[qkey] = q[:width], q[width:]
            q = self._queues[qkey]
            self._run_batch(take)

    def _run_batch(self, take: List[_Pending]) -> None:
        """Execute one flushed take: split it into per-mode sub-batches
        in arrival order (a fifo take can mix classes), resolve the
        futures, and do the latency/deadline accounting."""
        # Coalesce wait: the oldest rider's queue time, as a
        # retroactive X span (its duration is only known now).
        wait_s = self._now() - min(t0 for _, _, t0, _ in take)
        self.metrics.histogram("coalesce_wait_ms").observe(wait_s * 1e3)
        if self.tracer is not None:
            self.tracer.complete(
                "coalesce.wait",
                self.tracer.now() - int(wait_s * 1e9),
                waiters=len(take))
        groups: "collections.OrderedDict[str, List[_Pending]]" = \
            collections.OrderedDict()
        for entry in take:
            groups.setdefault(entry[3], []).append(entry)
        for mode, entries in groups.items():
            reqs = np.asarray([r for r, _, _, _ in entries],
                              dtype=np.int32)
            try:
                rows = self._execute(reqs, mode)
            except Exception as exc:
                # Never strand co-riders: a poisoned batch (e.g. an
                # out-of-range source) fails every request in it.
                for _, fut, _, _ in entries:
                    if not fut.done():
                        fut.set_exception(exc)
                continue
            share = self._last_batch_bytes / len(entries)
            now = self._now()
            for (req, fut, t0, _), row in zip(entries, rows):
                self.stats.requests += 1
                self._observe(now - t0, cached=False, mode=mode)
                src, tgt = req if isinstance(req, tuple) else (req, None)
                if not fut.done():
                    d, p, nd = self._row_fields(row, mode)
                    fut.set_result(QueryResult(
                        source=src, target=tgt, dist=d, pred=p,
                        nodes=nd, mode=mode, latency_s=now - t0,
                        batched_with=len(entries), io_bytes=share))

    def _flush(self, include_partial: bool = True) -> None:
        """Flush every queue unconditionally (drain / legacy callers),
        then re-derive the timer from whatever remains."""
        for qkey in list(self._queues):
            self._flush_queue(qkey, partial=include_partial)
        self._arm_timer()

    async def drain(self) -> None:
        """Flush every queued request (shutdown / end of trace)."""
        self._flush()

    def pending_count(self) -> int:
        """Queued-but-unflushed requests (scheduler introspection)."""
        return sum(len(q) for q in self._queues.values())

    # ------------------------------------------------------------- reporting
    def slo_report(self) -> List[dict]:
        """Per-class latency/deadline rows (the ``slo`` bench table's
        currency): one row per admitted mode plus its ``.cached`` /
        ``.cold`` sub-classes that saw traffic."""
        rows: List[dict] = []
        for mode in self.modes:
            cls = self._slo.get(mode)
            for sub in ("", ".cached", ".cold"):
                hist = self.metrics.histograms(
                    f"latency_ms.{mode}{sub}").get(
                        f"latency_ms.{mode}{sub}")
                if hist is None or not hist.count:
                    continue
                s = hist.summary()
                row = {"cls": f"{mode}{sub}", "mode": mode,
                       "requests": s["count"], "mean_ms": s["mean"],
                       "p50_ms": s["p50"], "p99_ms": s["p99"],
                       "deadline_ms": (cls.deadline_ms if cls else None),
                       "deadline_misses": 0}
                if cls is not None and sub == "":
                    row["deadline_misses"] = int(self.metrics.counter(
                        f"slo.miss.{mode}").value)
                rows.append(row)
        return rows

    def fleet_report(self):
        """Point-in-time :class:`repro.fleet.FleetStats` snapshot
        (per-shard hit rates, bytes, budgets) for a sharded server;
        ``None`` when unsharded."""
        return self.fleet.stats() if self.fleet is not None else None

    @property
    def modeled_scan_bytes(self) -> int:
        """Compact-payload cost of one full index scan (the model a
        store-backed server's real reads are compared against) — the
        primary mode's; per-mode figures sit in _mode_sweep_bytes."""
        return self._sweep_bytes

    def modeled_io(self) -> IOStats:
        """Device-metered I/O: actual block reads for store-backed
        servers, the synthetic per-batch scan charge otherwise."""
        return self.device.stats

    def close(self) -> None:
        """Release store file handles / prefetch thread (store-backed),
        cancel the flush timer, and fail any still-pending futures so
        no submitter hangs on a closed server."""
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
            self._timer_deadline = None
        for q in self._queues.values():
            for _, fut, _, _ in q:
                if not fut.done():
                    fut.set_exception(
                        RuntimeError("QueryServer closed with the "
                                     "request still pending"))
            q.clear()
        if self.store is not None:
            self.engine.close()


# ----------------------------------------------------------- config plumbing
def server_from_config(cfg: Config, *, engine=None,
                       store_path: Optional[str] = None,
                       cache_bytes: Optional[int] = None,
                       device=None, tracer=None,
                       metrics=None) -> QueryServer:
    """Build a :class:`QueryServer` from a validated serve config
    (DESIGN.md §12).  The caller supplies the engine *or* store path
    (graph/index/store construction stays outside the config spine);
    everything else — batch, scheduler, SLO classes, cache sizing,
    pipeline depth — comes from ``cfg``."""
    validate_serve(cfg)
    mode = cfg.get("serve.mode", "ssd")
    # CLI aliases -> server modes: "threshold" is served as "within";
    # "topk" is a batch job (core.topk_closeness driven by the caller
    # after construction), so its server runs plain ssd sweeps.
    mode = {"threshold": "within", "topk": "ssd"}.get(mode, mode)
    mix = cfg.get("serve.mix") or {}
    modes = tuple(mix) if mix else (mode,)
    if mode not in modes:
        mode = modes[0]
    for m in modes:
        if m not in QueryServer.MODES:
            raise ConfigError(f"config key 'serve.mix' names unknown "
                              f"mode {m!r} (one of {QueryServer.MODES})")
    slo = {}
    for m, spec in (cfg.get("serve.slo") or {}).items():
        # Mirror QueryServer's constructor check: a typo'd class name
        # must not silently serve with no deadline.
        if m not in modes:
            raise ConfigError(
                f"config key 'serve.slo.{m}' names a class outside the "
                f"admitted modes {modes} (fix the name or add it to "
                f"'serve.mix')")
        slo[m] = ClassSLO(deadline_ms=float(spec["deadline_ms"]),
                          batch=spec.get("batch"))
    kw = dict(batch_size=cfg.get("serve.batch", 32),
              max_wait_ms=cfg.get("serve.max_wait_ms", 2.0),
              cache_entries=cfg.get("serve.cache_entries", 1024),
              mode=mode, modes=modes,
              scheduler=cfg.get("serve.scheduler", "fifo"),
              slo=slo,
              within_d=cfg.get("serve.threshold", float("inf")),
              knn_k=cfg.get("serve.k", 10),
              device=device, tracer=tracer, metrics=metrics)
    if engine is not None:
        return QueryServer(engine, **kw)
    return QueryServer(
        store_path=store_path, cache_bytes=cache_bytes,
        cache_policy=cfg.get("store.cache_policy", "2q"),
        pin_frac=cfg.get("store.pin_frac"),
        queue_depth=cfg.get("store.queue_depth"),
        decode_workers=cfg.get("store.decode_workers"),
        shards=cfg.get("serve.shards"),
        engine_opts={"use_pallas": cfg.get("serve.use_pallas", False),
                     "prefetch": cfg.get("store.prefetch", True)},
        **kw)


def mixed_request_stream(cfg: Config, n_nodes: int, n_requests: int,
                         rng: np.random.Generator,
                         p2p_pool: int = 16) -> List[Tuple[str, tuple]]:
    """Deterministic mixed-traffic stream from ``serve.mix`` shares:
    a list of ``(mode, args)`` submissions.  p2p pairs draw from a
    small pool so the cheap *cached* class actually exists (the
    millions-of-lookups traffic hub-label systems serve)."""
    mix = cfg.get("serve.mix") or {cfg.get("serve.mode", "ssd"): 1.0}
    names = sorted(mix)
    shares = np.asarray([float(mix[m]) for m in names], dtype=np.float64)
    shares /= shares.sum()
    size = max(2, p2p_pool)
    pool = rng.integers(0, n_nodes, size=(size, 2))
    if n_nodes > 1:
        # Drop self-pairs, but never to an empty pool: on tiny graphs
        # one draw can be all self-pairs, and an empty pool would make
        # the first p2p request raise.  n_nodes > 1 guarantees the
        # resample loop terminates.
        kept = pool[pool[:, 0] != pool[:, 1]]
        while len(kept) == 0:
            pool = rng.integers(0, n_nodes, size=(size, 2))
            kept = pool[pool[:, 0] != pool[:, 1]]
        pool = kept
    picks = rng.choice(len(names), size=n_requests, p=shares)
    stream: List[Tuple[str, tuple]] = []
    for i in range(n_requests):
        m = names[picks[i]]
        if m == "p2p":
            s, t = pool[int(rng.integers(0, len(pool)))]
            stream.append((m, (int(s), int(t))))
        else:
            stream.append((m, (int(rng.integers(0, n_nodes)),)))
    return stream


# --------------------------------------------------------------------- CLI
async def _open_loop(server: QueryServer, requests, rate: float,
                     seed: int = 0) -> List[QueryResult]:
    """Poisson arrivals at `rate` req/s; returns per-request results.
    ``requests`` is an array of sources / (s, t) rows, or a
    ``mixed_request_stream`` list of ``(mode, args)`` tuples."""
    rng = np.random.default_rng(seed)
    n = len(requests)
    gaps = rng.exponential(1.0 / rate, n)
    tasks = []
    for r, gap in zip(list(requests), gaps.tolist()):
        if isinstance(r, tuple) and len(r) == 2 and isinstance(r[0], str):
            mode, args = r
            coro = server.submit(*args, mode=mode)
        elif isinstance(r, (list, np.ndarray)):
            coro = server.submit(*(int(x) for x in r))
        else:
            coro = server.submit(int(r))
        tasks.append(asyncio.create_task(coro))
        await asyncio.sleep(gap)
    await server.drain()
    return list(await asyncio.gather(*tasks))


def _frac_type(lo: float, hi: float, lo_open: bool = False):
    """argparse type: a float fraction range-checked at parse time
    (ISSUE-9 satellite — a bad --cache-frac dies here with a clear
    message, not inside PageCache)."""
    def parse(text: str) -> float:
        try:
            v = float(text)
        except ValueError:
            raise argparse.ArgumentTypeError(f"{text!r} is not a number")
        if (v <= lo if lo_open else v < lo) or v > hi:
            bound = f"({lo}, {hi}]" if lo_open else f"[{lo}, {hi}]"
            raise argparse.ArgumentTypeError(
                f"{v:g} is out of range {bound}")
        return v
    return parse


def _nonneg_float(text: str) -> float:
    try:
        v = float(text)
    except ValueError:
        raise argparse.ArgumentTypeError(f"{text!r} is not a number")
    if v < 0:
        raise argparse.ArgumentTypeError(f"{v:g} must be >= 0")
    return v


def _pos_int(text: str) -> int:
    try:
        v = int(text)
    except ValueError:
        raise argparse.ArgumentTypeError(f"{text!r} is not an integer")
    if v < 1:
        raise argparse.ArgumentTypeError(f"{v} must be >= 1")
    return v


#: CLI flag -> dotted config key (the override layer, DESIGN.md §12).
_CLI_SPEC = (
    ("graph", "graph.kind"), ("side", "graph.side"),
    ("requests", "serve.requests"), ("batch", "serve.batch"),
    ("mode", "serve.mode"), ("threshold", "serve.threshold"),
    ("k", "serve.k"), ("cache", "serve.cache_entries"),
    ("rate", "serve.rate"), ("max_wait_ms", "serve.max_wait_ms"),
    ("use_pallas", "serve.use_pallas"),
    ("scheduler", "serve.scheduler"),
    ("shards", "serve.shards"),
    ("store", "store.enabled"), ("cache_frac", "store.cache_frac"),
    ("cache_policy", "store.cache_policy"), ("codec", "store.codec"),
    ("queue_depth", "store.queue_depth"),
    ("decode_workers", "store.decode_workers"),
    ("pin_frac", "store.pin_frac"),
    ("trace_out", "obs.trace_out"), ("metrics_out", "obs.metrics_out"),
)


def build_arg_parser() -> argparse.ArgumentParser:
    """The serve CLI: every flag defaults to ``argparse.SUPPRESS`` so
    only *explicitly typed* flags land in the override layer above the
    config file (documented defaults live in ``SERVE_DEFAULTS``)."""
    S = argparse.SUPPRESS
    ap = argparse.ArgumentParser(
        description="batched HoD query serving (defaults from "
                    "repro.config.SERVE_DEFAULTS; --config layers a "
                    "YAML/JSON file under any explicit flag)")
    ap.add_argument("--config", default=None,
                    help="hierarchical serve config (YAML/JSON with an "
                         "_include chain, see configs/serve_mixed.yaml);"
                         " explicit CLI flags override it")
    ap.add_argument("--graph", default=S, choices=["road", "web"])
    ap.add_argument("--side", type=_pos_int, default=S)
    ap.add_argument("--requests", type=_pos_int, default=S)
    ap.add_argument("--batch", type=_pos_int, default=S)
    ap.add_argument("--mode", default=S,
                    choices=["ssd", "p2p", "threshold", "topk", "knn"],
                    help="query mode (DESIGN.md §7): full SSD sweeps, "
                         "point-to-point pairs, distance-threshold "
                         "queries, exact top-k closeness, or k-nearest "
                         "nodes per source")
    ap.add_argument("--threshold", type=_frac_type(0, float("inf"),
                                                   lo_open=True),
                    default=S, help="distance bound for --mode threshold")
    ap.add_argument("--k", type=_pos_int, default=S,
                    help="result count for --mode topk / knn")
    ap.add_argument("--sssp", action="store_true", default=S)
    ap.add_argument("--use-pallas", action="store_true", default=S)
    ap.add_argument("--cache", type=int, default=S,
                    help="result-row LRU entries (0 disables)")
    ap.add_argument("--rate", type=_nonneg_float, default=S,
                    help="req/s for open-loop Poisson arrivals (0 = closed)")
    ap.add_argument("--max-wait-ms", type=_nonneg_float, default=S)
    ap.add_argument("--scheduler", default=S, choices=["fifo", "slo"],
                    help="admission policy for mixed traffic "
                         "(DESIGN.md §12): one shared fifo queue, or "
                         "per-class deadline-aware queues")
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard batches over all local devices (shardlib)")
    ap.add_argument("--store", action="store_true", default=S,
                    help="serve disk-resident: save_store the index and "
                         "stream it through a bounded page cache")
    ap.add_argument("--shards", type=_pos_int, default=S,
                    help="serve the store as an N-shard fleet "
                         "(DESIGN.md §13): per-shard page caches split "
                         "the --cache-frac budget, per-shard worker "
                         "pools read/decode in parallel; answers are "
                         "bit-identical to unsharded serving (implies "
                         "--store)")
    ap.add_argument("--cache-frac", type=_frac_type(0.0, 1.0,
                                                    lo_open=True),
                    default=S,
                    help="page-cache budget as a fraction in (0, 1] of "
                         "the store's DECOMPRESSED segment bytes (with "
                         "--store) — codec-independent, since the cache "
                         "holds decompressed blocks")
    ap.add_argument("--cache-policy", default=S,
                    choices=["lru", "clock", "arc", "2q"],
                    help="page-cache eviction policy (with --store); "
                         "arc/2q are scan-resistant (DESIGN.md §6)")
    ap.add_argument("--codec", default=S,
                    choices=["raw", "delta", "f16"],
                    help="per-block segment codec (with --store): delta "
                         "compresses id streams losslessly, f16 also "
                         "narrows weights within a documented eps "
                         "(DESIGN.md §6)")
    ap.add_argument("--queue-depth", type=_pos_int, default=S,
                    help="read-pipeline depth (with --store): levels of "
                         "block reads kept in flight ahead of the sweep "
                         "(1 = no read-ahead)")
    ap.add_argument("--decode-workers", type=_pos_int, default=S,
                    help="off-thread decompression pool width (with "
                         "--store)")
    ap.add_argument("--pin-frac", type=_frac_type(0.0, 1.0), default=S,
                    help="fraction in [0, 1] of the page-cache budget "
                         "reservable by pinned core blocks (with "
                         "--store; default 0.5)")
    ap.add_argument("--no-prefetch", action="store_true", default=S,
                    help="disable the read pipeline entirely (with "
                         "--store): every block read is synchronous")
    ap.add_argument("--trace-out", default=S,
                    help="write a per-query trace of the served run: "
                         "Chrome trace-event JSON (open in "
                         "https://ui.perfetto.dev), or a flat JSONL "
                         "event log if the path ends in .jsonl")
    ap.add_argument("--metrics-out", default=S,
                    help="write the server's metrics snapshot (counters"
                         ", gauges, latency histograms) as JSON")
    return ap


def load_serve_config(args: argparse.Namespace) -> Config:
    """Layer ``SERVE_DEFAULTS < --config file (+ its includes) <
    explicit CLI flags`` and validate at parse time."""
    overrides = overrides_from_args(args, _CLI_SPEC)
    if getattr(args, "no_prefetch", False):
        overrides.setdefault("store", {})["prefetch"] = False
    cfg = Config(args.config, defaults=SERVE_DEFAULTS,
                 overrides=overrides)
    return validate_serve(cfg)


def main() -> None:
    ap = build_arg_parser()
    args = ap.parse_args()
    try:
        cfg = load_serve_config(args)
    except ConfigError as exc:
        ap.error(str(exc))
    sssp = getattr(args, "sssp", False)
    cli_mode = cfg.get("serve.mode", "ssd")
    if sssp and cli_mode != "ssd":
        ap.error("--sssp only combines with the default ssd mode")
    # CLI "threshold" = server mode "within"; "topk" drives the engine
    # directly through core.closeness (it is a batch job, not a
    # stream), so its server runs plain ssd sweeps.  validate_serve
    # already rejected anything outside this table — no fallback.
    server_mode = {"ssd": "sssp" if sssp else "ssd", "sssp": "sssp",
                   "p2p": "p2p", "threshold": "within",
                   "within": "within", "knn": "knn",
                   "topk": "ssd"}[cli_mode]
    # The server is built from the *remapped* mode so the config path
    # and the CLI agree (a raw serve.mode of "topk" is not a server
    # mode and must never reach QueryServer).
    cfg.data.setdefault("serve", {})["mode"] = server_mode
    mix = cfg.get("serve.mix") or {}
    if cli_mode != "topk" and not mix:
        cfg.data.setdefault("serve", {})["mix"] = {server_mode: 1.0}
        mix = cfg.get("serve.mix")
    tracer = None
    if cfg.get("obs.trace_out"):
        from ..obs.trace import Tracer
        tracer = Tracer()

    side = int(cfg.get("graph.side", 60))
    g = (grid_road_graph(side) if cfg.get("graph.kind") == "road"
         else power_law_digraph(side * side, 4, weighted=True))
    print(f"graph: n={g.n} m={g.m}")
    t0 = time.perf_counter()
    res = build_hod_fast(g, BuildConfig(max_core_nodes=512,
                                        max_core_edges=1 << 15))
    ix = pack_index(g, res, chunk=2048)
    print(f"index built in {time.perf_counter()-t0:.1f}s "
          f"({ix.n_levels} levels, core {ix.n_core}, "
          f"{res.stats.shortcuts_added} shortcuts)")
    store_dir = None
    try:
        if cfg.get("store.enabled") or cfg.get("serve.shards") is not None:
            import tempfile
            store_dir = tempfile.mkdtemp(prefix="hod_store_")
            ix.save_store(store_dir, codec=cfg.get("store.codec"))
            from ..storage import segment_bytes, segment_logical_bytes
            # budget against the DECOMPRESSED footprint: the cache
            # meters decompressed bytes, so a fraction of the
            # compressed file size would shrink the effective budget
            # by the compression ratio
            frac = float(cfg.get("store.cache_frac"))
            budget = int(frac * segment_logical_bytes(store_dir))
            print(f"store: {store_dir} ({cfg.get('store.codec')} codec, "
                  f"{segment_bytes(store_dir)} bytes on disk, page cache "
                  f"{budget} bytes = {frac:.0%} of the "
                  f"decompressed segments)")
            server = server_from_config(cfg, store_path=store_dir,
                                        cache_bytes=budget,
                                        tracer=tracer)
        else:
            eng = QueryEngine(ix, use_pallas=cfg.get("serve.use_pallas",
                                                     False))
            server = server_from_config(cfg, engine=eng, tracer=tracer)
    except ConfigError as exc:
        # A config error this late (e.g. an slo class outside the mix)
        # must not leak the just-saved /tmp store.
        if store_dir is not None:
            import shutil
            shutil.rmtree(store_dir, ignore_errors=True)
        ap.error(str(exc))
    if cfg.path:
        print(f"config: {cfg.path} "
              f"(+{len(cfg.includes)} include(s)), scheduler "
              f"{server.scheduler}, classes {', '.join(server.modes)}")

    rng = np.random.default_rng(0)
    n_requests = int(cfg.get("serve.requests"))
    if len(server.modes) > 1:
        requests = mixed_request_stream(cfg, g.n, n_requests, rng)
    elif server_mode == "p2p":
        requests = rng.integers(0, g.n, (n_requests, 2)).astype(np.int32)
    else:
        requests = rng.integers(0, g.n, (n_requests,)).astype(np.int32)

    def drive():
        server.warmup()
        if cli_mode == "topk":
            from ..core import topk_closeness
            return topk_closeness(server.engine,
                                  k=int(cfg.get("serve.k")),
                                  batch_size=int(cfg.get("serve.batch")))
        rate = float(cfg.get("serve.rate", 0.0))
        if len(server.modes) > 1 and rate <= 0:
            rate = 1000.0   # mixed traffic is inherently open-loop
        if rate > 0:
            return asyncio.run(_open_loop(server, requests, rate))
        return server.serve_stream(requests)

    try:
        if args.data_parallel:
            import jax

            from .. import shardlib as sl
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
            with sl.axis_rules(mesh, {"batch": "data"}):
                results = drive()
            print(f"data-parallel over {len(jax.devices())} device(s)")
        else:
            results = drive()

        st = server.stats
        io = server.modeled_io()
        if cli_mode == "topk":
            tk = results
            print(f"top-{tk.k} closeness: {tk.batches} batches, "
                  f"{tk.pruned} candidates pruned mid-sweep, "
                  f"{tk.query_seconds:.2f}s")
            for v, c, f in zip(tk.nodes.tolist(), tk.closeness,
                               tk.farness):
                print(f"  node {v:>7}  closeness {c:.5f}  "
                      f"farness {f:.1f}")
            if server.store is not None:
                cs = server.store.cache.stats
                total = cs.hits + cs.misses
                print(f"page cache: hit rate "
                      f"{cs.hits / max(total, 1):.1%} "
                      f"({cs.hits} hits / {cs.misses} misses), "
                      f"{cs.bytes_read/1e6:.2f} MB read")
            return
        label = {"ssd": "SSD", "sssp": "SSSP", "p2p": "P2P",
                 "within": f"within(d={cfg.get('serve.threshold'):g})",
                 "knn": f"kNN(k={cfg.get('serve.k')})"}[server_mode]
        if len(server.modes) > 1:
            label = "+".join(server.modes)
        print(st.report(
            label=label, batch_size=int(cfg.get("serve.batch")),
            latency=server.metrics.histogram(
                f"latency_ms.{server.mode}"),
            slo_rows=server.slo_report(),
            fleet_stats=server.fleet_report()))
        kind = "measured" if server.store is not None else "modeled"
        io_s = io.modeled_seconds(block_bytes=server.device.block_bytes)
        print(f"{kind} disk: {io.seq_blocks} seq + {io.rand_blocks} rand "
              f"blocks, {io_s*1e3:.1f} ms total, "
              f"{io_s/max(st.requests,1)*1e3:.2f} ms/query")
        if server.store is not None:
            real = st.store_bytes_read
            modeled = server.modeled_scan_bytes * st.batches
            print(f"page cache: hit rate {st.page_hit_rate():.1%} "
                  f"({st.page_hits} hits / {st.page_misses} misses), "
                  f"real {real/1e6:.2f} MB vs modeled {modeled/1e6:.2f} MB "
                  f"across {st.batches} batches")
            if st.store_bytes_filled != real:
                print(f"codec {server.store.codec}: {real/1e6:.2f} MB "
                      f"compressed read -> {st.store_bytes_filled/1e6:.2f}"
                      f" MB decompressed on fill "
                      f"({real/max(st.store_bytes_filled,1):.0%} ratio)")
            if cfg.get("store.prefetch", True):
                print(f"read pipeline (depth "
                      f"{cfg.get('store.queue_depth')}, "
                      f"{cfg.get('store.decode_workers')} decode "
                      f"workers): modeled "
                      f"stall {st.stall_seconds*1e3:.1f} ms, measured "
                      f"wait {st.stall_wall_seconds*1e3:.1f} ms, "
                      f"time-to-first-level {st.ttfl_seconds*1e3:.2f} ms")
    finally:
        trace_out = cfg.get("obs.trace_out")
        if tracer is not None:
            if trace_out.endswith(".jsonl"):
                tracer.write_jsonl(trace_out)
            else:
                tracer.write_chrome(trace_out)
            print(f"trace: {len(tracer.events())} events -> "
                  f"{trace_out}")
        if cfg.get("obs.metrics_out"):
            with open(cfg.get("obs.metrics_out"), "w") as f:
                json.dump(server.metrics.snapshot(), f, indent=2)
                f.write("\n")
            print(f"metrics -> {cfg.get('obs.metrics_out')}")
        # The --store index is a throwaway in /tmp: always release the
        # segment fds / prefetch thread and remove it, even on Ctrl-C.
        if server.store is not None:
            import shutil
            store_dir = server.store.path
            server.close()
            shutil.rmtree(store_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
