"""Batched HoD query serving (DESIGN.md §8): async request coalescing,
fixed jit batch shapes, an LRU source-row cache, and disk cost — modeled
for in-memory engines, *measured* for store-backed ones.

The paper's flagship workload (closeness centrality, Table 5) issues
hundreds of SSD queries; the ROADMAP north-star is the same shape at
traffic scale — many independent clients, each asking for one source.
:class:`QueryServer` sits between the two: it accepts an async request
stream, coalesces sources into fixed-size batches (padding to the jit'd
batch shape so no request triggers a recompile), answers repeats from an
LRU cache of recent source rows, and accounts each batch's index scan
through the block-I/O model (DESIGN.md §9) — one scan of F_f + core +
F_b *per batch*, which is exactly the amortization HoD's sweep
structure buys (every source in the batch shares the scan).

Two index residency modes (DESIGN.md §6):

* ``QueryServer(engine)`` — the classic fully-resident engine; each
  batch charges one *synthetic* sequential scan to the device;
* ``QueryServer(store_path=..., cache_bytes=...)`` — disk-resident: the
  index streams from its block store through a bounded page cache, the
  device meters *actual* block reads (cache misses), and per-batch
  real-vs-modeled I/O plus the cache hit-rate land in ``batch_io``.
  ``cache_policy`` picks the eviction policy (``"2q"`` by default —
  the scan-resistant choice for cyclic sweeps; ``"arc"``, ``"lru"``,
  ``"clock"`` also available, DESIGN.md §6).  ``--codec`` writes the
  store with a per-block segment codec (``delta``/``f16``): misses
  then read *compressed* bytes and decompress on cache fill, so
  ``store_bytes_read`` < ``store_bytes_filled``.

    PYTHONPATH=src python -m repro.launch.serve --requests 200 --batch 32
    PYTHONPATH=src python -m repro.launch.serve --store --cache-frac 0.05
    PYTHONPATH=src python -m repro.launch.serve --store --codec delta
    PYTHONPATH=src python -m repro.launch.serve --store --mode p2p
    PYTHONPATH=src python -m repro.launch.serve --mode threshold \
        --threshold 8
    PYTHONPATH=src python -m repro.launch.serve --store --mode topk --k 10
    PYTHONPATH=src python -m repro.launch.serve --store --mode knn --k 8
    PYTHONPATH=src python -m repro.launch.serve --store --queue-depth 8 \
        --decode-workers 4
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import (BuildConfig, QueryEngine, grid_road_graph, pack_index,
                    power_law_digraph)
from ..core.build_fast import build_hod_fast
from ..core.io_sim import BlockDevice, IOStats
from ..obs.metrics import Histogram, MetricsRegistry
from ..obs.trace import span_if

__all__ = ["QueryResult", "ServerStats", "BatchIO", "QueryServer"]


@dataclasses.dataclass
class QueryResult:
    """One answered request."""

    source: int
    dist: np.ndarray                    # [n] distances, original node order
    #                                     (p2p: a scalar; knn: [k] distances)
    pred: Optional[np.ndarray] = None   # [n] predecessors (SSSP mode only)
    nodes: Optional[np.ndarray] = None  # knn mode: [k] nearest node ids
    target: Optional[int] = None        # p2p mode: the other endpoint
    latency_s: float = 0.0              # submit -> answer (includes waiting)
    batched_with: int = 1               # real requests sharing the batch
    cached: bool = False                # answered from the LRU cache
    io_bytes: float = 0.0               # this request's share of the scan


@dataclasses.dataclass
class ServerStats:
    requests: int = 0
    batches: int = 0
    cache_hits: int = 0                 # result-row LRU hits
    padded_slots: int = 0               # jit-shape filler rows executed
    busy_seconds: float = 0.0           # time inside the engine
    page_hits: int = 0                  # store page-cache block hits
    page_misses: int = 0                # store page-cache block misses
    store_bytes_read: int = 0           # actual bytes read from segments
    #: decompressed bytes the cache was filled with; exceeds
    #: ``store_bytes_read`` on codec stores (decompress-on-fill)
    store_bytes_filled: int = 0
    # Read-pipeline overlap metrics (store-backed with prefetch):
    stall_seconds: float = 0.0          # modeled consumer wait on the device
    stall_wall_seconds: float = 0.0     # measured wait for in-flight fills
    ttfl_seconds: float = 0.0           # time-to-first-level, first sweep

    def throughput(self) -> float:
        return self.requests / self.busy_seconds if self.busy_seconds else 0.0

    def page_hit_rate(self) -> float:
        total = self.page_hits + self.page_misses
        return self.page_hits / total if total else 0.0

    def report(self, label: str = "", batch_size: Optional[int] = None,
               latency: Optional[Histogram] = None) -> str:
        """Human-readable serving summary (the CLI footer), shared with
        ``benchmarks/serve_throughput.py``.  ``latency`` is the served
        mode's ``latency_ms.*`` histogram from the server's
        :class:`~repro.obs.metrics.MetricsRegistry` — percentiles come
        from its fixed buckets, no per-request list needed."""
        extras = []
        if batch_size is not None:
            extras.append(f"batch={batch_size}")
        extras += [f"{self.cache_hits} cache hits",
                   f"{self.padded_slots} padded slots"]
        what = f"{label} requests" if label else "requests"
        lines = [f"served {self.requests} {what} in "
                 f"{self.batches} batches ({', '.join(extras)})"]
        if latency is not None and latency.count:
            s = latency.summary()
            lines.append(f"latency: mean {s['mean']:.2f} ms  "
                         f"p50 {s['p50']:.2f}  p95 {s['p95']:.2f}  "
                         f"p99 {s['p99']:.2f} ms")
        lines.append(f"throughput: {self.throughput():.0f} queries/s "
                     "(engine-busy basis)")
        return "\n".join(lines)


@dataclasses.dataclass
class BatchIO:
    """Real-vs-modeled I/O of one executed batch (store-backed servers).
    ``page_hits / (page_hits + page_misses)`` is the batch's hit rate."""

    batch: int                          # stats.batches ordinal
    real_bytes: int                     # actual segment bytes read (misses;
    #                                     compressed bytes on codec stores)
    modeled_bytes: int                  # compact-payload scan model
    page_hits: int = 0
    page_misses: int = 0
    filled_bytes: int = 0               # decompressed bytes cached
    stall_s: float = 0.0                # modeled pipeline stall this batch


class QueryServer:
    """Coalesces HoD query requests into fixed-size batched sweeps.

    Every batch runs at exactly ``batch_size`` requests — short batches
    are padded by repeating the last request — so the engine compiles one
    batch shape once.  ``max_wait_ms`` bounds how long a lone request
    waits for co-riders before a partial batch is flushed anyway.

    ``mode`` picks the query type (DESIGN.md §7):

    * ``"ssd"`` — full single-source distances (default; also what
      ``sssp=False`` meant before modes existed);
    * ``"sssp"`` — distances + predecessors (``sssp=True`` back-compat);
    * ``"p2p"`` — point-to-point: requests are ``(source, target)``
      pairs, answers are scalar distances.  Store-backed engines run the
      meet-in-the-middle sweep, which reads strictly less than a full
      SSD scan (its ``BatchIO.modeled_bytes`` stays the full-scan model,
      so ``real_bytes`` visibly undercuts it);
    * ``"within"`` — distances clamped to the server-level ``within_d``
      threshold (labels past it are ``+inf``);
    * ``"knn"`` — the ``knn_k`` nearest nodes of each source (answers
      carry ``[k]`` node ids + distances; store-backed engines run the
      shrinking-radius bounded sweep).

    Store-backed servers stream through the depth-N read pipeline:
    ``queue_depth``/``decode_workers`` size it (``None`` keeps the
    engine defaults), ``pin_frac`` sizes the page cache's pin budget,
    and ``ServerStats`` reports the overlap metrics (modeled stall
    seconds, time-to-first-level).
    """

    MODES = ("ssd", "sssp", "p2p", "within", "knn")

    def __init__(self, engine: Optional[QueryEngine] = None,
                 batch_size: int = 32,
                 max_wait_ms: float = 2.0, cache_entries: int = 1024,
                 sssp: bool = False, mode: Optional[str] = None,
                 within_d: float = float("inf"), knn_k: int = 10,
                 device: Optional[BlockDevice] = None,
                 warm_start: bool = False,
                 store_path: Optional[str] = None,
                 cache_bytes: Optional[int] = None,
                 cache_policy: str = "2q",
                 pin_frac: Optional[float] = None,
                 queue_depth: Optional[int] = None,
                 decode_workers: Optional[int] = None,
                 engine_opts: Optional[dict] = None,
                 tracer=None,
                 metrics: Optional[MetricsRegistry] = None):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if mode is None:
            mode = "sssp" if sssp else "ssd"
        elif sssp and mode != "sssp":
            raise ValueError(f"sssp=True contradicts mode={mode!r}")
        if mode not in self.MODES:
            raise ValueError(f"unknown mode {mode!r} (one of {self.MODES})")
        if engine is None:
            if store_path is None:
                raise ValueError("pass an engine or a store_path")
            # Store-backed serving (DESIGN.md §6): stream the index from
            # its block store under a bounded page-cache budget; the
            # device then meters *actual* block reads (cache misses),
            # so no synthetic scan charge is applied per batch.
            from ..storage import (IndexStore, PageCache,
                                   StreamingQueryEngine)
            cache = PageCache(cache_bytes, policy=cache_policy,
                              pin_frac=pin_frac)
            store = IndexStore(store_path, device=device, cache=cache)
            device = store.device
            opts = dict(engine_opts or {})
            if queue_depth is not None:
                opts.setdefault("queue_depth", queue_depth)
            if decode_workers is not None:
                opts.setdefault("decode_workers", decode_workers)
            try:
                engine = StreamingQueryEngine(store, **opts)
            except Exception:
                store.close()   # don't leak the opened segments
                raise
        elif store_path is not None:
            raise ValueError("pass either an engine or a store_path, "
                             "not both")
        self.engine = engine
        self.store = getattr(engine, "store", None)   # None = in-memory
        # Observability (DESIGN.md §11): the tracer threads down through
        # the engine into pipeline/cache/device hooks; the registry
        # collects per-mode latency histograms + server counters.  Both
        # are optional — tracer=None keeps every hook inert, and an
        # unshared registry is created so histograms always exist.
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if tracer is not None:
            if hasattr(engine, "set_tracer"):
                engine.set_tracer(tracer)
            else:
                engine.tracer = tracer
        pipe = getattr(engine, "_pipe", None)
        if pipe is not None:
            self.metrics.gauge("pipeline.queue_depth").set(
                pipe.queue_depth)
        self.batch_size = int(batch_size)
        self.max_wait_ms = float(max_wait_ms)
        self.cache_entries = int(cache_entries)
        self.mode = mode
        self.sssp = mode == "sssp"
        self.within_d = float(within_d)
        self.knn_k = int(knn_k)
        self.device = device or BlockDevice()
        self.stats = ServerStats()
        self.batch_io: List[BatchIO] = []
        # Cache / pending keys are ints (one source) or (source, target)
        # tuples (p2p), namespaced by mode.
        self._cache: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self._pending: List[Tuple[object, asyncio.Future, float]] = []
        self._timer: Optional[asyncio.Task] = None
        self._last_batch_bytes = 0.0    # real (store) or modeled (in-mem)

        # One query's disk cost = one sequential scan of the index "files"
        # (paper §5: traversal order == file order); a batch shares it.
        # The executor scans the persisted SweepPlans, so those are the
        # bytes charged (assoc slots only when SSSP reconstruction runs).
        # The core search reads the dense closure OR the raw CSR, never
        # both — charge whichever this engine's core_mode actually scans.
        # Store-backed servers keep this as the *model* to compare real
        # reads against; only in-memory engines charge it to the device.
        if self.store is not None:
            self._sweep_bytes = self.store.scan_bytes(
                sssp=self.sssp, core_mode=engine.core_mode)
        else:
            from ..core.index import core_scan_bytes
            ix = engine.index
            self._sweep_bytes = (
                ix.plan_f.scan_bytes(include_assoc=self.sssp)
                + ix.plan_b.scan_bytes(include_assoc=self.sssp)
                + (ix.plan_core.scan_bytes(True) if self.sssp else 0)
                + core_scan_bytes(ix, engine.core_mode))
        if warm_start:
            # Compile the batch shape at construction (server startup),
            # off the first request's latency path.
            self.warmup()

    # ------------------------------------------------------------- internals
    def _keys(self, requests: np.ndarray) -> List:
        """Hashable request identities: ints, or (source, target) pairs."""
        if requests.ndim == 2:
            return [(int(s), int(t)) for s, t in requests]
        return [int(s) for s in requests]

    def _cache_get(self, req):
        key = (self.mode, req)
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, req, row: tuple) -> None:
        if self.cache_entries <= 0:
            return
        key = (self.mode, req)
        self._cache[key] = row
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_entries:
            self._cache.popitem(last=False)

    def _execute(self, requests: np.ndarray) -> List[tuple]:
        """Run one padded batch; returns one (dist, pred) row per request
        (``requests`` is ``[B]`` sources, or ``[B, 2]`` pairs in p2p)."""
        fill = requests.shape[0]
        batch = requests
        if fill < self.batch_size:     # pad to the compiled shape
            pad = ((0, self.batch_size - fill),) + ((0, 0),) * (
                requests.ndim - 1)
            batch = np.pad(requests, pad, mode="edge")
        before = (self.store.cache.stats.snapshot()
                  if self.store is not None else None)
        pstats = (self.engine.pipeline_stats()
                  if hasattr(self.engine, "pipeline_stats") else None)
        pbefore = pstats.snapshot() if pstats is not None else None
        t0 = time.perf_counter()
        with span_if(self.tracer, f"query.{self.mode}",
                     batch=self.stats.batches + 1, fill=fill), \
             span_if(self.tracer, "jit.dispatch", mode=self.mode):
            if self.mode == "sssp":
                dist, pred = self.engine.sssp(batch)
            elif self.mode == "p2p":
                dist, pred = (self.engine.p2p(batch[:, 0], batch[:, 1]),
                              None)
            elif self.mode == "within":
                dist, pred = (self.engine.ssd_within(batch,
                                                     self.within_d), None)
            elif self.mode == "knn":
                # rows carry (distances, node ids); _row_fields unpacks
                nodes, dist = self.engine.knn(batch, self.knn_k)
                pred = nodes
            else:
                dist, pred = self.engine.ssd(batch), None
        busy = time.perf_counter() - t0
        self.stats.busy_seconds += busy
        pdelta = (pstats - pbefore) if pstats is not None else None
        if pdelta is not None:
            self.stats.stall_seconds += pdelta.stall_model_s
            self.stats.stall_wall_seconds += pdelta.stall_wall_s
            if self.stats.ttfl_seconds == 0.0:
                self.stats.ttfl_seconds = pdelta.ttfl_s
        self.stats.batches += 1
        self.stats.padded_slots += self.batch_size - fill
        m = self.metrics
        m.counter("server.batches").inc()
        m.counter("server.padded_slots").inc(self.batch_size - fill)
        m.counter("server.busy_seconds").inc(busy)
        if pdelta is not None:
            m.counter("pipeline.stall_seconds").inc(pdelta.stall_model_s)
        if self.store is None:
            # In-memory engine: no real reads happen, charge the modeled
            # sequential scan so I/O reporting stays meaningful.
            self.device.sequential(self._sweep_bytes)
            self._last_batch_bytes = float(self._sweep_bytes)
        else:
            # Store-backed: the page cache already metered every actual
            # block read (miss) through the device — record the delta.
            delta = self.store.cache.stats - before
            self.stats.page_hits += delta.hits
            self.stats.page_misses += delta.misses
            self.stats.store_bytes_read += delta.bytes_read
            self.stats.store_bytes_filled += delta.bytes_filled
            self.batch_io.append(BatchIO(
                batch=self.stats.batches, real_bytes=delta.bytes_read,
                modeled_bytes=self._sweep_bytes, page_hits=delta.hits,
                page_misses=delta.misses,
                filled_bytes=delta.bytes_filled,
                stall_s=pdelta.stall_model_s if pdelta else 0.0))
            self._last_batch_bytes = float(delta.bytes_read)
            m.counter("page_cache.hits").inc(delta.hits)
            m.counter("page_cache.misses").inc(delta.misses)
            m.counter("store.bytes_read").inc(delta.bytes_read)
            m.counter("store.bytes_filled").inc(delta.bytes_filled)
            m.gauge("page_cache.hit_rate").set(
                self.stats.page_hit_rate())
        rows = []
        for i, req in enumerate(self._keys(requests)):
            if self.mode == "p2p":     # scalar answer per pair
                row = (np.float32(dist[i]), None)
            else:
                row = (dist[i].copy(),
                       None if pred is None else pred[i].copy())
            self._cache_put(req, row)
            rows.append(row)
        return rows

    def _observe(self, latency_s: float, cached: bool) -> None:
        """Per-request metrics: request counters + the per-mode (and
        per-class: ``.cached``) latency histograms the p99 bench gate
        reads back (DESIGN.md §11)."""
        m = self.metrics
        m.counter("server.requests").inc()
        ms = latency_s * 1e3
        m.histogram(f"latency_ms.{self.mode}").observe(ms)
        if cached:
            m.counter("server.result_cache_hits").inc()
            m.histogram(f"latency_ms.{self.mode}.cached").observe(ms)

    def _row_fields(self, row: tuple) -> tuple:
        """Split a cached row into ``(dist, pred, nodes)`` — knn rows
        carry node ids in the second slot, SSSP rows predecessors."""
        if self.mode == "knn":
            return row[0], None, row[1]
        return row[0], row[1], None

    # ------------------------------------------------------------- sync path
    def warmup(self) -> None:
        """Trigger the one-and-only jit compile outside the latency path."""
        shape = (1, 2) if self.mode == "p2p" else (1,)
        self._execute(np.zeros(shape, dtype=np.int32))
        self.stats = ServerStats()
        self.batch_io.clear()
        self._cache.clear()   # the warmup row must not count as a hit
        ps = (self.engine.pipeline_stats()
              if hasattr(self.engine, "pipeline_stats") else None)
        if self.store is not None:
            # Zero the page-cache counters — warmed *blocks* stay
            # resident (that is what a real warm start buys) — and the
            # device + pipeline counters under the SAME cache lock:
            # every fill charges cache and device inside that lock, so
            # the compound reset cannot interleave with a half-charged
            # fill (ISSUE-8 reset-race fix).
            also = [self.device.reset]
            if ps is not None:
                also.append(ps.reset)  # no stall/ttfl from warmup sweeps
            self.store.cache.reset_stats(also=also)
        else:
            self.device.reset()
            if ps is not None:
                ps.reset()
        self.metrics.reset()
        if self.tracer is not None:
            # Compile-time spans must not pollute the served trace.
            self.tracer.clear()

    def serve_stream(self, requests: np.ndarray) -> List[QueryResult]:
        """Closed-loop driver: answer a request list in arrival order.

        ``requests`` is ``[N]`` sources — or ``[N, 2]`` (source, target)
        rows in p2p mode.  All requests of a chunk arrive together, so
        each one's ``latency_s`` is the full chunk wall time (submit →
        answer, same semantics as the async path) — divide by
        ``batched_with`` for the amortized per-query cost.
        """
        requests = np.asarray(requests, dtype=np.int32)
        if (requests.ndim == 2) != (self.mode == "p2p"):
            raise ValueError("p2p mode takes [N, 2] (source, target) "
                             "rows; other modes take [N] sources")
        out: List[QueryResult] = []
        for lo in range(0, requests.shape[0], self.batch_size):
            chunk = requests[lo: lo + self.batch_size]
            t0 = time.perf_counter()
            misses = sorted({k for k in self._keys(chunk)
                             if self._cache_get(k) is None})
            miss_rows: Dict[object, tuple] = {}
            if misses:
                uniq = np.asarray(misses, dtype=np.int32)
                for k, row in zip(misses, self._execute(uniq)):
                    miss_rows[k] = row
            lat = time.perf_counter() - t0
            share = self._last_batch_bytes / len(misses) if misses else 0.0
            charged = set()   # charge each missed request's share once
            for k in self._keys(chunk):
                cached = k not in miss_rows
                row = miss_rows.get(k) or self._cache_get(k)
                self.stats.requests += 1
                self.stats.cache_hits += cached
                self._observe(lat, cached)
                src, tgt = k if isinstance(k, tuple) else (k, None)
                d, p, nd = self._row_fields(row)
                out.append(QueryResult(
                    source=src, target=tgt, dist=d, pred=p, nodes=nd,
                    latency_s=lat, batched_with=chunk.shape[0],
                    cached=cached,
                    io_bytes=0.0 if (cached or k in charged) else share))
                charged.add(k)
        return out

    # ------------------------------------------------------------ async path
    async def submit(self, source: int,
                     target: Optional[int] = None) -> QueryResult:
        """Enqueue one request; resolves when its batch executes (or on a
        cache hit, immediately).  p2p mode requires ``target``."""
        if (target is not None) != (self.mode == "p2p"):
            raise ValueError("target is required in p2p mode and "
                             "meaningless otherwise")
        req = ((int(source), int(target)) if target is not None
               else int(source))
        t0 = time.perf_counter()
        hit = self._cache_get(req)
        if hit is not None:
            self.stats.requests += 1
            self.stats.cache_hits += 1
            lat = time.perf_counter() - t0
            self._observe(lat, cached=True)
            d, p, nd = self._row_fields(hit)
            return QueryResult(source=int(source), target=target,
                               dist=d, pred=p, nodes=nd,
                               latency_s=lat, cached=True)
        fut = asyncio.get_running_loop().create_future()
        self._pending.append((req, fut, t0))
        if len(self._pending) >= self.batch_size:
            self._flush(include_partial=False)
        elif self._timer is None:
            self._timer = asyncio.create_task(self._flush_later())
        return await fut

    async def _flush_later(self) -> None:
        await asyncio.sleep(self.max_wait_ms / 1e3)
        self._timer = None
        self._flush()

    def _flush(self, include_partial: bool = True) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        while self._pending and (include_partial
                                 or len(self._pending) >= self.batch_size):
            take, self._pending = (self._pending[: self.batch_size],
                                   self._pending[self.batch_size:])
            reqs = np.asarray([r for r, _, _ in take], dtype=np.int32)
            # Coalesce wait: the oldest rider's queue time, as a
            # retroactive X span (its duration is only known now).
            wait_s = time.perf_counter() - min(t0 for _, _, t0 in take)
            self.metrics.histogram("coalesce_wait_ms").observe(
                wait_s * 1e3)
            if self.tracer is not None:
                self.tracer.complete(
                    "coalesce.wait",
                    self.tracer.now() - int(wait_s * 1e9),
                    waiters=len(take))
            try:
                rows = self._execute(reqs)
            except Exception as exc:
                # Never strand co-riders: a poisoned batch (e.g. an
                # out-of-range source) fails every request in it.
                for _, fut, _ in take:
                    if not fut.done():
                        fut.set_exception(exc)
                continue
            share = self._last_batch_bytes / len(take)
            now = time.perf_counter()
            for (req, fut, t0), row in zip(take, rows):
                self.stats.requests += 1
                self._observe(now - t0, cached=False)
                src, tgt = req if isinstance(req, tuple) else (req, None)
                if not fut.done():
                    d, p, nd = self._row_fields(row)
                    fut.set_result(QueryResult(
                        source=src, target=tgt, dist=d, pred=p, nodes=nd,
                        latency_s=now - t0, batched_with=len(take),
                        io_bytes=share))
        if self._pending and self._timer is None:
            self._timer = asyncio.create_task(self._flush_later())

    async def drain(self) -> None:
        """Flush every queued request (shutdown / end of trace)."""
        self._flush()

    # ------------------------------------------------------------- reporting
    @property
    def modeled_scan_bytes(self) -> int:
        """Compact-payload cost of one full index scan (the model a
        store-backed server's real reads are compared against)."""
        return self._sweep_bytes

    def modeled_io(self) -> IOStats:
        """Device-metered I/O: actual block reads for store-backed
        servers, the synthetic per-batch scan charge otherwise."""
        return self.device.stats

    def close(self) -> None:
        """Release store file handles / prefetch thread (store-backed)."""
        if self.store is not None:
            self.engine.close()


# --------------------------------------------------------------------- CLI
async def _open_loop(server: QueryServer, requests: np.ndarray,
                     rate: float, seed: int = 0) -> List[QueryResult]:
    """Poisson arrivals at `rate` req/s; returns per-request results."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, requests.shape[0])
    tasks = []
    for r, gap in zip(requests.tolist(), gaps.tolist()):
        coro = (server.submit(*r) if isinstance(r, list)
                else server.submit(r))
        tasks.append(asyncio.create_task(coro))
        await asyncio.sleep(gap)
    await server.drain()
    return list(await asyncio.gather(*tasks))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="road", choices=["road", "web"])
    ap.add_argument("--side", type=int, default=60)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--mode", default="ssd",
                    choices=["ssd", "p2p", "threshold", "topk", "knn"],
                    help="query mode (DESIGN.md §7): full SSD sweeps, "
                         "point-to-point pairs, distance-threshold "
                         "queries, exact top-k closeness, or k-nearest "
                         "nodes per source")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="distance bound for --mode threshold")
    ap.add_argument("--k", type=int, default=10,
                    help="result count for --mode topk / knn")
    ap.add_argument("--sssp", action="store_true")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--cache", type=int, default=1024)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="req/s for open-loop Poisson arrivals (0 = closed)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard batches over all local devices (shardlib)")
    ap.add_argument("--store", action="store_true",
                    help="serve disk-resident: save_store the index and "
                         "stream it through a bounded page cache")
    ap.add_argument("--cache-frac", type=float, default=0.25,
                    help="page-cache budget as a fraction of the store's "
                         "DECOMPRESSED segment bytes (with --store) — "
                         "codec-independent, since the cache holds "
                         "decompressed blocks")
    ap.add_argument("--cache-policy", default="2q",
                    choices=["lru", "clock", "arc", "2q"],
                    help="page-cache eviction policy (with --store); "
                         "arc/2q are scan-resistant (DESIGN.md §6)")
    ap.add_argument("--codec", default="raw",
                    choices=["raw", "delta", "f16"],
                    help="per-block segment codec (with --store): delta "
                         "compresses id streams losslessly, f16 also "
                         "narrows weights within a documented eps "
                         "(DESIGN.md §6)")
    ap.add_argument("--queue-depth", type=int, default=4,
                    help="read-pipeline depth (with --store): levels of "
                         "block reads kept in flight ahead of the sweep "
                         "(1 = no read-ahead)")
    ap.add_argument("--decode-workers", type=int, default=2,
                    help="off-thread decompression pool width (with "
                         "--store)")
    ap.add_argument("--pin-frac", type=float, default=None,
                    help="fraction of the page-cache budget reservable "
                         "by pinned core blocks (with --store; default "
                         "0.5)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the read pipeline entirely (with "
                         "--store): every block read is synchronous")
    ap.add_argument("--trace-out", default=None,
                    help="write a per-query trace of the served run: "
                         "Chrome trace-event JSON (open in "
                         "https://ui.perfetto.dev), or a flat JSONL "
                         "event log if the path ends in .jsonl")
    ap.add_argument("--metrics-out", default=None,
                    help="write the server's metrics snapshot (counters"
                         ", gauges, latency histograms) as JSON")
    args = ap.parse_args()
    if args.sssp and args.mode != "ssd":
        ap.error("--sssp only combines with the default ssd mode")
    # CLI "threshold" = server mode "within"; "topk" drives the engine
    # directly through core.closeness (it is a batch job, not a stream).
    server_mode = {"ssd": "sssp" if args.sssp else "ssd",
                   "p2p": "p2p", "threshold": "within",
                   "knn": "knn"}.get(args.mode, "ssd")
    tracer = None
    if args.trace_out:
        from ..obs.trace import Tracer
        tracer = Tracer()

    g = (grid_road_graph(args.side) if args.graph == "road"
         else power_law_digraph(args.side * args.side, 4, weighted=True))
    print(f"graph: n={g.n} m={g.m}")
    t0 = time.perf_counter()
    res = build_hod_fast(g, BuildConfig(max_core_nodes=512,
                                        max_core_edges=1 << 15))
    ix = pack_index(g, res, chunk=2048)
    print(f"index built in {time.perf_counter()-t0:.1f}s "
          f"({ix.n_levels} levels, core {ix.n_core}, "
          f"{res.stats.shortcuts_added} shortcuts)")
    if args.store:
        import tempfile
        store_dir = tempfile.mkdtemp(prefix="hod_store_")
        ix.save_store(store_dir, codec=args.codec)
        from ..storage import segment_bytes, segment_logical_bytes
        # budget against the DECOMPRESSED footprint: the cache meters
        # decompressed bytes, so a fraction of the compressed file size
        # would shrink the effective budget by the compression ratio
        budget = int(args.cache_frac * segment_logical_bytes(store_dir))
        print(f"store: {store_dir} ({args.codec} codec, "
              f"{segment_bytes(store_dir)} bytes on disk, page cache "
              f"{budget} bytes = {args.cache_frac:.0%} of the "
              f"decompressed segments)")
        server = QueryServer(store_path=store_dir, cache_bytes=budget,
                             batch_size=args.batch, mode=server_mode,
                             within_d=args.threshold, knn_k=args.k,
                             cache_entries=args.cache,
                             max_wait_ms=args.max_wait_ms,
                             cache_policy=args.cache_policy,
                             pin_frac=args.pin_frac,
                             queue_depth=args.queue_depth,
                             decode_workers=args.decode_workers,
                             engine_opts={"use_pallas": args.use_pallas,
                                          "prefetch": not args.no_prefetch},
                             tracer=tracer)
    else:
        eng = QueryEngine(ix, use_pallas=args.use_pallas)
        server = QueryServer(eng, batch_size=args.batch, mode=server_mode,
                             within_d=args.threshold, knn_k=args.k,
                             cache_entries=args.cache,
                             max_wait_ms=args.max_wait_ms,
                             tracer=tracer)

    rng = np.random.default_rng(0)
    shape = ((args.requests, 2) if args.mode == "p2p"
             else (args.requests,))
    requests = rng.integers(0, g.n, shape).astype(np.int32)

    def drive():
        server.warmup()
        if args.mode == "topk":
            from ..core import topk_closeness
            return topk_closeness(server.engine, k=args.k,
                                  batch_size=args.batch)
        if args.rate > 0:
            return asyncio.run(_open_loop(server, requests, args.rate))
        return server.serve_stream(requests)

    try:
        if args.data_parallel:
            import jax

            from .. import shardlib as sl
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
            with sl.axis_rules(mesh, {"batch": "data"}):
                results = drive()
            print(f"data-parallel over {len(jax.devices())} device(s)")
        else:
            results = drive()

        st = server.stats
        io = server.modeled_io()
        if args.mode == "topk":
            tk = results
            print(f"top-{tk.k} closeness: {tk.batches} batches, "
                  f"{tk.pruned} candidates pruned mid-sweep, "
                  f"{tk.query_seconds:.2f}s")
            for v, c, f in zip(tk.nodes.tolist(), tk.closeness,
                               tk.farness):
                print(f"  node {v:>7}  closeness {c:.5f}  "
                      f"farness {f:.1f}")
            if server.store is not None:
                cs = server.store.cache.stats
                total = cs.hits + cs.misses
                print(f"page cache: hit rate "
                      f"{cs.hits / max(total, 1):.1%} "
                      f"({cs.hits} hits / {cs.misses} misses), "
                      f"{cs.bytes_read/1e6:.2f} MB read")
            return
        label = {"ssd": "SSD", "sssp": "SSSP", "p2p": "P2P",
                 "within": f"within(d={args.threshold:g})",
                 "knn": f"kNN(k={args.k})"}[server_mode]
        print(st.report(
            label=label, batch_size=args.batch,
            latency=server.metrics.histogram(
                f"latency_ms.{server_mode}")))
        kind = "measured" if server.store is not None else "modeled"
        io_s = io.modeled_seconds(block_bytes=server.device.block_bytes)
        print(f"{kind} disk: {io.seq_blocks} seq + {io.rand_blocks} rand "
              f"blocks, {io_s*1e3:.1f} ms total, "
              f"{io_s/max(st.requests,1)*1e3:.2f} ms/query")
        if server.store is not None:
            real = st.store_bytes_read
            modeled = server.modeled_scan_bytes * st.batches
            print(f"page cache: hit rate {st.page_hit_rate():.1%} "
                  f"({st.page_hits} hits / {st.page_misses} misses), "
                  f"real {real/1e6:.2f} MB vs modeled {modeled/1e6:.2f} MB "
                  f"across {st.batches} batches")
            if st.store_bytes_filled != real:
                print(f"codec {server.store.codec}: {real/1e6:.2f} MB "
                      f"compressed read -> {st.store_bytes_filled/1e6:.2f}"
                      f" MB decompressed on fill "
                      f"({real/max(st.store_bytes_filled,1):.0%} ratio)")
            if not args.no_prefetch:
                print(f"read pipeline (depth {args.queue_depth}, "
                      f"{args.decode_workers} decode workers): modeled "
                      f"stall {st.stall_seconds*1e3:.1f} ms, measured "
                      f"wait {st.stall_wall_seconds*1e3:.1f} ms, "
                      f"time-to-first-level {st.ttfl_seconds*1e3:.2f} ms")
    finally:
        if tracer is not None:
            if args.trace_out.endswith(".jsonl"):
                tracer.write_jsonl(args.trace_out)
            else:
                tracer.write_chrome(args.trace_out)
            print(f"trace: {len(tracer.events())} events -> "
                  f"{args.trace_out}")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(server.metrics.snapshot(), f, indent=2)
                f.write("\n")
            print(f"metrics -> {args.metrics_out}")
        # The --store index is a throwaway in /tmp: always release the
        # segment fds / prefetch thread and remove it, even on Ctrl-C.
        if server.store is not None:
            import shutil
            store_dir = server.store.path
            server.close()
            shutil.rmtree(store_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
