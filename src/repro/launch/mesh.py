"""Production meshes + logical-axis rules.

``make_production_mesh`` is a *function* (importing this module never
touches jax device state): 16×16 = 256 chips per pod, and 2×16×16 = 512
for the multi-pod dry-run, axes ('pod', 'data', 'model').

Rule sets map the logical axis names used by the model code to mesh axes.
They differ by workload kind:

* train  — batch over (pod, data); FSDP (weight input dims) over data;
  TP dims (heads/mlp/experts/vocab) over model; residual-stream sequence
  sharding over model (sequence parallelism).
* serve  — no FSDP (weights replicated over data, sharded over model so
  per-layer all-gathers never sit on the decode latency path); KV cache
  sequence-sharded over model (split-KV decode).
* gnn    — nodes/edges sharded over every axis (flat 256/512-way).
* recsys — batch over (pod, data); embedding rows over model; candidate
  lists over (pod, data).
"""
from __future__ import annotations

from typing import Dict

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1×1 mesh over the single CPU device: same code path, world size 1."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def _dp(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def rules_train_lm(mesh, batch: int = 0) -> Dict:
    dp = _dp(mesh)
    return {
        "batch": dp, "fsdp": "data", "heads": "model", "kv_heads": "model",
        "mlp": "model", "expert": "model", "vocab": "model", "seq": "model",
        "kv_seq": "model", "model_dim": "model", "layer_stack": None,
        "expert_mlp": None, "embed": None,
    }


def rules_serve_lm(mesh, batch: int) -> Dict:
    dp = _dp(mesh)
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    batch_ax = dp if batch % max(dp_size, 1) == 0 and batch >= dp_size else None
    return {
        "batch": batch_ax, "fsdp": None, "heads": "model",
        "kv_heads": "model", "mlp": "model", "expert": "model",
        "vocab": "model", "seq": "model", "kv_seq": "model",
        "model_dim": "model", "layer_stack": None, "expert_mlp": None,
        "embed": None,
    }


def rules_gnn(mesh, batch: int = 0) -> Dict:
    dp = _dp(mesh)
    flat = dp + ("model",)
    return {
        "nodes": flat, "edges": flat, "batch": dp, "model_dim": "model",
        "layer_stack": None,
    }


def rules_recsys(mesh, batch: int) -> Dict:
    dp = _dp(mesh)
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    batch_ax = dp if batch % max(dp_size, 1) == 0 and batch >= dp_size else None
    return {
        "batch": batch_ax, "rows": "model", "model_dim": "model",
        "cand": dp, "layer_stack": None,
    }
