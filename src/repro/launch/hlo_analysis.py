"""Post-SPMD HLO cost analyzer with correct while-loop accounting.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
which understates every scanned model (layer scans, attention chunk scans,
MoE loops) by the trip count — and silently drops collectives inside loops
from any naive text scan.  This analyzer parses the optimized HLO text and
computes, per computation and transitively through ``calls=`` /
``condition=/body=`` edges:

* flops         — dot ops: 2·|result|·|contracted dims| (from the lhs
                  operand's shape resolved in the computation-local symbol
                  table); elementwise/reduce ops contribute |result|.
* bytes         — operand + result bytes of top-level ops (fusions count
                  their boundary, matching XLA's bytes-accessed semantics).
* collective bytes — operand bytes of all-reduce / all-gather /
                  reduce-scatter / all-to-all / collective-permute,
                  bucketed per op kind.

While ops multiply their body+condition cost by ``known_trip_count`` (from
``backend_config``), falling back to the loop-bound constant in the
condition computation.  Conditionals take the max across branches.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 1, "u1": 1, "s1": 1, "f8e4m3b11fnuz": 1, "f8e3m4": 1,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "cosine", "sine", "atan2", "logistic", "reduce", "reduce-window",
    "compare", "select", "and", "or", "xor", "clamp", "remainder",
    "exponential-minus-one", "log-plus-one", "cbrt", "erf",
}

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "bitcast-convert",
}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over every shape token in ``text``."""
    elems = 0
    byts = 0
    for m in _SHAPE_TOKEN.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _first_shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_TOKEN.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


class Op:
    __slots__ = ("name", "result", "opcode", "rest", "operands")

    def __init__(self, name, result, opcode, rest):
        self.name = name
        self.result = result
        self.opcode = opcode
        self.rest = rest                      # operand list + attributes
        self.operands = [x[1:] for x in re.findall(r"%[\w.\-]+",
                                                   rest.split("metadata")[0])]


class Computation:
    def __init__(self, name):
        self.name = name
        self.ops: List[Op] = []
        self.shapes: Dict[str, str] = {}      # op name -> result type text


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if (not line.startswith(" ")) and ("{" in line) and ("->" in line):
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                # params declared in header: %name: type
                for pm in re.finditer(r"([\w.\-]+):\s*([^,)]+)",
                                      m.group(2)):
                    cur.shapes[pm.group(1)] = pm.group(2)
                continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            name, result, opcode, rest = m.groups()
            op = Op(name, result, opcode, rest)
            cur.ops.append(op)
            cur.shapes[name] = result
    return comps


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = re.search(r'known_trip_count[^0-9]*?"n":"(\d+)"', op.rest)
    if m:
        return int(m.group(1))
    m = re.search(r"condition=%([\w.\-]+)", op.rest)
    if m and m.group(1) in comps:
        best = 1
        for o in comps[m.group(1)].ops:
            if o.opcode == "constant":
                c = re.match(r"(\d+)\)", o.rest)
                if c:
                    best = max(best, int(c.group(1)))
        return best
    return 1


def _dot_flops(op: Op, comp: Computation) -> float:
    _, rdims = _first_shape_dims(op.result) or ("", [])
    out = 1.0
    for d in rdims:
        out *= d
    # contraction size from the lhs operand shape
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contract = 1.0
    if cm and op.operands:
        lhs_t = comp.shapes.get(op.operands[0], "")
        sh = _first_shape_dims(lhs_t)
        if sh:
            dims = sh[1]
            for idx in (cm.group(1).split(",") if cm.group(1) else []):
                i = int(idx)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out * contract


BYTE_CLASSES = ("dot", "elementwise", "gather_scatter", "copy_layout",
                "collective", "other")


class Cost:
    __slots__ = ("flops", "bytes", "coll", "by_class")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = {k: 0.0 for k in COLLECTIVES}
        self.by_class = {k: 0.0 for k in BYTE_CLASSES}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
        for k in BYTE_CLASSES:
            self.by_class[k] += other.by_class[k] * mult

    def add_bytes(self, n: float, cls: str):
        self.bytes += n
        self.by_class[cls] += n


def analyze(text: str) -> Dict:
    comps = parse_module(text)
    memo: Dict[str, Cost] = {}
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                entry = m.group(1)
            break

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = Cost()
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                trips = _trip_count(op, comps)
                bm = re.search(r"body=%([\w.\-]+)", op.rest)
                cm = re.search(r"condition=%([\w.\-]+)", op.rest)
                sub = Cost()
                if bm:
                    sub.add(comp_cost(bm.group(1)))
                if cm:
                    sub.add(comp_cost(cm.group(1)))
                total.add(sub, trips)
                continue
            if oc == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%([\w.\-]+))", op.rest)
                names: List[str] = []
                for grp in branches:
                    if grp[0]:
                        names += [x.strip().lstrip("%")
                                  for x in grp[0].split(",")]
                    if grp[1]:
                        names.append(grp[1])
                if names:
                    worst = max((comp_cost(n) for n in names),
                                key=lambda c: c.flops + c.bytes,
                                default=Cost())
                    total.add(worst)
                continue
            cm = re.search(r"calls=%?([\w.\-]+)", op.rest)
            if cm:  # fusion/call: inner flops+collectives, boundary bytes
                sub = comp_cost(cm.group(1))
                total.flops += sub.flops
                for k in COLLECTIVES:
                    total.coll[k] += sub.coll[k]
                _, rb = _shape_elems_bytes(op.result)
                # Gather-aware operand charging: an operand vastly larger
                # than the fusion's result is being indexed into (embedding
                # tables, node-feature gathers) — real HBM traffic is the
                # gathered rows, not the whole table.  Cap such operands at
                # 4× the result size.
                # 16× headroom keeps in-fusion reductions honest while still
                # catching pathological whole-table reads.
                ob = 0
                for name in op.operands:
                    _, o1 = _shape_elems_bytes(comp.shapes.get(name, ""))
                    ob += min(o1, max(16 * rb, 1 << 20))
                cls = "dot" if sub.flops > 0 else "elementwise"
                total.add_bytes(rb + ob, cls)
                continue
            if oc in COLLECTIVES or oc.rstrip("-start") in COLLECTIVES \
                    or oc.replace("-start", "") in COLLECTIVES:
                base = oc.replace("-start", "")
                if base in COLLECTIVES:
                    _, b = _shape_elems_bytes(_operand_shapes(op, comp))
                    total.coll[base] += b
                    total.add_bytes(b, "collective")
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, comp)
                _, b = _shape_elems_bytes(
                    op.result + " " + _operand_shapes(op, comp))
                total.add_bytes(b, "dot")
                continue
            if oc in _SKIP_BYTES_OPS:
                continue
            e, b = _shape_elems_bytes(op.result)
            if oc in ELEMENTWISE_FLOP_OPS or oc in (
                    "broadcast", "convert", "iota", "reverse", "pad",
                    "concatenate", "slice", "reshape"):
                # TPU-fusion convention: producer-consumer chains of
                # elementwise/layout ops fuse — count result bytes only.
                total.flops += e if oc in ELEMENTWISE_FLOP_OPS else 0
                total.add_bytes(b, "elementwise")
                continue
            _, ob = _shape_elems_bytes(_operand_shapes(op, comp))
            if oc in ("gather", "dynamic-slice"):
                # traffic = gathered rows (result) + indices, not the table
                total.add_bytes(2 * b, "gather_scatter")
            elif oc in ("scatter", "dynamic-update-slice", "sort",
                        "custom-call"):
                total.add_bytes(b + min(ob, 4 * b), "gather_scatter")
            elif oc in ("copy", "transpose", "copy-start", "copy-done"):
                total.add_bytes(b, "copy_layout")
            else:
                total.add_bytes(b + ob, "other")
        memo[name] = total
        return total

    def _operand_shapes(op: Op, comp: Computation) -> str:
        return " ".join(comp.shapes.get(o, "") for o in op.operands)

    # bind helper before use
    analyze_cost = comp_cost
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0,
                "collectives": {k: 0.0 for k in COLLECTIVES}}
    c = analyze_cost(entry)
    return {"flops": c.flops, "bytes": c.bytes,
            "collectives": dict(c.coll),
            "bytes_by_class": dict(c.by_class),
            "collective_bytes": float(sum(c.coll.values()))}
