"""Production training driver: any arch, fault-tolerant, resumable.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

Wires the cell builder, the checkpoint manager (async, keep-last-k), the
step monitor (straggler/hang verdicts), deterministic data resume, and —
on a real cluster — the production mesh.  In this container it runs the
reduced smoke config on the 1-device mesh; the full config path is
identical modulo the mesh constructor.
"""
from __future__ import annotations

import argparse

import jax

from .. import shardlib as sl
from ..checkpoint import CheckpointManager
from ..configs import get_arch
from ..ft import StepMonitor
from .mesh import make_smoke_mesh
from .steps import build_cell, rules_for


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    shape = args.shape
    if mod.FAMILY == "gnn" and shape == "train_4k":
        shape = "full_graph_sm"
    if mod.FAMILY == "recsys" and shape == "train_4k":
        shape = "train_batch"

    mesh = make_smoke_mesh()
    mgr = CheckpointManager(args.ckpt_dir, keep_last=3)
    mon = StepMonitor()

    with sl.axis_rules(mesh, rules_for(args.arch, shape, mesh)):
        cell = build_cell(args.arch, shape, smoke=True)
        step_fn = jax.jit(cell.fn, donate_argnums=cell.donate_argnums)
        state, *batch_args = cell.args

        start = 0
        if mgr.latest_step() is not None:
            state, extra = mgr.restore(state)
            start = int(extra["step"]) + 1
            print(f"resumed from step {start - 1}")

        for step in range(start, args.steps):
            mon.start_step()
            state, metrics = step_fn(state, *batch_args)
            loss = float(metrics["loss"])
            verdict = mon.end_step()
            if verdict != "ok":
                print(f"[ft] step {step}: {verdict} "
                      f"(median {mon.median*1e3:.0f} ms)")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({mon.median*1e3:.0f} ms/step)")
            if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
                mgr.save(step, state)
        mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
