"""Cell builder: (architecture × input shape) -> executable step + specs.

A *cell* packages everything the dry-run, the trainer, and the smoke tests
need: the step function (train_step / prefill / decode / serve /
retrieval), its argument pytree (ShapeDtypeStructs for the dry-run,
concrete arrays for smoke mode), per-argument shardings resolved from the
logical axis rules, and the analytic MODEL_FLOPS used by §Roofline.

Every full-size config is only ever *traced* (jax.eval_shape — zero
allocation); smoke mode instantiates the reduced config for real.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import shardlib as sl
from ..configs import get_arch
from ..configs.shapes import SHAPE_PARAMS
from ..models import dlrm as dlrm_mod
from ..models import transformer as tf
from ..models.gnn import equiformer_v2, gcn, gin, schnet
from ..models.gnn.common import GraphBatch
from ..optim import adamw_init, adamw_update
from ..optim.schedules import cosine_schedule
from . import mesh as mesh_mod

GNN_MODULES = {"gcn-cora": gcn, "gin-tu": gin, "schnet": schnet,
               "equiformer-v2": equiformer_v2}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode | serve | retrieval
    family: str
    fn: Callable
    args: Tuple
    in_shardings: Optional[Tuple]
    donate_argnums: Tuple[int, ...]
    model_flops: float
    meta: Dict[str, Any]


# ---------------------------------------------------------------------------
# sharding resolution helpers
# ---------------------------------------------------------------------------

def _resolve(logical_tree):
    """Map a pytree of logical-axis tuples (or None) to NamedShardings."""
    def leaf(ax):
        if ax is None:
            return sl.sharding_for()
        return sl.sharding_for(*ax)
    return jax.tree.map(leaf, logical_tree,
                        is_leaf=lambda x: x is None or isinstance(x, tuple))


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _mesh_total() -> int:
    mesh = sl.current_mesh()
    return int(np.prod(list(mesh.shape.values()))) if mesh is not None else 1


def rules_for(arch_id: str, shape_name: str, mesh):
    mod = get_arch(arch_id)
    params = SHAPE_PARAMS[mod.FAMILY][shape_name]
    kind = params["kind"]
    if mod.FAMILY == "lm":
        if kind == "train":
            return mesh_mod.rules_train_lm(mesh)
        return mesh_mod.rules_serve_lm(mesh, params["global_batch"])
    if mod.FAMILY == "gnn":
        return mesh_mod.rules_gnn(mesh)
    return mesh_mod.rules_recsys(mesh, params.get("batch", 0))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_train_step(cfg):
    def step(state, tokens, labels):
        def lf(p):
            return tf.loss_fn(p, tokens, labels, cfg)
        loss, grads = jax.value_and_grad(lf)(state["params"])
        lr = cosine_schedule(state["opt"].count, 3e-4, 2000, 200_000)
        new_p, new_opt, gnorm = adamw_update(state["params"], grads,
                                             state["opt"], lr)
        return {"params": new_p, "opt": new_opt}, \
            {"loss": loss, "gnorm": gnorm}
    return step


def _lm_flops(cfg, kind, batch, seq):
    n_act = cfg.active_param_count()
    # per-token per-layer attention context: S/2 causal, ~W for local layers
    ctx_global = seq / 2
    if cfg.sliding_window and cfg.local_global_period > 1:
        period = cfg.local_global_period
        ctx = ((period - 1) / period * min(cfg.sliding_window, seq)
               + (1 / period) * ctx_global)
    else:
        ctx = ctx_global
    attn = 4 * cfg.n_heads * cfg.hd * ctx  # qk + av per token per layer
    if kind == "train":
        toks = batch * seq
        return 6.0 * n_act * toks + 3 * cfg.n_layers * attn * toks
    if kind == "prefill":
        toks = batch * seq
        return 2.0 * n_act * toks + cfg.n_layers * attn * toks
    # decode: one token per sequence; attention reads the full cache
    per_tok_attn = 4 * cfg.n_heads * cfg.hd * seq
    if cfg.sliding_window and cfg.local_global_period > 1:
        period = cfg.local_global_period
        local_frac = (period - 1) / period
        per_tok_attn = (local_frac * 4 * cfg.n_heads * cfg.hd
                        * min(cfg.sliding_window, seq)
                        + (1 / period) * 4 * cfg.n_heads * cfg.hd * seq)
        per_tok_attn *= cfg.n_layers
    else:
        per_tok_attn *= cfg.n_layers
    return batch * (2.0 * n_act + per_tok_attn)


def _build_lm_cell(arch_id, shape_name, mod, smoke):
    cfg = mod.smoke_config() if smoke else mod.CONFIG
    sp = dict(SHAPE_PARAMS["lm"][shape_name])
    kind = sp["kind"]
    if smoke:
        sp["seq_len"] = 64 if kind != "decode" else 128
        sp["global_batch"] = 2
    b, s = sp["global_batch"], sp["seq_len"]

    params_shape = jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    p_logical = tf.param_shardings(cfg)

    if kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        state_shape = {"params": params_shape, "opt": opt_shape}
        state_logical = {
            "params": p_logical,
            "opt": {"m": p_logical, "v": p_logical, "count": None},
        }
        tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
        fn = _lm_train_step(cfg)
        if smoke:
            params = tf.init_params(jax.random.PRNGKey(0), cfg)
            state = {"params": params, "opt": adamw_init(params)}
            rng = np.random.default_rng(0)
            toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)),
                               jnp.int32)
            args = (state, toks[:, :-1], toks[:, 1:])
            return Cell(arch_id, shape_name, kind, "lm", fn, args, None,
                        (0,), _lm_flops(cfg, kind, b, s), {"cfg": cfg})
        in_sh = (_resolve(state_logical), sl.sharding_for("batch", None),
                 sl.sharding_for("batch", None))
        # OptState is a NamedTuple — rebuild matching structure
        in_sh = ({"params": in_sh[0]["params"],
                  "opt": type(opt_shape)(m=in_sh[0]["opt"]["m"],
                                         v=in_sh[0]["opt"]["v"],
                                         count=sl.sharding_for())},
                 in_sh[1], in_sh[2])
        args = (state_shape, tok_sds, tok_sds)
        return Cell(arch_id, shape_name, kind, "lm", fn, args, in_sh, (0,),
                    _lm_flops(cfg, kind, b, s), {"cfg": cfg})

    # serving: bf16 params
    serve_params_shape = jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(
            sd.shape, jnp.bfloat16 if sd.dtype == jnp.float32 else sd.dtype),
        params_shape)
    p_shard = _resolve(p_logical)

    if kind == "prefill":
        fn = functools.partial(tf.prefill, cfg=cfg)
        if smoke:
            params = jax.tree.map(
                lambda a: a.astype(jnp.bfloat16)
                if a.dtype == jnp.float32 else a,
                tf.init_params(jax.random.PRNGKey(0), cfg))
            toks = jnp.asarray(np.random.default_rng(0).integers(
                0, cfg.vocab, (b, s)), jnp.int32)
            return Cell(arch_id, shape_name, kind, "lm", fn, (params, toks),
                        None, (), _lm_flops(cfg, kind, b, s), {"cfg": cfg})
        tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
        in_sh = (p_shard, sl.sharding_for("batch", None))
        return Cell(arch_id, shape_name, kind, "lm", fn,
                    (serve_params_shape, tok_sds), in_sh, (),
                    _lm_flops(cfg, kind, b, s), {"cfg": cfg})

    # decode
    fn = functools.partial(tf.decode_step, cfg=cfg)
    cache_shape = jax.eval_shape(
        lambda: tf.make_cache(cfg, b, s, dtype=jnp.bfloat16))
    cache_logical = tf.cache_shardings(cfg)
    if smoke:
        params = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a,
            tf.init_params(jax.random.PRNGKey(0), cfg))
        caches = tf.make_cache(cfg, b, s, dtype=jnp.bfloat16)
        toks = jnp.zeros((b,), jnp.int32)
        args = (params, caches, toks, jnp.int32(s - 1))
        return Cell(arch_id, shape_name, kind, "lm", fn, args, None, (1,),
                    _lm_flops(cfg, kind, b, s), {"cfg": cfg})
    in_sh = (p_shard, _resolve(cache_logical), sl.sharding_for("batch"),
             sl.sharding_for())
    args = (serve_params_shape, cache_shape,
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    return Cell(arch_id, shape_name, kind, "lm", fn, args, in_sh, (1,),
                _lm_flops(cfg, kind, b, s), {"cfg": cfg})


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell_config(arch_id, cfg, sp, smoke, variant="base"):
    """Adapt the family config to the cell's dataset (input dim, classes,
    task level, edge chunking, §Perf layout variant)."""
    d_feat = sp.get("d_feat", 0)
    n_classes = sp.get("n_classes", 2)
    repl: Dict[str, Any] = {}
    big_e = (not smoke) and sp.get("n_edges", 0) > 2_000_000
    if arch_id == "gcn-cora":
        repl = dict(d_in=d_feat if d_feat else 16, n_classes=n_classes)
    elif arch_id == "gin-tu":
        repl = dict(d_in=d_feat if d_feat else 16, n_classes=n_classes,
                    node_level="batch" not in sp)
    elif arch_id == "schnet":
        repl = dict(d_in=d_feat, n_targets=n_classes)
    else:  # equiformer-v2
        repl = dict(d_in=d_feat, n_targets=n_classes)
    if big_e:
        repl["edge_chunk"] = 1 << 20 if arch_id == "equiformer-v2" else 1 << 22
    if variant == "opt":
        repl["edge_layout"] = ("dst_ranged" if arch_id == "equiformer-v2"
                               else "partitioned")
    return dataclasses.replace(cfg, **repl)


def _node_level(arch_id: str, sp) -> bool:
    """GCN has no graph readout — always node-level (molecule labels are
    broadcast to nodes); others are graph-level on packed-molecule cells."""
    return arch_id == "gcn-cora" or "batch" not in sp


def _gnn_abstract_batch(arch_id, sp, mult: int) -> Tuple[GraphBatch, Any]:
    """ShapeDtypeStruct GraphBatch (+ its sharding tree) for a cell."""
    if "batch" in sp:        # molecule: packed small graphs
        n = sp["batch"] * sp["n_nodes"]
        e = sp["batch"] * sp["n_edges"]
        n_graphs = sp["batch"]
    elif "batch_nodes" in sp:  # minibatch_lg: sampled block
        layer = sp["batch_nodes"]
        n, e = layer, 0
        for f in sp["fanout"]:
            layer *= f
            e += layer
            n += layer
        n_graphs = 1
    else:
        n, e = sp["n_nodes"], sp["n_edges"]
        n_graphs = 1
    n, e = _pad_to(n, mult), _pad_to(e, mult)
    d_feat = sp.get("d_feat", 0)
    geo = arch_id in ("schnet", "equiformer-v2")
    if not geo and d_feat == 0:
        d_feat = 16      # gcn/gin need dense features (one-hot atom types)
    node_level = _node_level(arch_id, sp)
    sds = lambda shp, dt: jax.ShapeDtypeStruct(shp, dt)
    if d_feat:
        feat = sds((n, d_feat), jnp.float32)
        feat_sh = ("nodes", None)
    else:
        feat = sds((n,), jnp.int32)
        feat_sh = ("nodes",)
    batch = GraphBatch(
        n_nodes=n, n_graphs=n_graphs,
        src=sds((e,), jnp.int32), dst=sds((e,), jnp.int32),
        node_feat=feat,
        edge_feat=sds((e, 3), jnp.float32) if geo else None,
        graph_ids=None if node_level else sds((n,), jnp.int32),
        labels=sds((n if node_level else n_graphs,), jnp.int32),
        train_mask=sds((n,), jnp.bool_) if node_level else None)
    shard = GraphBatch(
        n_nodes=n, n_graphs=n_graphs,
        src=sl.sharding_for("edges"), dst=sl.sharding_for("edges"),
        node_feat=sl.sharding_for(*feat_sh),
        edge_feat=sl.sharding_for("edges", None) if geo else None,
        graph_ids=None if node_level else sl.sharding_for("nodes"),
        labels=sl.sharding_for("nodes") if node_level else sl.sharding_for(),
        train_mask=sl.sharding_for("nodes") if node_level else None)
    return batch, shard


def _gnn_concrete_batch(arch_id, sp, smoke_scale=True):
    import jax.nn as jnn
    from ..data.graphs import make_graph_batch, synth_molecule_batch
    geo = arch_id in ("schnet", "equiformer-v2")
    if "batch" in sp:
        g = synth_molecule_batch(batch=4 if smoke_scale else sp["batch"],
                                 n_nodes=sp["n_nodes"],
                                 n_edges=sp["n_edges"],
                                 n_classes=sp["n_classes"])
        if not geo:  # gcn/gin want dense features: one-hot atom types
            g = dataclasses.replace(
                g, node_feat=jnn.one_hot(g.node_feat % 16, 16))
        if _node_level(arch_id, sp):  # gcn: broadcast graph labels to nodes
            g = dataclasses.replace(
                g, labels=jnp.take(g.labels, g.graph_ids), graph_ids=None,
                train_mask=jnp.ones(g.n_nodes, bool))
        return g
    n = 64 if smoke_scale else sp["n_nodes"]
    e = 256 if smoke_scale else sp["n_edges"]
    return make_graph_batch(n, e, min(sp.get("d_feat", 16), 32)
                            if smoke_scale else sp.get("d_feat", 16),
                            n_classes=sp["n_classes"],
                            with_geometry=True)


def _gnn_flops(arch_id, cfg, n, e):
    d = getattr(cfg, "d_hidden", 16)
    if arch_id == "gcn-cora":
        per = cfg.d_in * d * n + e * d + n * d * cfg.n_classes
        return 3.0 * 2 * per
    if arch_id == "gin-tu":
        per = cfg.n_layers * (e * d + 2 * n * d * d)
        return 3.0 * 2 * per
    if arch_id == "schnet":
        per = cfg.n_interactions * (e * (cfg.n_rbf * d + d * d)
                                    + 3 * n * d * d)
        return 3.0 * 2 * per
    # equiformer: per-edge eSCN cost = rotation build/compose/apply +
    # per-m dense SO(2) mixes over (l, channel)
    rot_apply = 4 * d * sum((2 * l + 1) ** 2
                            for l in range(cfg.l_max + 1))   # to+from frame
    rot_build = 6 * sum((2 * l + 1) ** 3 for l in range(cfg.l_max + 1))
    n0 = cfg.l_max + 1
    so2 = 2 * (n0 * d) ** 2
    for m in range(1, cfg.m_max + 1):
        so2 += 4 * ((cfg.l_max + 1 - m) * d) ** 2
    per_edge = rot_apply + rot_build + so2
    per = cfg.n_layers * (e * per_edge + n * (cfg.l_max + 1) * 2 * d * d)
    return 3.0 * per


def _gnn_train_step(mod, cfg):
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.loss_fn(p, batch, cfg))(state["params"])
        new_p, new_opt, gnorm = adamw_update(
            state["params"], grads, state["opt"], 1e-3, weight_decay=0.0)
        return {"params": new_p, "opt": new_opt}, \
            {"loss": loss, "gnorm": gnorm}
    return step


def _build_gnn_cell(arch_id, shape_name, mod, smoke, variant="base"):
    base = mod.smoke_config() if smoke else mod.CONFIG
    sp = dict(SHAPE_PARAMS["gnn"][shape_name])
    model = GNN_MODULES[arch_id]
    if smoke:
        cfg = _gnn_cell_config(arch_id, base,
                               {**sp, "d_feat": min(sp.get("d_feat", 16), 32),
                                "n_classes": sp["n_classes"]}, smoke=True)
        batch = _gnn_concrete_batch(arch_id, sp)
        cfg = dataclasses.replace(
            cfg, d_in=(batch.node_feat.shape[1]
                       if batch.node_feat.ndim == 2 else 0))
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        state = {"params": params, "opt": adamw_init(params)}
        fn = _gnn_train_step(model, cfg)
        return Cell(arch_id, shape_name, "train", "gnn", fn, (state, batch),
                    None, (0,),
                    _gnn_flops(arch_id, cfg, batch.n_nodes,
                               batch.src.shape[0]), {"cfg": cfg})
    cfg = _gnn_cell_config(arch_id, base, sp, smoke=False, variant=variant)
    mult = _mesh_total()
    if variant == "opt":
        # owner-bucketed edge layouts pad per-bucket to equal counts
        sp = dict(sp)
        if "n_edges" in sp:
            sp["n_edges"] = int(sp["n_edges"] * 1.15)
    batch, batch_sh = _gnn_abstract_batch(arch_id, sp, mult)
    if batch.node_feat.ndim == 1:
        cfg = dataclasses.replace(cfg, d_in=0)
    params_shape = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    state_shape = {"params": params_shape, "opt": opt_shape}
    repl = jax.tree.map(lambda _: sl.sharding_for(), params_shape)
    state_sh = {"params": repl,
                "opt": type(opt_shape)(
                    m=jax.tree.map(lambda _: sl.sharding_for(), opt_shape.m),
                    v=jax.tree.map(lambda _: sl.sharding_for(), opt_shape.v),
                    count=sl.sharding_for())}
    fn = _gnn_train_step(model, cfg)
    return Cell(arch_id, shape_name, "train", "gnn", fn,
                (state_shape, batch), (state_sh, batch_sh), (0,),
                _gnn_flops(arch_id, cfg, batch.n_nodes, batch.src.shape[0]),
                {"cfg": cfg})


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _dlrm_flops(cfg, kind, batch, n_cand=0):
    dims = list(cfg.bot_mlp)
    bot = sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    d_top = [cfg.n_interactions + cfg.bot_mlp[-1]] + list(cfg.top_mlp)
    top = sum(d_top[i] * d_top[i + 1] for i in range(len(d_top) - 1))
    inter = (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
    per = 2 * (bot + top + inter)
    if kind == "train":
        return 3.0 * batch * per
    if kind == "retrieval":
        return per + 2.0 * n_cand * cfg.embed_dim
    return 1.0 * batch * per


def _dlrm_train_step(cfg):
    def step(state, dense, sparse, labels):
        loss, grads = jax.value_and_grad(
            lambda p: dlrm_mod.loss_fn(p, dense, sparse, labels, cfg))(
                state["params"])
        new_p, new_opt, gnorm = adamw_update(
            state["params"], grads, state["opt"], 1e-3, weight_decay=0.0)
        return {"params": new_p, "opt": new_opt}, \
            {"loss": loss, "gnorm": gnorm}
    return step


def _build_recsys_cell(arch_id, shape_name, mod, smoke):
    cfg = mod.smoke_config() if smoke else mod.CONFIG
    sp = dict(SHAPE_PARAMS["recsys"][shape_name])
    kind = sp["kind"]
    b = 8 if smoke else sp.get("batch", 1)
    n_cand = (1024 if smoke else sp.get("n_candidates", 0))

    p_logical = dlrm_mod.param_shardings(cfg)
    params_shape = jax.eval_shape(
        lambda: dlrm_mod.init_params(jax.random.PRNGKey(0), cfg))

    def concrete_inputs(rng):
        dense = jnp.asarray(rng.normal(size=(b, cfg.n_dense)), jnp.float32)
        sparse = jnp.asarray(
            rng.integers(0, cfg.vocab_per_table, (b, cfg.n_sparse)),
            jnp.int32)
        return dense, sparse

    if kind == "train":
        fn = _dlrm_train_step(cfg)
        if smoke:
            rng = np.random.default_rng(0)
            params = dlrm_mod.init_params(jax.random.PRNGKey(0), cfg)
            state = {"params": params, "opt": adamw_init(params)}
            dense, sparse = concrete_inputs(rng)
            labels = jnp.asarray(rng.integers(0, 2, b), jnp.int32)
            return Cell(arch_id, shape_name, kind, "recsys", fn,
                        (state, dense, sparse, labels), None, (0,),
                        _dlrm_flops(cfg, kind, b), {"cfg": cfg})
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        state_shape = {"params": params_shape, "opt": opt_shape}
        psh = _resolve(p_logical)
        state_sh = {"params": psh,
                    "opt": type(opt_shape)(m=psh, v=psh,
                                           count=sl.sharding_for())}
        args = (state_shape,
                jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32),
                jax.ShapeDtypeStruct((b,), jnp.int32))
        in_sh = (state_sh, sl.sharding_for("batch", None),
                 sl.sharding_for("batch", None), sl.sharding_for("batch"))
        return Cell(arch_id, shape_name, kind, "recsys", fn, args, in_sh,
                    (0,), _dlrm_flops(cfg, kind, b), {"cfg": cfg})

    if kind == "serve":
        fn = functools.partial(dlrm_mod.forward, cfg=cfg)
        if smoke:
            rng = np.random.default_rng(0)
            params = dlrm_mod.init_params(jax.random.PRNGKey(0), cfg)
            dense, sparse = concrete_inputs(rng)
            return Cell(arch_id, shape_name, kind, "recsys", fn,
                        (params, dense, sparse), None, (),
                        _dlrm_flops(cfg, kind, b), {"cfg": cfg})
        args = (params_shape,
                jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32))
        in_sh = (_resolve(p_logical), sl.sharding_for("batch", None),
                 sl.sharding_for("batch", None))
        return Cell(arch_id, shape_name, kind, "recsys", fn, args, in_sh,
                    (), _dlrm_flops(cfg, kind, b), {"cfg": cfg})

    # retrieval
    fn = functools.partial(dlrm_mod.retrieval_scores, cfg=cfg)
    if smoke:
        rng = np.random.default_rng(0)
        params = dlrm_mod.init_params(jax.random.PRNGKey(0), cfg)
        dense, sparse = concrete_inputs(rng)
        cand = jnp.asarray(rng.integers(0, cfg.vocab_per_table, n_cand),
                           jnp.int32)
        return Cell(arch_id, shape_name, kind, "recsys", fn,
                    (params, dense[:1], sparse[:1], cand), None, (),
                    _dlrm_flops(cfg, kind, 1, n_cand), {"cfg": cfg})
    n_cand = _pad_to(n_cand, _mesh_total())
    args = (params_shape,
            jax.ShapeDtypeStruct((1, cfg.n_dense), jnp.float32),
            jax.ShapeDtypeStruct((1, cfg.n_sparse), jnp.int32),
            jax.ShapeDtypeStruct((n_cand,), jnp.int32))
    in_sh = (_resolve(p_logical), sl.sharding_for(None, None),
             sl.sharding_for(None, None), sl.sharding_for("cand"))
    return Cell(arch_id, shape_name, kind, "recsys", fn, args, in_sh, (),
                _dlrm_flops(cfg, kind, 1, n_cand), {"cfg": cfg})


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

class _OptLM:
    """Wrap an arch module, replacing CONFIG with the §Perf-opt variant."""

    def __init__(self, mod):
        self._mod = mod
        self.FAMILY = mod.FAMILY
        self.CONFIG = dataclasses.replace(mod.CONFIG, attn_opt=True,
                                          remat_policy="block_outs")
        self.smoke_config = mod.smoke_config


def build_cell(arch_id: str, shape_name: str, smoke: bool = False,
               variant: str = "base") -> Cell:
    """Must be called inside ``sl.axis_rules(mesh, rules_for(...))`` for
    abstract (dry-run) cells; smoke cells need no mesh.

    ``variant="opt"`` applies the §Perf beyond-baseline configuration:
    LM — optimized attention schedule; GNN — owner-bucketed edge layouts.
    """
    mod = get_arch(arch_id)
    if mod.FAMILY == "lm":
        if variant == "opt":
            mod = _OptLM(mod)
        return _build_lm_cell(arch_id, shape_name, mod, smoke)
    if mod.FAMILY == "gnn":
        return _build_gnn_cell(arch_id, shape_name, mod, smoke,
                               variant=variant)
    return _build_recsys_cell(arch_id, shape_name, mod, smoke)


def model_flops_for(arch_id: str, shape_name: str, mult: int = 256) -> float:
    """Analytic MODEL_FLOPS for a full-size cell, mesh-free (``mult`` is
    only the padding multiple for GNN node/edge counts)."""
    mod = get_arch(arch_id)
    sp = dict(SHAPE_PARAMS[mod.FAMILY][shape_name])
    if mod.FAMILY == "lm":
        return _lm_flops(mod.CONFIG, sp["kind"], sp["global_batch"],
                         sp["seq_len"])
    if mod.FAMILY == "gnn":
        cfg = _gnn_cell_config(arch_id, mod.CONFIG, sp, smoke=False)
        if "batch" in sp:
            n, e = sp["batch"] * sp["n_nodes"], sp["batch"] * sp["n_edges"]
        elif "batch_nodes" in sp:
            layer, n, e = sp["batch_nodes"], sp["batch_nodes"], 0
            for f in sp["fanout"]:
                layer *= f
                e += layer
                n += layer
        else:
            n, e = sp["n_nodes"], sp["n_edges"]
        return _gnn_flops(arch_id, cfg, _pad_to(n, mult), _pad_to(e, mult))
    kind = sp["kind"]
    return _dlrm_flops(mod.CONFIG, kind, sp.get("batch", 1),
                       _pad_to(sp.get("n_candidates", 0), mult)
                       if kind == "retrieval" else 0)
