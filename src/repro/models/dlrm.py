"""DLRM (Naumov et al., arXiv:1906.00091) — RM2 configuration.

13 dense features → bottom MLP (13-512-256-64); 26 categorical features →
per-table embedding lookup (the hot path); dot-product feature interaction
over the 27 resulting vectors; top MLP (512-512-256-1) → CTR logit.

JAX has no native EmbeddingBag: lookups are ``jnp.take`` +
``jax.ops.segment_sum`` (multi-hot path), built here as a first-class op.
The 26 tables are stacked into one ``[26, V, D]`` tensor **row-sharded over
the model axis**; the lookup runs in a shard_map where each shard gathers
the ids that fall in its row range and one psum of the pooled output
``[B, 26, D]`` combines shards — never the 6.7 GB all-gather of the table
that the naive pjit gather lowers to.

``retrieval_cand`` scores one query against 10⁶ candidates as a sharded
matvec + local-top-k + gathered global top-k — batched dot, not a loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import shardlib as sl
from .gnn.common import mlp, mlp_init

TP = "model_dim"
DP = "batch"


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_per_table: int = 1_000_000
    bot_mlp: Tuple[int, ...] = (13, 512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    interaction: str = "dot"
    dtype: Any = jnp.float32

    @property
    def n_interactions(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2

    def param_count(self) -> int:
        emb = self.n_sparse * self.vocab_per_table * self.embed_dim
        bot = sum(self.bot_mlp[i] * self.bot_mlp[i + 1]
                  for i in range(len(self.bot_mlp) - 1))
        d_top_in = self.n_interactions + self.bot_mlp[-1]
        dims = (d_top_in,) + self.top_mlp
        top = sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        return emb + bot + top


def init_params(key, cfg: DLRMConfig) -> Dict[str, Any]:
    k_emb, k_bot, k_top = jax.random.split(key, 3)
    scale = cfg.vocab_per_table ** -0.5
    tables = (jax.random.uniform(
        k_emb, (cfg.n_sparse, cfg.vocab_per_table, cfg.embed_dim),
        minval=-scale, maxval=scale)).astype(cfg.dtype)
    d_top_in = cfg.n_interactions + cfg.bot_mlp[-1]
    return {
        "tables": tables,
        "bot": mlp_init(k_bot, list(cfg.bot_mlp), cfg.dtype),
        "top": mlp_init(k_top, [d_top_in] + list(cfg.top_mlp), cfg.dtype),
    }


def param_shardings(cfg: DLRMConfig):
    # lists (not tuples) group (W, b) so each array gets its own leaf
    return {"tables": (None, "rows", None),
            "bot": [[(None, None), (None,)]
                    for _ in range(len(cfg.bot_mlp) - 1)],
            "top": [[(None, None), (None,)]
                    for _ in range(len(cfg.top_mlp))]}


# ---------------------------------------------------------------------------
# EmbeddingBag (single- and multi-hot), row-sharded
# ---------------------------------------------------------------------------

def embedding_lookup(tables: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """tables [T, V, D] (V row-sharded on the model axis); ids [B, T] ->
    [B, T, D].  Each shard resolves ids in its row range; one psum joins."""
    tp = sl._live_axes(TP)
    dp = sl._live_axes(DP)
    mesh = sl.current_mesh()

    def inner(tables_l, ids):
        t, v_l, d = tables_l.shape
        shard = sl.axis_index(tp)
        lo = shard * v_l
        local = ids - lo
        ok = (local >= 0) & (local < v_l)
        local = jnp.clip(local, 0, v_l - 1)

        def one_table(tab, idx, okc):
            g = jnp.take(tab, idx, axis=0)                  # [B, D]
            return g * okc[:, None].astype(g.dtype)
        out = jax.vmap(one_table, in_axes=(0, 1, 1), out_axes=1)(
            tables_l, local, ok)
        return sl.psum(out, tp)

    if mesh is None:
        return inner(tables, ids)
    dpa = dp if dp else None
    tpa = tp[0] if tp else None
    fn = sl.maybe_shard_map(
        inner,
        in_specs=(P(None, tpa, None), P(dpa, None)),
        out_specs=P(dpa, None, None))
    return fn(tables, ids)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  offsets: jnp.ndarray, n_bags: int,
                  mode: str = "sum") -> jnp.ndarray:
    """Multi-hot EmbeddingBag over one table: ids [L], offsets [n_bags+1].

    bag b pools rows ids[offsets[b]:offsets[b+1]] — realized as gather +
    segment-sum with a static-shape bag-id vector.
    """
    l = ids.shape[0]
    bag_of = jnp.searchsorted(offsets[1:], jnp.arange(l), side="right")
    g = jnp.take(table, ids, axis=0, fill_value=0)           # [L, D]
    out = jnp.zeros((n_bags + 1, table.shape[1]), g.dtype).at[bag_of].add(g)
    out = out[:n_bags]
    if mode == "mean":
        cnt = jnp.maximum(jnp.diff(offsets).astype(g.dtype), 1.0)
        out = out / cnt[:, None]
    return out


# ---------------------------------------------------------------------------
# Forward / loss / retrieval
# ---------------------------------------------------------------------------

def forward(params, dense: jnp.ndarray, sparse_ids: jnp.ndarray,
            cfg: DLRMConfig) -> jnp.ndarray:
    """dense [B, 13] f32, sparse_ids [B, 26] int32 -> CTR logits [B]."""
    dense = sl.shard(dense, DP, None)
    bot = mlp(dense.astype(cfg.dtype), params["bot"])        # [B, 64]
    emb = embedding_lookup(params["tables"], sparse_ids)     # [B, 26, 64]
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)      # [B, 27, 64]
    zz = jnp.einsum("bfd,bgd->bfg", z, z)                    # [B, 27, 27]
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    inter = zz[:, iu, ju]                                    # [B, 351]
    top_in = jnp.concatenate([bot, inter], axis=-1)
    logit = mlp(top_in, params["top"])[:, 0]
    return logit


def loss_fn(params, dense, sparse_ids, labels, cfg: DLRMConfig):
    logit = forward(params, dense, sparse_ids, cfg)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def user_vector(params, dense, sparse_ids, cfg: DLRMConfig) -> jnp.ndarray:
    """Query-side representation for retrieval: bottom-MLP out + pooled
    sparse embeddings (a two-tower view of the same parameters)."""
    bot = mlp(dense.astype(cfg.dtype), params["bot"])
    emb = embedding_lookup(params["tables"], sparse_ids)
    return bot + emb.sum(axis=1)


def retrieval_scores(params, dense, sparse_ids, cand_ids,
                     cfg: DLRMConfig, top_k: int = 128):
    """Score 1 query against N candidates (table-0 rows); return top-k.

    Candidates are sharded over the data axes, table rows over the model
    axis.  Each shard gathers its in-range candidate rows locally (zeros
    elsewhere); a psum of the [N_local] partial scores over the model axis
    completes them; a local-top-k + all-gather + final-top-k merges the
    per-data-shard winners.  No table all-gather anywhere.
    """
    u = user_vector(params, dense, sparse_ids, cfg)[0]       # [D]
    tp = sl._live_axes(TP)
    dp = sl._live_axes(DP)
    mesh = sl.current_mesh()

    def inner(u, cand_ids_l, table0_l):
        v_l = table0_l.shape[0]
        lo = sl.axis_index(tp) * v_l
        local = cand_ids_l - lo
        ok = (local >= 0) & (local < v_l)
        rows = jnp.take(table0_l, jnp.clip(local, 0, v_l - 1), axis=0)
        rows = rows * ok[:, None].astype(rows.dtype)
        scores = sl.psum(rows @ u, tp)                       # [N_l] complete
        k = min(top_k, scores.shape[0])
        v, i = jax.lax.top_k(scores, k)
        gi = jnp.take(cand_ids_l, i)
        v = sl.all_gather(v, dp, axis=0)
        gi = sl.all_gather(gi, dp, axis=0)
        vv, ii = jax.lax.top_k(v, min(top_k, v.shape[0]))
        return vv, jnp.take(gi, ii)

    if mesh is None:
        return inner(u, cand_ids, params["tables"][0])
    dpa = dp if dp else None
    tpa = tp[0] if tp else None
    fn = sl.maybe_shard_map(
        inner, in_specs=(P(), P(dpa), P(tpa, None)),
        out_specs=(P(), P()))
    return fn(u, cand_ids, params["tables"][0])
