"""Model zoo: LM transformers (dense + MoE), GNN family, DLRM.

Every model is a pair of pure functions — ``init(rng, cfg)`` returning a
param pytree and ``apply``-style step functions — annotated with *logical*
sharding axes via :mod:`repro.shardlib`, so the same code runs unsharded in
tests and under the production mesh in the dry-run.
"""
