"""Shared transformer building blocks (pure JAX, logically sharded).

Attention comes in three schedules, all exact:

* :func:`attention_causal`   — blockwise (flash-style running-softmax) scan
  over KV chunks; used for training and prefill of *global* layers.
* :func:`attention_window`   — sliding-window layers touch only the two KV
  chunks that can intersect the window (chunk size == window), so local
  layers are O(S·W) not O(S²) — this is what makes gemma3's 5:1
  local:global pattern and the 500k-token decode shape viable.
* :func:`attention_decode`   — one-token split-KV attention: the cache is
  sharded along the *sequence* axis, each shard computes partial softmax
  statistics, and three tiny collectives (pmax + 2 psum) combine them.
  This is flash-decoding re-expressed as a JAX shard_map.

The MoE block uses a sort-based dropping dispatch (argsort by expert id →
static-capacity buckets → batched expert GEMMs → scatter-combine) inside a
shard_map: experts are sharded over the model axis, activations are
replicated over it, and the only communication is one psum of the layer
output — the same volume as a Megatron tensor-parallel FFN, with zero
flop inflation from one-hot dispatch einsums.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import shardlib as sl

DP = "batch"        # logical data-parallel axis (('pod','data') on the mesh)
TP = "model_dim"    # logical tensor-parallel axis ('model' on the mesh)


# ---------------------------------------------------------------------------
# Initializers / numerics
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [..., T, n_heads, head_dim]; positions: [..., T] (int)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs    # [..., T, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                   # [..., T, 1, d/2]
    sin = sin[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — training / prefill
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: [B, T, Kh, G, dh]; k: [B, Sk, Kh, dh] -> [B, Kh, G, T, Sk]."""
    return jnp.einsum("btkgd,bskd->bkgts", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_values(p, v):
    """p: [B, Kh, G, T, Sk]; v: [B, Sk, Kh, dh] -> [B, T, Kh, G, dh]."""
    return jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v)


def attention_causal_opt(q, k, v, *, chunk: int = 1024,
                         q_positions: Optional[jnp.ndarray] = None,
                         kv_positions: Optional[jnp.ndarray] = None):
    """§Perf-optimized exact causal GQA (see EXPERIMENTS.md):

    * KV heads are broadcast to the flat query-head dim before the score
      einsum, so every attention tensor keeps the [.., H, ..] axis that is
      already sharded on the model axis — no (Kh, G) reshape for SPMD to
      trip over (kills the involuntary-resharding copies of the baseline);
    * probabilities are cast to bf16 for the PV matmul (scores/softmax
      stats stay f32) — halves the dominant dot-operand traffic;
    * chunk tensors carry explicit sharding annotations.
    """
    b, t0, h, dh = q.shape
    s0, kh = k.shape[1], k.shape[2]
    g = h // kh
    cq = min(chunk, t0)
    ck = min(chunk, s0)
    qpos = (jnp.arange(t0, dtype=jnp.int32) if q_positions is None
            else q_positions)
    kpos = (jnp.arange(s0, dtype=jnp.int32) if kv_positions is None
            else kv_positions)
    pad_t, pad_s = (-t0) % cq, (-s0) % ck
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, pad_t), constant_values=-1)
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad_s), constant_values=2**30)
    t, s = t0 + pad_t, s0 + pad_s
    # broadcast KV heads -> flat H (sharded end to end on the model axis)
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    q = q * (dh ** -0.5)

    nq, nk = t // cq, s // ck
    q_c = q.reshape(b, nq, cq, h, dh).transpose(1, 0, 2, 3, 4)
    k_c = k.reshape(b, nk, ck, h, dh).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, nk, ck, h, dh).transpose(1, 0, 2, 3, 4)
    qp_c = qpos.reshape(nq, cq)
    kp_c = kpos.reshape(nk, ck)

    def per_q_chunk(qi, qpi):
        qi = sl.shard(qi, DP, None, "heads", None)
        m0 = jnp.full((b, h, cq), -jnp.inf, jnp.float32)
        s0_ = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, cq, h, dh), jnp.float32)

        def body(carry, blk):
            m, se, acc = carry
            ki, vi, kpi = blk
            ki = sl.shard(ki, DP, None, "heads", None)
            vi = sl.shard(vi, DP, None, "heads", None)
            sc = jnp.einsum("bthd,bshd->bhts", qi, ki,
                            preferred_element_type=jnp.float32)
            sc = sl.shard(sc, DP, "heads", None, None)
            mask = qpi[:, None] >= kpi[None, :]
            sc = jnp.where(mask[None, None], sc, -jnp.inf)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - m_safe[..., None]).astype(vi.dtype)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            se_new = se * corr + p.sum(axis=-1).astype(jnp.float32)
            pv = jnp.einsum("bhts,bshd->bthd", p, vi,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
            return (m_new, se_new, acc_new), None

        (m, se, acc), _ = jax.lax.scan(body, (m0, s0_, a0),
                                       (k_c, v_c, kp_c))
        se = jnp.maximum(se, 1e-30)
        return acc / se.transpose(0, 2, 1)[..., None]

    out = jax.lax.map(lambda args: per_q_chunk(*args), (q_c, qp_c))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, t, h, dh)
    return out[:, :t0].astype(v.dtype)


def attention_causal(q, k, v, *, chunk: int = 1024,
                     q_positions: Optional[jnp.ndarray] = None,
                     kv_positions: Optional[jnp.ndarray] = None):
    """Exact causal GQA with a flash-style running softmax over KV chunks.

    q: [B, T, H, dh]; k, v: [B, S, Kh, dh].  Returns [B, T, H, dh] (f32
    accumulation, cast back).  Blocks above the diagonal are masked, not
    skipped — the §Perf log tracks the resulting flop inflation.
    """
    b, t0, h, dh = q.shape
    s0, kh = k.shape[1], k.shape[2]
    g = h // kh
    cq = min(chunk, t0)
    ck = min(chunk, s0)
    qpos = (jnp.arange(t0, dtype=jnp.int32) if q_positions is None
            else q_positions)
    kpos = (jnp.arange(s0, dtype=jnp.int32) if kv_positions is None
            else kv_positions)
    # Pad ragged tails to chunk multiples; padded KV positions are +BIG so
    # no real query attends them, padded query rows are sliced off below.
    pad_t, pad_s = (-t0) % cq, (-s0) % ck
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, pad_t), constant_values=-1)
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad_s), constant_values=2**30)
    t, s = t0 + pad_t, s0 + pad_s
    q = q.reshape(b, t, kh, g, dh) * (dh ** -0.5)

    nq, nk = t // cq, s // ck
    q_c = q.reshape(b, nq, cq, kh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    k_c = k.reshape(b, nk, ck, kh, dh).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, nk, ck, kh, dh).transpose(1, 0, 2, 3, 4)
    qp_c = qpos.reshape(nq, cq)
    kp_c = kpos.reshape(nk, ck)

    def per_q_chunk(qi, qpi):
        # Running (max, sum, acc) across KV chunks — exact softmax.
        m0 = jnp.full((b, kh, g, cq), -jnp.inf, jnp.float32)
        s0 = jnp.zeros((b, kh, g, cq), jnp.float32)
        a0 = jnp.zeros((b, cq, kh, g, dh), jnp.float32)

        def body(carry, blk):
            m, se, acc = carry
            ki, vi, kpi = blk
            sc = _gqa_scores(qi, ki)                       # [B,Kh,G,cq,ck]
            mask = qpi[:, None] >= kpi[None, :]            # causal
            sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - m_safe[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            se_new = se * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgts,bskd->btkgd", p, vi.astype(jnp.float32))
            acc_new = acc * corr.transpose(0, 3, 1, 2)[:, :, :, :, None] + pv
            return (m_new, se_new, acc_new), None

        (m, se, acc), _ = jax.lax.scan(body, (m0, s0, a0), (k_c, v_c, kp_c))
        se = jnp.maximum(se, 1e-30)
        out = acc / se.transpose(0, 3, 1, 2)[:, :, :, :, None]
        return out

    out = jax.lax.map(lambda args: per_q_chunk(*args), (q_c, qp_c))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, h, dh)
    return out[:, :t0].astype(v.dtype)


def attention_window(q, k, v, window: int, *,
                     q_positions: Optional[jnp.ndarray] = None):
    """Sliding-window causal GQA: position i attends (i-window, i].

    Chunk size == window, so q chunk j only needs kv chunks j-1 and j:
    O(S·W) work with static shapes.  q: [B, T, H, dh], k/v: [B, T, Kh, dh].
    """
    b, t0, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    w = min(window, t0)
    pos = (jnp.arange(t0, dtype=jnp.int32) if q_positions is None
           else q_positions)
    pad = (-t0) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.pad(pos, (0, pad), constant_values=-(2**30))
    t = t0 + pad
    n = t // w
    q = q.reshape(b, t, kh, g, dh) * (dh ** -0.5)

    q_c = q.reshape(b, n, w, kh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    k_c = k.reshape(b, n, w, kh, dh).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, n, w, kh, dh).transpose(1, 0, 2, 3, 4)
    p_c = pos.reshape(n, w)
    zk = jnp.zeros_like(k_c[:1])
    k_prev = jnp.concatenate([zk, k_c[:-1]], axis=0)
    v_prev = jnp.concatenate([zk, v_c[:-1]], axis=0)
    p_prev = jnp.concatenate([jnp.full((1, w), -10**9, jnp.int32),
                              p_c[:-1]], axis=0)

    def one(qi, kp, vp, ki, vi, qpi, kpp, kpi):
        kk = jnp.concatenate([kp, ki], axis=1)       # [B, 2w, Kh, dh]
        vv = jnp.concatenate([vp, vi], axis=1)
        kpos = jnp.concatenate([kpp, kpi], axis=0)    # [2w]
        sc = _gqa_scores(qi, kk)                      # [B,Kh,G,w,2w]
        mask = ((qpi[:, None] >= kpos[None, :])
                & (qpi[:, None] - kpos[None, :] < w))
        sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
        m = sc.max(axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(sc - m)
        se = jnp.maximum(p.sum(axis=-1), 1e-30)
        out = jnp.einsum("bkgts,bskd->btkgd", p, vv.astype(jnp.float32))
        return out / se.transpose(0, 3, 1, 2)[..., None]

    out = jax.lax.map(lambda a: one(*a),
                      (q_c, k_prev, v_prev, k_c, v_c, p_c, p_prev, p_c))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, h, dh)
    return out[:, :t0].astype(v.dtype)


# ---------------------------------------------------------------------------
# Attention — decode (split-KV over the model axis)
# ---------------------------------------------------------------------------

def attention_decode(q, k_cache, v_cache, k_new, v_new, cur_len,
                     *, window: Optional[int] = None):
    """One-token GQA over a sequence-sharded KV cache.

    q: [B, H, dh]; caches: [B, Smax, Kh, dh] (Smax sharded on the model
    axis); k_new/v_new: [B, Kh, dh] (already RoPE'd, replicated).  cur_len:
    scalar — entries [0, cur_len) are valid; the new KV is written at slot
    cur_len (mod window for rolling local caches).  Returns (out [B, H, dh],
    k_cache, v_cache).
    """
    tp = sl._live_axes(TP)
    dp = sl._live_axes(DP)
    mesh = sl.current_mesh()

    def inner(q, kc, vc, kn, vn, cur):
        b, s_l, kh, dh = kc.shape
        h = q.shape[1]
        g = h // kh
        shard = sl.axis_index(tp)
        offset = shard * s_l
        slot = cur if window is None else cur % window
        gpos = offset + jnp.arange(s_l, dtype=jnp.int32)      # global slots
        write = (gpos == slot)[None, :, None, None]
        kc = jnp.where(write, kn[:, None], kc)
        vc = jnp.where(write, vn[:, None], vc)
        if window is None:
            valid = gpos <= cur
        else:
            valid = gpos <= jnp.minimum(cur, window - 1)
        qg = q.reshape(b, 1, kh, g, dh) * (dh ** -0.5)
        sc = _gqa_scores(qg, kc)[..., 0, :]                    # [B,Kh,G,s_l]
        sc = jnp.where(valid[None, None, None], sc, -jnp.inf)
        m_loc = sc.max(axis=-1)
        m_glob = sl.pmax(m_loc, tp)
        m_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
        p = jnp.exp(sc - m_safe[..., None])
        num = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
        den = p.sum(axis=-1)
        num = sl.psum(num, tp)
        den = jnp.maximum(sl.psum(den, tp), 1e-30)
        out = (num / den[..., None]).reshape(b, h, dh)
        return out.astype(vc.dtype), kc, vc

    if mesh is None:
        return inner(q, k_cache, v_cache, k_new, v_new, cur_len)

    dspec = P(dp if dp else None)
    fn = sl.maybe_shard_map(
        inner,
        in_specs=(P(dspec[0], None, None),                    # q
                  P(dspec[0], tp[0] if tp else None, None, None),
                  P(dspec[0], tp[0] if tp else None, None, None),
                  P(dspec[0], None, None), P(dspec[0], None, None),
                  P()),
        out_specs=(P(dspec[0], None, None),
                   P(dspec[0], tp[0] if tp else None, None, None),
                   P(dspec[0], tp[0] if tp else None, None, None)))
    return fn(q, k_cache, v_cache, k_new, v_new, cur_len)


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def swiglu(x, wg, wu, wd):
    """x: [..., D]; wg/wu: [D, F]; wd: [F, D]."""
    h = jax.nn.silu(x @ wg) * (x @ wu)
    h = sl.shard(h, DP, "seq", "mlp")
    return h @ wd


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


def moe_block(x, router_w, wg, wu, wd, cfg: MoEConfig):
    """Sort-based top-k MoE with experts sharded over the model axis.

    x: [B, S, D]; router_w: [D, E]; wg/wu: [E, D, F]; wd: [E, F, D].
    Returns (y [B, S, D], aux_loss scalar).
    """
    tp = sl._live_axes(TP)
    dp = sl._live_axes(DP)
    mesh = sl.current_mesh()
    e, k = cfg.n_experts, cfg.top_k
    e_l = e // max(sl.axis_size(tp), 1)

    def inner(x, router_w, wg, wu, wd):
        b, s, d = x.shape
        t = b * s
        xt = x.reshape(t, d)
        logits = (xt.astype(jnp.float32) @ router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)                 # [T, E]
        gate, eid = jax.lax.top_k(probs, k)                     # [T, k]
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
        me = probs.mean(axis=0)
        ce = jnp.zeros(e, jnp.float32).at[eid.reshape(-1)].add(1.0) / (t * k)
        aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

        cap = int(-(-t * k * cfg.capacity_factor // e))
        cap = max(8, -(-cap // 8) * 8)

        fe = eid.reshape(-1)                                    # [T*k]
        ft = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
        fg = gate.reshape(-1)
        order = jnp.argsort(fe, stable=True)
        fe_s, ft_s, fg_s = fe[order], ft[order], fg[order]
        counts = jnp.zeros(e, jnp.int32).at[fe_s].add(1)
        starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(t * k, dtype=jnp.int32) - starts[fe_s]

        shard = sl.axis_index(tp)
        e_lo = shard * e_l
        local = (fe_s >= e_lo) & (fe_s < e_lo + e_l) & (pos < cap)
        slot = jnp.where(local, (fe_s - e_lo) * cap + pos, e_l * cap)

        buf = jnp.zeros((e_l * cap + 1, d), x.dtype).at[slot].set(xt[ft_s])
        hb = buf[: e_l * cap].reshape(e_l, cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hb, wg)) \
            * jnp.einsum("ecd,edf->ecf", hb, wu)
        ob = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_l * cap, d)
        ob = jnp.concatenate([ob, jnp.zeros((1, d), ob.dtype)], axis=0)

        contrib = ob[slot] * jnp.where(local, fg_s, 0.0)[:, None].astype(ob.dtype)
        y = jnp.zeros((t, d), x.dtype).at[ft_s].add(contrib)
        y = sl.psum(y, tp)
        # aux differs per data shard (x does); average so it is replicated.
        aux = sl.psum(aux, dp) / max(sl.axis_size(dp), 1)
        return y.reshape(b, s, d), aux

    if mesh is None:
        return inner(x, router_w, wg, wu, wd)

    dpa = dp if dp else None
    tpa = tp[0] if tp else None
    fn = sl.maybe_shard_map(
        inner,
        in_specs=(P(dpa, None, None), P(None, None),
                  P(tpa, None, None), P(tpa, None, None), P(tpa, None, None)),
        out_specs=(P(dpa, None, None), P()))
    return fn(x, router_w, wg, wu, wd)


def moe_block_paramspec(cfg: MoEConfig, d_model: int):
    return dict(router=("embed", "expert"),
                wg=("expert", "embed", "expert_mlp"),
                wu=("expert", "embed", "expert_mlp"),
                wd=("expert", "expert_mlp", "embed"))
