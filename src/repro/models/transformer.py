"""Decoder-only LM family covering the five assigned transformer archs.

One config class expresses all of them:

* dense GQA (glm4-9b, command-r-35b)         — ``moe=None``
* 5:1 local:global sliding window (gemma3)   — ``local_global_period=6``
* MoE top-k (granite-moe 32e/top-8,
  qwen3-moe 128e/top-8)                      — ``moe=MoEConfig(...)``

Layers are scanned in *cycles* of ``local_global_period`` (1 for uniform
stacks): params are stacked ``[n_cycles, ...]`` per cycle position, the
cycle body is remat'd (``jax.checkpoint``), and the scan keeps HLO size
independent of depth — essential for compiling 40 dry-run cells.

Distribution (all via logical axes, resolved by the launcher's rules):
batch → ('pod','data'); heads / mlp / experts / vocab → 'model'; weight
input dims → 'data' (FSDP: XLA all-gathers parameters per layer); the
residual stream is sequence-sharded on 'model' between blocks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .. import shardlib as sl
from .layers import (MoEConfig, apply_rope, attention_causal,
                     attention_causal_opt, attention_decode,
                     attention_window, dense_init, moe_block, rms_norm,
                     swiglu)

DP = "batch"


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # defaults to d_model // n_heads
    rope_theta: float = 1e4
    moe: Optional[MoEConfig] = None
    sliding_window: Optional[int] = None    # window for *local* layers
    local_global_period: int = 1            # 6 => 5 local + 1 global (gemma3)
    tie_embeddings: bool = True
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk: int = 1024
    loss_chunk: int = 2048
    subquadratic: bool = False              # True iff long-context decode ok
    # §Perf optimized attention: flat-GQA head broadcast (stable sharding),
    # bf16 probabilities, chunk annotations — see layers.attention_causal_opt
    attn_opt: bool = False
    # remat policy: "none" saves only layer boundaries (min memory, max
    # recompute); "block_outs" additionally saves each attention/MLP block
    # output, skipping their recompute in backward (§Perf iteration 2)
    remat_policy: str = "none"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_cycles(self) -> int:
        assert self.n_layers % self.local_global_period == 0
        return self.n_layers // self.local_global_period

    def layer_is_local(self, pos_in_cycle: int) -> bool:
        """gemma3 pattern: positions 0..p-2 local, p-1 global."""
        if self.sliding_window is None or self.local_global_period == 1:
            return self.sliding_window is not None
        return pos_in_cycle != self.local_global_period - 1

    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.moe is None:
            mlp = 3 * d * self.d_ff
        else:
            mlp = self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        per_layer = attn + mlp + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.moe.n_experts * 3 * d * self.moe.d_ff
        return dense + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _layer_params(key, cfg: TransformerConfig, dt) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.zeros((d,), dt),
        "ln2": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dt),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dt),
    }
    if cfg.moe is None:
        p.update(wg=dense_init(ks[4], (d, cfg.d_ff), dtype=dt),
                 wu=dense_init(ks[5], (d, cfg.d_ff), dtype=dt),
                 wd=dense_init(ks[6], (cfg.d_ff, d), dtype=dt))
    else:
        e, f = cfg.moe.n_experts, cfg.moe.d_ff
        p.update(router=dense_init(ks[7], (d, e), dtype=jnp.float32),
                 wg=dense_init(ks[4], (e, d, f), in_axis=1, dtype=dt),
                 wu=dense_init(ks[5], (e, d, f), in_axis=1, dtype=dt),
                 wd=dense_init(ks[6], (e, f, d), in_axis=1, dtype=dt))
    return p


def init_params(key, cfg: TransformerConfig) -> Dict[str, Any]:
    dt = cfg.param_dtype
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    # Stack per cycle position: pytree of arrays [n_cycles, ...].
    per_pos: List[Dict[str, Any]] = []
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    for pos in range(cfg.local_global_period):
        stack = [
            _layer_params(lkeys[c * cfg.local_global_period + pos], cfg, dt)
            for c in range(cfg.n_cycles)
        ]
        per_pos.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stack))
    params = {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), dtype=dt),
        "ln_f": jnp.zeros((cfg.d_model,), dt),
        "layers": per_pos,
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, (cfg.d_model, cfg.vocab), dtype=dt)
    return params


def param_shardings(cfg: TransformerConfig):
    """Logical axes per parameter (FSDP on input dims, TP on output dims)."""
    attn = dict(ln1=(None,), ln2=(None,),
                wq=("fsdp", "heads"), wk=("fsdp", "kv_heads"),
                wv=("fsdp", "kv_heads"), wo=("heads", "fsdp"))
    if cfg.moe is None:
        attn.update(wg=("fsdp", "mlp"), wu=("fsdp", "mlp"), wd=("mlp", "fsdp"))
    else:
        attn.update(router=(None, None),
                    wg=("expert", "fsdp", None), wu=("expert", "fsdp", None),
                    wd=("expert", None, "fsdp"))
    layer = {k: ("layer_stack",) + v if not isinstance(v, tuple) else
             ("layer_stack",) + v for k, v in attn.items()}
    tree = {"embed": ("vocab", "fsdp"), "ln_f": (None,),
            "layers": [dict(layer) for _ in range(cfg.local_global_period)]}
    if not cfg.tie_embeddings:
        tree["head"] = ("fsdp", "vocab")
    return tree


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attn_train(x, lp, cfg: TransformerConfig, local: bool, positions):
    b, s, d = x.shape
    hd = cfg.hd
    h = rms_norm(x, lp["ln1"])
    h = sl.shard(h, DP, "seq", None)
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = sl.shard(q, DP, None, "heads", None)
    k = sl.shard(apply_rope(k, positions, cfg.rope_theta), DP, None, None, None)
    q = apply_rope(q, positions, cfg.rope_theta)
    if local and cfg.sliding_window is not None:
        o = attention_window(q, k, v, cfg.sliding_window,
                             q_positions=positions)
    elif cfg.attn_opt:
        o = attention_causal_opt(q, k, v, chunk=cfg.attn_chunk,
                                 q_positions=positions,
                                 kv_positions=positions)
    else:
        o = attention_causal(q, k, v, chunk=cfg.attn_chunk,
                             q_positions=positions, kv_positions=positions)
    o = sl.shard(o, DP, None, "heads", None)
    return o.reshape(b, s, cfg.n_heads * hd) @ lp["wo"]


def _mlp_train(x, lp, cfg: TransformerConfig):
    h = rms_norm(x, lp["ln2"])
    if cfg.moe is None:
        return swiglu(h, lp["wg"], lp["wu"], lp["wd"]), jnp.float32(0.0)
    return moe_block(h, lp["router"], lp["wg"], lp["wu"], lp["wd"], cfg.moe)


def _cycle_body(carry, cycle_params, cfg: TransformerConfig, positions):
    x, aux = carry
    for pos in range(cfg.local_global_period):
        lp = cycle_params[pos]
        local = cfg.layer_is_local(pos)
        attn_out = sl.shard(_attn_train(x, lp, cfg, local, positions),
                            DP, "seq", None)
        if cfg.remat_policy == "block_outs":
            attn_out = checkpoint_name(attn_out, "block_out")
        x = x + attn_out
        dx, a = _mlp_train(x, lp, cfg)
        dx = sl.shard(dx, DP, "seq", None)
        if cfg.remat_policy == "block_outs":
            dx = checkpoint_name(dx, "block_out")
        x = x + dx
        aux = aux + a
    return (x, aux), None


def forward(params, tokens, cfg: TransformerConfig,
            positions: Optional[jnp.ndarray] = None):
    """tokens [B, S] -> final hidden states [B, S, D] (+ MoE aux loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    cd = cfg.compute_dtype
    x = params["embed"].astype(cd)[tokens] * jnp.sqrt(
        jnp.asarray(cfg.d_model, cd))
    x = sl.shard(x, DP, "seq", None)

    body = functools.partial(_cycle_body, cfg=cfg, positions=positions)
    if cfg.remat:
        policy = (jax.checkpoint_policies.save_only_these_names("block_out")
                  if cfg.remat_policy == "block_outs"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy, static_argnums=())

    cast = lambda t: jax.tree.map(lambda a: a.astype(cd)
                                  if a.dtype != jnp.float32 or a.ndim > 1
                                  else a, t)
    stacked = [cast(p) for p in params["layers"]]
    (x, aux), _ = jax.lax.scan(lambda c, ps: body(c, ps),
                               (x, jnp.float32(0.0)),
                               stacked)
    x = rms_norm(x, params["ln_f"].astype(cd))
    return sl.shard(x, DP, "seq", None), aux


def lm_head_weight(params, cfg: TransformerConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def loss_fn(params, tokens, labels, cfg: TransformerConfig):
    """Chunked cross-entropy: logits are materialized per seq chunk only."""
    x, aux = forward(params, tokens, cfg)
    b, s, d = x.shape
    w = lm_head_weight(params, cfg).astype(cfg.compute_dtype)
    c = min(cfg.loss_chunk, s)
    nc = s // c

    def chunk_loss(xc, yc):
        logits = (xc @ w).astype(jnp.float32)
        logits = sl.shard(logits, DP, None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return (lse - picked).sum()

    chunk_loss = jax.checkpoint(chunk_loss)
    xs = x.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(b, nc, c).transpose(1, 0, 2)

    def body(tot, blk):
        xc, yc = blk
        return tot + chunk_loss(xc, yc), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ys))
    return tot / (b * s) + aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def make_cache(cfg: TransformerConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    """Cache pytree: per cycle position, K and V of [n_cycles, B, S*, Kh, hd].

    Local layers get a rolling window-sized cache; global layers the full
    ``seq_len`` — at gemma3's 5:1 ratio this is an ~83% cache-byte saving
    and the only reason long_500k fits.
    """
    caches = []
    for pos in range(cfg.local_global_period):
        s = (min(cfg.sliding_window, seq_len)
             if cfg.layer_is_local(pos) and cfg.sliding_window else seq_len)
        shp = (cfg.n_cycles, batch, s, cfg.n_kv_heads, cfg.hd)
        caches.append({"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)})
    return caches


def cache_shardings(cfg: TransformerConfig):
    ax = ("layer_stack", "batch", "kv_seq", None, None)
    return [{"k": ax, "v": ax} for _ in range(cfg.local_global_period)]


def decode_step(params, caches, tokens, cur_len, cfg: TransformerConfig):
    """One decode step: tokens [B] int32, cur_len scalar -> (logits, caches).

    The new token sits at position cur_len; entries [0, cur_len) are valid.
    """
    cd = cfg.compute_dtype
    b = tokens.shape[0]
    x = params["embed"].astype(cd)[tokens] * jnp.sqrt(
        jnp.asarray(cfg.d_model, cd))            # [B, D]
    pos = jnp.asarray(cur_len, jnp.int32)

    def cycle(carry, scanned):
        x, = carry
        cycle_params, cycle_caches = scanned
        new_caches = []
        for p_i in range(cfg.local_global_period):
            lp = jax.tree.map(lambda a: a.astype(cd)
                              if a.ndim > 1 else a.astype(cd), cycle_params[p_i])
            local = cfg.layer_is_local(p_i)
            window = cfg.sliding_window if local else None
            h = rms_norm(x, lp["ln1"])
            q = (h @ lp["wq"]).reshape(b, cfg.n_heads, cfg.hd)
            kn = (h @ lp["wk"]).reshape(b, cfg.n_kv_heads, cfg.hd)
            vn = (h @ lp["wv"]).reshape(b, cfg.n_kv_heads, cfg.hd)
            q = apply_rope(q[:, None], pos[None], cfg.rope_theta)[:, 0]
            kn = apply_rope(kn[:, None], pos[None], cfg.rope_theta)[:, 0]
            kc, vc = cycle_caches[p_i]["k"], cycle_caches[p_i]["v"]
            o, kc, vc = attention_decode(q, kc, vc, kn, vn, pos,
                                         window=window)
            new_caches.append({"k": kc, "v": vc})
            x = x + (o.reshape(b, cfg.n_heads * cfg.hd) @ lp["wo"])
            h2 = rms_norm(x, lp["ln2"])
            if cfg.moe is None:
                dx = swiglu(h2, lp["wg"], lp["wu"], lp["wd"])
            else:
                dx, _ = moe_block(h2[:, None, :], lp["router"], lp["wg"],
                                  lp["wu"], lp["wd"], cfg.moe)
                dx = dx[:, 0]
            x = x + dx
        return (x,), new_caches

    (x,), new_caches = jax.lax.scan(cycle, (x,),
                                    (params["layers"], caches))
    x = rms_norm(x, params["ln_f"].astype(cd))
    logits = (x @ lm_head_weight(params, cfg).astype(cd)).astype(jnp.float32)
    return sl.shard(logits, DP, "vocab"), new_caches


def prefill(params, tokens, cfg: TransformerConfig):
    """tokens [B, S] -> (last-position logits [B, V], caches filled [0, S))."""
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    cd = cfg.compute_dtype
    x = params["embed"].astype(cd)[tokens] * jnp.sqrt(
        jnp.asarray(cfg.d_model, cd))
    x = sl.shard(x, DP, "seq", None)

    def cycle(x, cycle_params):
        kvs = []
        for p_i in range(cfg.local_global_period):
            lp = jax.tree.map(lambda a: a.astype(cd), cycle_params[p_i])
            local = cfg.layer_is_local(p_i)
            h = rms_norm(x, lp["ln1"])
            q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
            k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
            v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            q = sl.shard(q, DP, None, "heads", None)
            if local and cfg.sliding_window is not None:
                o = attention_window(q, k, v, cfg.sliding_window,
                                     q_positions=positions)
                w = min(cfg.sliding_window, s)
                kvs.append({"k": k[:, -w:], "v": v[:, -w:]})
            else:
                o = attention_causal(q, k, v, chunk=cfg.attn_chunk,
                                     q_positions=positions,
                                     kv_positions=positions)
                kvs.append({"k": k, "v": v})
            x = x + (o.reshape(b, s, cfg.n_heads * cfg.hd) @ lp["wo"])
            h2 = rms_norm(x, lp["ln2"])
            if cfg.moe is None:
                dx = swiglu(h2, lp["wg"], lp["wu"], lp["wd"])
            else:
                dx, _ = moe_block(h2, lp["router"], lp["wg"], lp["wu"],
                                  lp["wd"], cfg.moe)
            x = x + sl.shard(dx, DP, "seq", None)
        return x, kvs

    x, caches = jax.lax.scan(cycle, x, params["layers"])
    x = rms_norm(x, params["ln_f"].astype(cd))
    logits = (x[:, -1] @ lm_head_weight(params, cfg).astype(cd))
    return sl.shard(logits.astype(jnp.float32), DP, "vocab"), caches
