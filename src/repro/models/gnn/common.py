"""Shared GNN substrate: padded graph batches + segment primitives."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ... import shardlib as sl


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Static-shape graph (or packed batch of graphs).

    ``src``/``dst`` are edge endpoints; padding edges point at the sentinel
    node ``n_nodes`` (one scrap row appended to every node tensor).
    ``graph_ids`` maps nodes to graphs for packed molecule batches
    (sentinel graph == n_graphs).  Registered as a jax pytree (counts are
    static metadata) so it can be a jit argument.
    """
    n_nodes: int
    n_graphs: int
    src: jnp.ndarray              # [E] int32
    dst: jnp.ndarray              # [E] int32
    node_feat: jnp.ndarray        # [N, F] (or int atom types for schnet)
    edge_feat: Optional[jnp.ndarray] = None    # [E, ...] dist / vectors
    graph_ids: Optional[jnp.ndarray] = None    # [N] int32
    labels: Optional[jnp.ndarray] = None       # [N] or [G]
    train_mask: Optional[jnp.ndarray] = None   # [N] bool


jax.tree_util.register_dataclass(
    GraphBatch,
    data_fields=["src", "dst", "node_feat", "edge_feat", "graph_ids",
                 "labels", "train_mask"],
    meta_fields=["n_nodes", "n_graphs"])


def edge_chunks(n_chunks: int, *arrays, sentinel: int = 0):
    """Reshape [E, ...] edge arrays to [n_chunks, E/n_chunks, ...] (padding
    int arrays with ``sentinel``, float arrays with 0)."""
    e = arrays[0].shape[0]
    per = -(-e // n_chunks)
    pad = per * n_chunks - e
    out = []
    for a in arrays:
        if pad:
            cv = sentinel if jnp.issubdtype(a.dtype, jnp.integer) else 0
            widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
            a = jnp.pad(a, widths, constant_values=cv)
        out.append(a.reshape((n_chunks, per) + a.shape[1:]))
    return out


def chunked_scatter_sum(edge_fn, n_chunks: int, arrays, n: int,
                        out_shape, dtype, dst_ranged: bool = False):
    """Accumulate scatter-sums over edge chunks.

    ``edge_fn(*chunk_arrays) -> (values [e_c, ...], dst [e_c])``; values are
    scatter-added into an [n(+1 scrap), ...] accumulator via lax.scan, so
    the per-edge intermediate never exceeds one chunk.

    ``dst_ranged``: edges are pre-bucketed so chunk i's destinations fall in
    node range [i·(n/n_chunks), (i+1)·(n/n_chunks)) — the HoD level-blocked
    layout.  Each chunk then scatters into a range-sized local buffer that
    is written once via dynamic_update_slice, instead of re-touching the
    whole [n, ...] accumulator every iteration (n_chunks× less traffic, and
    SPMD keeps the write local to the range's owner).  Chunk arrays are
    sharding-annotated inside the body so the per-edge work stays sharded
    through the scan.
    """
    from ... import shardlib as sl
    chunked = edge_chunks(n_chunks, *arrays, sentinel=n)

    if not dst_ranged:
        # Remat the per-chunk edge work: without it, backward stores every
        # chunk's [e_c, F] intermediates (hundreds of GB/device on the
        # 62M-edge cells); with it, backward recomputes the chunk and only
        # the [n, F] carries persist.
        @jax.checkpoint
        def body(acc, chunk):
            vals, dst = edge_fn(*chunk)
            return acc.at[dst].add(vals.astype(dtype)), None

        init = jnp.zeros((n + 1,) + tuple(out_shape), dtype)
        acc, _ = jax.lax.scan(body, init, tuple(chunked))
        return acc[:n]

    rng_sz = -(-n // n_chunks)

    # Each chunk owns one contiguous destination range, so no carry is
    # needed at all: every iteration *returns* its range's buffer and the
    # stacked scan outputs concatenate into the full node tensor — zero
    # cross-chunk reduction, zero accumulator re-reads.  (No body remat:
    # callers remat at layer level — body remat would double the backward
    # collective traffic; measured in §Perf iter 3.)
    def body(_, xs):
        i, chunk = xs
        chunk = tuple(sl.shard(c, "edges", *([None] * (c.ndim - 1)))
                      for c in chunk)
        vals, dst = edge_fn(*chunk)
        local = dst - i * rng_sz
        ok = (local >= 0) & (local < rng_sz)
        local = jnp.where(ok, local, rng_sz)      # scrap row
        buf = jnp.zeros((rng_sz + 1,) + tuple(out_shape), dtype)
        buf = buf.at[local].add(vals.astype(dtype))
        return None, buf[:rng_sz]

    idx = jnp.arange(n_chunks, dtype=jnp.int32)
    _, bufs = jax.lax.scan(body, None, (idx, tuple(chunked)))
    return bufs.reshape((rng_sz * n_chunks,) + tuple(out_shape))[:n]


def partitioned_aggregate(x, arrays, edge_fn, n: int, out_shape, dtype,
                          n_chunks: int = 1):
    """Owner-partitioned message passing inside a shard_map.

    Precondition (data layout): ``arrays`` edge arrays are reordered so
    shard k holds exactly the edges whose *destination* lives in node shard
    k (``bucket_edges_by_dst``) — the distributed analogue of HoD's
    file-order-equals-traversal-order layout.

    Inside each shard: one all-gather of the (small) node features, a local
    gather + ``edge_fn`` + scatter into the local node slice — the per-layer
    communication is exactly one all-gather forward (+ its reduce-scatter
    transpose backward), replacing the per-chunk full-buffer all-reduces the
    generic SPMD scatter lowers to.

    ``edge_fn(x_full, *chunk_arrays) -> (values, global_dst)``.
    """
    from ... import shardlib as sl
    from jax.sharding import PartitionSpec as P
    axes = sl._live_axes("nodes")
    mesh = sl.current_mesh()

    def inner(x_l, *arr_l):
        n_local = x_l.shape[0]
        offset = sl.axis_index(axes) * n_local
        x_full = sl.all_gather(x_l, axes, axis=0)

        @jax.checkpoint
        def chunk_body(acc, chunk):
            vals, dst = edge_fn(x_full, *chunk)
            local = dst - offset
            ok = (local >= 0) & (local < n_local)
            local = jnp.where(ok, local, n_local)
            return acc.at[local].add(
                vals * ok.reshape((-1,) + (1,) * (vals.ndim - 1))
                .astype(vals.dtype)).astype(dtype), None

        init = jnp.zeros((n_local + 1,) + tuple(out_shape), dtype)
        if n_chunks <= 1:
            acc, _ = chunk_body(init, arr_l)
        else:
            chunked = edge_chunks(n_chunks, *arr_l, sentinel=n)
            acc, _ = jax.lax.scan(chunk_body, init, tuple(chunked))
        return acc[:n_local]

    if mesh is None or not axes:
        return inner(x, *arrays)

    ax = axes if len(axes) > 1 else axes[0]
    in_specs = (P(ax, *([None] * (x.ndim - 1))),) + tuple(
        P(ax, *([None] * (a.ndim - 1))) for a in arrays)
    fn = sl.maybe_shard_map(
        inner, in_specs=in_specs,
        out_specs=P(ax, *([None] * len(out_shape))))
    return fn(x, *arrays)


def scatter_sum(values: jnp.ndarray, index: jnp.ndarray,
                n: int) -> jnp.ndarray:
    """segment-sum of ``values`` [E, ...] into ``n`` rows (+1 scrap row)."""
    out_shape = (n + 1,) + values.shape[1:]
    out = jnp.zeros(out_shape, values.dtype).at[index].add(values)
    return out[:n]


def scatter_max(values: jnp.ndarray, index: jnp.ndarray, n: int,
                fill: float = -jnp.inf) -> jnp.ndarray:
    out_shape = (n + 1,) + values.shape[1:]
    out = jnp.full(out_shape, fill, values.dtype).at[index].max(values)
    return out[:n]


def gather_scatter_sum(x: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                       n: int, edge_weight: Optional[jnp.ndarray] = None):
    """The SpMM core: out[dst] += w * x[src], static shapes, sentinel-safe."""
    msgs = jnp.take(x, src, axis=0, fill_value=0)
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None].astype(msgs.dtype)
    msgs = sl.shard(msgs, "edges", None)
    return scatter_sum(msgs, dst, n)


def segment_softmax(logits: jnp.ndarray, index: jnp.ndarray,
                    n: int) -> jnp.ndarray:
    """Softmax over edges grouped by ``index`` (per-destination)."""
    m = scatter_max(logits, index, n)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - jnp.take(m, index, axis=0, fill_value=0))
    z = scatter_sum(p, index, n)
    z = jnp.take(jnp.maximum(z, 1e-30), index, axis=0, fill_value=1.0)
    return p / z


def degrees(index: jnp.ndarray, n: int) -> jnp.ndarray:
    return scatter_sum(jnp.ones(index.shape[0], jnp.float32), index, n)


def graph_readout(x: jnp.ndarray, graph_ids: jnp.ndarray, n_graphs: int,
                  op: str = "sum") -> jnp.ndarray:
    s = scatter_sum(x, graph_ids, n_graphs)
    if op == "sum":
        return s
    cnt = jnp.maximum(degrees(graph_ids, n_graphs), 1.0)
    return s / cnt[:, None]


def mlp(x, weights, act=jax.nn.relu):
    """weights: list of (W, b); activation between layers, none after last."""
    for i, (w, b) in enumerate(weights):
        x = x @ w + b
        if i < len(weights) - 1:
            x = act(x)
    return x


def mlp_init(key, dims, dtype=jnp.float32):
    from ..layers import dense_init
    ks = jax.random.split(key, len(dims) - 1)
    return [[dense_init(ks[i], (dims[i], dims[i + 1]), dtype=dtype),
             jnp.zeros((dims[i + 1],), dtype)] for i in range(len(dims) - 1)]
