"""GIN (Xu et al., arXiv:1810.00826): 5 layers, sum aggregator, learnable ε.

h_v' = MLP((1 + ε) h_v + Σ_{u∈N(v)} h_u); graph-level tasks read out with a
sum pool per layer (jumping knowledge, as in the paper's TU setup).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ... import shardlib as sl
from .common import (GraphBatch, gather_scatter_sum, graph_readout, mlp,
                     mlp_init)


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_in: int = 64
    d_hidden: int = 64
    n_classes: int = 2
    node_level: bool = False      # node classification (full-graph shapes)
    edge_chunk: int = 0
    edge_layout: str = "arbitrary"   # | "partitioned" (see gcn.py)
    dtype: Any = jnp.float32


def init_params(key, cfg: GINConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_prev = cfg.d_in
    for i in range(cfg.n_layers):
        layers.append({
            "mlp": mlp_init(ks[i], [d_prev, cfg.d_hidden, cfg.d_hidden],
                            cfg.dtype),
            "eps": jnp.zeros((), cfg.dtype),
        })
        d_prev = cfg.d_hidden
    # per-layer readout heads (JK): d_in for layer 0's input + hidden each
    heads = mlp_init(ks[-1], [cfg.d_hidden * cfg.n_layers, cfg.n_classes],
                     cfg.dtype)
    return {"layers": layers, "head": heads}


def forward(params, g: GraphBatch, cfg: GINConfig) -> jnp.ndarray:
    n = g.n_nodes
    x = g.node_feat.astype(cfg.dtype)
    x = sl.shard(x, "nodes", None)
    e = g.src.shape[0]
    n_chunks = (-(-e // cfg.edge_chunk)
                if cfg.edge_chunk and e > cfg.edge_chunk else 1)
    reps = []
    for lp in params["layers"]:
        if cfg.edge_layout == "partitioned":
            from .common import partitioned_aggregate
            agg = partitioned_aggregate(
                x, (g.src, g.dst),
                lambda xf, s, d: (jnp.take(xf, s, axis=0, fill_value=0), d),
                n, x.shape[1:], x.dtype, n_chunks=n_chunks)
        elif n_chunks == 1:
            agg = gather_scatter_sum(x, g.src, g.dst, n)
        else:
            from .common import chunked_scatter_sum
            agg = chunked_scatter_sum(
                lambda s, d: (jnp.take(x, s, axis=0, fill_value=0), d),
                n_chunks, (g.src, g.dst), n, x.shape[1:], x.dtype)
        x = mlp((1.0 + lp["eps"]) * x + agg, lp["mlp"])
        x = sl.shard(x, "nodes", None)
        reps.append(x)
    h = jnp.concatenate(reps, axis=-1)
    if cfg.node_level:
        return mlp(h, [params["head"][0]])
    pooled = graph_readout(h, g.graph_ids, g.n_graphs, op="sum")
    return mlp(pooled, [params["head"][0]])


def loss_fn(params, g: GraphBatch, cfg: GINConfig) -> jnp.ndarray:
    logits = forward(params, g, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, g.labels[:, None], axis=-1)[:, 0]
    if cfg.node_level and g.train_mask is not None:
        return (nll * g.train_mask).sum() / jnp.maximum(g.train_mask.sum(), 1)
    return nll.mean()
