"""EquiformerV2 (arXiv:2306.12059) — eSCN-style equivariant graph attention.

The O(L⁶) Clebsch-Gordan tensor product is replaced (as in eSCN /
EquiformerV2) by rotating each edge's features into a frame aligned with
the edge axis, where the tensor product collapses to SO(2) convolutions
over the azimuthal index m, truncated at ``m_max``.

TPU adaptation of the rotation math: Wigner little-d matrices are *not*
table-interpolated (the GPU implementation memoizes grids); instead we use
the exact spectral form  d^l(β) = Re[P_l diag(e^{-imβ}) P_l†]  with
P_l = T_l U_l (real-basis transform × eigenvectors of J_y), which unrolls
into a cos/sin einsum against tiny precomputed constant tensors:

    d^l(β)[e] = Σ_m cos(m·β_e)·A_l[m] + sin(m·β_e)·B_l[m]

— dense, branch-free VPU work, no gathers.  z-rotations use the same
machinery with P_l = T_l.  Constants are computed once in numpy (complex),
baked into the HLO as f32.

Per layer: rotate source features to the edge frame → SO(2) conv
(m=0 full l-mix; |m|≤m_max complex-pair mixes) modulated by an
edge-distance filter → multi-head attention logits from the m=0 part →
segment-softmax over incoming edges → rotate back → scatter-sum →
equivariant RMS norm + gated nonlinearity + residual.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ... import shardlib as sl
from .common import GraphBatch, graph_readout, mlp, mlp_init, scatter_sum


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 64
    cutoff: float = 10.0
    d_in: int = 0
    n_atom_types: int = 100
    n_targets: int = 1
    edge_chunk: int = 0
    # "arbitrary" | "dst_ranged": edges bucketed into contiguous destination
    # ranges (HoD's level-blocked layout) — each scan chunk writes one node
    # slice instead of re-touching the whole [N, 49, C] accumulator, and
    # chunk arrays carry explicit sharding so SPMD never replicates the
    # per-edge work across the mesh (see EXPERIMENTS.md §Perf).
    edge_layout: str = "arbitrary"
    logit_cap: float = 5.0      # soft-cap => chunk-safe exp (no max pass)
    dtype: Any = jnp.float32

    @property
    def n_coef(self) -> int:
        return (self.l_max + 1) ** 2


# ---------------------------------------------------------------------------
# Wigner rotation constants (numpy, cached per l_max)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4)
def _rotation_constants(l_max: int):
    """Per l: (A, B) with d^l(β) = Σ_m cos(mβ)A[m] + sin(mβ)B[m], and the
    analogous (Az, Bz) for z-rotations. All real f32, shapes [2l+1, D, D]."""
    out = []
    for l in range(l_max + 1):
        d = 2 * l + 1
        m = np.arange(-l, l + 1)
        # J_y in the complex |l,m> basis.
        jp = np.zeros((d, d), complex)   # J+ |m> = c+ |m+1>
        for i, mm in enumerate(m[:-1]):
            jp[i + 1, i] = np.sqrt(l * (l + 1) - mm * (mm + 1))
        jm = jp.conj().T
        jy = (jp - jm) / 2j
        evals, u = np.linalg.eigh(jy)    # evals ≈ -l..l
        # Real SH basis transform T (rows: real index m'=-l..l).
        t = np.zeros((d, d), complex)
        for i, mm in enumerate(m):
            j_pos, j_neg = l + abs(mm), l - abs(mm)
            if mm == 0:
                t[i, l] = 1.0
            elif mm > 0:
                t[i, j_pos] = (-1) ** mm / np.sqrt(2)
                t[i, j_neg] = 1 / np.sqrt(2)
            else:
                t[i, j_pos] = 1j * (-1) ** abs(mm) / np.sqrt(2) * -1
                t[i, j_neg] = 1j / np.sqrt(2)
        # d(β) = T U diag(e^{-i λ β}) (T U)^† ; λ = eigenvalue.
        p = t @ u
        a = np.empty((d, d, d), np.float32)
        b = np.empty((d, d, d), np.float32)
        for k in range(d):
            outer = np.outer(p[:, k], p[:, k].conj())
            a[k] = outer.real.astype(np.float32)
            b[k] = outer.imag.astype(np.float32)
        lam = evals.astype(np.float32)   # multipliers for β
        # z-rotation: same with P = T, eigenvalues = m.
        az = np.empty((d, d, d), np.float32)
        bz = np.empty((d, d, d), np.float32)
        for k in range(d):
            outer = np.outer(t[:, k], t[:, k].conj())
            az[k] = outer.real.astype(np.float32)
            bz[k] = outer.imag.astype(np.float32)
        lamz = m.astype(np.float32)
        out.append((a, b, lam, az, bz, lamz))
    return out


def _edge_rotations(vec: jnp.ndarray, l_max: int) -> List[jnp.ndarray]:
    """Per l: R_l [E, D, D] rotating each edge's frame so the edge direction
    lies along +z:  R = d(-θ) · z(-φ)."""
    x, y, z = vec[:, 0], vec[:, 1], vec[:, 2]
    r = jnp.sqrt(jnp.maximum(x * x + y * y + z * z, 1e-12))
    theta = jnp.arccos(jnp.clip(z / r, -1.0, 1.0))
    phi = jnp.arctan2(y, x)
    consts = _rotation_constants(l_max)
    rots = []
    for l in range(l_max + 1):
        a, b, lam, az, bz, lamz = consts[l]
        cb = jnp.cos(lam[None, :] * (-theta[:, None]))
        sb = jnp.sin(lam[None, :] * (-theta[:, None]))
        d_beta = jnp.einsum("ek,kij->eij", cb, a) \
            + jnp.einsum("ek,kij->eij", sb, b)
        ca = jnp.cos(lamz[None, :] * (-phi[:, None]))
        sa = jnp.sin(lamz[None, :] * (-phi[:, None]))
        d_alpha = jnp.einsum("ek,kij->eij", ca, az) \
            + jnp.einsum("ek,kij->eij", sa, bz)
        rots.append(jnp.einsum("eij,ejk->eik", d_beta, d_alpha))
    return rots


def _block_apply(rots, feats, l_max, transpose=False):
    """feats [E, n_coef, C]; apply block-diag rotation per l."""
    outs = []
    for l in range(l_max + 1):
        lo = l * l
        blk = feats[:, lo: lo + 2 * l + 1]
        r = rots[l]
        eq = "eji,ejc->eic" if transpose else "eij,ejc->eic"
        outs.append(jnp.einsum(eq, r, blk))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _so2_shapes(cfg: EquiformerV2Config):
    """Row counts feeding each m-channel of the SO(2) conv."""
    n0 = cfg.l_max + 1
    rows = {0: n0}
    for m in range(1, cfg.m_max + 1):
        rows[m] = cfg.l_max + 1 - m
    return rows


def init_params(key, cfg: EquiformerV2Config) -> Dict[str, Any]:
    from ..layers import dense_init
    c = cfg.d_hidden
    rows = _so2_shapes(cfg)
    ks = jax.random.split(key, 4 + cfg.n_layers)
    params: Dict[str, Any] = {
        "embed": dense_init(ks[0], (max(cfg.n_atom_types, cfg.d_in, 1), c),
                            dtype=cfg.dtype),
        "head": mlp_init(ks[1], [c, c, cfg.n_targets], cfg.dtype),
    }
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[4 + i], 8)
        lp = {
            "w0": dense_init(lk[0], (rows[0] * c, rows[0] * c), dtype=cfg.dtype),
            "filter": mlp_init(lk[1], [cfg.n_rbf, c, c], cfg.dtype),
            "attn": dense_init(lk[2], (c, cfg.n_heads), dtype=cfg.dtype),
            "gate": dense_init(lk[3], (c, c), dtype=cfg.dtype),
            "self": [dense_init(k, (c, c), dtype=cfg.dtype)
                     for k in jax.random.split(lk[4], cfg.l_max + 1)],
        }
        for m in range(1, cfg.m_max + 1):
            km = jax.random.split(lk[4 + m], 2)
            lp[f"w{m}r"] = dense_init(km[0], (rows[m] * c, rows[m] * c),
                                      dtype=cfg.dtype)
            lp[f"w{m}i"] = dense_init(km[1], (rows[m] * c, rows[m] * c),
                                      dtype=cfg.dtype)
        layers.append(lp)
    params["layers"] = layers
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _m_index(l_max: int, m: int, sign: int) -> np.ndarray:
    """Coefficient rows (l ≥ |m|) of azimuthal index ±m, real basis."""
    return np.array([l * l + l + sign * m for l in range(abs(m), l_max + 1)],
                    np.int32)


def _so2_conv(feats, lp, cfg: EquiformerV2Config):
    """feats [E, n_coef, C] in edge-aligned frames -> same shape out."""
    e = feats.shape[0]
    c = cfg.d_hidden
    out = jnp.zeros_like(feats)
    # m = 0: dense mix across (l, channel).
    idx0 = _m_index(cfg.l_max, 0, +1)
    x0 = feats[:, idx0].reshape(e, -1)
    y0 = (x0 @ lp["w0"]).reshape(e, len(idx0), c)
    out = out.at[:, idx0].set(y0)
    # 0 < m <= m_max: SO(2)-equivariant complex pair mixing.
    for m in range(1, cfg.m_max + 1):
        ip = _m_index(cfg.l_max, m, +1)
        im = _m_index(cfg.l_max, m, -1)
        xr = feats[:, ip].reshape(e, -1)
        xi = feats[:, im].reshape(e, -1)
        yr = xr @ lp[f"w{m}r"] - xi @ lp[f"w{m}i"]
        yi = xr @ lp[f"w{m}i"] + xi @ lp[f"w{m}r"]
        out = out.at[:, ip].set(yr.reshape(e, len(ip), c))
        out = out.at[:, im].set(yi.reshape(e, len(im), c))
    # rows with |m| > m_max stay zero — the eSCN truncation.
    return out


def _equiv_norm(x, l_max):
    """RMS over (m, channel) per l block, per node."""
    outs = []
    for l in range(l_max + 1):
        lo = l * l
        blk = x[:, lo: lo + 2 * l + 1]
        rms = jnp.sqrt(jnp.mean(jnp.square(blk), axis=(1, 2),
                                keepdims=True) + 1e-6)
        outs.append(blk / rms)
    return jnp.concatenate(outs, axis=1)


def rbf_expand(dist, cfg):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf, dtype=jnp.float32)
    gamma = (cfg.n_rbf / cfg.cutoff) ** 2 * 0.5
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def _edge_message(x, lp, cfg, src, vec, capped_only=False):
    """Per-edge pipeline: gather → rotate → SO(2) conv (m=0 only when
    ``capped_only``) → distance filter → soft-capped attention logits."""
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, axis=-1), 1e-12))
    rots = _edge_rotations(vec, cfg.l_max)
    rbf = rbf_expand(dist, cfg)
    src_f = jnp.take(x, src, axis=0, fill_value=0)           # [e, 49, C]
    f_edge = _block_apply(rots, src_f, cfg.l_max)
    filt = mlp(rbf, lp["filter"], act=jax.nn.silu)           # [e, C]
    if capped_only:
        # m=0 rows only — enough for the attention logits.
        idx0 = _m_index(cfg.l_max, 0, +1)
        x0 = f_edge[:, idx0].reshape(f_edge.shape[0], -1)
        y0 = (x0 @ lp["w0"]).reshape(f_edge.shape[0], len(idx0), cfg.d_hidden)
        m0 = y0[:, 0] * filt
        logits = m0 @ lp["attn"]
    else:
        msg = _so2_conv(f_edge, lp, cfg) * filt[:, None, :]
        logits = msg[:, 0] @ lp["attn"]
    cap = cfg.logit_cap
    logits = cap * jnp.tanh(logits / cap)                    # soft-cap
    if capped_only:
        return logits
    return msg, logits, rots


def forward(params, g: GraphBatch, cfg: EquiformerV2Config) -> jnp.ndarray:
    n, c = g.n_nodes, cfg.d_hidden
    vec = g.edge_feat.astype(jnp.float32).reshape(-1, 3)
    e = g.src.shape[0]
    n_chunks = (-(-e // cfg.edge_chunk)
                if cfg.edge_chunk and e > cfg.edge_chunk else 1)

    if cfg.d_in == 0:
        x0 = jnp.take(params["embed"], g.node_feat.astype(jnp.int32), axis=0)
    else:
        x0 = g.node_feat.astype(cfg.dtype) @ params["embed"][: cfg.d_in]
    x = jnp.zeros((n, cfg.n_coef, c), cfg.dtype).at[:, 0].set(x0)
    x = sl.shard(x, "nodes", None, None)

    def layer_fn(x, lp):
        if n_chunks == 1:
            msg, logits, rots = _edge_message(x, lp, cfg, g.src, vec)
            denom = scatter_sum(jnp.exp(logits), g.dst, n)       # [N, H]
            alpha = jnp.exp(logits) / jnp.take(
                jnp.maximum(denom, 1e-30), g.dst, axis=0, fill_value=1.0)
            alpha = jnp.repeat(alpha, c // cfg.n_heads, axis=-1)  # [E, C]
            msg = msg * alpha[:, None, :]
            msg = _block_apply(rots, msg, cfg.l_max, transpose=True)
            agg = scatter_sum(msg, g.dst, n)
        else:
            from .common import chunked_scatter_sum
            ranged = cfg.edge_layout == "dst_ranged"
            # pass 1: soft-capped exp-sum per destination (m=0 conv only)
            denom = chunked_scatter_sum(
                lambda s, d, v: (jnp.exp(_edge_message(
                    x, lp, cfg, s, v, capped_only=True)), d),
                n_chunks, (g.src, g.dst, vec), n, (cfg.n_heads,),
                jnp.float32, dst_ranged=ranged)
            denom = jnp.maximum(denom, 1e-30)

            # pass 2: full message, normalized, rotated back, scattered
            def edge_op(s, d, v):
                m, lo, rots_c = _edge_message(x, lp, cfg, s, v)
                al = jnp.exp(lo) / jnp.take(denom, d, axis=0, fill_value=1.0)
                al = jnp.repeat(al, c // cfg.n_heads, axis=-1)
                m = m * al[:, None, :]
                return _block_apply(rots_c, m, cfg.l_max, transpose=True), d

            agg = chunked_scatter_sum(edge_op, n_chunks,
                                      (g.src, g.dst, vec), n,
                                      (cfg.n_coef, c), x.dtype,
                                      dst_ranged=ranged)
        agg = _equiv_norm(agg, cfg.l_max)
        # node update: per-l channel mix + scalar-gated nonlinearity
        ups = []
        for l in range(cfg.l_max + 1):
            lo = l * l
            ups.append(agg[:, lo: lo + 2 * l + 1] @ lp["self"][l])
        up = jnp.concatenate(ups, axis=1)
        gate = jax.nn.sigmoid(up[:, 0] @ lp["gate"])         # [N, C]
        scal = jax.nn.silu(up[:, :1])
        rest = up[:, 1:] * gate[:, None, :]
        x = x + jnp.concatenate([scal, rest], axis=1)
        return sl.shard(x, "nodes", None, None)

    # NOTE on remat (§Perf iter-3, measured and refuted): wrapping layer_fn
    # in jax.checkpoint halves nothing here — the backward recompute re-runs
    # both chunk scans and doubles the scatter-transpose all-reduce traffic
    # (43.7 -> 87 TB/dev) while residual temp grows.  The real fix for both
    # temp and collectives is src-side ownership + all-to-all message
    # delivery (designed in EXPERIMENTS.md §Perf A).
    for lp in params["layers"]:
        x = layer_fn(x, lp)
    return x


def predict(params, g: GraphBatch, cfg: EquiformerV2Config) -> jnp.ndarray:
    x = forward(params, g, cfg)
    inv = mlp(x[:, 0], params["head"], act=jax.nn.silu)      # invariant head
    if g.graph_ids is None:
        return inv
    return graph_readout(inv, g.graph_ids, g.n_graphs, op="mean")


def loss_fn(params, g: GraphBatch, cfg: EquiformerV2Config) -> jnp.ndarray:
    pred = predict(params, g, cfg)
    if g.labels.dtype in (jnp.int32, jnp.int64):
        logp = jax.nn.log_softmax(pred, axis=-1)
        nll = -jnp.take_along_axis(logp, g.labels[:, None], axis=-1)[:, 0]
        if g.train_mask is not None and g.graph_ids is None:
            return (nll * g.train_mask).sum() / jnp.maximum(
                g.train_mask.sum(), 1)
        return nll.mean()
    target = g.labels.astype(jnp.float32).reshape(pred.shape)
    return jnp.mean((pred - target) ** 2)
