"""GNN family: GCN, GIN, SchNet, EquiformerV2 (eSCN).

All message passing is built on ``jnp.take`` (gather by edge endpoint) +
``jax.ops.segment_sum``-style scatter reductions — JAX has no native sparse
SpMM, so the edge-index formulation IS the substrate (see kernel taxonomy
§GNN).  Edge arrays are padded with a sentinel node (id == n_nodes) whose
row is sliced off after every scatter, keeping shapes static.
"""
from . import equiformer_v2, gcn, gin, schnet  # noqa: F401
from .common import GraphBatch, gather_scatter_sum, segment_softmax  # noqa: F401
