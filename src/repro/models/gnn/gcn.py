"""GCN (Kipf & Welling, arXiv:1609.02907): 2-layer, symmetric-normalized.

out = Ã ReLU(Ã X W1) W2,  Ã = D^-1/2 (A + I) D^-1/2 — expressed as
gather→scale→scatter over the edge list (self loops added by the caller or
handled here via the identity term).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ... import shardlib as sl
from .common import GraphBatch, degrees, gather_scatter_sum, mlp_init


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    norm: str = "sym"
    aggregator: str = "mean"   # paper config: sym-norm mean
    edge_chunk: int = 0        # >0: max edges per scan chunk (big graphs)
    # "arbitrary" | "partitioned" (edges pre-bucketed by dst owner; one
    # all-gather per layer instead of per-chunk all-reduces — see §Perf)
    edge_layout: str = "arbitrary"
    dtype: Any = jnp.float32


def init_params(key, cfg: GCNConfig) -> Dict[str, Any]:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    return {"layers": mlp_init(key, dims, cfg.dtype)}


def forward(params, g: GraphBatch, cfg: GCNConfig) -> jnp.ndarray:
    n = g.n_nodes
    deg = degrees(g.dst, n) + 1.0                      # +1: self loop
    inv_sqrt = jax.lax.rsqrt(deg)
    coef = (jnp.take(inv_sqrt, g.src, fill_value=0.0)
            * jnp.take(inv_sqrt, g.dst, fill_value=0.0))
    x = g.node_feat.astype(cfg.dtype)
    x = sl.shard(x, "nodes", None)
    e = g.src.shape[0]
    n_chunks = (-(-e // cfg.edge_chunk)
                if cfg.edge_chunk and e > cfg.edge_chunk else 1)
    for i, (w, b) in enumerate(params["layers"]):
        x = x @ w                                       # transform first:
        x = sl.shard(x, "nodes", None)                  # smaller SpMM width
        if cfg.edge_layout == "partitioned":
            from .common import partitioned_aggregate
            agg = partitioned_aggregate(
                x, (g.src, g.dst, coef),
                lambda xf, s, d, c: (jnp.take(xf, s, axis=0, fill_value=0)
                                     * c[:, None], d),
                n, x.shape[1:], x.dtype, n_chunks=n_chunks)
        elif n_chunks == 1:
            agg = gather_scatter_sum(x, g.src, g.dst, n, edge_weight=coef)
        else:
            from .common import chunked_scatter_sum
            agg = chunked_scatter_sum(
                lambda s, d, c: (jnp.take(x, s, axis=0, fill_value=0)
                                 * c[:, None], d),
                n_chunks, (g.src, g.dst, coef), n, x.shape[1:], x.dtype)
        x = agg + x * inv_sqrt[:, None] ** 2 + b        # self-loop term
        x = sl.shard(x, "nodes", None)
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params, g: GraphBatch, cfg: GCNConfig) -> jnp.ndarray:
    logits = forward(params, g, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, g.labels[:, None], axis=-1)[:, 0]
    mask = (g.train_mask if g.train_mask is not None
            else jnp.ones_like(nll, dtype=bool))
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
