"""SchNet (Schütt et al., arXiv:1706.08566): continuous-filter convolutions.

Interaction block: x → Dense → (gather src) ⊙ W(rbf(d)) → scatter-sum dst →
Dense → ssp → Dense → residual, with rbf = 300 Gaussians on [0, cutoff].
Per the assignment, the geometry frontend is a stub: edge distances arrive
precomputed in ``GraphBatch.edge_feat`` (for non-molecular graphs the data
pipeline synthesizes them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ... import shardlib as sl
from .common import GraphBatch, graph_readout, mlp, mlp_init, scatter_sum


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    d_in: int = 0              # 0 => integer atom types -> embedding
    n_atom_types: int = 100
    n_targets: int = 1         # energy regression
    edge_chunk: int = 0
    edge_layout: str = "arbitrary"   # | "partitioned" (see gcn.py)
    dtype: Any = jnp.float32


def init_params(key, cfg: SchNetConfig) -> Dict[str, Any]:
    from ..layers import dense_init
    ks = jax.random.split(key, 2 + 4 * cfg.n_interactions)
    params: Dict[str, Any] = {}
    if cfg.d_in == 0:
        params["embed"] = dense_init(ks[0], (cfg.n_atom_types, cfg.d_hidden),
                                     dtype=cfg.dtype)
    else:
        params["embed_w"] = dense_init(ks[0], (cfg.d_in, cfg.d_hidden),
                                       dtype=cfg.dtype)
    inter = []
    for i in range(cfg.n_interactions):
        k0, k1, k2, k3 = ks[2 + 4 * i: 6 + 4 * i]
        inter.append({
            "filter": mlp_init(k0, [cfg.n_rbf, cfg.d_hidden, cfg.d_hidden],
                               cfg.dtype),
            "in_w": dense_init(k1, (cfg.d_hidden, cfg.d_hidden),
                               dtype=cfg.dtype),
            "out": mlp_init(k2, [cfg.d_hidden, cfg.d_hidden, cfg.d_hidden],
                            cfg.dtype),
        })
    params["interactions"] = inter
    params["head"] = mlp_init(ks[1], [cfg.d_hidden, cfg.d_hidden // 2,
                                      cfg.n_targets], cfg.dtype)
    return params


def rbf_expand(dist: jnp.ndarray, cfg: SchNetConfig) -> jnp.ndarray:
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf, dtype=jnp.float32)
    gamma = (cfg.n_rbf / cfg.cutoff) ** 2 * 0.5
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def forward(params, g: GraphBatch, cfg: SchNetConfig) -> jnp.ndarray:
    n = g.n_nodes
    if cfg.d_in == 0:
        x = jnp.take(params["embed"], g.node_feat.astype(jnp.int32), axis=0)
    else:
        x = g.node_feat.astype(cfg.dtype) @ params["embed_w"]
    x = sl.shard(x, "nodes", None)
    if g.edge_feat.ndim == 2 and g.edge_feat.shape[-1] == 3:
        dist = jnp.sqrt(jnp.maximum(
            jnp.sum(g.edge_feat.astype(jnp.float32) ** 2, -1), 1e-12))
    else:
        dist = g.edge_feat.reshape(-1).astype(jnp.float32)
    e = g.src.shape[0]
    n_chunks = (-(-e // cfg.edge_chunk)
                if cfg.edge_chunk and e > cfg.edge_chunk else 1)
    for lp in params["interactions"]:
        h = x @ lp["in_w"]

        def edge_op(s, d, dd):
            rbf = rbf_expand(dd, cfg)
            env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dd / cfg.cutoff, 0, 1))
                         + 1.0)
            w_edge = mlp(rbf, lp["filter"], act=shifted_softplus)
            w_edge = w_edge * env[:, None]
            return jnp.take(h, s, axis=0, fill_value=0) * w_edge, d

        if cfg.edge_layout == "partitioned":
            from .common import partitioned_aggregate

            def edge_op_p(hf, s, d, dd):
                rbf = rbf_expand(dd, cfg)
                env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dd / cfg.cutoff,
                                                       0, 1)) + 1.0)
                w_edge = mlp(rbf, lp["filter"], act=shifted_softplus)
                w_edge = w_edge * env[:, None]
                return jnp.take(hf, s, axis=0, fill_value=0) * w_edge, d

            agg = partitioned_aggregate(h, (g.src, g.dst, dist), edge_op_p,
                                        n, (cfg.d_hidden,), h.dtype,
                                        n_chunks=n_chunks)
        elif n_chunks == 1:
            msgs, _ = edge_op(g.src, g.dst, dist)
            msgs = sl.shard(msgs, "edges", None)
            agg = scatter_sum(msgs, g.dst, n)
        else:
            from .common import chunked_scatter_sum
            agg = chunked_scatter_sum(edge_op, n_chunks,
                                      (g.src, g.dst, dist), n,
                                      (cfg.d_hidden,), h.dtype)
        x = x + mlp(agg, lp["out"], act=shifted_softplus)
        x = sl.shard(x, "nodes", None)
    return x


def predict(params, g: GraphBatch, cfg: SchNetConfig) -> jnp.ndarray:
    x = forward(params, g, cfg)
    atomwise = mlp(x, params["head"], act=shifted_softplus)
    if g.graph_ids is None:
        return atomwise
    return graph_readout(atomwise, g.graph_ids, g.n_graphs, op="sum")


def loss_fn(params, g: GraphBatch, cfg: SchNetConfig) -> jnp.ndarray:
    pred = predict(params, g, cfg)
    if g.labels.dtype in (jnp.int32, jnp.int64):     # classification cells
        import jax.nn as jnn
        logp = jnn.log_softmax(pred, axis=-1)
        nll = -jnp.take_along_axis(logp, g.labels[:, None], axis=-1)[:, 0]
        if g.train_mask is not None and g.graph_ids is None:
            return (nll * g.train_mask).sum() / jnp.maximum(
                g.train_mask.sum(), 1)
        return nll.mean()
    target = g.labels.astype(jnp.float32).reshape(pred.shape)
    return jnp.mean((pred - target) ** 2)
