"""Weighted directed graph substrate for HoD.

The paper (§2) assumes a directed, positively-weighted graph stored on disk
as adjacency lists with every edge recorded twice (once per endpoint, the
reverse copy carrying a negated length).  In this system the canonical
in-memory form is CSR (out-edges) + CSC (in-edges) over numpy arrays; the
"two copies" trick of §4.1 reappears in :mod:`repro.core.build` as signed
triplets during the sort-merge.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

import numpy as np

__all__ = [
    "Digraph",
    "from_edges",
    "gnm_random_digraph",
    "power_law_digraph",
    "grid_road_graph",
    "symmetrize",
    "largest_weakly_connected_component",
]


@dataclasses.dataclass
class Digraph:
    """CSR/CSC weighted digraph. Node ids are 0..n-1; weights positive f64."""

    n: int
    # CSR over out-edges
    out_ptr: np.ndarray   # [n+1] int64
    out_dst: np.ndarray   # [m]   int64
    out_w: np.ndarray     # [m]   float64
    # CSC over in-edges (mirrors the same edge set)
    in_ptr: np.ndarray    # [n+1] int64
    in_src: np.ndarray    # [m]   int64
    in_w: np.ndarray      # [m]   float64

    @property
    def m(self) -> int:
        return int(self.out_dst.shape[0])

    def out_edges(self, v: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.out_ptr[v], self.out_ptr[v + 1]
        return self.out_dst[s:e], self.out_w[s:e]

    def in_edges(self, v: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.in_ptr[v], self.in_ptr[v + 1]
        return self.in_src[s:e], self.in_w[s:e]

    def edge_list(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (src, dst, w) arrays of length m."""
        src = np.repeat(np.arange(self.n, dtype=np.int64),
                        np.diff(self.out_ptr))
        return src, self.out_dst.copy(), self.out_w.copy()

    def reverse(self) -> "Digraph":
        """Transpose — supports the paper's destination-node formulation."""
        src, dst, w = self.edge_list()
        return from_edges(self.n, dst, src, w)

    def nbytes(self) -> int:
        return sum(a.nbytes for a in (self.out_ptr, self.out_dst, self.out_w,
                                      self.in_ptr, self.in_src, self.in_w))

    def to_networkx(self):
        import networkx as nx

        g = nx.DiGraph()
        g.add_nodes_from(range(self.n))
        src, dst, w = self.edge_list()
        g.add_weighted_edges_from(zip(src.tolist(), dst.tolist(), w.tolist()))
        return g


def from_edges(n: int, src: Iterable[int], dst: Iterable[int],
               w: Iterable[float], dedup: str = "min") -> Digraph:
    """Build a Digraph from parallel edge arrays.

    Parallel edges collapse to the shortest one (``dedup="min"``); self loops
    are dropped (they never lie on a shortest path with positive weights).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    if src.size:
        keep = src != dst
        src, dst, w = src[keep], dst[keep], w[keep]
    if w.size and (w <= 0).any():
        raise ValueError("edge lengths must be positive (paper §2)")
    if src.size and dedup == "min":
        order = np.lexsort((w, dst, src))
        src, dst, w = src[order], dst[order], w[order]
        first = np.ones(src.shape[0], dtype=bool)
        first[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
        src, dst, w = src[first], dst[first], w[first]

    def _csr(key: np.ndarray, val: np.ndarray, vw: np.ndarray):
        order = np.argsort(key, kind="stable")
        key, val, vw = key[order], val[order], vw[order]
        ptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(ptr, key + 1, 1)
        np.cumsum(ptr, out=ptr)
        return ptr, val, vw

    out_ptr, out_dst, out_w = _csr(src, dst, w)
    in_ptr, in_src, in_w = _csr(dst, src, w)
    return Digraph(n, out_ptr, out_dst, out_w, in_ptr, in_src, in_w)


def symmetrize(g: Digraph) -> Digraph:
    """Undirected view: add the reverse of every edge (paper's u-BTC prep)."""
    src, dst, w = g.edge_list()
    return from_edges(g.n, np.concatenate([src, dst]),
                      np.concatenate([dst, src]), np.concatenate([w, w]))


def largest_weakly_connected_component(g: Digraph) -> Digraph:
    """Restrict to the largest WCC and relabel (paper §7.1 does the same)."""
    # Union-find over the undirected edge set.
    parent = np.arange(g.n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    src, dst, w = g.edge_list()
    for a, b in zip(src.tolist(), dst.tolist()):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    roots = np.array([find(i) for i in range(g.n)], dtype=np.int64)
    vals, counts = np.unique(roots, return_counts=True)
    big = vals[np.argmax(counts)]
    keep = roots == big
    new_id = np.full(g.n, -1, dtype=np.int64)
    new_id[keep] = np.arange(keep.sum(), dtype=np.int64)
    mask = keep[src] & keep[dst]
    return from_edges(int(keep.sum()), new_id[src[mask]], new_id[dst[mask]],
                      w[mask])


# ---------------------------------------------------------------------------
# Generators (stand-ins for the paper's USRN / FB / BTC / Meme / UKWeb inputs)
# ---------------------------------------------------------------------------

def gnm_random_digraph(n: int, m: int, seed: int = 0,
                       weighted: bool = True) -> Digraph:
    """Erdős–Rényi style G(n, m) digraph with integer-ish positive weights."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=int(m * 1.2), dtype=np.int64)
    dst = rng.integers(0, n, size=int(m * 1.2), dtype=np.int64)
    keep = src != dst
    src, dst = src[keep][:m], dst[keep][:m]
    w = (rng.integers(1, 11, size=src.shape[0]).astype(np.float64)
         if weighted else np.ones(src.shape[0]))
    return from_edges(n, src, dst, w)


def power_law_digraph(n: int, m_per_node: int = 4, seed: int = 0,
                      weighted: bool = False) -> Digraph:
    """Preferential-attachment digraph — web/social-like (FB/Meme stand-in)."""
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    targets = np.arange(min(m_per_node, n), dtype=np.int64)
    repeated = list(targets)
    for v in range(len(targets), n):
        picks = rng.choice(len(repeated), size=min(m_per_node, len(repeated)),
                           replace=False)
        for p in picks:
            u = repeated[p]
            if rng.random() < 0.5:
                src_l.append(v); dst_l.append(u)
            else:
                src_l.append(u); dst_l.append(v)
            repeated.append(u)
        repeated.extend([v] * m_per_node)
    src = np.asarray(src_l, dtype=np.int64)
    dst = np.asarray(dst_l, dtype=np.int64)
    w = (rng.integers(1, 11, size=src.shape[0]).astype(np.float64)
         if weighted else np.ones(src.shape[0]))
    return from_edges(n, src, dst, w)


def grid_road_graph(side: int, seed: int = 0) -> Digraph:
    """4-connected grid with jittered weights — USRN (road network) stand-in.

    Degree-bounded and high-diameter, the regime where hierarchy/shortcut
    methods shine (paper §8 contrasts road networks vs. general graphs).
    """
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n, dtype=np.int64).reshape(side, side)
    src_l, dst_l = [], []
    right_s, right_d = idx[:, :-1].ravel(), idx[:, 1:].ravel()
    down_s, down_d = idx[:-1, :].ravel(), idx[1:, :].ravel()
    for s, d in ((right_s, right_d), (down_s, down_d)):
        src_l.append(s); dst_l.append(d)
        src_l.append(d); dst_l.append(s)
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    w = rng.integers(1, 6, size=src.shape[0]).astype(np.float64)
    return from_edges(n, src, dst, w)
