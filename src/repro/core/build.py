"""HoD index construction (paper §4).

Iteratively removes low-score nodes from a working copy of the graph,
patching distances with shortcuts, until the survivors (the *core graph*)
are small.  Removed nodes' adjacency snapshots stream to the forward file
``F_f`` (out-edges) and backward file ``F_b`` (in-edges); the iteration in
which a node dies is its *rank*.

Faithfulness notes
------------------
* score (Eq. 1):  ``s(v) = |B_in|·|B_out \\ B_in| + |B_out|·|B_in \\ B_out|``
* threshold: approximated median over a node sample (§4.2)
* independent set: no two adjacent nodes removed in one round (§4.2)
* shortcut pruning: candidate vs. baseline triplets, sort-merge with the
  §4.1 ordering rules; baselines = coinciding direct edges + ``c·Σ s(v)``
  sampled two-hop paths through retained nodes, c = 5 (§4.3)
* termination: core fits the memory budget AND one more round shrinks the
  reduced graph by < 5 % (§4.4)
* SSSP annotations (§6): every augmented edge (u, w) carries the node that
  immediately precedes w on the u→w path it represents; shortcuts inherit
  the annotation of the (v, w) half they replace.

The external triplet sort is performed in memory but charged against the
:class:`~repro.core.io_sim.BlockDevice` so the I/O-cost benchmarks reflect
the paper's accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .graph import Digraph
from .io_sim import BlockDevice, IOStats

__all__ = ["BuildConfig", "BuildStats", "BuildResult", "build_hod"]

TRIPLET_BYTES = 20  # (node, node, length) on disk: 2×int64 + float32


@dataclasses.dataclass
class BuildConfig:
    # Memory-budget analogue: the core graph must fit these bounds ("M").
    max_core_nodes: int = 1024
    max_core_edges: int = 1 << 16
    min_shrink: float = 0.05       # §4.4 keep-going threshold
    baseline_factor: int = 5       # c in §4.3
    median_sample: int = 1024      # §4.2 approximated median
    max_rounds: int = 64
    # cap on sampled two-hop baselines per round: keeps preprocessing
    # near-linear on huge rounds; extra (unpruned) shortcuts only cost
    # space, never correctness (§4.1 safety argument)
    max_baseline_per_round: int = 200_000
    # stop contracting when shortcut fill-in outweighs removals: if the
    # reduced graph's edge count exceeds this multiple of the smallest
    # edge count seen, further rounds only inflate the index (scale-free
    # graphs; road networks never trigger it).  The survivors become the
    # core, exactly as when the §4.4 memory condition fires.
    fill_stop_ratio: float = 3.0
    seed: int = 0


@dataclasses.dataclass
class BuildStats:
    rounds: int = 0
    removed: int = 0
    candidates_generated: int = 0
    shortcuts_added: int = 0
    baselines_sampled: int = 0
    build_seconds: float = 0.0
    io: IOStats = dataclasses.field(default_factory=IOStats)
    core_nodes: int = 0
    core_edges: int = 0
    f_edges: int = 0
    b_edges: int = 0


@dataclasses.dataclass
class BuildResult:
    """Raw build output, consumed by :mod:`repro.core.index`."""

    n: int
    rank: np.ndarray                 # [n] 1-based round of removal; core = rounds+1
    removal_order: List[int]         # non-core nodes, round-major
    level_sizes: List[int]           # nodes removed per round
    # forward file: per removed node, its out-edges (dst, w, assoc) at death
    f_adj: List[List[Tuple[int, float, int]]]
    # backward file: per removed node, its in-edges (src, w, assoc) at death
    b_adj: List[List[Tuple[int, float, int]]]
    core_nodes: List[int]
    # core graph edges (u, v, w, assoc) in original ids
    core_edges: List[Tuple[int, int, float, int]]
    stats: BuildStats = dataclasses.field(default_factory=BuildStats)


def _scores(cands: np.ndarray, out_adj, in_adj) -> np.ndarray:
    s = np.empty(cands.shape[0], dtype=np.int64)
    for i, v in enumerate(cands):
        b_out = out_adj[v].keys()
        b_in = in_adj[v]
        n_out, n_in = len(b_out), len(b_in)
        inter = 0
        small, big = (b_out, b_in) if n_out <= n_in else (b_in, b_out)
        for x in small:
            if x in big:
                inter += 1
        s[i] = n_in * (n_out - inter) + n_out * (n_in - inter)
    return s


def build_hod(g: Digraph, cfg: Optional[BuildConfig] = None,
              device: Optional[BlockDevice] = None) -> BuildResult:
    cfg = cfg or BuildConfig()
    device = device or BlockDevice()
    rng = np.random.default_rng(cfg.seed)
    t0 = time.perf_counter()

    n = g.n
    # Working adjacency: out_adj[u][v] = (weight, assoc); in_adj[v] = {u}.
    out_adj: List[Dict[int, Tuple[float, int]]] = [dict() for _ in range(n)]
    in_adj: List[Set[int]] = [set() for _ in range(n)]
    src, dst, w = g.edge_list()
    for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
        out_adj[a][b] = (ww, a)          # original edge: assoc = start point
        in_adj[b].add(a)
    device.sequential(g.m * TRIPLET_BYTES * 2)  # initial adjacency-list scan

    alive = np.ones(n, dtype=bool)
    rank = np.zeros(n, dtype=np.int64)
    removal_order: List[int] = []
    level_sizes: List[int] = []
    f_adj: List[List[Tuple[int, float, int]]] = [None] * n  # type: ignore
    b_adj: List[List[Tuple[int, float, int]]] = [None] * n  # type: ignore
    stats = BuildStats()

    n_alive = n
    m_alive = g.m
    m_min_seen = g.m
    rounds = 0
    while rounds < cfg.max_rounds:
        core_fits = (n_alive <= cfg.max_core_nodes
                     and m_alive <= cfg.max_core_edges)
        alive_ids = np.flatnonzero(alive)
        if alive_ids.size == 0:
            break

        # ---- Step 1: select R_i (score ≤ ~median, independent set) -------
        sample = (alive_ids if alive_ids.size <= cfg.median_sample else
                  rng.choice(alive_ids, size=cfg.median_sample, replace=False))
        thresh = float(np.median(_scores(sample, out_adj, in_adj)))
        scores = _scores(alive_ids, out_adj, in_adj)
        cand_mask = scores <= thresh
        cand_ids = alive_ids[cand_mask]
        cand_ids = cand_ids[np.argsort(scores[cand_mask], kind="stable")]

        blocked = np.zeros(n, dtype=bool)
        selected: List[int] = []
        for v in cand_ids.tolist():
            if blocked[v]:
                continue
            selected.append(v)
            blocked[v] = True
            for u in in_adj[v]:
                blocked[u] = True
            for u2 in out_adj[v]:
                blocked[u2] = True
        if not selected:
            break

        # ---- Step 2: candidate edges for every v* ∈ R_i -------------------
        # cand_best[(u, w)] = (length, assoc) keeping the shortest candidate.
        cand_best: Dict[Tuple[int, int], Tuple[float, int]] = {}
        n_cands = 0
        for v in selected:
            for u in in_adj[v]:
                w_uv = out_adj[u][v][0]
                for w_node, (w_vw, assoc_vw) in out_adj[v].items():
                    if w_node == u:
                        continue
                    length = w_uv + w_vw
                    n_cands += 1
                    key = (u, w_node)
                    prev = cand_best.get(key)
                    if prev is None or length < prev[0]:
                        cand_best[key] = (length, assoc_vw)
        stats.candidates_generated += n_cands

        # ---- Step 3: baseline edges ---------------------------------------
        # Group 1: direct edges between retained endpoints coinciding with a
        # candidate pair (sufficient for the sort-merge: other groups can
        # never eliminate a candidate).
        base_best: Dict[Tuple[int, int], float] = {}
        for (u, w_node) in cand_best:
            e = out_adj[u].get(w_node)
            if e is not None:
                base_best[(u, w_node)] = e[0]
        # Group 2: c·Σs(v) sampled two-hop paths through retained nodes.
        n_base = min(cfg.baseline_factor * max(1, len(cand_best)),
                     cfg.max_baseline_per_round)
        retained = alive_ids[~np.isin(alive_ids, np.asarray(selected))]
        if retained.size and n_base:
            deg = np.fromiter((len(out_adj[v]) + len(in_adj[v])
                               for v in retained), dtype=np.float64,
                              count=retained.size)
            tot = deg.sum()
            if tot > 0:
                mids = rng.choice(retained, size=n_base, p=deg / tot)
                sel_set = set(selected)
                for v in mids.tolist():
                    ins = in_adj[v]
                    outs = out_adj[v]
                    if not ins or not outs:
                        continue
                    u = next(iter(ins)) if len(ins) == 1 else \
                        list(ins)[rng.integers(len(ins))]
                    keys = list(outs.keys())
                    w_node = keys[rng.integers(len(keys))]
                    if u in sel_set or w_node in sel_set or u == w_node:
                        continue
                    length = out_adj[u][v][0] + outs[w_node][0]
                    key = (u, w_node)
                    if key in cand_best:  # only colliding groups matter
                        prev = base_best.get(key)
                        if prev is None or length < prev:
                            base_best[key] = length
                        stats.baselines_sampled += 1
        # Charge the external sort of all triplets (2 signed copies each).
        n_triplets = 2 * (n_cands + len(base_best))
        device.external_sort(n_triplets * TRIPLET_BYTES,
                             mem_bytes=64 << 20)

        # ---- Step 4: merge — retain candidates shorter than every baseline
        shortcuts: List[Tuple[int, int, float, int]] = []
        for (u, w_node), (length, assoc) in cand_best.items():
            base = base_best.get((u, w_node))
            if base is not None and base <= length:
                continue
            shortcuts.append((u, w_node, length, assoc))
        stats.shortcuts_added += len(shortcuts)

        # ---- Step 5: snapshot + delete R_i, stream to F_f / F_b ----------
        f_bytes = 0
        for v in selected:
            fo = [(d, wv, asc) for d, (wv, asc) in out_adj[v].items()]
            fb = [(u, out_adj[u][v][0], out_adj[u][v][1]) for u in in_adj[v]]
            f_adj[v] = fo
            b_adj[v] = fb
            f_bytes += (len(fo) + len(fb)) * TRIPLET_BYTES
            stats.f_edges += len(fo)
            stats.b_edges += len(fb)
        device.sequential(f_bytes)  # appends to F_f / F_b are sequential

        removed_edges = 0
        for v in selected:
            for d in out_adj[v]:
                in_adj[d].discard(v)
            for u in in_adj[v]:
                del out_adj[u][v]
                removed_edges += 1
            removed_edges += len(out_adj[v])
            out_adj[v] = {}
            in_adj[v] = set()
            alive[v] = False
            rank[v] = rounds + 1
        removal_order.extend(selected)
        level_sizes.append(len(selected))

        # ---- Step 6: install retained shortcuts ---------------------------
        added_edges = 0
        for (u, w_node, length, assoc) in shortcuts:
            prev = out_adj[u].get(w_node)
            if prev is None:
                out_adj[u][w_node] = (length, assoc)
                in_adj[w_node].add(u)
                added_edges += 1
            elif length < prev[0]:
                out_adj[u][w_node] = (length, assoc)

        rounds += 1
        removed_frac = len(selected) / n_alive
        n_alive -= len(selected)
        m_alive += added_edges - removed_edges
        m_min_seen = min(m_min_seen, m_alive)
        stats.removed += len(selected)
        if core_fits and removed_frac < cfg.min_shrink:
            break
        if m_alive > cfg.fill_stop_ratio * max(m_min_seen, 1):
            break  # fill-in dominates: survivors become the core

    # ---- Core graph ------------------------------------------------------
    core_nodes = np.flatnonzero(alive).tolist()
    rank[alive] = rounds + 1
    core_edges: List[Tuple[int, int, float, int]] = []
    for u in core_nodes:
        for v, (wv, asc) in out_adj[u].items():
            core_edges.append((u, v, wv, asc))

    stats.rounds = rounds
    stats.core_nodes = len(core_nodes)
    stats.core_edges = len(core_edges)
    stats.build_seconds = time.perf_counter() - t0
    stats.io = device.stats

    return BuildResult(n=n, rank=rank, removal_order=removal_order,
                       level_sizes=level_sizes, f_adj=f_adj, b_adj=b_adj,
                       core_nodes=core_nodes, core_edges=core_edges,
                       stats=stats)
