"""Competitor methods from the paper's experiments (§7).

* :func:`em_dijkstra`  — EM-Dijk [18]: Dijkstra over disk-resident adjacency
  lists with a bounded block cache; every cache miss is a *random* block
  access. This exposes the paper's core complaint: visit order diverges
  from storage order.
* :func:`em_bfs`       — EM-BFS [6] (Munagala–Ranade flavor): level-by-level
  frontier expansion with external sorts; unweighted graphs only.
* :class:`VCIndex`     — VC-Index [8]: vertex-cover hierarchy for undirected
  graphs. Non-cover nodes form an independent set, so removing them while
  cliquing their (cover) neighbors preserves cover-to-cover distances;
  queries resolve top-down with sequential scans per level. This is a
  faithful simplification of Cheng et al.'s index (same reduction
  invariant, same scan-oriented I/O pattern).

All methods meter their I/O through :class:`~repro.core.io_sim.BlockDevice`
so benchmarks can compare modeled disk time next to CPU time.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import Digraph
from .io_sim import BlockDevice, IOStats

__all__ = ["em_dijkstra", "em_bfs", "VCIndex"]

EDGE_BYTES = 12  # (dst int64-ish, w float32) packed on disk


# ---------------------------------------------------------------------------
# EM-Dijkstra
# ---------------------------------------------------------------------------

def em_dijkstra(g: Digraph, source: int, device: Optional[BlockDevice] = None,
                cache_blocks: int = 4096) -> Tuple[np.ndarray, IOStats]:
    """Dijkstra with an LRU-cached block view of the CSR adjacency file."""
    device = device or BlockDevice()
    block_edges = max(1, device.block_bytes // EDGE_BYTES)
    cache: OrderedDict[int, None] = OrderedDict()

    def touch(node: int) -> None:
        lo, hi = int(g.out_ptr[node]), int(g.out_ptr[node + 1])
        for blk in range(lo // block_edges, max(lo, hi - 1) // block_edges + 1):
            if blk in cache:
                cache.move_to_end(blk)
                continue
            device.random(device.block_bytes)
            cache[blk] = None
            if len(cache) > cache_blocks:
                cache.popitem(last=False)

    n = g.n
    dist = np.full(n, np.inf, dtype=np.float64)
    dist[source] = 0.0
    heap = [(0.0, source)]
    while heap:
        d_u, u = heapq.heappop(heap)
        if d_u > dist[u]:
            continue
        touch(u)
        dsts, ws = g.out_edges(u)
        for v, wv in zip(dsts.tolist(), ws.tolist()):
            nd = d_u + wv
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist, device.stats


# ---------------------------------------------------------------------------
# EM-BFS (unweighted)
# ---------------------------------------------------------------------------

def em_bfs(g: Digraph, source: int,
           device: Optional[BlockDevice] = None) -> Tuple[np.ndarray, IOStats]:
    """Munagala–Ranade external BFS: N(L_t) gathered (random I/O), then
    deduplicated against L_t, L_{t-1} via external sort + sequential scans."""
    device = device or BlockDevice()
    n = g.n
    dist = np.full(n, np.inf, dtype=np.float64)
    dist[source] = 0.0
    frontier = np.array([source], dtype=np.int64)
    prev = np.empty(0, dtype=np.int64)
    level = 0
    while frontier.size:
        level += 1
        # gather adjacency of the frontier — one random block hit per node
        neigh: List[np.ndarray] = []
        nbytes = 0
        for u in frontier.tolist():
            dsts, _ = g.out_edges(u)
            neigh.append(dsts)
            nbytes += max(1, dsts.size) * EDGE_BYTES
            device.random(min(nbytes, device.block_bytes))
        cand = (np.unique(np.concatenate(neigh)) if neigh
                else np.empty(0, dtype=np.int64))
        device.external_sort(cand.size * 8, mem_bytes=64 << 20)
        device.sequential((frontier.size + prev.size) * 8)
        new = cand[~np.isfinite(dist[cand])]
        dist[new] = level
        prev, frontier = frontier, new
    return dist, device.stats


# ---------------------------------------------------------------------------
# VC-Index
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _VCLevel:
    # adjacency (to cover nodes) of every node removed at this level
    removed: np.ndarray                 # node ids
    adj: List[List[Tuple[int, float]]]  # parallel to `removed`
    nbytes: int


class VCIndex:
    """Vertex-cover hierarchy index for *undirected* graphs (VC-Index [8]).

    Build: repeatedly take a maximal-matching 2-approx vertex cover; the
    independent non-cover nodes (degree-capped to bound clique fill-in) are
    removed, their neighbor pairs cliqued with summed weights. Distances
    between surviving nodes are preserved exactly.
    """

    def __init__(self, g: Digraph, top_nodes: int = 2048, deg_cap: int = 8,
                 max_levels: int = 40,
                 device: Optional[BlockDevice] = None):
        self.device = device or BlockDevice()
        t0 = time.perf_counter()
        n = g.n
        adj: List[Dict[int, float]] = [dict() for _ in range(n)]
        src, dst, w = g.edge_list()
        for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
            if adj[a].get(b, np.inf) > ww:
                adj[a][b] = ww
                adj[b][a] = ww
        self.device.sequential(g.m * EDGE_BYTES * 2)

        alive = np.ones(n, dtype=bool)
        self.levels: List[_VCLevel] = []
        n_alive = n
        for _ in range(max_levels):
            if n_alive <= top_nodes:
                break
            alive_ids = np.flatnonzero(alive)
            # maximal matching -> cover; unmatched nodes are independent
            in_cover = np.zeros(n, dtype=bool)
            for u in alive_ids.tolist():
                if in_cover[u]:
                    continue
                for v in adj[u]:
                    if not in_cover[v]:
                        in_cover[u] = True
                        in_cover[v] = True
                        break
            removable = [int(v) for v in alive_ids.tolist()
                         if not in_cover[v] and len(adj[v]) <= deg_cap]
            if not removable:
                break
            rem_adj: List[List[Tuple[int, float]]] = []
            nbytes = 0
            for v in removable:
                items = sorted(adj[v].items())
                rem_adj.append([(int(u), float(ww)) for u, ww in items])
                nbytes += len(items) * EDGE_BYTES
                # clique fill-in among neighbors (all in the cover)
                for i, (u, wu) in enumerate(items):
                    for (x, wx) in items[i + 1:]:
                        if u == x:
                            continue
                        nw = wu + wx
                        if adj[u].get(x, np.inf) > nw:
                            adj[u][x] = nw
                            adj[x][u] = nw
                for u, _ in items:
                    adj[u].pop(v, None)
                adj[v] = {}
                alive[v] = False
            self.device.sequential(nbytes)
            self.levels.append(_VCLevel(np.asarray(removable, dtype=np.int64),
                                        rem_adj, nbytes))
            n_alive -= len(removable)

        self.top_nodes_ids = np.flatnonzero(alive)
        self.top_adj = {int(u): dict(adj[u]) for u in self.top_nodes_ids}
        self.top_bytes = sum(len(a) for a in self.top_adj.values()) * EDGE_BYTES
        self.n = n
        self.build_seconds = time.perf_counter() - t0
        self.build_io = self.device.reset()

    def index_bytes(self) -> int:
        return sum(l.nbytes for l in self.levels) + self.top_bytes

    def ssd(self, source: int) -> Tuple[np.ndarray, IOStats]:
        n = self.n
        dist = np.full(n, np.inf, dtype=np.float64)
        dist[source] = 0.0
        # upward: every removed node with a finite tentative distance seeds
        # its (surviving, cover) neighbors. Monotone-chain argument: some
        # shortest path to any survivor ascends removal levels, so one
        # ascending pass suffices for exact top-level seeds.
        for lvl in self.levels:
            self.device.sequential(lvl.nbytes)
            for i, v in enumerate(lvl.removed.tolist()):
                dv = dist[v]
                if not np.isfinite(dv):
                    continue
                for (u, wu) in lvl.adj[i]:
                    if dv + wu < dist[u]:
                        dist[u] = dv + wu
        # top level: in-memory Dijkstra over the residual graph
        heap = [(float(dist[u]), int(u)) for u in self.top_nodes_ids
                if np.isfinite(dist[u])]
        heapq.heapify(heap)
        self.device.sequential(self.top_bytes)
        while heap:
            d_u, u = heapq.heappop(heap)
            if d_u > dist[u]:
                continue
            for v, wv in self.top_adj[u].items():
                nd = d_u + wv
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        # downward: removed nodes resolve from their (cover) neighbors,
        # one sequential scan per level, highest level first
        for lvl in reversed(self.levels):
            self.device.sequential(lvl.nbytes)
            for i, v in enumerate(lvl.removed.tolist()):
                best = dist[v]
                for (u, wu) in lvl.adj[i]:
                    cand = dist[u] + wu
                    if cand < best:
                        best = cand
                dist[v] = best
        return dist, self.device.reset()
