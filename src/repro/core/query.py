"""HoD query processing (paper §5) as batched, level-synchronous JAX sweeps.

An SSD query runs three phases (paper §5): a *forward search* over ``G_f``,
a *core search* inside ``G_c``, and a *backward search* over ``G_b``.  The
paper's key property — traversal order equals file order, so every phase is
one sequential scan — maps onto TPU as data-independent ``lax.scan`` sweeps
over level-aligned edge chunks:

* **forward**: chunks ascend rank levels; every edge goes strictly up-rank
  and same-rank nodes are never adjacent, so each node's distance is final
  before its out-edges are relaxed (single-pass DAG sweep);
* **core**: one min-plus (tropical) matmul against the precomputed core
  closure (beyond-paper; the paper-faithful iterative/Dijkstra modes are
  kept for validation);
* **backward**: chunks descend rank levels — the paper's heap-free linear
  scan, verbatim.

Queries are *batched over sources* (``dist`` is ``[S, n_pad]``): the
paper's flagship application (closeness estimation, Table 5) issues
hundreds of SSD queries, which here amortize into dense VPU work.

SSSP (paper §6) is answered by one extra *reconstruction sweep*: after
distances are final, every augmented edge ``(u, v, w, assoc)`` with
``dist[u] + w == dist[v]`` scatters its predecessor annotation into
``pred[v]``.  Any matching edge yields a valid shortest-path predecessor,
so duplicate winners are harmless; correctness follows from the arch-path
argument (Theorem 1): the realizing path's last edge is always tight.
"""
from __future__ import annotations

import functools
import heapq
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import shardlib as sl
from ..kernels.edge_relax.ops import relax_bucketed
from .index import HoDIndex, level_buckets

__all__ = ["QueryEngine", "dijkstra_reference"]

INF = jnp.float32(jnp.inf)


def _sweep(dist: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
           w: jnp.ndarray) -> jnp.ndarray:
    """Relax all edge chunks in order: dist[:, dst] <- min(dist[:, src]+w)."""
    if src.shape[0] == 0:
        return dist

    def body(d, blk):
        s, t, ww = blk
        cand = d[:, s] + ww[None, :]
        return d.at[:, t].min(cand), None

    dist, _ = jax.lax.scan(body, dist, (src, dst, w))
    return dist


def _recon_sweep(dist: jnp.ndarray, pred: jnp.ndarray, src: jnp.ndarray,
                 dst: jnp.ndarray, w: jnp.ndarray, assoc: jnp.ndarray,
                 eps: float) -> jnp.ndarray:
    """Predecessor reconstruction: scatter assoc of tight edges (SSSP §6)."""
    if src.shape[0] == 0:
        return pred

    def body(p, blk):
        s, t, ww, a = blk
        cand = dist[:, s] + ww[None, :]
        tgt = dist[:, t]
        matched = jnp.isfinite(cand) & (cand <= tgt + eps * (1.0 + tgt))
        pcand = jnp.where(matched, a[None, :], -1)
        return p.at[:, t].max(pcand), None

    pred, _ = jax.lax.scan(body, pred, (src, dst, w, assoc))
    return pred


def _minplus_blocked(a: jnp.ndarray, b: jnp.ndarray,
                     block_k: int = 256) -> jnp.ndarray:
    """out[s, j] = min_k a[s, k] + b[k, j], accumulated over k blocks."""
    s_dim, k_dim = a.shape
    pad = (-k_dim) % block_k
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)), constant_values=jnp.inf)
        b = jnp.pad(b, ((0, pad), (0, 0)), constant_values=jnp.inf)
    kb = a.shape[1] // block_k
    a_blocks = a.reshape(s_dim, kb, block_k).transpose(1, 0, 2)
    b_blocks = b.reshape(kb, block_k, b.shape[1])

    def body(acc, blk):
        ab, bb = blk
        acc = jnp.minimum(acc, jnp.min(ab[:, :, None] + bb[None, :, :],
                                       axis=1))
        return acc, None

    init = jnp.full((s_dim, b.shape[1]), jnp.inf, a.dtype)
    out, _ = jax.lax.scan(body, init, (a_blocks, b_blocks))
    return out


class QueryEngine:
    """Batched SSD/SSSP execution over a packed :class:`HoDIndex`.

    core_mode:
      * ``"closure"``  — beyond-paper: single tropical matmul (default)
      * ``"bellman"``  — in-JAX iterative min-plus to fixpoint (diameter-
                          bounded), closest in spirit to scanning G_c
      * ``"dijkstra"`` — paper-faithful host-side heap Dijkstra on the core

    With ``use_pallas=True`` the forward/backward sweeps run through the
    fused ``relax_bucketed`` kernel over the per-level ``[M, K]`` bucketed
    layout (DESIGN.md §5), and the core search through the Pallas tropical
    matmul; ``interpret`` (default: auto, on except on real TPUs) selects
    Pallas interpret mode so the same path runs on CPU.
    """

    def __init__(self, index: HoDIndex, core_mode: str = "closure",
                 use_pallas: bool = False, eps: float = 0.0,
                 interpret: Optional[bool] = None, k_cap: int = 16):
        if core_mode not in ("closure", "bellman", "dijkstra"):
            raise ValueError(core_mode)
        if core_mode == "closure" and index.n_core \
                and index.core_closure.shape[0] == 0:
            core_mode = "bellman"   # closure skipped at pack time (big core)
        self.index = index
        self.core_mode = core_mode
        self.use_pallas = use_pallas
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self.eps = float(eps)

        if use_pallas:
            self._f_bkt = [
                (jnp.asarray(b.dst), jnp.asarray(b.src_idx), jnp.asarray(b.w))
                for b in level_buckets(index, forward=True, k_cap=k_cap)]
            self._b_bkt = [
                (jnp.asarray(b.dst), jnp.asarray(b.src_idx), jnp.asarray(b.w))
                for b in level_buckets(index, forward=False, k_cap=k_cap)]
        else:
            self._f_bkt = self._b_bkt = []

        ix = index
        self._f = (jnp.asarray(ix.f_src), jnp.asarray(ix.f_dst),
                   jnp.asarray(ix.f_w))
        self._b = (jnp.asarray(ix.b_src), jnp.asarray(ix.b_dst),
                   jnp.asarray(ix.b_w))
        self._f_assoc = jnp.asarray(ix.f_assoc)
        self._b_assoc = jnp.asarray(ix.b_assoc)
        self._perm = jnp.asarray(ix.perm)
        self._closure = jnp.asarray(ix.core_closure)

        # Dense core adjacency for the paper-faithful Bellman mode.
        c = ix.n_core
        adj = np.full((c, c), np.inf, dtype=np.float32)
        if c:
            np.fill_diagonal(adj, 0.0)
        for cu in range(c):
            lo, hi = ix.core_ptr[cu], ix.core_ptr[cu + 1]
            for cv, wv in zip(ix.core_dst[lo:hi], ix.core_w[lo:hi]):
                adj[cu, cv] = min(adj[cu, cv], wv)
        self._core_adj = jnp.asarray(adj)

        # Core edges as one reconstruction chunk set (permuted global ids).
        if ix.core_dst.shape[0]:
            cu = np.repeat(np.arange(c, dtype=np.int32),
                           np.diff(ix.core_ptr))
            c_src = (cu + ix.n_noncore).astype(np.int32)
            c_dst = (ix.core_dst + ix.n_noncore).astype(np.int32)
            chunk = ix.chunk
            padn = (-c_src.shape[0]) % chunk
            pad_i = np.full(padn, ix.n, np.int32)
            self._c_edges = (
                jnp.asarray(np.concatenate([c_src, pad_i]).reshape(-1, chunk)),
                jnp.asarray(np.concatenate([c_dst, pad_i]).reshape(-1, chunk)),
                jnp.asarray(np.concatenate(
                    [ix.core_w,
                     np.full(padn, np.inf, np.float32)]).reshape(-1, chunk)),
                jnp.asarray(np.concatenate(
                    [ix.core_assoc,
                     np.full(padn, -1, np.int32)]).reshape(-1, chunk)))
        else:
            z_i = jnp.zeros((0, ix.chunk), jnp.int32)
            z_f = jnp.zeros((0, ix.chunk), jnp.float32)
            self._c_edges = (z_i, z_i, z_f, z_i)

        self._ssd_jit = jax.jit(functools.partial(
            self._ssd_impl, core_mode=core_mode), static_argnames=())
        self._sssp_jit = jax.jit(functools.partial(
            self._sssp_impl, core_mode=core_mode))

    # ------------------------------------------------------------------ SSD
    def _sweep_bucketed(self, dist: jnp.ndarray, buckets) -> jnp.ndarray:
        """Level-by-level fused relaxation via the Pallas kernel.

        Within one level the gathered sources and the scattered
        destinations are disjoint (DESIGN.md §3), so gather-then-scatter is
        race-free; rows that split one destination's long in-edge list are
        merged by the scatter-min.
        """
        for (dsts, src_idx, w) in buckets:
            cur = dist[:, dsts]
            new = relax_bucketed(dist, src_idx, w, cur, use_pallas=True,
                                 interpret=self.interpret)
            dist = dist.at[:, dsts].min(new)
        return dist

    def _core_update(self, dist: jnp.ndarray, core_mode: str) -> jnp.ndarray:
        ix = self.index
        c = ix.n_core
        if c == 0:
            return dist
        lo = ix.n_noncore
        dc = jax.lax.dynamic_slice_in_dim(dist, lo, c, axis=1)
        if core_mode == "bellman":
            # Iterate min-plus relaxation to fixpoint — the closest in-JAX
            # analogue of the paper's in-memory core scan. Converges in at
            # most C-1 rounds; real cores settle in a handful.
            def cond(state):
                d, changed, it = state
                return changed & (it < c)

            def body(state):
                d, _, it = state
                nd = jnp.minimum(d, _minplus_blocked(d, self._core_adj))
                return nd, jnp.any(nd < d), it + 1

            dc, _, _ = jax.lax.while_loop(
                cond, body, (dc, jnp.bool_(True), jnp.int32(0)))
        else:  # closure
            if self.use_pallas:
                from ..kernels.tropical_matmul.ops import minplus
                dc = minplus(dc, self._closure, interpret=self.interpret)
            else:
                dc = _minplus_blocked(dc, self._closure)
        return jax.lax.dynamic_update_slice_in_dim(dist, dc, lo, axis=1)

    def _ssd_impl(self, sources_perm: jnp.ndarray,
                  core_mode: str) -> jnp.ndarray:
        ix = self.index
        s = sources_perm.shape[0]
        dist = jnp.full((s, ix.n_pad), INF, jnp.float32)
        dist = dist.at[jnp.arange(s), sources_perm].set(0.0)
        # Sources are embarrassingly parallel: under an active mesh whose
        # rules bind "batch", the [S, n_pad] state shards over devices and
        # every sweep below runs data-parallel (no-op without a mesh).
        dist = sl.shard(dist, "batch", None)
        if self.use_pallas:                            # forward search  (§5.1)
            dist = self._sweep_bucketed(dist, self._f_bkt)
        else:
            dist = _sweep(dist, *self._f)
        if core_mode != "dijkstra":
            dist = self._core_update(dist, core_mode)  # core search     (§5.2)
        if self.use_pallas:                            # backward search (§5.3)
            dist = self._sweep_bucketed(dist, self._b_bkt)
        else:
            dist = _sweep(dist, *self._b)
        return dist

    def _sssp_impl(self, sources_perm: jnp.ndarray, core_mode: str):
        ix = self.index
        dist = self._ssd_impl(sources_perm, core_mode)
        s = sources_perm.shape[0]
        pred = jnp.full((s, ix.n_pad), -1, jnp.int32)
        pred = _recon_sweep(dist, pred, *self._f, self._f_assoc, self.eps)
        pred = _recon_sweep(dist, pred, *self._c_edges[:3],
                            self._c_edges[3], self.eps)
        pred = _recon_sweep(dist, pred, *self._b, self._b_assoc, self.eps)
        return dist, pred

    # ---------------------------------------------------------------- public
    def ssd(self, sources: np.ndarray) -> np.ndarray:
        """Distances from each source to every node, original node order."""
        sources = np.asarray(sources, dtype=np.int32)
        src_perm = self.index.perm[sources]
        if self.core_mode == "dijkstra":
            dist = self._dijkstra_path(src_perm)
        else:
            dist = self._ssd_jit(jnp.asarray(src_perm))
        return np.asarray(dist)[:, self.index.perm]

    def sssp(self, sources: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(dist, pred): pred[v] = node preceding v on a shortest path, -1
        for sources/unreachable. Node ids in original order."""
        sources = np.asarray(sources, dtype=np.int32)
        src_perm = jnp.asarray(self.index.perm[sources])
        dist, pred = self._sssp_jit(src_perm)
        dist = np.asarray(dist)[:, self.index.perm]
        pred = np.asarray(pred)[:, self.index.perm]
        return dist, pred

    def paths(self, sources: np.ndarray, targets: np.ndarray) -> list:
        """Unfold predecessors into explicit node paths (one per source)."""
        dist, pred = self.sssp(sources)
        out = []
        for i, t in enumerate(np.asarray(targets).tolist()):
            if not np.isfinite(dist[i, t]):
                out.append(None)
                continue
            path = [t]
            guard = 0
            while pred[i, path[-1]] >= 0 and guard <= self.index.n:
                path.append(int(pred[i, path[-1]]))
                guard += 1
            out.append(path[::-1])
        return out

    # ----------------------------------------------- paper-faithful Dijkstra
    def _dijkstra_path(self, sources_perm: np.ndarray) -> np.ndarray:
        """Forward sweep (JAX) -> host heap Dijkstra on G_c -> backward
        sweep (JAX): the literal §5 pipeline, used as a validation mode."""
        ix = self.index
        s = sources_perm.shape[0]
        dist = jnp.full((s, ix.n_pad), INF, jnp.float32)
        dist = dist.at[jnp.arange(s), jnp.asarray(sources_perm)].set(0.0)
        dist = np.array(_sweep(dist, *self._f))  # writable host copy

        lo, c = ix.n_noncore, ix.n_core
        for i in range(s):
            dc = dist[i, lo:lo + c].copy()
            heap = [(float(d), int(v)) for v, d in enumerate(dc)
                    if np.isfinite(d)]
            heapq.heapify(heap)
            done = np.zeros(c, dtype=bool)
            while heap:
                d_u, u = heapq.heappop(heap)
                if done[u] or d_u > dc[u]:
                    continue
                done[u] = True
                e0, e1 = ix.core_ptr[u], ix.core_ptr[u + 1]
                for v, wv in zip(ix.core_dst[e0:e1], ix.core_w[e0:e1]):
                    nd = d_u + float(wv)
                    if nd < dc[v]:
                        dc[v] = nd
                        heapq.heappush(heap, (nd, int(v)))
            dist[i, lo:lo + c] = dc
        return np.asarray(_sweep(jnp.asarray(dist), *self._b))


def dijkstra_reference(g, sources) -> np.ndarray:
    """Plain in-memory Dijkstra oracle on the *original* graph."""
    n = g.n
    out = np.full((len(sources), n), np.inf, dtype=np.float64)
    for i, s in enumerate(np.asarray(sources).tolist()):
        dist = out[i]
        dist[s] = 0.0
        heap = [(0.0, s)]
        while heap:
            d_u, u = heapq.heappop(heap)
            if d_u > dist[u]:
                continue
            dsts, ws = g.out_edges(u)
            for v, wv in zip(dsts.tolist(), ws.tolist()):
                nd = d_u + wv
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
    return out
