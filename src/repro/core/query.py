"""HoD query processing (paper §5) as one compiled SweepPlan executor.

An SSD query runs three phases (paper §5): a *forward search* over ``G_f``,
a *core search* inside ``G_c``, and a *backward search* over ``G_b``.  The
paper's key property — traversal order equals file order, so every phase is
one sequential scan — maps onto TPU as ONE ``lax.scan`` over the levels of
a static-shape :class:`~repro.core.index.SweepPlan` (DESIGN.md §5):

* **forward**: plan levels ascend rank; every edge goes strictly up-rank
  and same-rank nodes are never adjacent, so each node's distance is final
  before its out-edges are relaxed (single-pass DAG sweep);
* **core**: one min-plus (tropical) matmul against the precomputed core
  closure (beyond-paper; the paper-faithful iterative/Dijkstra modes are
  kept for validation);
* **backward**: plan levels descend rank — the paper's heap-free linear
  scan, verbatim.

Every plan level is one fused bucketed relaxation (``relax_bucketed`` —
Pallas kernel or jnp fallback, selected per engine, same executor either
way).  Because the plan is padded to ``[L_pad, M_pad, K_fix]``, the scan
body traces ONCE per sweep: trace count is independent of the graph's
level count, and no per-level Python dispatch survives.

Queries are *batched over sources* (``dist`` is ``[S, n_pad]``): the
paper's flagship application (closeness estimation, Table 5) issues
hundreds of SSD queries, which here amortize into dense VPU work.

SSSP (paper §6) rides the SAME executor: after distances are final, each
plan (forward, core, backward) is re-scanned with the reconstruction
level-body — every augmented edge ``(u, v, w, assoc)`` with
``dist[u] + w == dist[v]`` scatters its predecessor annotation into
``pred[v]``.  The assoc slots live in the same plan buckets, so there is
no separate reconstruction layout.  Any matching edge yields a valid
shortest-path predecessor, so duplicate winners are harmless; correctness
follows from the arch-path argument (Theorem 1): the realizing path's
last edge is always tight.
"""
from __future__ import annotations

import functools
import heapq
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import shardlib as sl
from ..kernels.edge_relax.ops import relax_bucketed
from ..obs.trace import span_if
from .index import HoDIndex, SweepPlan, node_levels, plan_level_ids

__all__ = ["QueryEngine", "dijkstra_reference"]

INF = jnp.float32(jnp.inf)


def _knn_select(dist: np.ndarray, k: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Host top-k over a ``[S, n]`` distance matrix (original node
    order): the k smallest entries per row, ascending by ``(distance,
    node id)``; unreachable tail padded with ``(-1, +inf)``.  Shared by
    the in-memory and streaming kNN modes so ties break identically."""
    s, n = dist.shape
    nodes = np.full((s, k), -1, np.int32)
    out = np.full((s, k), np.inf, np.float32)
    ids = np.arange(n)
    for i in range(s):
        order = np.lexsort((ids, dist[i]))[:k]
        d = dist[i, order]
        m = int(np.isfinite(d).sum())     # finite entries sort first
        nodes[i, :m] = order[:m]
        out[i, :m] = d[:m]
    return nodes, out


def _plan_to_device(plan: SweepPlan):
    """Device-resident plan arrays, in the executor's scan order."""
    return (jnp.asarray(plan.dst), jnp.asarray(plan.src_idx),
            jnp.asarray(plan.w), jnp.asarray(plan.assoc),
            jnp.asarray(plan.row_valid), jnp.asarray(plan.level_mask))


def _dense_core_adjacency(ix: HoDIndex) -> np.ndarray:
    """Dense [C, C] core adjacency from the raw CSR (scatter, no Python
    loop) — only the paper-faithful Bellman core mode reads it."""
    c = ix.n_core
    adj = np.full((c, c), np.inf, dtype=np.float32)
    if c:
        np.fill_diagonal(adj, 0.0)
        if ix.core_dst.shape[0]:
            cu = np.repeat(np.arange(c, dtype=np.int32),
                           np.diff(ix.core_ptr))
            np.minimum.at(adj, (cu, ix.core_dst),
                          ix.core_w.astype(np.float32))
    return adj


def _minplus_blocked(a: jnp.ndarray, b: jnp.ndarray,
                     block_k: int = 256) -> jnp.ndarray:
    """out[s, j] = min_k a[s, k] + b[k, j], accumulated over k blocks."""
    s_dim, k_dim = a.shape
    pad = (-k_dim) % block_k
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)), constant_values=jnp.inf)
        b = jnp.pad(b, ((0, pad), (0, 0)), constant_values=jnp.inf)
    kb = a.shape[1] // block_k
    a_blocks = a.reshape(s_dim, kb, block_k).transpose(1, 0, 2)
    b_blocks = b.reshape(kb, block_k, b.shape[1])

    def body(acc, blk):
        ab, bb = blk
        acc = jnp.minimum(acc, jnp.min(ab[:, :, None] + bb[None, :, :],
                                       axis=1))
        return acc, None

    init = jnp.full((s_dim, b.shape[1]), jnp.inf, a.dtype)
    out, _ = jax.lax.scan(body, init, (a_blocks, b_blocks))
    return out


class QueryEngine:
    """Batched SSD/SSSP execution over a packed :class:`HoDIndex`.

    core_mode:
      * ``"closure"``  — beyond-paper: single tropical matmul (default)
      * ``"bellman"``  — in-JAX iterative min-plus to fixpoint (diameter-
                          bounded), closest in spirit to scanning G_c
      * ``"dijkstra"`` — paper-faithful host-side heap Dijkstra on the core

    Forward/backward sweeps and SSSP reconstruction all run through the
    single SweepPlan executor (:meth:`_run_plan`): one ``lax.scan`` over
    static-shape plan levels.  ``use_pallas`` picks the level kernel —
    the fused ``relax_bucketed`` Pallas kernel vs. its jnp oracle — and
    the core search's tropical matmul flavor; ``interpret`` (default:
    auto, on except on real TPUs) selects Pallas interpret mode so the
    same path runs on CPU.
    """

    #: Optional :class:`repro.obs.trace.Tracer` (DESIGN.md §11) — set by
    #: the streaming engine / server; ``None`` keeps every hook inert.
    tracer = None

    def __init__(self, index: HoDIndex, core_mode: str = "closure",
                 use_pallas: bool = False, eps: float = 0.0,
                 interpret: Optional[bool] = None, k_cap: int = 16):
        self._init_engine(index, core_mode, use_pallas, eps, interpret)

        index.ensure_plans(k_cap)   # no-op for pack_index/v2+-load indexes
        self._plan_f = _plan_to_device(index.plan_f)
        self._plan_b = _plan_to_device(index.plan_b)
        self._plan_c = _plan_to_device(index.plan_core)

        self._ssd_jit = jax.jit(functools.partial(
            self._ssd_impl, core_mode=self.core_mode), static_argnames=())
        self._sssp_jit = jax.jit(functools.partial(
            self._sssp_impl, core_mode=self.core_mode))
        self._p2p_jit = jax.jit(functools.partial(
            self._p2p_impl, core_mode=self.core_mode))
        self._within_jit = jax.jit(functools.partial(
            self._within_impl, core_mode=self.core_mode))

    def _init_engine(self, index: HoDIndex, core_mode: str,
                     use_pallas: bool, eps: float,
                     interpret: Optional[bool]) -> None:
        """Plan-independent engine state: everything a sweep level body
        or core search needs that is NOT a device-resident SweepPlan.
        Shared with the store-backed streaming engine
        (`repro.storage.stream`), which feeds plan levels from the page
        cache instead of uploading them whole."""
        if core_mode not in ("closure", "bellman", "dijkstra"):
            raise ValueError(core_mode)
        if core_mode == "closure" and index.n_core \
                and index.core_closure.shape[0] == 0:
            core_mode = "bellman"   # closure skipped at pack time (big core)
        self.index = index
        self.core_mode = core_mode
        self.use_pallas = use_pallas
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self.eps = float(eps)

        self._perm = jnp.asarray(index.perm)
        self._closure = jnp.asarray(index.core_closure)
        # Meet-node metadata (DESIGN.md §7): the graph level behind each
        # real plan level, in scan order — derived from the resident
        # chunk arrays, so the store-backed engine gets it without
        # materializing a plan.  P2P / threshold sweeps use it to skip
        # provably-inert levels (everything below the query endpoints).
        self._level_ids_f = plan_level_ids(index, forward=True)
        self._level_ids_b = plan_level_ids(index, forward=False)
        # Dense core adjacency is only materialized for the mode that
        # scans it; closure/dijkstra engines skip the [C, C] build.
        self._core_adj = (jnp.asarray(_dense_core_adjacency(index))
                          if core_mode == "bellman" else None)

    # ------------------------------------------------------- plan executor
    def _run_plan(self, state: jnp.ndarray, plan, level_body,
                  reverse: bool = False) -> jnp.ndarray:
        """THE sweep executor: one ``lax.scan`` over static plan levels.

        ``level_body(state, dst, src_idx, w, assoc, valid) -> state``
        consumes one ``[M_pad(, K_fix)]`` level slice; ``valid`` is the
        row-validity mask with the level mask already folded in, so
        padding rows and padding levels are inert regardless of the body.
        The scan body traces once — O(1) traces per sweep, not O(levels).
        ``reverse=True`` scans the plan's levels back-to-front (the P2P
        backward-label sweep walks ``plan_b`` in ascending rank order —
        DESIGN.md §7) at the same single trace.
        """
        dst, src_idx, w, assoc, row_valid, level_mask = plan
        if dst.shape[0] == 0:
            return state

        def body(carry, lvl):
            l_dst, l_src, l_w, l_assoc, l_valid, l_mask = lvl
            return level_body(carry, l_dst, l_src, l_w, l_assoc,
                              l_valid & l_mask), None

        state, _ = jax.lax.scan(
            body, state, (dst, src_idx, w, assoc, row_valid, level_mask),
            reverse=reverse)
        return state

    def _run_plan_stream(self, state: jnp.ndarray, levels,
                         step, label: str = "") -> jnp.ndarray:
        """Level-granular donate/feed twin of :meth:`_run_plan`.

        ``levels`` yields host-side ``(dst, src_idx, w, assoc, valid)``
        slabs — typically straight off the store's page cache
        (DESIGN.md §6) — and ``step`` is a jitted level function with
        ``state`` donated, so peak plan memory is one level slab, not
        the whole ``[L_pad, M_pad, K_fix]`` envelope.  Every slab of one
        plan shares a shape, so ``step`` traces once per plan — the
        same O(1)-trace property as the ``lax.scan`` executor.  With a
        tracer, each level's step runs inside a ``level.relax`` span
        tagged ``label`` (the plan name).
        """
        tracer = self.tracer
        for lvl, (dst, src_idx, w, assoc, valid) in enumerate(levels):
            with span_if(tracer, "level.relax", plan=label, level=lvl):
                state = step(state, jnp.asarray(dst),
                             jnp.asarray(src_idx), jnp.asarray(w),
                             jnp.asarray(assoc), jnp.asarray(valid))
        return state

    def _relax_level(self, dist, dst, src_idx, w, assoc, valid):
        """Distance relaxation for one level (SSD sweeps, DESIGN.md §5).

        Within one level the gathered sources and the scattered
        destinations are disjoint (DESIGN.md §3), so gather-then-scatter
        is race-free; rows that split one destination's long in-edge list
        are merged by the scatter-min, and sentinel rows scatter into the
        scrap column (which stays +inf forever).
        """
        del assoc
        cur = dist[:, dst]
        new = relax_bucketed(dist, src_idx, w, cur, row_valid=valid,
                             use_pallas=self.use_pallas,
                             interpret=self.interpret)
        return dist.at[:, dst].min(new)

    def _relax_level_rev(self, dlab, dst, src_idx, w, assoc, valid):
        """Reverse relaxation for one level: backward *labels* (P2P mode,
        DESIGN.md §7).  ``dlab[u]`` is the shortest strictly-descending
        distance from ``u`` to the query target, so each backward edge
        ``(x -> v, w)`` is relaxed against its direction:
        ``dlab[x] = min(dlab[x], w + dlab[v])``.  Gather at ``dst`` (the
        level-defining node, final once its level is reached scanning
        ``plan_b`` in reverse = ascending rank), scatter-min into the
        higher-rank ``src_idx`` slots.  Padding slots carry ``+inf``
        weight and sentinel sources — absorbing, as in the forward body.
        """
        del assoc
        cand = dlab[:, dst][:, :, None] + w[None]        # [S, M, K]
        cand = jnp.where(valid[None, :, None], cand, INF)
        return dlab.at[:, src_idx].min(cand)

    def _relax_level_thresh(self, d):
        """:meth:`_relax_level` with the distance-threshold mask folded
        into the scan body (DESIGN.md §7): any label that exceeds ``d``
        is snapped back to ``+inf`` *inside the sweep*, so it can never
        seed further relaxations.  Sound because weights are positive —
        every prefix of a path with total length ``<= d`` is itself
        ``<= d`` — and exactly what lets the streaming engine skip
        whole levels whose source values are all masked."""
        def body(dist, dst, src_idx, w, assoc, valid):
            dist = self._relax_level(dist, dst, src_idx, w, assoc, valid)
            return jnp.where(dist <= d, dist, INF)

        return body

    def _recon_level(self, pred, dist, dst, src_idx, w, assoc, valid):
        """SSSP predecessor reconstruction for one level (§6): scatter
        the assoc of every tight edge, max-merged (-1 = none).  ``dist``
        is an explicit operand (not a closure) so the streaming engine
        can jit this once and feed per-query distances."""
        cand = dist[:, src_idx] + w[None]            # [S, M, K]
        tgt = dist[:, dst]                           # [S, M]
        tight = jnp.isfinite(cand) \
            & (cand <= (tgt + self.eps * (1.0 + tgt))[..., None])
        tight &= valid[None, :, None]
        pcand = jnp.max(jnp.where(tight, assoc[None], -1), axis=-1)
        return pred.at[:, dst].max(pcand)

    def _recon_level_body(self, dist):
        """:meth:`_recon_level` curried into the plan-executor body
        signature (``dist`` closed over, for the all-on-device path)."""
        def body(pred, dst, src_idx, w, assoc, valid):
            return self._recon_level(pred, dist, dst, src_idx, w, assoc,
                                     valid)

        return body

    # ------------------------------------------------------------------ SSD
    def _core_update(self, dist: jnp.ndarray, core_mode: str) -> jnp.ndarray:
        ix = self.index
        c = ix.n_core
        if c == 0:
            return dist
        lo = ix.n_noncore
        dc = jax.lax.dynamic_slice_in_dim(dist, lo, c, axis=1)
        if core_mode == "bellman":
            # Iterate min-plus relaxation to fixpoint — the closest in-JAX
            # analogue of the paper's in-memory core scan. Converges in at
            # most C-1 rounds; real cores settle in a handful.
            def cond(state):
                d, changed, it = state
                return changed & (it < c)

            def body(state):
                d, _, it = state
                nd = jnp.minimum(d, _minplus_blocked(d, self._core_adj))
                return nd, jnp.any(nd < d), it + 1

            dc, _, _ = jax.lax.while_loop(
                cond, body, (dc, jnp.bool_(True), jnp.int32(0)))
        else:  # closure
            if self.use_pallas:
                from ..kernels.tropical_matmul.ops import minplus
                dc = minplus(dc, self._closure, interpret=self.interpret)
            else:
                dc = _minplus_blocked(dc, self._closure)
        return jax.lax.dynamic_update_slice_in_dim(dist, dc, lo, axis=1)

    def _init_state(self, nodes_perm: jnp.ndarray) -> jnp.ndarray:
        """[S, n_pad] all-+inf label state with 0 at each row's node.
        Sources are embarrassingly parallel: under an active mesh whose
        rules bind "batch", the state shards over devices and every
        sweep runs data-parallel (no-op without a mesh)."""
        s = nodes_perm.shape[0]
        state = jnp.full((s, self.index.n_pad), INF, jnp.float32)
        state = state.at[jnp.arange(s), nodes_perm].set(0.0)
        return sl.shard(state, "batch", None)

    def _forward_core(self, sources_perm: jnp.ndarray, core_mode: str,
                      level_body=None) -> jnp.ndarray:
        """Forward search (§5.1) + core search (§5.2): the shared first
        two phases of SSD, P2P, and threshold queries."""
        dist = self._init_state(sources_perm)
        dist = self._run_plan(dist, self._plan_f,
                              level_body or self._relax_level)
        if core_mode != "dijkstra":
            dist = self._core_update(dist, core_mode)
        return dist

    def _ssd_impl(self, sources_perm: jnp.ndarray,
                  core_mode: str) -> jnp.ndarray:
        dist = self._forward_core(sources_perm, core_mode)
        dist = self._run_plan(dist, self._plan_b,       # backward search(§5.3)
                              self._relax_level)
        return dist

    def _p2p_impl(self, sources_perm: jnp.ndarray, targets_perm: jnp.ndarray,
                  core_mode: str) -> jnp.ndarray:
        """Meet-in-the-middle P2P distances (DESIGN.md §7).

        Forward labels of ``s`` (forward sweep + core search — exactly
        the SSD front half) meet backward labels of ``t`` (``plan_b``
        scanned in *reverse* = ascending rank with the reversed level
        body), and ``dist(s, t) = min_m fwd[m] + bwd[m]``: by the arch
        property (Theorem 1) every shortest path ascends, optionally
        crosses the core — folded into ``fwd`` by the core search — and
        descends, so some node ``m`` on it carries both labels."""
        fwd = self._forward_core(sources_perm, core_mode)
        bwd = self._init_state(targets_perm)
        bwd = self._run_plan(bwd, self._plan_b, self._relax_level_rev,
                             reverse=True)
        return jnp.min(fwd + bwd, axis=1)

    def _within_impl(self, sources_perm: jnp.ndarray, d: jnp.ndarray,
                     core_mode: str) -> jnp.ndarray:
        """Distance-threshold SSD (DESIGN.md §7): the full sweep pipeline
        with the ``<= d`` mask applied inside every scan body, so labels
        past the threshold die where they arise instead of being
        filtered at the end — the masked levels are what the streaming
        engine skips reading entirely."""
        body = self._relax_level_thresh(d)
        dist = self._forward_core(sources_perm, core_mode, level_body=body)
        dist = jnp.where(dist <= d, dist, INF)          # mask core output
        return self._run_plan(dist, self._plan_b, body)

    def _sssp_impl(self, sources_perm: jnp.ndarray, core_mode: str):
        ix = self.index
        dist = self._ssd_impl(sources_perm, core_mode)
        s = sources_perm.shape[0]
        pred = jnp.full((s, ix.n_pad), -1, jnp.int32)
        recon = self._recon_level_body(dist)
        # The per-plan reconstruction scatters are max-merges over a
        # fixed `dist`, so the plan order commutes; the store-backed
        # engine exploits this by walking plans in reverse (cache
        # affinity with the distance pass) and stays bit-identical.
        for plan in (self._plan_f, self._plan_c, self._plan_b):
            pred = self._run_plan(pred, plan, recon)
        return dist, pred

    # ---------------------------------------------------------------- public
    def ssd(self, sources: np.ndarray) -> np.ndarray:
        """Distances from each source to every node, original node order."""
        sources = np.asarray(sources, dtype=np.int32)
        src_perm = self.index.perm[sources]
        if self.core_mode == "dijkstra":
            dist = self._dijkstra_path(src_perm)
        else:
            dist = self._ssd_jit(jnp.asarray(src_perm))
        return np.asarray(dist)[:, self.index.perm]

    def sssp(self, sources: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(dist, pred): pred[v] = node preceding v on a shortest path, -1
        for sources/unreachable. Node ids in original order."""
        sources = np.asarray(sources, dtype=np.int32)
        src_perm = jnp.asarray(self.index.perm[sources])
        if self.core_mode == "dijkstra":
            # The host-Dijkstra core search lives outside the jit'd
            # pipeline; run it first, then reconstruction over the same
            # plans (eagerly — this mode is for validation, not serving).
            dist = jnp.asarray(self._dijkstra_path(np.asarray(src_perm)))
            pred = jnp.full((dist.shape[0], self.index.n_pad), -1,
                            jnp.int32)
            recon = self._recon_level_body(dist)
            for plan in (self._plan_f, self._plan_c, self._plan_b):
                pred = self._run_plan(pred, plan, recon)
        else:
            dist, pred = self._sssp_jit(src_perm)
        dist = np.asarray(dist)[:, self.index.perm]
        pred = np.asarray(pred)[:, self.index.perm]
        return dist, pred

    def p2p(self, sources: np.ndarray, targets: np.ndarray) -> np.ndarray:
        """Point-to-point distances ``dist(sources[i], targets[i])``
        (meet-in-the-middle, DESIGN.md §7) — a ``[S]`` float32 vector.

        Exact: bit-identical to ``ssd(sources)[i, targets[i]]`` (the
        meet combine and the backward sweep compose the same (min, +)
        sums over the same augmented edges).
        """
        sources = np.asarray(sources, dtype=np.int32)
        targets = np.asarray(targets, dtype=np.int32)
        src_perm = self.index.perm[sources]
        tgt_perm = self.index.perm[targets]
        if self.core_mode == "dijkstra":
            fwd = self._dijkstra_forward_core(src_perm)
            bwd = self._init_state(jnp.asarray(tgt_perm))
            bwd = self._run_plan(bwd, self._plan_b, self._relax_level_rev,
                                 reverse=True)
            return np.asarray(jnp.min(jnp.asarray(fwd) + bwd, axis=1))
        return np.asarray(self._p2p_jit(jnp.asarray(src_perm),
                                        jnp.asarray(tgt_perm)))

    def ssd_within(self, sources: np.ndarray, d: float) -> np.ndarray:
        """Distance-threshold query (DESIGN.md §7): distances from each
        source in original node order, with every entry beyond ``d``
        masked to ``+inf`` — nodes within the threshold carry exactly
        their SSD distance.  ``d`` is a traced operand, so changing the
        threshold never recompiles."""
        sources = np.asarray(sources, dtype=np.int32)
        src_perm = self.index.perm[sources]
        if self.core_mode == "dijkstra":
            body = self._relax_level_thresh(jnp.float32(d))
            dist = self._init_state(jnp.asarray(src_perm))
            dist = self._run_plan(dist, self._plan_f, body)
            dist = self._core_dijkstra_host(np.array(dist))
            dist = jnp.where(jnp.asarray(dist) <= d, jnp.asarray(dist), INF)
            dist = self._run_plan(dist, self._plan_b, body)
        else:
            dist = self._within_jit(jnp.asarray(src_perm), jnp.float32(d))
        return np.asarray(dist)[:, self.index.perm]

    def knn(self, sources: np.ndarray, k: int
            ) -> Tuple[np.ndarray, np.ndarray]:
        """The ``k`` nearest nodes of each source (DESIGN.md §7):
        ``(nodes, dist)``, each ``[S, k]``, ascending by ``(distance,
        node id)`` with the source itself included at distance 0; rows
        with fewer than ``k`` reachable nodes pad with ``(-1, +inf)``.

        In-memory reference: a full SSD sweep + host top-k selection.
        The streaming engine's bounded-sweep variant
        (`repro.storage.stream`) is bit-identical.
        """
        if not 1 <= k <= self.index.n:
            raise ValueError(f"k must be in [1, {self.index.n}], got {k}")
        return _knn_select(self.ssd(sources), k)

    def paths(self, sources: np.ndarray, targets: np.ndarray) -> list:
        """Unfold predecessors into explicit node paths (one per source)."""
        dist, pred = self.sssp(sources)
        out = []
        for i, t in enumerate(np.asarray(targets).tolist()):
            if not np.isfinite(dist[i, t]):
                out.append(None)
                continue
            path = [t]
            guard = 0
            while pred[i, path[-1]] >= 0 and guard <= self.index.n:
                path.append(int(pred[i, path[-1]]))
                guard += 1
            out.append(path[::-1])
        return out

    # ----------------------------------------------- paper-faithful Dijkstra
    def _core_dijkstra_host(self, dist: np.ndarray) -> np.ndarray:
        """Host heap Dijkstra on the core CSR for every batch row — the
        literal §5.2 in-memory core search.  Mutates and returns the
        writable ``[S, n_pad]`` host array; shared by the in-memory
        validation mode and the store-backed streaming engine."""
        ix = self.index
        lo, c = ix.n_noncore, ix.n_core
        for i in range(dist.shape[0]):
            dc = dist[i, lo:lo + c].copy()
            heap = [(float(d), int(v)) for v, d in enumerate(dc)
                    if np.isfinite(d)]
            heapq.heapify(heap)
            done = np.zeros(c, dtype=bool)
            while heap:
                d_u, u = heapq.heappop(heap)
                if done[u] or d_u > dc[u]:
                    continue
                done[u] = True
                e0, e1 = ix.core_ptr[u], ix.core_ptr[u + 1]
                for v, wv in zip(ix.core_dst[e0:e1], ix.core_w[e0:e1]):
                    nd = d_u + float(wv)
                    if nd < dc[v]:
                        dc[v] = nd
                        heapq.heappush(heap, (nd, int(v)))
            dist[i, lo:lo + c] = dc
        return dist

    def _dijkstra_forward_core(self, sources_perm: np.ndarray) -> np.ndarray:
        """Forward plan sweep (JAX) -> host heap Dijkstra on G_c: the
        shared front half of the paper-faithful SSD and P2P pipelines."""
        dist = self._init_state(jnp.asarray(sources_perm))
        dist = np.array(self._run_plan(dist, self._plan_f,
                                       self._relax_level))  # writable copy
        return self._core_dijkstra_host(dist)

    def _dijkstra_path(self, sources_perm: np.ndarray) -> np.ndarray:
        """Forward plan sweep (JAX) -> host heap Dijkstra on G_c ->
        backward plan sweep (JAX): the literal §5 pipeline, used as a
        validation mode."""
        dist = self._dijkstra_forward_core(sources_perm)
        return np.asarray(self._run_plan(jnp.asarray(dist), self._plan_b,
                                         self._relax_level))


def dijkstra_reference(g, sources) -> np.ndarray:
    """Plain in-memory Dijkstra oracle on the *original* graph."""
    n = g.n
    out = np.full((len(sources), n), np.inf, dtype=np.float64)
    for i, s in enumerate(np.asarray(sources).tolist()):
        dist = out[i]
        dist[s] = 0.0
        heap = [(0.0, s)]
        while heap:
            d_u, u = heapq.heappop(heap)
            if d_u > dist[u]:
                continue
            dsts, ws = g.out_edges(u)
            for v, wv in zip(dsts.tolist(), ws.tolist()):
                nd = d_u + wv
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
    return out
