"""Closeness-centrality estimation (the paper's flagship application).

Eppstein–Wang [11]: sample ``k = ln n / eps^2`` source nodes, run an SSD
query from each, and estimate every node's *farness* as
``n / (k (n-1)) * sum_i dist(s_i, v)`` (inverted for closeness).  Table 5
of the paper scores methods by total wall time = preprocessing + k queries;
HoD's batched engine answers the k queries in a handful of batched sweeps.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import numpy as np

from .query import QueryEngine

__all__ = ["ClosenessResult", "TopKCloseness", "estimate_closeness",
           "topk_closeness"]


@dataclasses.dataclass
class ClosenessResult:
    closeness: np.ndarray      # [n] estimated closeness per node
    k: int                     # number of sampled sources
    query_seconds: float
    batches: int


def estimate_closeness(engine: QueryEngine, eps: float = 0.1,
                       batch_size: int = 64, seed: int = 0,
                       k_override: Optional[int] = None) -> ClosenessResult:
    n = engine.index.n
    k = k_override if k_override is not None else max(
        1, int(math.ceil(math.log(max(n, 2)) / (eps * eps))))
    rng = np.random.default_rng(seed)
    sources = rng.choice(n, size=min(k, n), replace=False).astype(np.int32)
    k = sources.shape[0]

    t0 = time.perf_counter()
    farness_sum = np.zeros(n, dtype=np.float64)
    batches = 0
    for lo in range(0, k, batch_size):
        batch = sources[lo:lo + batch_size]
        if batch.shape[0] < batch_size:  # keep one compiled shape
            batch = np.pad(batch, (0, batch_size - batch.shape[0]),
                           mode="edge")
        d = engine.ssd(batch)[:len(sources[lo:lo + batch_size]), :n]
        d = np.where(np.isfinite(d), d, 0.0)  # WCC assumption (paper §7.1)
        farness_sum += d.sum(axis=0)
        batches += 1
    dt = time.perf_counter() - t0

    denom = farness_sum * (n / (k * max(n - 1, 1)))
    with np.errstate(divide="ignore"):
        closeness = np.where(denom > 0, 1.0 / denom, 0.0)
    return ClosenessResult(closeness=closeness, k=k, query_seconds=dt,
                           batches=batches)


@dataclasses.dataclass
class TopKCloseness:
    """The ``k`` most-central candidates by *exact* (out-)closeness."""

    nodes: np.ndarray          # [k] node ids, best first
    closeness: np.ndarray      # [k] (n-1) / farness per node
    farness: np.ndarray        # [k] sum of finite out-distances
    k: int
    query_seconds: float
    batches: int
    pruned: int                # candidates abandoned mid-sweep (bounded
    #                            engines only; 0 for full-sweep engines)


def topk_closeness(engine: QueryEngine, k: int,
                   candidates: Optional[np.ndarray] = None,
                   batch_size: int = 32, seed: int = 0) -> TopKCloseness:
    """Exact top-``k`` closeness over a candidate set (DESIGN.md §7).

    Each candidate's *farness* is the sum of its finite out-distances
    (the same WCC convention as :func:`estimate_closeness` — unreachable
    nodes contribute 0); closeness is ``(n-1) / farness`` and top-k
    means the ``k`` smallest farness values, node id breaking ties.

    Candidates run through the engine in fixed-shape batches.  When the
    engine exposes ``ssd_bounded`` (the store-backed streaming engine),
    each batch's sweep carries the current k-th best farness as an
    abandon threshold: the backward sweep finalizes nodes level by
    level, so a batch whose every row's partial farness sum already
    exceeds the threshold stops reading plan levels — real I/O saved,
    identical answers (a partial sum of nonnegative distances is a
    lower bound on the total).  Candidates are visited in a seeded
    random order so early batches seed a tight threshold regardless of
    how the candidate list was sorted.
    """
    n = engine.index.n
    cand = (np.arange(n, dtype=np.int32) if candidates is None
            else np.asarray(candidates, dtype=np.int32))
    if not 1 <= k <= cand.shape[0]:
        raise ValueError(f"k={k} out of range for {cand.shape[0]} "
                         "candidates")
    order = np.random.default_rng(seed).permutation(cand.shape[0])
    cand = cand[order]
    bounded = getattr(engine, "ssd_bounded", None)

    t0 = time.perf_counter()
    completed: list = []       # (farness, node) for fully-swept candidates
    threshold = math.inf       # current k-th best farness
    batches = pruned = 0
    for lo in range(0, cand.shape[0], batch_size):
        batch = cand[lo:lo + batch_size]
        real = batch.shape[0]
        if real < batch_size:  # keep one compiled shape
            batch = np.pad(batch, (0, batch_size - real), mode="edge")
        if bounded is not None and math.isfinite(threshold):
            dist, done = bounded(batch, threshold)
        else:
            dist, done = engine.ssd(batch), True
        batches += 1
        if not done:
            pruned += real
            continue
        d = dist[:real, :n]
        far = np.where(np.isfinite(d), d, 0.0).sum(axis=1)
        completed.extend(zip(far.tolist(), batch[:real].tolist()))
        completed.sort()
        if len(completed) >= k:
            threshold = completed[k - 1][0]
    dt = time.perf_counter() - t0

    top = completed[:k]
    far = np.array([f for f, _ in top])
    with np.errstate(divide="ignore"):
        clo = np.where(far > 0, (n - 1) / far, 0.0)
    return TopKCloseness(
        nodes=np.array([v for _, v in top], dtype=np.int32),
        closeness=clo, farness=far, k=k, query_seconds=dt,
        batches=batches, pruned=pruned)
