"""Closeness-centrality estimation (the paper's flagship application).

Eppstein–Wang [11]: sample ``k = ln n / eps^2`` source nodes, run an SSD
query from each, and estimate every node's *farness* as
``n / (k (n-1)) * sum_i dist(s_i, v)`` (inverted for closeness).  Table 5
of the paper scores methods by total wall time = preprocessing + k queries;
HoD's batched engine answers the k queries in a handful of batched sweeps.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import numpy as np

from .query import QueryEngine

__all__ = ["ClosenessResult", "estimate_closeness"]


@dataclasses.dataclass
class ClosenessResult:
    closeness: np.ndarray      # [n] estimated closeness per node
    k: int                     # number of sampled sources
    query_seconds: float
    batches: int


def estimate_closeness(engine: QueryEngine, eps: float = 0.1,
                       batch_size: int = 64, seed: int = 0,
                       k_override: Optional[int] = None) -> ClosenessResult:
    n = engine.index.n
    k = k_override if k_override is not None else max(
        1, int(math.ceil(math.log(max(n, 2)) / (eps * eps))))
    rng = np.random.default_rng(seed)
    sources = rng.choice(n, size=min(k, n), replace=False).astype(np.int32)
    k = sources.shape[0]

    t0 = time.perf_counter()
    farness_sum = np.zeros(n, dtype=np.float64)
    batches = 0
    for lo in range(0, k, batch_size):
        batch = sources[lo:lo + batch_size]
        if batch.shape[0] < batch_size:  # keep one compiled shape
            batch = np.pad(batch, (0, batch_size - batch.shape[0]),
                           mode="edge")
        d = engine.ssd(batch)[:len(sources[lo:lo + batch_size]), :n]
        d = np.where(np.isfinite(d), d, 0.0)  # WCC assumption (paper §7.1)
        farness_sum += d.sum(axis=0)
        batches += 1
    dt = time.perf_counter() - t0

    denom = farness_sum * (n / (k * max(n - 1, 1)))
    with np.errstate(divide="ignore"):
        closeness = np.where(denom > 0, 1.0 / denom, 0.0)
    return ClosenessResult(closeness=closeness, k=k, query_seconds=dt,
                           batches=batches)
