"""Vectorized HoD preprocessing — the paper's sort-merge, done in numpy.

Semantically equivalent to :mod:`repro.core.build` (same §4 algorithm,
same invariants, same BuildResult contract) but every per-edge loop is
replaced by array ops, which is *more* faithful to the paper than the
dict-based reference: the paper's preprocessing is explicitly an
external-memory **sort-merge over edge triplets**, and ``np.lexsort`` is
that sort.  ~50-100× faster in this container; the reference
implementation is kept for differential testing.

Differences (documented, correctness-neutral):
* independent-set selection uses one Luby round over the candidate-induced
  subgraph (random priorities, local minima win) instead of the reference's
  sequential greedy scan — still an independent set, so the §4.2 "never
  remove two adjacent nodes" invariant holds; the paper does not specify
  tie-breaking.
* the two-hop baseline sample is drawn fully vectorized (edge-endpoint
  sampling ≙ degree-proportional node sampling, as §4.3 prescribes).
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

from .build import BuildConfig, BuildResult, BuildStats, TRIPLET_BYTES
from .graph import Digraph
from .io_sim import BlockDevice

__all__ = ["build_hod_fast"]


def _dedup_min(src, dst, w, assoc):
    """Keep the shortest copy of every (src, dst) edge."""
    if src.size == 0:
        return src, dst, w, assoc
    order = np.lexsort((w, dst, src))
    src, dst, w, assoc = src[order], dst[order], w[order], assoc[order]
    first = np.ones(src.size, bool)
    first[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
    return src[first], dst[first], w[first], assoc[first]


def _scores_vectorized(n, src, dst, alive):
    """Eq. 1 scores for every alive node (exact, including intersections).

    |B_in ∩ B_out|(v) = number of neighbors u with edges in both
    directions — counted by canonical-pair grouping.
    """
    out_deg = np.bincount(src, minlength=n)
    in_deg = np.bincount(dst, minlength=n)
    a = np.minimum(src, dst)
    b = np.maximum(src, dst)
    fwd = src < dst
    key = a.astype(np.int64) * n + b
    order = np.argsort(key, kind="stable")
    k_s = key[order]
    f_s = fwd[order]
    grp = np.ones(k_s.size, bool)
    if k_s.size:
        grp[1:] = k_s[1:] != k_s[:-1]
    gid = np.cumsum(grp) - 1
    n_grp = gid[-1] + 1 if k_s.size else 0
    has_f = np.zeros(n_grp, bool)
    has_b = np.zeros(n_grp, bool)
    np.logical_or.at(has_f, gid, f_s)
    np.logical_or.at(has_b, gid, ~f_s)
    bidir = has_f & has_b
    # endpoints of each group
    firsts = np.flatnonzero(grp)
    ga = (k_s[firsts] // n).astype(np.int64)
    gb = (k_s[firsts] % n).astype(np.int64)
    inter = np.zeros(n, np.int64)
    np.add.at(inter, ga[bidir], 1)
    np.add.at(inter, gb[bidir], 1)
    s = in_deg * (out_deg - inter) + out_deg * (in_deg - inter)
    return np.where(alive, s, np.iinfo(np.int64).max)


def _luby_select(n, src, dst, cand_mask, rng):
    """One Luby round: candidates that beat every candidate neighbor."""
    pri = rng.permutation(n)
    both = cand_mask[src] & cand_mask[dst]
    s, d = src[both], dst[both]
    best = np.full(n, n + 1, np.int64)
    np.minimum.at(best, s, pri[d])
    np.minimum.at(best, d, pri[s])
    sel = cand_mask & (pri < best)
    return np.flatnonzero(sel)


def _cross_products(sel, in_ptr, in_src, in_w, in_assoc,
                    out_ptr, out_dst, out_w, out_assoc):
    """All (incoming u, outgoing w) pairs through each selected node —
    vectorized cross-product expansion."""
    p = (in_ptr[sel + 1] - in_ptr[sel]).astype(np.int64)
    q = (out_ptr[sel + 1] - out_ptr[sel]).astype(np.int64)
    total = p * q
    keep = total > 0
    sel, p, q, total = sel[keep], p[keep], q[keep], total[keep]
    if sel.size == 0:
        z = np.zeros(0, np.int64)
        return z, z, np.zeros(0, np.float64), np.zeros(0, np.int64), 0
    starts = np.concatenate([[0], np.cumsum(total)[:-1]])
    k = np.arange(int(total.sum()), dtype=np.int64)
    vid = np.repeat(np.arange(sel.size), total)
    local = k - starts[vid]
    i_in = local // q[vid]
    i_out = local % q[vid]
    in_pos = in_ptr[sel][vid] + i_in
    out_pos = out_ptr[sel][vid] + i_out
    u = in_src[in_pos]
    wnode = out_dst[out_pos]
    length = in_w[in_pos] + out_w[out_pos]
    assoc = out_assoc[out_pos]
    ok = u != wnode
    return u[ok], wnode[ok], length[ok], assoc[ok], int(total.sum())


def build_hod_fast(g: Digraph, cfg: Optional[BuildConfig] = None,
                   device: Optional[BlockDevice] = None) -> BuildResult:
    cfg = cfg or BuildConfig()
    device = device or BlockDevice()
    rng = np.random.default_rng(cfg.seed)
    t0 = time.perf_counter()

    n = g.n
    src, dst, w = g.edge_list()
    assoc = src.copy()                       # §6: original edges carry src
    src = src.astype(np.int64)
    dst = dst.astype(np.int64)
    device.sequential(src.size * TRIPLET_BYTES * 2)

    alive = np.ones(n, bool)
    rank = np.zeros(n, np.int64)
    removal_order: List[int] = []
    level_sizes: List[int] = []
    f_store: List[Tuple] = []                # per round: removed out-edges
    b_store: List[Tuple] = []
    stats = BuildStats()
    rounds = 0
    m_min_seen = src.size

    while rounds < cfg.max_rounds:
        m_alive = src.size
        n_alive = int(alive.sum())
        m_min_seen = min(m_min_seen, m_alive)
        if m_alive > cfg.fill_stop_ratio * max(m_min_seen, 1):
            break  # fill-in dominates: survivors become the core
        core_fits = (n_alive <= cfg.max_core_nodes
                     and m_alive <= cfg.max_core_edges)
        if n_alive == 0:
            break

        # CSR / CSC of the current reduced graph
        o_order = np.argsort(src, kind="stable")
        o_src, o_dst = src[o_order], dst[o_order]
        o_w, o_assoc = w[o_order], assoc[o_order]
        out_ptr = np.zeros(n + 1, np.int64)
        np.add.at(out_ptr, o_src + 1, 1)
        np.cumsum(out_ptr, out=out_ptr)
        i_order = np.argsort(dst, kind="stable")
        i_dst, i_src = dst[i_order], src[i_order]
        i_w, i_assoc = w[i_order], assoc[i_order]
        in_ptr = np.zeros(n + 1, np.int64)
        np.add.at(in_ptr, i_dst + 1, 1)
        np.cumsum(in_ptr, out=in_ptr)

        # ---- §4.2: scores ≤ ~median, Luby independent set --------------
        scores = _scores_vectorized(n, src, dst, alive)
        alive_ids = np.flatnonzero(alive)
        sample = (alive_ids if alive_ids.size <= cfg.median_sample else
                  rng.choice(alive_ids, cfg.median_sample, replace=False))
        thresh = np.median(scores[sample])
        cand_mask = alive & (scores <= thresh)
        selected = _luby_select(n, src, dst, cand_mask, rng)
        if selected.size == 0:
            break

        # ---- §4.1: candidate edges through each selected node ----------
        cu, cw, clen, cassoc, n_cands = _cross_products(
            selected, in_ptr, i_src, i_w, i_assoc,
            out_ptr, o_dst, o_w, o_assoc)
        stats.candidates_generated += n_cands
        # shortest candidate per (u, w)
        cu, cw, clen, cassoc = _dedup_min(cu, cw, clen, cassoc)

        # ---- §4.3: baseline edges --------------------------------------
        sel_mask = np.zeros(n, bool)
        sel_mask[selected] = True
        retained_edge = ~(sel_mask[src] | sel_mask[dst])
        bu = src[retained_edge]
        bw_ = dst[retained_edge]
        blen = w[retained_edge]
        n_base = min(cfg.baseline_factor * max(1, cu.size),
                     cfg.max_baseline_per_round)
        if n_base and m_alive:
            # degree-proportional mid sampling == random edge endpoint
            eidx = rng.integers(0, m_alive, n_base)
            pick_src = rng.random(n_base) < 0.5
            mids = np.where(pick_src, src[eidx], dst[eidx])
            ok = ~sel_mask[mids] & alive[mids]
            mids = mids[ok]
            p = (in_ptr[mids + 1] - in_ptr[mids])
            q = (out_ptr[mids + 1] - out_ptr[mids])
            ok2 = (p > 0) & (q > 0)
            mids, p, q = mids[ok2], p[ok2], q[ok2]
            if mids.size:
                ri = in_ptr[mids] + (rng.random(mids.size) * p).astype(
                    np.int64)
                ro = out_ptr[mids] + (rng.random(mids.size) * q).astype(
                    np.int64)
                uu, ww_ = i_src[ri], o_dst[ro]
                ll = i_w[ri] + o_w[ro]
                ok3 = (~sel_mask[uu]) & (~sel_mask[ww_]) & (uu != ww_)
                bu = np.concatenate([bu, uu[ok3]])
                bw_ = np.concatenate([bw_, ww_[ok3]])
                blen = np.concatenate([blen, ll[ok3]])
                stats.baselines_sampled += int(ok3.sum())

        # ---- §4.1 sort-merge: drop candidates beaten by a baseline -----
        device.external_sort(2 * (cu.size + bu.size) * TRIPLET_BYTES,
                             mem_bytes=64 << 20)
        if cu.size:
            all_u = np.concatenate([cu, bu])
            all_w = np.concatenate([cw, bw_])
            all_l = np.concatenate([clen, blen])
            is_cand = np.zeros(all_u.size, bool)
            is_cand[: cu.size] = True
            cand_row = np.full(all_u.size, -1, np.int64)
            cand_row[: cu.size] = np.arange(cu.size)
            order = np.lexsort((is_cand, all_l, all_w, all_u))
            su, sw = all_u[order], all_w[order]
            first = np.ones(su.size, bool)
            first[1:] = (su[1:] != su[:-1]) | (sw[1:] != sw[:-1])
            winner_cand = is_cand[order] & first
            keep_rows = cand_row[order][winner_cand]
            scu, scw = cu[keep_rows], cw[keep_rows]
            scl, sca = clen[keep_rows], cassoc[keep_rows]
        else:
            scu = scw = np.zeros(0, np.int64)
            scl = np.zeros(0, np.float64)
            sca = np.zeros(0, np.int64)
        stats.shortcuts_added += scu.size

        # ---- store removed nodes' adjacency (the F_f / F_b files) ------
        rm_out = sel_mask[o_src]
        rm_in = sel_mask[i_dst]
        f_store.append((o_src[rm_out], o_dst[rm_out], o_w[rm_out],
                        o_assoc[rm_out]))
        b_store.append((i_dst[rm_in], i_src[rm_in], i_w[rm_in],
                        i_assoc[rm_in]))
        stats.f_edges += int(rm_out.sum())
        stats.b_edges += int(rm_in.sum())
        device.sequential(int(rm_out.sum() + rm_in.sum()) * TRIPLET_BYTES)

        # ---- delete + install shortcuts ---------------------------------
        alive[selected] = False
        rank[selected] = rounds + 1
        removal_order.extend(np.sort(selected).tolist())
        level_sizes.append(int(selected.size))
        stats.removed += int(selected.size)

        keep_e = ~(sel_mask[src] | sel_mask[dst])
        src = np.concatenate([src[keep_e], scu])
        dst = np.concatenate([dst[keep_e], scw])
        w = np.concatenate([w[keep_e], scl])
        assoc = np.concatenate([assoc[keep_e], sca])
        src, dst, w, assoc = _dedup_min(src, dst, w, assoc)

        rounds += 1
        removed_frac = selected.size / n_alive
        if core_fits and removed_frac < cfg.min_shrink:
            break

    # ---- assemble BuildResult (same contract as the reference) ---------
    core_nodes = np.flatnonzero(alive).tolist()
    rank[alive] = rounds + 1
    core_edges = [(int(u), int(v), float(ww), int(a))
                  for u, v, ww, a in zip(src, dst, w, assoc)]

    f_adj: List = [None] * n
    b_adj: List = [None] * n
    for (fs, fd, fw, fa) in f_store:
        order = np.argsort(fs, kind="stable")
        fs, fd, fw, fa = fs[order], fd[order], fw[order], fa[order]
        bounds = np.flatnonzero(np.concatenate(
            [[True], fs[1:] != fs[:-1]])) if fs.size else []
        bounds = list(bounds) + [fs.size]
        for bi in range(len(bounds) - 1):
            lo, hi = bounds[bi], bounds[bi + 1]
            f_adj[fs[lo]] = [(int(fd[i]), float(fw[i]), int(fa[i]))
                             for i in range(lo, hi)]
    for (bs, bsrc, bw2, ba) in b_store:
        order = np.argsort(bs, kind="stable")
        bs, bsrc, bw2, ba = bs[order], bsrc[order], bw2[order], ba[order]
        bounds = np.flatnonzero(np.concatenate(
            [[True], bs[1:] != bs[:-1]])) if bs.size else []
        bounds = list(bounds) + [bs.size]
        for bi in range(len(bounds) - 1):
            lo, hi = bounds[bi], bounds[bi + 1]
            b_adj[bs[lo]] = [(int(bsrc[i]), float(bw2[i]), int(ba[i]))
                             for i in range(lo, hi)]
    for v in removal_order:
        if f_adj[v] is None:
            f_adj[v] = []
        if b_adj[v] is None:
            b_adj[v] = []

    stats.rounds = rounds
    stats.core_nodes = len(core_nodes)
    stats.core_edges = len(core_edges)
    stats.build_seconds = time.perf_counter() - t0
    stats.io = device.stats
    return BuildResult(n=n, rank=rank, removal_order=removal_order,
                       level_sizes=level_sizes, f_adj=f_adj, b_adj=b_adj,
                       core_nodes=core_nodes, core_edges=core_edges,
                       stats=stats)
