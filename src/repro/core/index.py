"""HoD index file organization (paper §4.5), packed for TPU sweeps.

The paper stores removed nodes' out-edges in a forward file ``F_f``
(ascending rank order) and in-edges in a backward file ``F_b`` (descending
rank order), so both query scans are sequential.  Here the same invariant —
*file order == traversal order* — becomes *chunk order == scan order*:

* forward edges are grouped by the **rank level of their source** and packed
  into fixed-size chunks that never straddle a level boundary, so a
  ``lax.scan`` over chunks relaxes each node only after its distance is
  final (the level graph is a DAG: every ``F_f``/``F_b`` edge goes strictly
  up-rank, and no two same-rank nodes are adjacent — §4.2);
* backward edges are grouped by the **level of their destination** and laid
  out in descending level order, mirroring the reversed ``F_b`` file;
* the core graph is closed transitively at build time (Floyd–Warshall), so
  the query-time core search is a single min-plus matmul against the
  closure — a beyond-paper optimization; the raw core CSR is kept for the
  paper-faithful iterative modes;
* on top of the chunk arrays, ``pack_index`` builds a :class:`SweepPlan`
  per sweep direction — the padded, static-shape ``[L_pad, M_pad, K_fix]``
  bucketed layout the query executor scans (DESIGN.md §5).  Plans are
  persisted inside the ``.npz`` (format version 2) so an index load never
  re-derives the layout; version-1 files rebuild it with a warning.

Padding edges use the sentinel node ``n`` with length +inf: they relax into
a scrap column and can never win a min.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from .build import BuildResult
from .graph import Digraph

__all__ = ["HoDIndex", "LevelBuckets", "SweepPlan", "build_sweep_plan",
           "build_core_plan", "level_buckets", "pack_index",
           "floyd_warshall_closure", "FORMAT_VERSION",
           "scan_cost_bytes", "core_scan_bytes",
           "plan_level_ids", "node_levels"]

INF = np.float32(np.inf)


def scan_cost_bytes(rows: int, edges: int, include_assoc: bool = False,
                    id_itemsize: int = 4, w_itemsize: int = 4) -> int:
    """Compact-payload cost of one sequential sweep over a plan: one dst
    id per real row plus (src, w[, assoc]) per real edge.  THE scan cost
    model — shared by :meth:`SweepPlan.scan_bytes` (from live arrays)
    and ``repro.storage.IndexStore.scan_bytes`` (from persisted
    counts), so the accounting cannot drift between them."""
    per_edge = id_itemsize + w_itemsize \
        + (id_itemsize if include_assoc else 0)
    return rows * id_itemsize + edges * per_edge


def core_scan_bytes(ix: "HoDIndex", core_mode: str) -> int:
    """Bytes one core search reads: the dense closure for
    ``core_mode="closure"``, the raw CSR otherwise — never both."""
    if core_mode == "closure":
        return int(ix.core_closure.nbytes)
    return int(ix.core_ptr.nbytes + ix.core_dst.nbytes + ix.core_w.nbytes)

#: Index layout version.  v1 = chunk arrays only (plans re-derived at
#: load time); v2 = chunk arrays + serialized SweepPlans; v3 = the
#: store generation: same ``.npz`` keys, plus the disk-resident block
#: store (`repro.storage`, :meth:`HoDIndex.save_store`) as the serving
#: format; v4 = the affinity segment layout: level slabs stored
#: compactly (padding rows trimmed) and packed back-to-back at byte
#: granularity so co-accessed level runs share block neighborhoods,
#: plus per-block CRCs (DESIGN.md §6); v5 = compressed block segments:
#: every data block is a ``(codec_id, comp_len, crc)`` frame encoded by
#: a per-block codec (``raw`` / ``delta`` id compression / ``f16``
#: weight narrowing — `repro.storage.codecs`), decompressed on page-
#: cache fill.  v1–v4 ``.npz`` files and v3/v4 ``.seg`` segment files
#: keep loading.
FORMAT_VERSION = 5


@dataclasses.dataclass
class SweepPlan:
    """Padded, static-shape per-level bucketed sweep layout (DESIGN.md §5).

    All arrays share the ``[L_pad, M_pad, K_fix]`` envelope so the query
    executor can run the whole sweep as ONE ``lax.scan`` over the level
    axis — one jit trace regardless of how many levels the graph has.
    Padding is absorbing under (min, +): padding rows/slots point at the
    sentinel column with ``+inf`` weight and ``-1`` assoc, padding levels
    are all-padding rows, and ``row_valid`` / ``level_mask`` make the
    masking explicit for the kernel.
    """

    dst: np.ndarray         # [L_pad, M_pad]         int32, sentinel padding
    src_idx: np.ndarray     # [L_pad, M_pad, K_fix]  int32, sentinel padding
    w: np.ndarray           # [L_pad, M_pad, K_fix]  f32, +inf padding
    assoc: np.ndarray       # [L_pad, M_pad, K_fix]  int32, -1 padding
    row_valid: np.ndarray   # [L_pad, M_pad]         bool, False on padding
    level_mask: np.ndarray  # [L_pad]                bool, False on padding

    @property
    def l_pad(self) -> int:
        return int(self.dst.shape[0])

    @property
    def m_pad(self) -> int:
        return int(self.dst.shape[1])

    @property
    def k_fix(self) -> int:
        return int(self.src_idx.shape[2])

    @property
    def n_real_levels(self) -> int:
        return int(self.level_mask.sum())

    def scan_bytes(self, include_assoc: bool = False) -> int:
        """Modeled sequential-scan footprint of one sweep over this plan:
        the *compact* payload a disk layout would stream — one dst id per
        real row plus (src, w[, assoc]) per real edge.  The static
        padding envelope is a compile-time artifact, not file content,
        so it is not charged (charging it would inflate the paper-
        comparable I/O numbers ~10x on level-skewed graphs)."""
        return scan_cost_bytes(
            rows=int(self.row_valid.sum()),
            edges=int(np.isfinite(self.w).sum()),
            include_assoc=include_assoc,
            id_itemsize=self.src_idx.itemsize,
            w_itemsize=self.w.itemsize)

    def nbytes(self) -> int:
        """In-memory (padded) footprint of the plan arrays."""
        return int(self.dst.nbytes + self.src_idx.nbytes + self.w.nbytes
                   + self.assoc.nbytes + self.row_valid.nbytes
                   + self.level_mask.nbytes)


def _empty_plan(k_fix: int) -> SweepPlan:
    return SweepPlan(
        dst=np.zeros((0, 1), np.int32),
        src_idx=np.zeros((0, 1, k_fix), np.int32),
        w=np.zeros((0, 1, k_fix), np.float32),
        assoc=np.zeros((0, 1, k_fix), np.int32),
        row_valid=np.zeros((0, 1), bool),
        level_mask=np.zeros((0,), bool))


def _bucket_rows(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                 assoc: np.ndarray, k_fix: int, sentinel: int):
    """Bucket one level's edges by destination into padded ``[M, K]`` rows.

    A destination with more than ``k_fix`` in-edges owns ``ceil(indeg/K)``
    rows; splitting is lossless because rows of one destination are merged
    by the executor's scatter-min (scatter-max for assoc reconstruction).
    """
    o = np.argsort(dst, kind="stable")
    s_l, d_l, w_l, a_l = src[o], dst[o], w[o], assoc[o]
    uniq, starts, counts = np.unique(d_l, return_index=True,
                                     return_counts=True)
    rows_per = -(-counts // k_fix)
    row_off = np.concatenate([[0], np.cumsum(rows_per)])
    grp = np.repeat(np.arange(uniq.size), counts)
    pos = np.arange(d_l.size) - np.repeat(starts, counts)
    row, col = row_off[grp] + pos // k_fix, pos % k_fix
    m = int(row_off[-1])
    src_idx = np.full((m, k_fix), sentinel, dtype=np.int32)
    w_bkt = np.full((m, k_fix), INF, dtype=np.float32)
    a_bkt = np.full((m, k_fix), -1, dtype=np.int32)
    src_idx[row, col] = s_l
    w_bkt[row, col] = w_l
    a_bkt[row, col] = a_l
    return (np.repeat(uniq, rows_per).astype(np.int32), src_idx, w_bkt,
            a_bkt)


def _stack_levels(levels, k_fix: int, sentinel: int, m_align: int = 8,
                  l_align: int = 1) -> SweepPlan:
    """Pad per-level ``[M_l, K]`` buckets to a common static envelope."""
    if not levels:
        return _empty_plan(k_fix)
    m_pad = max(d.shape[0] for (d, _, _, _) in levels)
    m_pad = max(m_align, -(-m_pad // m_align) * m_align)
    l_real = len(levels)
    l_pad = -(-l_real // l_align) * l_align
    dst = np.full((l_pad, m_pad), sentinel, np.int32)
    src_idx = np.full((l_pad, m_pad, k_fix), sentinel, np.int32)
    w = np.full((l_pad, m_pad, k_fix), INF, np.float32)
    assoc = np.full((l_pad, m_pad, k_fix), -1, np.int32)
    row_valid = np.zeros((l_pad, m_pad), bool)
    level_mask = np.zeros((l_pad,), bool)
    for i, (d_l, s_l, w_l, a_l) in enumerate(levels):
        m = d_l.shape[0]
        dst[i, :m] = d_l
        src_idx[i, :m] = s_l
        w[i, :m] = w_l
        assoc[i, :m] = a_l
        row_valid[i, :m] = True
        level_mask[i] = True
    return SweepPlan(dst=dst, src_idx=src_idx, w=w, assoc=assoc,
                     row_valid=row_valid, level_mask=level_mask)


def build_sweep_plan(ix: "HoDIndex", forward: bool,
                     k_cap: int = 16) -> SweepPlan:
    """Derive a static-shape :class:`SweepPlan` from the flat chunk arrays.

    The chunk arrays are level-aligned (DESIGN.md §4), so every real
    edge's level is recoverable from its level-defining endpoint: the
    *source* for forward edges, the *destination* for backward edges.
    Levels are emitted in sweep order — ascending for the forward sweep,
    descending for the backward sweep — empty levels are dropped, and the
    survivors are padded to one common ``[M_pad, K_fix]`` rectangle.
    """
    if forward:
        src, dst, w, assoc = ix.f_src, ix.f_dst, ix.f_w, ix.f_assoc
    else:
        src, dst, w, assoc = ix.b_src, ix.b_dst, ix.b_w, ix.b_assoc
    src, dst = src.reshape(-1), dst.reshape(-1)
    w, assoc = w.reshape(-1), assoc.reshape(-1)
    real = np.isfinite(w)
    src, dst, w, assoc = src[real], dst[real], w[real], assoc[real]
    if src.size == 0:
        return _empty_plan(k_cap)
    key = src if forward else dst
    lvl = np.searchsorted(ix.level_ptr, key, side="right") - 1

    levels = []
    order = range(ix.n_levels) if forward else range(ix.n_levels - 1, -1, -1)
    for level in order:
        sel = lvl == level
        if not sel.any():
            continue
        levels.append(_bucket_rows(src[sel], dst[sel], w[sel], assoc[sel],
                                   k_cap, ix.n))
    # l_align > 1 pads the level axis too: padding levels are all-padding
    # rows with level_mask=False, absorbed by the executor's masking.
    return _stack_levels(levels, k_cap, ix.n, l_align=4)


def plan_level_ids(ix: "HoDIndex", forward: bool) -> np.ndarray:
    """Graph level of each *real* plan level, in the plan's scan order.

    ``build_sweep_plan`` drops empty levels, so plan level ``j`` is not
    graph level ``j`` — this recovers the mapping from the (resident)
    chunk arrays without materializing the plan, mirroring
    :func:`build_sweep_plan`'s selection exactly: ascending non-empty
    levels for the forward plan, descending for the backward plan.
    This is the meet-node metadata the point-to-point / threshold query
    modes use to skip provably-inert plan levels (DESIGN.md §7): a P2P
    backward-label sweep for target ``t`` starts at ``t``'s level, a
    forward sweep from ``s`` at ``s``'s level.
    """
    if forward:
        key, w = ix.f_src.reshape(-1), ix.f_w.reshape(-1)
    else:
        key, w = ix.b_dst.reshape(-1), ix.b_w.reshape(-1)
    key = key[np.isfinite(w)]
    if key.size == 0:
        return np.zeros(0, np.int32)
    lvl = np.searchsorted(ix.level_ptr, key, side="right") - 1
    present = np.unique(lvl).astype(np.int32)       # ascending
    return present if forward else present[::-1].copy()


def node_levels(ix: "HoDIndex", perm_ids: np.ndarray) -> np.ndarray:
    """Graph level of each *permuted* node id (core nodes report
    ``n_levels`` — above every removal level)."""
    perm_ids = np.asarray(perm_ids)
    lvl = (np.searchsorted(ix.level_ptr, perm_ids, side="right") - 1)
    return np.where(perm_ids >= ix.n_noncore, ix.n_levels,
                    lvl).astype(np.int32)


def build_core_plan(ix: "HoDIndex", k_cap: int = 16) -> SweepPlan:
    """Bucket the raw core edges (permuted *global* ids) as a one-level
    plan.  Distances are final when SSSP reconstruction runs, so the core
    edges need no level structure — they ride the same executor as one
    extra plan level (DESIGN.md §5)."""
    if ix.core_dst.shape[0] == 0:
        return _empty_plan(k_cap)
    cu = np.repeat(np.arange(ix.n_core, dtype=np.int32),
                   np.diff(ix.core_ptr))
    src = (cu + ix.n_noncore).astype(np.int32)
    dst = (ix.core_dst + ix.n_noncore).astype(np.int32)
    return _stack_levels(
        [_bucket_rows(src, dst, ix.core_w.astype(np.float32),
                      ix.core_assoc, k_cap, ix.n)], k_cap, ix.n)


@dataclasses.dataclass
class HoDIndex:
    """Query-ready HoD index. All arrays numpy; node ids are *permuted* ids
    (removal order first, core last); ``assoc`` values are original ids."""

    n: int                    # original node count
    n_pad: int                # padded node dim (sentinel column + alignment)
    n_noncore: int
    n_core: int
    n_levels: int
    chunk: int
    perm: np.ndarray          # [n] original id -> permuted id
    inv_perm: np.ndarray      # [n] permuted id -> original id
    level_ptr: np.ndarray     # [n_levels+1] permuted-node ranges per level
    rank: np.ndarray          # [n] per original id (1-based; core = L+1)

    # forward sweep chunks: ascending level order  [n_chunks_f, chunk]
    f_src: np.ndarray
    f_dst: np.ndarray
    f_w: np.ndarray
    f_assoc: np.ndarray

    # backward sweep chunks: descending level order  [n_chunks_b, chunk]
    b_src: np.ndarray
    b_dst: np.ndarray
    b_w: np.ndarray
    b_assoc: np.ndarray

    # core graph: dense closure + raw CSR (paper-faithful modes)
    core_closure: np.ndarray  # [C, C] f32, closure[i, j] = dist in G_c
    core_diameter: int        # max hop count of any core shortest path
    core_ptr: np.ndarray      # raw core CSR (core-local ids)
    core_dst: np.ndarray
    core_w: np.ndarray
    core_assoc: np.ndarray    # original-id predecessor annotation

    # static-shape sweep plans (DESIGN.md §5): built by pack_index,
    # serialized since format v2, rebuilt (with a warning) for v1 files
    plan_f: Optional[SweepPlan] = None
    plan_b: Optional[SweepPlan] = None
    plan_core: Optional[SweepPlan] = None
    k_cap: int = 16
    format_version: int = FORMAT_VERSION

    def ensure_plans(self, k_cap: Optional[int] = None) -> "HoDIndex":
        """Build any missing sweep plan in place (no-op when present).

        ``k_cap`` only applies to plans being built; existing plans keep
        the ``K_fix`` they were packed with.
        """
        k = int(k_cap if k_cap is not None else self.k_cap)
        if self.plan_f is None:
            self.plan_f = build_sweep_plan(self, forward=True, k_cap=k)
        if self.plan_b is None:
            self.plan_b = build_sweep_plan(self, forward=False, k_cap=k)
        if self.plan_core is None:
            self.plan_core = build_core_plan(self, k_cap=k)
        return self

    def plan_bytes(self) -> int:
        """In-memory (padded) footprint of the three sweep plans.

        Reported separately from :meth:`index_bytes`: the padding
        envelope is ~10x the real payload on level-skewed graphs and
        would swamp the paper-comparable size accounting.
        """
        plans = (self.plan_f, self.plan_b, self.plan_core)
        return sum(p.nbytes() for p in plans if p is not None)

    def index_bytes(self) -> int:
        """On-'disk' size of the index core content (Table 3 accounting:
        chunk files + core + permutation — the paper-comparable number).
        The v2 file additionally serializes the sweep plans; see
        :meth:`plan_bytes` for their (padded) footprint."""
        arrays = (self.f_src, self.f_dst, self.f_w, self.f_assoc,
                  self.b_src, self.b_dst, self.b_w, self.b_assoc,
                  self.core_closure, self.core_ptr, self.core_dst,
                  self.core_w, self.core_assoc, self.perm, self.level_ptr)
        return int(sum(a.nbytes for a in arrays))

    @property
    def m_aug(self) -> int:
        """Edges in the augmented graph (m' in the paper's complexity)."""
        real_f = int((self.f_w != INF).sum()) if self.f_w.size else 0
        real_b = int((self.b_w != INF).sum()) if self.b_w.size else 0
        return real_f + real_b + int(self.core_dst.shape[0])

    # -- serialization ------------------------------------------------------
    _PLAN_PREFIXES = (("plan_f", "pf"), ("plan_b", "pb"),
                      ("plan_core", "pc"))
    #: the non-plan array roster — the single source of truth shared by
    #: ``save``/``load`` and the block store (`repro.storage.blockfile`),
    #: so a new index array cannot be silently dropped from one path.
    _ARRAY_FIELDS = ("perm", "inv_perm", "level_ptr", "rank",
                     "f_src", "f_dst", "f_w", "f_assoc",
                     "b_src", "b_dst", "b_w", "b_assoc",
                     "core_closure", "core_ptr", "core_dst", "core_w",
                     "core_assoc")

    def resident_arrays(self) -> Dict[str, np.ndarray]:
        """name -> array for every non-plan field (the store's
        always-in-memory tier)."""
        return {k: getattr(self, k) for k in self._ARRAY_FIELDS}

    def _meta_array(self) -> np.ndarray:
        return np.array([self.n, self.n_pad, self.n_noncore, self.n_core,
                         self.n_levels, self.chunk, self.core_diameter],
                        dtype=np.int64)

    @classmethod
    def _from_npz(cls, z) -> "HoDIndex":
        """Construct the plan-less index from an open ``.npz`` mapping
        (shared by :meth:`load` and ``repro.storage.IndexStore``)."""
        meta = z["meta"]
        version = int(z["format_version"]) if "format_version" in z else 1
        return cls(
            n=int(meta[0]), n_pad=int(meta[1]), n_noncore=int(meta[2]),
            n_core=int(meta[3]), n_levels=int(meta[4]), chunk=int(meta[5]),
            core_diameter=int(meta[6]),
            **{k: z[k] for k in cls._ARRAY_FIELDS},
            format_version=version,
            k_cap=int(z["k_cap"]) if "k_cap" in z else 16)

    def save(self, path: str) -> None:
        """Write the monolithic ``.npz`` layout: chunk arrays + sweep
        plans (one blob, fully resident on load).  For the disk-resident
        serving format see :meth:`save_store`."""
        self.ensure_plans()
        plans = {}
        for field, pre in self._PLAN_PREFIXES:
            p: SweepPlan = getattr(self, field)
            plans[f"{pre}_dst"] = p.dst
            plans[f"{pre}_src"] = p.src_idx
            plans[f"{pre}_w"] = p.w
            plans[f"{pre}_assoc"] = p.assoc
            plans[f"{pre}_valid"] = p.row_valid
            plans[f"{pre}_mask"] = p.level_mask
        np.savez_compressed(
            path, meta=self._meta_array(),
            format_version=np.int64(FORMAT_VERSION),
            k_cap=np.int64(self.k_cap),
            **self.resident_arrays(), **plans)

    def save_store(self, path: str, block_bytes: int = 65536,
                   codec: str = "raw") -> None:
        """Write the disk-resident block store (a directory): the small
        resident tier plus one block segment file per sweep plan,
        readable level-by-level without loading the whole index.
        ``codec`` picks the per-block compression (``"raw"`` /
        ``"delta"`` / ``"f16"``) — see `repro.storage.blockfile`,
        `repro.storage.codecs`, and DESIGN.md §6."""
        from ..storage.blockfile import save_store
        save_store(self, path, block_bytes=block_bytes, codec=codec)

    @staticmethod
    def load_store(path: str) -> "HoDIndex":
        """Fully materialize a store directory (plans bit-exact).
        Serving should stream via ``repro.storage.IndexStore`` instead."""
        from ..storage.blockfile import load_store
        return load_store(path)

    @staticmethod
    def load(path: str, mmap_mode: Optional[str] = None) -> "HoDIndex":
        """Load a ``.npz`` index (any format version), or a v3 store
        directory.

        The ``NpzFile`` is closed deterministically (context manager) —
        every array is materialized before return.  ``mmap_mode`` is
        passed through to :func:`numpy.load`; note numpy can only
        memory-map uncompressed member arrays, so for the default
        compressed archives it is a no-op.
        """
        import os
        if os.path.isdir(path):
            return HoDIndex.load_store(path)
        with np.load(path, mmap_mode=mmap_mode) as z:
            ix = HoDIndex._from_npz(z)
            has_plans = f"{HoDIndex._PLAN_PREFIXES[0][1]}_dst" in z
            if has_plans:
                for field, pre in HoDIndex._PLAN_PREFIXES:
                    setattr(ix, field, SweepPlan(
                        dst=z[f"{pre}_dst"], src_idx=z[f"{pre}_src"],
                        w=z[f"{pre}_w"], assoc=z[f"{pre}_assoc"],
                        row_valid=z[f"{pre}_valid"],
                        level_mask=z[f"{pre}_mask"]))
        if not has_plans:
            warnings.warn(
                f"{path}: old-format (v{ix.format_version}) HoD index "
                "without sweep plans — rebuilding the SweepPlan layout on "
                "the fly; re-save the index to persist it.", stacklevel=2)
            ix.ensure_plans()
        return ix


@dataclasses.dataclass
class LevelBuckets:
    """One sweep level in the bucketed ``[M, K]`` kernel layout (DESIGN.md §5).

    Each of the level's destination nodes owns ``ceil(indeg / K)`` rows of
    ``K`` padded in-edge slots; rows of one destination are combined by the
    scatter-min, so splitting long in-edge lists across rows is lossless.
    Padding slots point at the sentinel column with ``+inf`` weight —
    absorbing under (min, +).
    """

    dst: np.ndarray      # [M]    permuted destination node of each row
    src_idx: np.ndarray  # [M, K] permuted source node per in-edge slot
    w: np.ndarray        # [M, K] edge lengths, +inf in padding slots


def level_buckets(ix: "HoDIndex", forward: bool,
                  k_cap: int = 16) -> List[LevelBuckets]:
    """Legacy compat path: per-level ragged-M bucket list (fixed ``K``).

    Superseded by :class:`SweepPlan` for query execution, kept for tools
    that want the un-padded per-level layout.  ``K`` is always exactly
    ``k_cap`` (not ``min(max indegree, k_cap)``), so kernel shapes are
    uniform across levels — only the row count ``M`` varies.
    """
    if forward:
        src, dst, w = ix.f_src, ix.f_dst, ix.f_w
    else:
        src, dst, w = ix.b_src, ix.b_dst, ix.b_w
    src, dst, w = src.reshape(-1), dst.reshape(-1), w.reshape(-1)
    real = np.isfinite(w)
    src, dst, w = src[real], dst[real], w[real]
    if src.size == 0:
        return []
    key = src if forward else dst
    lvl = np.searchsorted(ix.level_ptr, key, side="right") - 1

    out: List[LevelBuckets] = []
    order = range(ix.n_levels) if forward else range(ix.n_levels - 1, -1, -1)
    for level in order:
        sel = lvl == level
        if not sel.any():
            continue
        d_rows, src_idx, w_bkt, _ = _bucket_rows(
            src[sel], dst[sel], w[sel],
            np.full(int(sel.sum()), -1, np.int32), k_cap, ix.n)
        out.append(LevelBuckets(dst=d_rows, src_idx=src_idx, w=w_bkt))
    return out


def _pack_chunks(levels: List[Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]],
                 chunk: int, sentinel: int):
    """Pad each level's edge list to a chunk multiple and stack.

    Level-aligned chunking is the correctness lynchpin: a chunk never mixes
    two levels, so gathers inside a chunk only read already-final rows.
    """
    srcs, dsts, ws, assocs = [], [], [], []
    for (s, d, w, a) in levels:
        if s.size == 0:
            continue
        pad = (-s.size) % chunk
        srcs.append(np.concatenate(
            [s, np.full(pad, sentinel, dtype=np.int32)]))
        dsts.append(np.concatenate(
            [d, np.full(pad, sentinel, dtype=np.int32)]))
        ws.append(np.concatenate([w, np.full(pad, INF, dtype=np.float32)]))
        assocs.append(np.concatenate([a, np.full(pad, -1, dtype=np.int32)]))
    if not srcs:
        z_i = np.zeros((0, chunk), dtype=np.int32)
        z_f = np.zeros((0, chunk), dtype=np.float32)
        return z_i, z_i.copy(), z_f, z_i.copy()
    return (np.concatenate(srcs).reshape(-1, chunk),
            np.concatenate(dsts).reshape(-1, chunk),
            np.concatenate(ws).reshape(-1, chunk).astype(np.float32),
            np.concatenate(assocs).reshape(-1, chunk))


def floyd_warshall_closure(adj: np.ndarray) -> Tuple[np.ndarray, int]:
    """All-pairs min-plus closure of the (small, memory-resident) core.

    Beyond-paper: the paper runs Dijkstra inside the core per query; closing
    the core once at build time turns every query's core search into one
    tropical matmul.  Returns (closure, hop-diameter bound).
    """
    import jax
    import jax.numpy as jnp

    c = adj.shape[0]
    if c == 0:
        return adj.astype(np.float32), 0

    def body(k, d):
        # Classic FW pivot step, O(C^2) memory (no C^3 intermediate).
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # [1, C]
        col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # [C, 1]
        return jnp.minimum(d, col + row)

    if c <= 4096:
        closure = jax.lax.fori_loop(0, c, body,
                                    jnp.asarray(adj, dtype=jnp.float32))
        closure = np.asarray(closure)
    else:  # host fallback for very large cores
        closure = adj.astype(np.float32).copy()
        for k in range(c):
            np.minimum(closure, closure[:, k:k + 1] + closure[k:k + 1, :],
                       out=closure)
    # Hop diameter of the core (for the paper-faithful Bellman–Ford mode);
    # the exact BFS bound costs O(C³·diam) — only worth it for small cores.
    hops = _hop_diameter(adj) if c <= 512 else c
    return closure, hops


def _hop_diameter(adj: np.ndarray) -> int:
    c = adj.shape[0]
    if c == 0:
        return 0
    finite = (np.isfinite(adj) & ~np.eye(c, dtype=bool)).astype(np.float32)
    reach = np.eye(c, dtype=bool)
    frontier = reach.copy()
    hops = 0
    for _ in range(c):
        nxt = ((frontier.astype(np.float32) @ finite) > 0) & ~reach
        if not nxt.any():
            break
        reach |= nxt
        frontier = nxt
        hops += 1
    return max(hops, 1)


def pack_index(g: Digraph, result: BuildResult, chunk: int = 2048,
               node_align: int = 1, closure_limit: int = 2048,
               k_cap: int = 16) -> HoDIndex:
    """Convert a :class:`BuildResult` into the packed, query-ready layout.

    The all-pairs core closure (beyond-paper fast path) is only computed
    when the core has ≤ ``closure_limit`` nodes — larger cores (scale-free
    fill-in) fall back to the paper-faithful iterative core search; the
    stored closure is then a 0×0 placeholder and ``QueryEngine`` defaults
    to ``core_mode="bellman"``.

    The static-shape sweep plans (forward, backward, core-reconstruction —
    DESIGN.md §5) are built here once, with bucket width ``k_cap``, and
    persisted by :meth:`HoDIndex.save`.
    """
    n = result.n
    order = list(result.removal_order)
    core_sorted = sorted(result.core_nodes)
    n_noncore = len(order)
    n_core = len(core_sorted)
    assert n_noncore + n_core == n

    perm = np.empty(n, dtype=np.int32)
    for new_id, old_id in enumerate(order + core_sorted):
        perm[old_id] = new_id
    inv_perm = np.empty(n, dtype=np.int32)
    inv_perm[perm] = np.arange(n, dtype=np.int32)

    n_levels = len(result.level_sizes)
    level_ptr = np.zeros(n_levels + 1, dtype=np.int64)
    np.cumsum(result.level_sizes, out=level_ptr[1:])

    n_pad = n + 1
    if node_align > 1:
        n_pad = -(-n_pad // node_align) * node_align
    sentinel = n  # scrap column for padding edges

    def _level_edges(adj_of, forward: bool):
        """Collect per-level (src, dst, w, assoc) with permuted endpoints."""
        levels = []
        for lvl in range(n_levels):
            lo, hi = level_ptr[lvl], level_ptr[lvl + 1]
            s_l, d_l, w_l, a_l = [], [], [], []
            for new_v in range(lo, hi):
                old_v = order[new_v]
                for (other, w_e, assoc) in adj_of[old_v]:
                    if forward:       # out-edge: removed node -> higher rank
                        s_l.append(new_v)
                        d_l.append(perm[other])
                    else:             # in-edge: higher rank -> removed node
                        s_l.append(perm[other])
                        d_l.append(new_v)
                    w_l.append(w_e)
                    a_l.append(assoc)
            levels.append((np.asarray(s_l, dtype=np.int32),
                           np.asarray(d_l, dtype=np.int32),
                           np.asarray(w_l, dtype=np.float32),
                           np.asarray(a_l, dtype=np.int32)))
        return levels

    f_levels = _level_edges(result.f_adj, forward=True)
    b_levels = _level_edges(result.b_adj, forward=False)
    b_levels.reverse()  # §4.5: F_b is scanned in descending rank order

    f_src, f_dst, f_w, f_assoc = _pack_chunks(f_levels, chunk, sentinel)
    b_src, b_dst, b_w, b_assoc = _pack_chunks(b_levels, chunk, sentinel)

    # ---- Core graph --------------------------------------------------------
    core_local = {old: i for i, old in enumerate(core_sorted)}
    csr_edges: List[List[Tuple[int, float, int]]] = \
        [[] for _ in range(n_core)]
    with_closure = n_core <= closure_limit
    adj = (np.full((n_core, n_core), INF, dtype=np.float32)
           if with_closure else None)
    if with_closure and n_core:
        np.fill_diagonal(adj, 0.0)
    for (u, v, w_e, assoc) in result.core_edges:
        cu, cv = core_local[u], core_local[v]
        if with_closure and w_e < adj[cu, cv]:
            adj[cu, cv] = w_e
        csr_edges[cu].append((cv, w_e, assoc))

    if with_closure:
        closure, diameter = floyd_warshall_closure(adj)
    else:
        closure = np.zeros((0, 0), np.float32)
        diameter = n_core

    core_ptr = np.zeros(n_core + 1, dtype=np.int64)
    core_dst_l, core_w_l, core_assoc_l = [], [], []
    for cu in range(n_core):
        core_ptr[cu + 1] = core_ptr[cu] + len(csr_edges[cu])
        for (cv, w_e, assoc) in csr_edges[cu]:
            core_dst_l.append(cv)
            core_w_l.append(w_e)
            core_assoc_l.append(assoc)

    ix = HoDIndex(
        n=n, n_pad=int(n_pad), n_noncore=n_noncore, n_core=n_core,
        n_levels=n_levels, chunk=chunk, perm=perm, inv_perm=inv_perm,
        level_ptr=level_ptr, rank=result.rank.astype(np.int32),
        f_src=f_src, f_dst=f_dst, f_w=f_w, f_assoc=f_assoc,
        b_src=b_src, b_dst=b_dst, b_w=b_w, b_assoc=b_assoc,
        core_closure=closure, core_diameter=diameter,
        core_ptr=core_ptr,
        core_dst=np.asarray(core_dst_l, dtype=np.int32),
        core_w=np.asarray(core_w_l, dtype=np.float32),
        core_assoc=np.asarray(core_assoc_l, dtype=np.int32),
        k_cap=int(k_cap))
    return ix.ensure_plans()
