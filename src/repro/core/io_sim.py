"""Block-I/O cost model.

The paper's whole point is the I/O pattern: HoD answers a query with
*sequential scans* (`O((n+m')/B)` I/O) whereas Dijkstra-style methods issue
*random* block reads.  This container has no disk-bound substrate, so we
meter I/O explicitly: every index/baseline codepath routes its "disk"
touches through a :class:`BlockDevice`, and the benchmarks report block
counts and modeled seek/scan time next to measured CPU time.

Modeled device (commodity HDD, matching the paper's 2013 setting):
sequential throughput 120 MB/s, random seek 8 ms, block size 64 KiB.
"""
from __future__ import annotations

import dataclasses

__all__ = ["BlockDevice", "IOStats"]


@dataclasses.dataclass
class IOStats:
    seq_blocks: int = 0
    rand_blocks: int = 0
    bytes_seq: int = 0
    bytes_rand: int = 0

    def modeled_seconds(self, block_bytes: int = 65536,
                        seq_mb_s: float = 120.0,
                        seek_ms: float = 8.0) -> float:
        """Modeled wall time on the reference device.

        Assumptions (commodity 2013 HDD, matching the paper's setting):

        * every access moves whole blocks — ``seq_blocks``/``rand_blocks``
          already count ``ceil(bytes / B)`` per access, so transfer time is
          ``(seq_blocks + rand_blocks) * block_bytes`` at the streaming
          rate (``seq_mb_s``); pass the same ``block_bytes`` the metering
          :class:`BlockDevice` was built with;
        * once the head is positioned, random blocks stream at the same
          rate as sequential ones — randomness costs exactly one full
          ``seek_ms`` per random block, nothing more;
        * no caching, no read-ahead, no overlap of seek and transfer.
        """
        blocks = self.seq_blocks + self.rand_blocks
        seq_t = blocks * block_bytes / (seq_mb_s * 1e6)
        seek_t = self.rand_blocks * seek_ms * 1e-3
        return seq_t + seek_t

    def __add__(self, other: "IOStats") -> "IOStats":
        return IOStats(self.seq_blocks + other.seq_blocks,
                       self.rand_blocks + other.rand_blocks,
                       self.bytes_seq + other.bytes_seq,
                       self.bytes_rand + other.bytes_rand)


class BlockDevice:
    """Accounting wrapper; all sizes in bytes, block size B (paper §2)."""

    def __init__(self, block_bytes: int = 65536):
        self.block_bytes = block_bytes
        self.stats = IOStats()
        self._cursor = -1  # last block touched, for seq/rand classification
        # Observability hook (DESIGN.md §11): called as
        # ``on_access(block_id, nbytes, sequential)`` after each
        # address-aware access.  Must be cheap and must not touch the
        # device — it fires on whichever thread charged the access.
        self.on_access = None

    def _blocks(self, nbytes: int) -> int:
        return max(1, -(-int(nbytes) // self.block_bytes))

    def sequential(self, nbytes: int) -> None:
        """A streaming read/write of nbytes (scan, append, external sort)."""
        b = self._blocks(nbytes)
        self.stats.seq_blocks += b
        self.stats.bytes_seq += int(nbytes)

    def random(self, nbytes: int) -> None:
        """A seek + read of nbytes at an arbitrary offset."""
        b = self._blocks(nbytes)
        self.stats.rand_blocks += b
        self.stats.bytes_rand += int(nbytes)

    def access_block(self, block_id: int, nbytes: int | None = None) -> None:
        """Address-aware access: consecutive block ids count as sequential."""
        nbytes = self.block_bytes if nbytes is None else nbytes
        seq = block_id == self._cursor + 1
        if seq:
            self.sequential(nbytes)
        else:
            self.random(nbytes)
        self._cursor = block_id
        if self.on_access is not None:
            self.on_access(block_id, nbytes, seq)

    def external_sort(self, nbytes: int, mem_bytes: int) -> None:
        """Charge a standard multi-way merge sort: 2 passes if it fits a
        single merge fan-in, else 2·ceil(log_k(N/M)) passes."""
        import math

        if nbytes <= mem_bytes:
            self.sequential(nbytes)  # read once, sort in memory, write once
            self.sequential(nbytes)
            return
        runs = -(-nbytes // mem_bytes)
        fan_in = max(2, mem_bytes // self.block_bytes - 1)
        passes = 1 + max(1, math.ceil(math.log(max(runs, 2), fan_in)))
        self.sequential(2 * passes * nbytes)

    def reset(self) -> IOStats:
        out, self.stats = self.stats, IOStats()
        self._cursor = -1
        return out
