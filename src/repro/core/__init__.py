# The paper's primary contribution: Highways-on-Disk (HoD) — a rank-ordered
# shortcut index whose SSD/SSSP queries are pure linear scans, implemented
# here as batched level-synchronous JAX sweeps (see DESIGN.md).
from .graph import (Digraph, from_edges, gnm_random_digraph,  # noqa: F401
                    power_law_digraph, grid_road_graph, symmetrize,
                    largest_weakly_connected_component)
from .build import BuildConfig, BuildResult, BuildStats, build_hod  # noqa: F401
from .index import (HoDIndex, LevelBuckets, SweepPlan,  # noqa: F401
                    build_core_plan, build_sweep_plan, level_buckets,
                    pack_index)
from .query import QueryEngine, dijkstra_reference  # noqa: F401
from .closeness import estimate_closeness, ClosenessResult  # noqa: F401
