# The paper's primary contribution: Highways-on-Disk (HoD) — a rank-ordered
# shortcut index whose SSD/SSSP queries are pure linear scans, implemented
# here as batched level-synchronous JAX sweeps (see DESIGN.md).
from .build import BuildConfig, BuildResult, BuildStats, build_hod  # noqa: F401
from .closeness import (ClosenessResult, TopKCloseness,  # noqa: F401
                        estimate_closeness, topk_closeness)
from .graph import (Digraph, from_edges, gnm_random_digraph,  # noqa: F401
                    grid_road_graph, largest_weakly_connected_component,
                    power_law_digraph, symmetrize)
from .index import (HoDIndex, LevelBuckets, SweepPlan,  # noqa: F401
                    build_core_plan, build_sweep_plan, level_buckets,
                    pack_index)
from .query import QueryEngine, dijkstra_reference  # noqa: F401
