"""Process-wide metrics: counters, gauges, fixed-bucket histograms
(DESIGN.md §11).

The serving layer needs per-class latency percentiles (the SLO
scheduler's currency) without keeping a per-request list: a
:class:`Histogram` counts observations into *fixed* log-spaced buckets
and reads p50/p95/p99 back by linear interpolation inside the
straddling bucket — O(buckets) memory forever, error bounded by one
bucket's width (the bounds grow by ``2**0.5`` per bucket, so a
percentile is off by at most ~19% of its value; DESIGN.md §11 states
the policy).

A :class:`MetricsRegistry` names the instruments and snapshots them
all as one JSON-able dict stamped with :data:`SCHEMA_VERSION` — the
same version ``benchmarks/run.py`` writes into BENCH_serve.json so
``check_regression.py`` can fail loudly on schema drift instead of
KeyError-ing.  The registry subsumes the ad-hoc ``ServerStats``
arithmetic: every server counter lands here too, plus the derived
rates, so ``--metrics-out`` is the one machine-readable summary of a
serving run.

Zero dependencies, thread-safe (one lock per instrument), and cheap
enough for per-request hot paths: an observe is a bisect + two adds.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["SCHEMA_VERSION", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "REGISTRY", "exp_buckets"]

#: Version of the metrics-snapshot / BENCH row schema.  Bump when a
#: snapshot or bench table changes shape incompatibly;
#: ``check_regression.py`` refuses to compare mismatched versions.
#: v2: BENCH_serve.json gained the ``slo`` table (ISSUE-9).
#: v3: BENCH_serve.json gained the ``fleet`` table (ISSUE-10).
SCHEMA_VERSION = 3


def exp_buckets(lo: float = 0.05, hi: float = 60_000.0,
                factor: float = 2 ** 0.5) -> Tuple[float, ...]:
    """Log-spaced bucket upper bounds covering [lo, hi] (inclusive of
    one bound past ``hi``).  The default spans 50µs–60s in ms units at
    √2 spacing — 42 buckets, good for sub-20% percentile error across
    six decades of latency."""
    if not (lo > 0 and hi > lo and factor > 1):
        raise ValueError("need 0 < lo < hi and factor > 1")
    bounds: List[float] = [lo]
    while bounds[-1] < hi:
        bounds.append(bounds[-1] * factor)
    return tuple(bounds)


#: Default latency bucket bounds, in milliseconds.
LATENCY_BUCKETS_MS = exp_buckets()


class Counter:
    """Monotonic float counter."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins value (queue depth, hit rate, …)."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with percentile read-back.

    ``bounds`` are ascending bucket *upper* bounds; one implicit
    overflow bucket catches everything past the last bound.  No
    per-observation state is kept.
    """

    __slots__ = ("_lock", "bounds", "counts", "count", "total")

    def __init__(self, bounds: Sequence[float] = LATENCY_BUCKETS_MS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or any(b2 <= b1 for b1, b2
                             in zip(bounds, bounds[1:])):
            raise ValueError("bounds must be non-empty and ascending")
        self._lock = threading.Lock()
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # [+overflow]
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.total += v

    def percentile(self, q: float) -> float:
        """Value at quantile ``q`` (0–1), interpolated linearly inside
        the straddling bucket; the overflow bucket reports the last
        bound (a floor — the true value is larger).  0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        with self._lock:
            if self.count == 0:
                return 0.0
            target = q * self.count
            cum = 0.0
            for i, c in enumerate(self.counts):
                if cum + c >= target and c > 0:
                    lo = self.bounds[i - 1] if i > 0 else 0.0
                    if i >= len(self.bounds):
                        return self.bounds[-1]
                    frac = (target - cum) / c
                    return lo + frac * (self.bounds[i] - lo)
                cum += c
            return self.bounds[-1]

    def mean(self) -> float:
        with self._lock:
            return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean(),
                "p50": self.percentile(0.50),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class MetricsRegistry:
    """Named instruments + one-dict JSON snapshot.

    ``counter``/``gauge``/``histogram`` create-or-fetch by name (a
    name that exists with a different type is an error — silent
    shadowing would corrupt the snapshot).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = factory()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} is {type(inst).__name__}, "
                    f"not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(name, Histogram,
                         lambda: Histogram(bounds or LATENCY_BUCKETS_MS))

    def histograms(self, prefix: str = "") -> Dict[str, Histogram]:
        """All histograms whose name starts with ``prefix``."""
        with self._lock:
            return {k: v for k, v in self._instruments.items()
                    if isinstance(v, Histogram) and k.startswith(prefix)}

    def snapshot(self) -> dict:
        """One JSON-able dict of everything, schema-versioned."""
        out = {"schema_version": SCHEMA_VERSION, "counters": {},
               "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._instruments.items())
        for name, inst in items:
            if isinstance(inst, Counter):
                out["counters"][name] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][name] = inst.value
            else:
                h = inst.summary()
                h["bounds"] = list(inst.bounds)
                h["bucket_counts"] = list(inst.counts)
                out["histograms"][name] = h
        return out

    def reset(self) -> None:
        """Zero every instrument in place (server warmup), keeping the
        registered names and histogram bucket bounds."""
        with self._lock:
            items = list(self._instruments.items())
        for _, inst in items:
            if isinstance(inst, (Counter, Gauge)):
                with inst._lock:
                    inst.value = 0.0
            else:
                with inst._lock:
                    inst.counts = [0] * (len(inst.bounds) + 1)
                    inst.count = 0
                    inst.total = 0.0


#: Process-wide default registry (library code that is not handed an
#: explicit registry records here).
REGISTRY = MetricsRegistry()
