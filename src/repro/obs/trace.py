"""Query tracing: nestable spans + instants, Chrome-trace export
(DESIGN.md §11).

The serving stack's whole argument is I/O *attribution* — which reads
a query caused, which it avoided, and how far the pipeline hid the
rest behind compute.  Aggregate counters (``IOStats`` / ``CacheStats``
/ ``PipelineStats``) answer that for a workload; the :class:`Tracer`
answers it for one query: every served request opens a root span
(``query.ssd``, ``query.p2p``, …) whose children cover coalesce-wait,
jit dispatch, and — per streamed level — the submit-side cache
transaction, the io-thread pread, the decode-pool frame decode, and
the query-thread reap/relax.  Exported as Chrome trace-event JSON
(open in https://ui.perfetto.dev) plus a flat JSONL event log.

**Tracks.** Chrome traces group events by thread id, and B/E spans
must nest *per thread*.  Events land on three kinds of tracks:

* the real thread that emitted them (query thread, ``hod-pipe-io``,
  ``hod-pipe-decode_*``) — the default, giving balanced nesting per
  thread and making read/decode/relax **overlap visible** as
  simultaneous spans on different rows of the timeline;
* a named *synthetic* track (``track="submit"`` …) for events whose
  emission point is pipelined but whose *order* is the deterministic
  submit order — ``pipe.submit`` spans and the cache hit/miss/evict
  instants fired inside them.  Keeping these off the query thread's
  track is what makes the query-thread span sequence identical at
  every queue depth (the determinism contract
  ``tests/test_pipeline.py`` locks in);
* retroactive ``"X"`` complete events (:meth:`complete`) for
  durations only measurable after the fact (``coalesce.wait``).

**Stitching.** Work that hops threads carries an explicit span id:
``Tracer.new_id()`` at submit, then every related event (the io
thread's ``level.read``, each decode worker's ``level.decode``, the
reaper's ``level.wait``) repeats it as a ``span``/``parent`` attr —
Perfetto's query view joins them back into one per-level story.

**Overhead contract** (DESIGN.md §11): a ``None`` tracer is the off
switch — every hook site guards with ``if tracer is not None`` (or
:func:`span_if`), so disabled tracing adds one attribute load per
site.  Enabled tracing buffers flat tuples in memory with a lock-free
append (atomic under the GIL) and must stay within 5% of untraced
serving throughput — asserted by the ``latency`` table in
``benchmarks/serve_throughput.py``.  Tracing never changes answers or
counter sequences: hooks only *observe* (asserted bit-identical in
the bench and ``tests/test_obs.py``).
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import nullcontext
from typing import Dict, List, Optional, Tuple

__all__ = ["Tracer", "span_if", "validate_chrome_trace"]


class _Span:
    """Context manager emitting a B/E pair on the tracer."""

    __slots__ = ("_tracer", "name", "track", "attrs")

    def __init__(self, tracer: "Tracer", name: str,
                 track: Optional[str], attrs: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.attrs = attrs

    def __enter__(self) -> "_Span":
        self._tracer._emit("B", self.name, self.track, self.attrs)
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._emit("E", self.name, self.track, None)
        return False


def span_if(tracer: Optional["Tracer"], name: str,
            track: Optional[str] = None, **attrs):
    """``tracer.span(...)`` or an inert context when tracing is off —
    the one-liner hook sites use so disabled tracing stays a no-op."""
    if tracer is None:
        return nullcontext()
    return tracer.span(name, track=track, **attrs)


class Tracer:
    """Append-only trace buffer with span/instant emission.

    Timestamps are ``time.perf_counter_ns`` relative to construction
    (exported as microseconds, the Chrome trace unit).  All methods
    are thread-safe; events record which real thread (or synthetic
    ``track``) emitted them.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # Internal buffer holds flat tuples, not dicts: (ph, name, ts,
        # tkey, tname, attrs, dur).  Appending one object to a list is
        # atomic under the GIL, so the hot path takes no lock and
        # builds no dict — that is what keeps enabled tracing inside
        # the 5% overhead budget; events() materializes dicts.
        self._events: List[tuple] = []
        self._next_id = 0
        self._t0 = time.perf_counter_ns()

    # ------------------------------------------------------------- emission
    def now(self) -> int:
        """Nanoseconds since tracer start (for :meth:`complete`)."""
        return time.perf_counter_ns() - self._t0

    def new_id(self) -> int:
        """Fresh span id for cross-thread stitching (ticket attrs)."""
        with self._lock:
            self._next_id += 1
            return self._next_id

    def _emit(self, ph: str, name: str, track: Optional[str],
              attrs: Optional[dict], ts_ns: Optional[int] = None,
              dur_ns: Optional[int] = None) -> None:
        ts = (time.perf_counter_ns() - self._t0) if ts_ns is None \
            else ts_ns
        if track is None:
            th = threading.current_thread()
            tkey: Tuple = ("thread", th.ident)
            tname = th.name
        else:
            tkey, tname = ("track", track), track
        self._events.append((ph, name, ts, tkey, tname, attrs, dur_ns))

    def span(self, name: str, track: Optional[str] = None,
             **attrs) -> _Span:
        """Nestable span (``with tracer.span("level.relax", level=3):``).
        Spans on one thread/track must nest — that is the Chrome B/E
        contract the validator enforces."""
        return _Span(self, name, track, attrs)

    def instant(self, name: str, track: Optional[str] = None,
                **attrs) -> None:
        """Zero-duration event (cache hit/miss/evict, device access)."""
        self._emit("i", name, track, attrs)

    def complete(self, name: str, start_ns: int,
                 track: Optional[str] = None, **attrs) -> None:
        """Retroactive span: ``start_ns`` from an earlier :meth:`now`
        call, duration until now (``coalesce.wait`` — the wait is only
        known once the batch flushes).  ``"X"`` events carry their own
        duration, so they need no nesting discipline."""
        end = self.now()
        self._emit("X", name, track, attrs, ts_ns=start_ns,
                   dur_ns=max(0, end - start_ns))

    def clear(self) -> None:
        """Drop buffered events (server warmup: compile-time spans must
        not pollute the served trace)."""
        self._events.clear()

    # -------------------------------------------------------------- reading
    def events(self) -> List[dict]:
        """Snapshot of the raw internal events (ns timestamps)."""
        out: List[dict] = []
        for ph, name, ts, tkey, tname, attrs, dur in self._events[:]:
            e = {"ph": ph, "name": name, "ts": ts,
                 "tkey": tkey, "tname": tname}
            if attrs:
                e["args"] = attrs
            if dur is not None:
                e["dur"] = dur
            out.append(e)
        return out

    def sequence(self, where: str) -> List[tuple]:
        """The deterministic shape of one track: ``(ph, name, attrs)``
        tuples for every event whose thread/track name is ``where``,
        timestamps and durations excluded.  This is what the
        cross-depth determinism tests compare — identical queries must
        yield identical sequences at every queue depth."""
        out = []
        for e in self.events():
            if e["tname"] != where:
                continue
            attrs = tuple(sorted((e.get("args") or {}).items()))
            out.append((e["ph"], e["name"], attrs))
        return out

    def spans(self) -> List[dict]:
        """Materialized intervals: B/E pairs (stack-matched per track)
        and X events as ``{"name", "tname", "t0", "t1", "args"}`` with
        ns bounds — what the overlap checks consume."""
        out: List[dict] = []
        stacks: Dict[tuple, list] = {}
        for e in sorted(self.events(), key=lambda e: e["ts"]):
            if e["ph"] == "B":
                stacks.setdefault(e["tkey"], []).append(e)
            elif e["ph"] == "E":
                stack = stacks.get(e["tkey"])
                if stack:
                    b = stack.pop()
                    out.append({"name": b["name"], "tname": b["tname"],
                                "t0": b["ts"], "t1": e["ts"],
                                "args": b.get("args") or {}})
            elif e["ph"] == "X":
                out.append({"name": e["name"], "tname": e["tname"],
                            "t0": e["ts"], "t1": e["ts"] + e["dur"],
                            "args": e.get("args") or {}})
        return out

    # -------------------------------------------------------------- export
    def chrome(self) -> dict:
        """Chrome trace-event document (Perfetto-loadable).

        Events are globally sorted by timestamp (stable, so same-thread
        order is preserved) and threads/tracks get small stable tids
        with ``thread_name`` metadata.  Timestamps are microseconds.
        """
        evs = sorted(self.events(), key=lambda e: e["ts"])
        tids: Dict[tuple, int] = {}
        meta: List[dict] = []
        out: List[dict] = []
        for e in evs:
            tid = tids.get(e["tkey"])
            if tid is None:
                tid = tids[e["tkey"]] = len(tids) + 1
                meta.append({"ph": "M", "name": "thread_name", "pid": 1,
                             "tid": tid, "args": {"name": e["tname"]}})
            ev = {"name": e["name"], "ph": e["ph"], "pid": 1,
                  "tid": tid, "ts": e["ts"] / 1e3}
            if e["ph"] == "X":
                ev["dur"] = e["dur"] / 1e3
            elif e["ph"] == "i":
                ev["s"] = "t"           # instant scope: thread
            if e.get("args"):
                ev["args"] = e["args"]
            out.append(ev)
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(), f)
            f.write("\n")

    def write_jsonl(self, path: str) -> None:
        """Flat event log, one JSON object per line (ns timestamps) —
        the grep/jq-friendly twin of the Chrome export."""
        with open(path, "w") as f:
            for e in self.events():
                e = dict(e)
                e["tkey"] = list(e["tkey"])
                f.write(json.dumps(e) + "\n")


def validate_chrome_trace(doc: dict) -> List[str]:
    """Schema problems in a Chrome trace-event document (empty = valid).

    Checks what Perfetto's importer relies on: every event carries
    ``name/ph/ts/pid/tid``; per ``(pid, tid)`` timestamps are
    monotonically non-decreasing, ``B``/``E`` pairs are balanced and
    properly nested (matching names), and no ``E`` arrives without an
    open ``B``.  Used by the CI smoke step on the traced-serve
    artifact.
    """
    problems: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    stacks: Dict[tuple, list] = {}
    last_ts: Dict[tuple, float] = {}
    for i, e in enumerate(evs):
        ph = e.get("ph")
        if ph == "M":
            continue
        missing = [f for f in ("name", "ph", "ts", "pid", "tid")
                   if f not in e]
        if missing:
            problems.append(f"event {i}: missing field(s) {missing}")
            continue
        tid = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(tid, float("-inf")):
            problems.append(f"event {i} ({e['name']!r}): ts "
                            f"{e['ts']} goes backwards on tid {e['tid']}")
        last_ts[tid] = e["ts"]
        if ph == "B":
            stacks.setdefault(tid, []).append(e["name"])
        elif ph == "E":
            stack = stacks.get(tid)
            if not stack:
                problems.append(f"event {i} ({e['name']!r}): E without "
                                f"matching B on tid {e['tid']}")
            elif stack[-1] != e["name"]:
                problems.append(f"event {i}: E {e['name']!r} closes "
                                f"B {stack[-1]!r} on tid {e['tid']}")
                stack.pop()
            else:
                stack.pop()
        elif ph == "X" and "dur" not in e:
            problems.append(f"event {i} ({e['name']!r}): X without dur")
    for tid, stack in stacks.items():
        if stack:
            problems.append(f"tid {tid[1]}: unbalanced B events "
                            f"left open: {stack}")
    return problems
