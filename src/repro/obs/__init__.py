# Observability spine (DESIGN.md §11): per-query tracing with
# Chrome-trace/Perfetto export, and a process-wide metrics registry
# with fixed-bucket latency histograms.  Zero dependencies; a None
# tracer / absent registry compiles every hook site down to one
# attribute check.
from .metrics import (LATENCY_BUCKETS_MS, REGISTRY,  # noqa: F401
                      SCHEMA_VERSION, Counter, Gauge, Histogram,
                      MetricsRegistry, exp_buckets)
from .trace import Tracer, span_if, validate_chrome_trace  # noqa: F401
