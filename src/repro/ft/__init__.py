from .watchdog import StepMonitor, StragglerPolicy  # noqa: F401
from .elastic import ElasticTrainer, surviving_mesh  # noqa: F401
