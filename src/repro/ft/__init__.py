from .elastic import ElasticTrainer, surviving_mesh  # noqa: F401
from .watchdog import StepMonitor, StragglerPolicy  # noqa: F401
