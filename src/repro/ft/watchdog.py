"""Step-time watchdog: failure detection + straggler mitigation policy.

At 1000+-node scale the two dominant incidents are (i) a host dying
mid-step (collective hangs) and (ii) a straggler stretching every step.
The monitor tracks a robust step-time estimate (median + MAD over a
window); a step beyond ``hang_factor``× the median is treated as a hang →
restart-from-checkpoint; persistent ``straggler_factor``× steps trigger
the straggler policy (at deployment: evict the slow host and re-mesh — in
this container the decision logic is what is exercised/tested).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Optional


@dataclasses.dataclass
class StragglerPolicy:
    straggler_factor: float = 1.5
    hang_factor: float = 5.0
    window: int = 50
    min_samples: int = 5
    patience: int = 3      # consecutive slow steps before eviction


class StepMonitor:
    def __init__(self, policy: Optional[StragglerPolicy] = None):
        self.policy = policy or StragglerPolicy()
        self.durations: Deque[float] = collections.deque(
            maxlen=self.policy.window)
        self._slow_streak = 0
        self._t0: Optional[float] = None
        self.events = []

    # -- timing ------------------------------------------------------------
    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self) -> str:
        assert self._t0 is not None, "start_step not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(dt)

    # -- decision ----------------------------------------------------------
    def observe(self, duration_s: float) -> str:
        """Feed one step duration; returns 'ok' | 'straggler' | 'hang'."""
        verdict = "ok"
        if len(self.durations) >= self.policy.min_samples:
            med = sorted(self.durations)[len(self.durations) // 2]
            if duration_s > self.policy.hang_factor * med:
                verdict = "hang"
                self.events.append(("hang", duration_s, med))
            elif duration_s > self.policy.straggler_factor * med:
                self._slow_streak += 1
                if self._slow_streak >= self.policy.patience:
                    verdict = "straggler"
                    self.events.append(("straggler", duration_s, med))
            else:
                self._slow_streak = 0
        self.durations.append(duration_s)
        return verdict

    @property
    def median(self) -> float:
        if not self.durations:
            return 0.0
        return sorted(self.durations)[len(self.durations) // 2]
