"""Elastic scaling: rebuild the mesh from surviving hosts and resume.

The recovery contract: checkpoints are mesh-agnostic (plain host arrays +
manifest), so after a failure the trainer (i) picks the largest mesh the
survivors can form, (ii) rebuilds shardings from the same *logical* axis
rules, and (iii) device_puts the checkpoint onto the new mesh.  Batch
semantics are preserved by keeping the *global* batch constant and
rescaling per-host microbatches (gradient accumulation absorbs non-divisor
counts).

``ElasticTrainer`` wires monitor + checkpoint manager + a rebuildable
train step into a crash-restart loop; tests drive it with injected
failures.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from .watchdog import StepMonitor, StragglerPolicy


def surviving_mesh(n_devices: int, axis_names: Sequence[str] = ("data",
                                                                "model"),
                   model_parallelism: int = 1):
    """Largest (data, model) mesh from ``n_devices`` devices.

    Model parallelism is fixed by memory (a shard must fit), so survivors
    re-form ``(n // model_parallelism, model_parallelism)``; leftover
    devices idle (standard practice — better than a ragged mesh).
    """
    devs = jax.devices()[:n_devices]
    dp = len(devs) // model_parallelism
    if dp < 1:
        raise RuntimeError("not enough devices for one model shard")
    use = devs[: dp * model_parallelism]
    arr = np.array(use).reshape(dp, model_parallelism)
    return jax.sharding.Mesh(arr, axis_names)


@dataclasses.dataclass
class ElasticTrainer:
    """Restart loop: run steps, checkpoint every k, recover on failure.

    ``build`` is called with (mesh_devices, restored_state|None) and must
    return (state, step_fn); it owns jit/shardings so a re-mesh is a
    rebuild.  ``failure_injector`` lets tests raise at chosen steps.
    """
    ckpt: CheckpointManager
    build: Callable
    total_steps: int
    ckpt_every: int = 10
    monitor: Optional[StepMonitor] = None
    failure_injector: Optional[Callable[[int], None]] = None
    max_restarts: int = 5

    def run(self, n_devices: int) -> Tuple[Dict, Dict]:
        restarts = 0
        log = {"restarts": 0, "steps_run": 0, "resumed_from": []}
        mon = self.monitor or StepMonitor(StragglerPolicy())
        while True:
            start = 0
            restored = None
            if self.ckpt.latest_step() is not None:
                template, extra = self._peek_template()
                restored, extra = self.ckpt.restore(template)
                start = int(extra["step"]) + 1
                log["resumed_from"].append(start - 1)
            state, step_fn = self.build(n_devices, restored)
            try:
                for step in range(start, self.total_steps):
                    if self.failure_injector is not None:
                        self.failure_injector(step)
                    mon.start_step()
                    state = step_fn(state, step)
                    mon.end_step()
                    log["steps_run"] += 1
                    if (step + 1) % self.ckpt_every == 0 \
                            or step == self.total_steps - 1:
                        self.ckpt.save(step, state)
                self.ckpt.wait()
                return state, log
            except RuntimeError:
                restarts += 1
                log["restarts"] = restarts
                if restarts > self.max_restarts:
                    raise
                continue  # restart from latest checkpoint

    def _peek_template(self):
        import json
        import os
        step = self.ckpt.latest_step()
        path = os.path.join(self.ckpt.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        # Rebuild a ShapeDtypeStruct pytree from the manifest alone so
        # restore works with no surviving in-memory state.
        leaves = {}
        for rec in manifest["leaves"]:
            leaves[rec["key"]] = jax.ShapeDtypeStruct(
                tuple(rec["shape"]), np.dtype(rec["dtype"]))
        return _unflatten_paths(leaves), manifest["extra"]


def _unflatten_paths(flat: Dict[str, jax.ShapeDtypeStruct]):
    """Inverse of the manager's path flattening for dict/list pytrees."""
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = val
    return _listify(root)


def _listify(node):
    if not isinstance(node, dict):
        return node
    keys = list(node.keys())
    if keys and all(k.isdigit() for k in keys):
        return [_listify(node[str(i)]) for i in range(len(keys))]
    return {k: _listify(v) for k, v in node.items()}
