"""equiformer-v2 [arXiv:2306.12059]: 12L d128 l_max=6 m_max=2 8H eSCN."""
import dataclasses

from ..models.gnn.equiformer_v2 import EquiformerV2Config

FAMILY = "gnn"

CONFIG = EquiformerV2Config(name="equiformer-v2", n_layers=12, d_hidden=128,
                            l_max=6, m_max=2, n_heads=8)

SKIP_SHAPES = {}


def smoke_config():
    return dataclasses.replace(CONFIG, n_layers=2, d_hidden=16, l_max=2,
                               m_max=1, n_heads=2, n_rbf=16)
