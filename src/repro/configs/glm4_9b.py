"""glm4-9b [hf:THUDM/glm-4-9b]: 40L d4096 32H (GQA kv=2) ff13696 v151552."""
import dataclasses

from ..models.transformer import TransformerConfig

FAMILY = "lm"

CONFIG = TransformerConfig(
    name="glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552, head_dim=128, rope_theta=1e4,
    tie_embeddings=False,
)

# Pure full attention: a 524288-token KV with O(S) per-token decode reads on
# EVERY layer has no sub-quadratic path — skipped per the assignment note
# (see DESIGN.md §Arch-applicability).
SKIP_SHAPES = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, attn_chunk=32, loss_chunk=32)
