"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]:
48L d2048 32H (GQA kv=4) MoE 128e top-8 d_ff=768 v151936."""
import dataclasses

from ..models.layers import MoEConfig
from ..models.transformer import TransformerConfig

FAMILY = "lm"

CONFIG = TransformerConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_ff=0, vocab=151936, head_dim=64, rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768),
    tie_embeddings=False,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
        head_dim=16, attn_chunk=32, loss_chunk=32,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32))
