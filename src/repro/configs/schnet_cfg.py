"""schnet [arXiv:1706.08566]: 3 interactions d64 rbf=300 cutoff=10."""
import dataclasses

from ..models.gnn.schnet import SchNetConfig

FAMILY = "gnn"

CONFIG = SchNetConfig(name="schnet", n_interactions=3, d_hidden=64,
                      n_rbf=300, cutoff=10.0)

SKIP_SHAPES = {}


def smoke_config():
    return dataclasses.replace(CONFIG, n_interactions=2, d_hidden=16,
                               n_rbf=32)
