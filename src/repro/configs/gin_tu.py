"""gin-tu [arXiv:1810.00826]: 5L d64 sum-agg learnable eps."""
import dataclasses

from ..models.gnn.gin import GINConfig

FAMILY = "gnn"

CONFIG = GINConfig(name="gin-tu", n_layers=5, d_hidden=64)

SKIP_SHAPES = {}


def smoke_config():
    return dataclasses.replace(CONFIG, n_layers=2, d_hidden=16)
