"""gcn-cora [arXiv:1609.02907]: 2L d16 mean-agg sym-norm."""
import dataclasses

from ..models.gnn.gcn import GCNConfig

FAMILY = "gnn"

CONFIG = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16, norm="sym",
                   aggregator="mean")

SKIP_SHAPES = {}


def smoke_config():
    return dataclasses.replace(CONFIG, d_hidden=8)
