"""dlrm-rm2 [arXiv:1906.00091]: 13 dense / 26 sparse, d64 embeddings,
bot 13-512-256-64, top 512-512-256-1, dot interaction."""
import dataclasses

from ..models.dlrm import DLRMConfig

FAMILY = "recsys"

CONFIG = DLRMConfig(name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
                    vocab_per_table=1_000_000,
                    bot_mlp=(13, 512, 256, 64),
                    top_mlp=(512, 512, 256, 1), interaction="dot")

SKIP_SHAPES = {}


def smoke_config():
    return dataclasses.replace(CONFIG, vocab_per_table=1000)
