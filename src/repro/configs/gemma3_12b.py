"""gemma3-12b [hf:google/gemma-3 family]: 48L d3840 16H (GQA kv=8) ff15360
v262144 — 5:1 local:global sliding window (1024), 128k+ context.

The 5:1 pattern is the sub-quadratic story: only every 6th layer carries a
full-length KV, local layers cap their cache at the 1024-token window —
this is the one LM arch that runs the long_500k cell.
"""
import dataclasses

from ..models.transformer import TransformerConfig

FAMILY = "lm"

CONFIG = TransformerConfig(
    name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144, head_dim=256, rope_theta=1e6,
    sliding_window=1024, local_global_period=6, tie_embeddings=True,
    subquadratic=True,
)

SKIP_SHAPES = {}


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, sliding_window=16, local_global_period=3,
        attn_chunk=32, loss_chunk=32)
