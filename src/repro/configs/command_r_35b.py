"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01]:
40L d8192 64H (GQA kv=8) ff22528 v256000 — GQA, no-bias."""
import dataclasses

from ..models.transformer import TransformerConfig

FAMILY = "lm"

CONFIG = TransformerConfig(
    name="command-r-35b", n_layers=40, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22528, vocab=256000, head_dim=128, rope_theta=1e4,
    tie_embeddings=True,   # command-r ties input/output embeddings
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, attn_chunk=32, loss_chunk=32)
