"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L d1024 16H (GQA kv=8) MoE 32e top-8 d_ff=512 v49155.

The 49155-entry vocab is padded to 49408 (next multiple of 256) so the
embedding/logit matrices shard evenly on the 16-way model axis; labels
never index the pad rows.
"""
import dataclasses

from ..models.layers import MoEConfig
from ..models.transformer import TransformerConfig

FAMILY = "lm"

VOCAB_TRUE = 49155

CONFIG = TransformerConfig(
    name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=8, d_ff=0, vocab=49408, head_dim=64, rope_theta=1e4,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff=512),
    tie_embeddings=True,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (no sub-quadratic path)"}


def smoke_config():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
        head_dim=16, attn_chunk=32, loss_chunk=32,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32))
