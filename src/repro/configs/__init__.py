"""Architecture registry: one module per assigned arch (exact public
configs) + the paper's own graph configs.  Each module exposes

* ``FAMILY``        — "lm" | "gnn" | "recsys"
* ``CONFIG``        — the full-size config (dry-run only; never allocated)
* ``smoke_config()``— reduced same-family config for CPU smoke tests
* ``SKIP_SHAPES``   — shape names this arch cannot run (with the reason)
"""
from __future__ import annotations

import importlib
from typing import List

ARCH_IDS: List[str] = [
    "glm4-9b", "command-r-35b", "gemma3-12b", "granite-moe-1b-a400m",
    "qwen3-moe-30b-a3b",
    "schnet", "gin-tu", "equiformer-v2", "gcn-cora",
    "dlrm-rm2",
]

_MODULES = {
    "glm4-9b": "glm4_9b",
    "command-r-35b": "command_r_35b",
    "gemma3-12b": "gemma3_12b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "schnet": "schnet_cfg",
    "gin-tu": "gin_tu",
    "equiformer-v2": "equiformer_v2_cfg",
    "gcn-cora": "gcn_cora",
    "dlrm-rm2": "dlrm_rm2",
}


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def shapes_for(arch_id: str) -> List[str]:
    from .shapes import FAMILY_SHAPES
    mod = get_arch(arch_id)
    skip = getattr(mod, "SKIP_SHAPES", {})
    return [s for s in FAMILY_SHAPES[mod.FAMILY] if s not in skip]


def all_cells() -> List[tuple]:
    """Every runnable (arch, shape) cell + skipped ones with reasons."""
    run, skipped = [], []
    from .shapes import FAMILY_SHAPES
    for a in ARCH_IDS:
        mod = get_arch(a)
        skip = getattr(mod, "SKIP_SHAPES", {})
        for s in FAMILY_SHAPES[mod.FAMILY]:
            if s in skip:
                skipped.append((a, s, skip[s]))
            else:
                run.append((a, s))
    return run, skipped
