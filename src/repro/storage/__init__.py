# Disk-resident index store (DESIGN.md §6): block segment files per
# SweepPlan, a bounded-byte page cache metered through the block-I/O
# device, and a streaming executor that runs queries with peak plan
# memory O(largest level) instead of O(index).
from .blockfile import (DEFAULT_BLOCK_BYTES, IndexStore,  # noqa: F401
                        SEGMENT_NAMES, SegmentReader, load_store,
                        open_store, save_store, segment_bytes)
from .pagecache import CacheStats, PageCache  # noqa: F401
from .stream import StreamingQueryEngine  # noqa: F401
