# Disk-resident index store (DESIGN.md §6): block segment files per
# SweepPlan (format v5: per-block codec frames, decompressed on cache
# fill), a bounded-byte page cache metered through the block-I/O
# device, and a streaming executor that runs queries with peak plan
# memory O(largest level) instead of O(index).
from .blockfile import (DEFAULT_BLOCK_BYTES, DEFAULT_CODEC,  # noqa: F401
                        IndexStore, SEGMENT_NAMES, SegmentReader,
                        load_store, open_store, save_store, segment_bytes,
                        segment_logical_bytes)
from .codecs import CODEC_IDS, F16_EPS_REL  # noqa: F401
from .pagecache import CacheStats, PageCache, PendingBlock  # noqa: F401
from .pipeline import PipelineStats, ReadPipeline  # noqa: F401
from .stream import StreamingQueryEngine  # noqa: F401
