"""Storage smoke check (CI): build → ``save_store`` → serve from the
store at 5% and 25% page-cache budgets → verify against the in-memory
oracle — then repeat the 25% run from a ``delta``-codec store.

Asserts the ISSUE-3/4/5 acceptance criteria end to end:

* store-served distances are **bit-identical** to the in-memory
  engine's and match the Dijkstra oracle to float tolerance;
* the page cache is genuinely memory-constrained (hit-rate < 1.0 at a
  5% budget);
* the server's ``IOStats`` come from *actual* block reads — every byte
  the device metered is a byte the cache read on a miss, and no
  synthetic scan charge was applied;
* a partial budget actually buys hit-rate: at 25% under the default
  scan-resistant policy the hit rate must be strictly positive (the
  PR-3 LRU cache thrashed to 0.0 here — guarded so policy or layout
  regressions fail CI);
* the ``delta`` codec (format v5) pays off at the same 25% budget:
  smaller segments on disk, fewer compressed bytes read, hit rate no
  worse than the raw store (the logical block space and the
  decompressed-byte budget are identical, so the access/hit sequence
  is too), and answers still bit-identical;
* store-backed P2P (ISSUE-6, DESIGN.md §7): served pair answers equal
  the full SSD rows' entries, and a cold P2P sweep reads strictly
  fewer bytes than a cold full sweep from the same source;
* the depth-4 read pipeline (ISSUE-7): a ``queue_depth=4`` server
  answers bit-identically to ``queue_depth=1`` while reading exactly
  the same bytes and hit/miss sequence (cache transactions are
  submit-ordered) and exposes the overlap metrics (time-to-first-level
  ticks, stall counters present);
* kNN mode (ISSUE-7 satellite): store-served ``--mode knn`` answers
  equal the in-memory engine's k-nearest rows exactly;
* end-to-end tracing (ISSUE-8, DESIGN.md §11): a *mixed* ssd + p2p
  workload served under a ``Tracer`` yields answers and cache counter
  sequences bit-identical to the untraced twin, the exported Chrome
  trace validates (balanced B/E, monotonic ts per tid) and contains
  the span taxonomy, read/decode/relax overlap is visible at queue
  depth 4, and the metrics snapshot carries sane per-mode latency
  histograms.  Set ``SMOKE_TRACE_OUT=<path>`` to keep the Chrome
  trace (CI uploads it as an artifact);
* the declarative config spine (ISSUE-9, DESIGN.md §12): the
  checked-in ``configs/serve_mixed.yaml`` (or an inline twin when the
  file is absent) builds a store-backed mixed ssd+p2p server under
  the ``slo`` scheduler with two SLO classes via
  ``server_from_config``; every answer is bit-identical to singleton
  in-memory engine calls and ``slo_report`` carries both classes'
  deadline accounting.

    PYTHONPATH=src python -m repro.storage.smoke
"""
from __future__ import annotations

import asyncio
import os
import tempfile

import numpy as np

from ..core import (BuildConfig, QueryEngine, build_hod, dijkstra_reference,
                    gnm_random_digraph, pack_index)
from ..launch.serve import QueryServer
from .blockfile import segment_bytes

N_QUERIES = 16


def _serve_and_verify(store_dir: str, budget: int, sources: np.ndarray,
                      direct: np.ndarray, **server_kw) -> QueryServer:
    """Serve from the store at one cache budget (bytes) and assert the
    answers are bit-identical to the in-memory engine's rows."""
    server = QueryServer(store_path=store_dir, cache_bytes=budget,
                         batch_size=8, cache_entries=0, warm_start=True,
                         **server_kw)
    try:
        results = server.serve_stream(sources)
    finally:
        server.close()
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.dist, direct[i])
    return server


def main() -> None:
    g = gnm_random_digraph(200, 800, seed=11, weighted=True)
    res = build_hod(g, BuildConfig(max_core_nodes=32, max_core_edges=1024,
                                   seed=0))
    ix = pack_index(g, res, chunk=64)
    rng = np.random.default_rng(0)
    sources = rng.choice(g.n, size=N_QUERIES,
                         replace=False).astype(np.int32)
    direct = QueryEngine(ix).ssd(sources)
    oracle = dijkstra_reference(g, sources[:4])
    for i in range(4):
        finite = np.isfinite(oracle[i])
        assert np.allclose(direct[i][: g.n][finite], oracle[i][finite],
                           rtol=1e-5)

    with tempfile.TemporaryDirectory() as tmp:
        store_dir = f"{tmp}/store"
        ix.save_store(store_dir, block_bytes=4096)
        raw_seg = segment_bytes(store_dir)

        server = _serve_and_verify(store_dir, int(0.05 * raw_seg),
                                   sources, direct)
        st = server.stats
        io = server.modeled_io()
        assert st.page_misses > 0, "no real block reads happened"
        assert st.page_hit_rate() < 1.0, \
            f"hit-rate {st.page_hit_rate()} not memory-constrained at 5%"
        assert io.bytes_seq + io.bytes_rand == st.store_bytes_read, \
            "device bytes != actual cache-miss reads (synthetic charge?)"

        # 25% budget: the scan-resistant default (2Q + affinity layout)
        # must buy actual hit-rate — 0.0 here means cyclic-scan thrash
        # is back (the PR-3 LRU baseline).
        budget25 = int(0.25 * raw_seg)
        st25 = _serve_and_verify(store_dir, budget25, sources, direct).stats
        assert st25.page_hit_rate() > 0.0, \
            "25% cache budget bought a 0.0 hit rate — scan-resistant " \
            "policy or affinity layout regressed"

        # delta-codec store (format v5) at the SAME decompressed-byte
        # budget: smaller on disk, fewer compressed bytes read, hit
        # rate no worse than raw, answers still bit-identical.
        delta_dir = f"{tmp}/store_delta"
        ix.save_store(delta_dir, block_bytes=4096, codec="delta")
        delta_seg = segment_bytes(delta_dir)
        assert delta_seg < raw_seg, \
            f"delta segments ({delta_seg}) not smaller than raw ({raw_seg})"
        std = _serve_and_verify(delta_dir, budget25, sources, direct).stats
        assert std.page_hit_rate() >= st25.page_hit_rate(), \
            f"delta hit rate {std.page_hit_rate():.3f} < raw " \
            f"{st25.page_hit_rate():.3f} at the same budget"
        assert std.store_bytes_read < st25.store_bytes_read, \
            "delta store read no fewer bytes than raw"
        assert std.store_bytes_filled > std.store_bytes_read, \
            "decompress-on-fill accounting missing (filled <= read)"

        # Depth-4 read pipeline (ISSUE-7): identical answers, identical
        # bytes and hit/miss sequence vs depth 1, overlap metrics live.
        st_d1 = _serve_and_verify(delta_dir, budget25, sources, direct,
                                  queue_depth=1).stats
        st_d4 = _serve_and_verify(delta_dir, budget25, sources, direct,
                                  queue_depth=4, decode_workers=2).stats
        assert st_d4.store_bytes_read == st_d1.store_bytes_read, \
            f"depth-4 read {st_d4.store_bytes_read} bytes, depth-1 " \
            f"{st_d1.store_bytes_read} — read-ahead changed the " \
            "cache sequence"
        assert (st_d4.page_hits, st_d4.page_misses) == \
            (st_d1.page_hits, st_d1.page_misses), \
            "depth-4 hit/miss sequence diverged from depth-1"
        assert st_d4.ttfl_seconds > 0.0, \
            "pipelined server never recorded a time-to-first-level"
        assert st_d4.stall_seconds >= 0.0 \
            and st_d4.stall_wall_seconds >= 0.0

        # kNN smoke (ISSUE-7 satellite): store-served k-nearest rows
        # must equal the in-memory engine's exactly (shared selection
        # + tie-breaking).
        knodes, kdist = QueryEngine(ix).knn(sources, 5)
        knn_server = QueryServer(store_path=store_dir,
                                 cache_bytes=budget25, batch_size=8,
                                 cache_entries=0, mode="knn", knn_k=5,
                                 warm_start=True)
        try:
            knn_results = knn_server.serve_stream(sources)
        finally:
            knn_server.close()
        for i, r in enumerate(knn_results):
            np.testing.assert_array_equal(r.nodes, knodes[i])
            np.testing.assert_array_equal(r.dist, kdist[i])

        # P2P smoke (ISSUE-6): serve pairs store-backed; answers must
        # equal the full SSD rows' entries, the cache must still see
        # real traffic, and a cold meet-in-the-middle sweep must read
        # strictly fewer bytes than a cold full sweep.
        targets = rng.choice(g.n, size=N_QUERIES,
                             replace=False).astype(np.int32)
        pairs = np.stack([sources, targets], axis=1)
        p2p_server = QueryServer(store_path=store_dir,
                                 cache_bytes=budget25, batch_size=8,
                                 cache_entries=0, mode="p2p",
                                 warm_start=True)
        try:
            p2p_results = p2p_server.serve_stream(pairs)
        finally:
            p2p_server.close()
        for i, r in enumerate(p2p_results):
            np.testing.assert_array_equal(
                r.dist, np.float32(direct[i][targets[i]]))
        stp = p2p_server.stats
        assert stp.page_hits + stp.page_misses > 0, \
            "p2p served without touching the page cache"
        assert 0.0 < stp.page_hit_rate() <= 1.0

        from . import IndexStore, PageCache, StreamingQueryEngine
        cold = StreamingQueryEngine(IndexStore(store_dir,
                                               cache=PageCache(0)),
                                    prefetch=False)
        try:
            dev = cold.store.device.stats
            # endpoints at level > 0, so both halves provably skip levels
            from ..core.index import node_levels
            lvl = node_levels(ix, np.arange(ix.n))[ix.perm]
            mid = np.nonzero((lvl > 0) & (lvl < ix.n_levels))[0]
            one_s = mid[:1].astype(np.int32)
            one_t = mid[-1:].astype(np.int32)
            base = dev.bytes_seq + dev.bytes_rand
            cold.ssd(one_s)
            ssd_bytes = dev.bytes_seq + dev.bytes_rand - base
            base = dev.bytes_seq + dev.bytes_rand
            cold.p2p(one_s, one_t)
            p2p_bytes = dev.bytes_seq + dev.bytes_rand - base
        finally:
            cold.close()
        assert 0 < p2p_bytes < ssd_bytes, \
            f"p2p read {p2p_bytes} bytes, full sweep {ssd_bytes} — " \
            "meet-in-the-middle is not saving I/O"

        # Traced mixed serve (ISSUE-8, DESIGN.md §11): alternate ssd and
        # p2p batches through one shared depth-4 engine under a Tracer.
        # Tracing must be a pure observer — answers and cache counter
        # totals bit-identical to the untraced twin — and the Chrome
        # export must validate, carry the span taxonomy, and show the
        # pipeline's read/decode work overlapping query-thread
        # relax/wait time.
        from ..obs import MetricsRegistry, Tracer, validate_chrome_trace

        def mixed_serve(tracer, metrics=None):
            store = IndexStore(delta_dir,
                               cache=PageCache(budget25, policy="2q"))
            engine = StreamingQueryEngine(store, queue_depth=4,
                                          decode_workers=2)
            srv = {m: QueryServer(engine, batch_size=8,
                                  cache_entries=0, mode=m,
                                  device=store.device, warm_start=True,
                                  tracer=tracer, metrics=metrics)
                   for m in ("ssd", "p2p")}
            answers = []
            try:
                for i, lo in enumerate(range(0, N_QUERIES, 8)):
                    if i % 2 == 0:
                        rs = srv["ssd"].serve_stream(sources[lo: lo + 8])
                    else:
                        rs = srv["p2p"].serve_stream(pairs[lo: lo + 8])
                    answers += [np.atleast_1d(r.dist) for r in rs]
            finally:
                engine.close()
            cs = store.cache.stats
            return answers, (cs.hits, cs.misses, cs.bytes_read,
                             cs.bytes_filled, cs.evictions)

        tracer = Tracer()
        metrics = MetricsRegistry()
        traced, ctr_traced = mixed_serve(tracer, metrics)
        plain, ctr_plain = mixed_serve(None)
        for a, b in zip(traced, plain):
            np.testing.assert_array_equal(a, b)
        assert ctr_traced == ctr_plain, \
            f"tracing perturbed the cache: {ctr_traced} != {ctr_plain}"
        for j in range(8):
            np.testing.assert_array_equal(traced[j], direct[j])
            np.testing.assert_array_equal(
                traced[8 + j],
                np.atleast_1d(np.float32(direct[8 + j][targets[8 + j]])))

        doc = tracer.chrome()
        problems = validate_chrome_trace(doc)
        assert not problems, f"invalid Chrome trace: {problems[:3]}"
        names = {e["name"] for e in doc["traceEvents"]}
        need = {"query.ssd", "query.p2p", "jit.dispatch", "pipe.submit",
                "level.wait", "level.relax", "level.read",
                "level.decode", "cache.hit", "cache.miss",
                "device.read"}
        assert need <= names, f"trace missing spans: {need - names}"
        sp = tracer.spans()
        pipe_sp = [s for s in sp
                   if s["name"] in ("level.read", "level.decode")
                   and s["tname"].startswith("hod-pipe-")]
        q_sp = [s for s in sp
                if s["name"] in ("level.relax", "level.wait")]
        assert pipe_sp and q_sp, "pipeline or query-thread spans missing"
        assert any(p["t0"] < q["t1"] and q["t0"] < p["t1"]
                   for p in pipe_sp for q in q_sp), \
            "no read/decode vs relax/wait overlap at queue depth 4"
        snap = metrics.snapshot()
        assert snap["schema_version"] >= 1
        for m in ("ssd", "p2p"):
            h = snap["histograms"][f"latency_ms.{m}"]
            assert h["count"] == 8 and 0.0 < h["p50"] <= h["p99"], \
                f"latency_ms.{m} histogram not sane: {h}"
        trace_out = os.environ.get("SMOKE_TRACE_OUT")
        if trace_out:
            tracer.write_chrome(trace_out)
            print(f"wrote {trace_out} "
                  f"({len(doc['traceEvents'])} events)")

        # Declarative-config end-to-end (ISSUE-9, DESIGN.md §12): the
        # checked-in mixed config drives a store-backed slo-scheduled
        # server — mixed ssd+p2p traffic under two SLO classes — and
        # every answer must stay bit-identical to a singleton call on
        # the in-memory engine (the unscheduled path).
        from ..config import SERVE_DEFAULTS, Config
        from ..launch.serve import mixed_request_stream, server_from_config

        cfg_path = os.path.join(
            os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))))),
            "configs", "serve_mixed.yaml")
        cfg = Config(cfg_path if os.path.exists(cfg_path) else None,
                     defaults=SERVE_DEFAULTS,
                     overrides={"serve": {"requests": 48, "batch": 8}})
        if not cfg.get("serve.mix"):
            # installed tree without configs/: the same shape, inline
            cfg.data["serve"].update(
                scheduler="slo", mix={"ssd": 1, "p2p": 3},
                slo={"ssd": {"deadline_ms": 200.0},
                     "p2p": {"deadline_ms": 60.0, "batch": 8}})
        mixed_srv = server_from_config(cfg, store_path=store_dir,
                                       cache_bytes=budget25)
        assert mixed_srv.scheduler == "slo"
        assert set(mixed_srv.modes) == {"ssd", "p2p"}
        assert len(mixed_srv._slo) == 2, "expected two SLO classes"
        stream = mixed_request_stream(cfg, g.n,
                                      int(cfg.get("serve.requests")),
                                      np.random.default_rng(5))

        async def config_drive():
            tasks = [asyncio.create_task(mixed_srv.submit(*a, mode=m))
                     for m, a in stream]
            await asyncio.sleep(0)
            await mixed_srv.drain()
            return await asyncio.gather(*tasks)

        try:
            mixed_srv.warmup()
            mixed_answers = asyncio.run(config_drive())
        finally:
            mixed_srv.close()
        eng_mem = QueryEngine(ix)
        for (m, a), r in zip(stream, mixed_answers):
            if m == "p2p":
                np.testing.assert_array_equal(
                    r.dist, np.float32(eng_mem.p2p(
                        np.array([a[0]], np.int32),
                        np.array([a[1]], np.int32))[0]))
            else:
                np.testing.assert_array_equal(
                    r.dist, eng_mem.ssd(np.array(a, np.int32))[0])
        slo_rows = {r["cls"]: r for r in mixed_srv.slo_report()}
        assert {"ssd", "p2p"} <= set(slo_rows), \
            f"slo_report lost a traffic class: {sorted(slo_rows)}"
        assert slo_rows["p2p"]["deadline_ms"] == \
            cfg.get("serve.slo.p2p.deadline_ms")

        print(f"storage smoke OK: {st.requests} queries from a "
              f"5% cache ({st.page_hit_rate():.1%} hit rate), "
              f"{st.store_bytes_read/1e6:.2f} MB actually read "
              f"({io.seq_blocks} seq / {io.rand_blocks} rand blocks), "
              f"{st25.page_hit_rate():.1%} hit rate at a 25% budget; "
              f"delta codec: segments {delta_seg/1e6:.2f} vs "
              f"{raw_seg/1e6:.2f} MB raw "
              f"({1 - delta_seg/raw_seg:.0%} smaller), "
              f"{std.store_bytes_read/1e6:.2f} vs "
              f"{st25.store_bytes_read/1e6:.2f} MB read, "
              f"hit rate {std.page_hit_rate():.1%}, "
              f"answers bit-identical to the in-memory engine; "
              f"depth-4 pipeline: bytes/hits identical to depth-1, "
              f"ttfl {st_d4.ttfl_seconds*1e3:.2f} ms; "
              f"knn(k=5): {len(knn_results)} queries bit-identical; "
              f"p2p: {stp.requests} pairs served "
              f"({stp.page_hit_rate():.1%} hit rate), cold sweep "
              f"{p2p_bytes/1e3:.0f} KB vs {ssd_bytes/1e3:.0f} KB full; "
              f"traced mixed serve bit-identical "
              f"({len(doc['traceEvents'])} trace events, "
              f"ssd p99 {snap['histograms']['latency_ms.ssd']['p99']:.1f}"
              f" ms); config-driven slo serve: {len(mixed_answers)} "
              f"mixed requests bit-identical "
              f"({'file ' + os.path.basename(cfg.path) if cfg.path else 'inline config'}, "
              f"p2p misses "
              f"{slo_rows['p2p']['deadline_misses']}"
              f"/{slo_rows['p2p']['requests']})")


if __name__ == "__main__":
    main()
