"""Storage smoke check (CI): build → ``save_store`` → serve from the
store at a 5% page-cache budget → verify against the in-memory oracle.

Asserts the ISSUE-3 acceptance criteria end to end:

* store-served distances are **bit-identical** to the in-memory
  engine's and match the Dijkstra oracle to float tolerance;
* the page cache is genuinely memory-constrained (hit-rate < 1.0 at a
  5% budget);
* the server's ``IOStats`` come from *actual* block reads — every byte
  the device metered is a byte the cache read on a miss, and no
  synthetic scan charge was applied.

    PYTHONPATH=src python -m repro.storage.smoke
"""
from __future__ import annotations

import tempfile

import numpy as np

from ..core import (BuildConfig, QueryEngine, build_hod, dijkstra_reference,
                    gnm_random_digraph, pack_index)
from ..launch.serve import QueryServer
from .blockfile import segment_bytes

N_QUERIES = 16


def main() -> None:
    g = gnm_random_digraph(200, 800, seed=11, weighted=True)
    res = build_hod(g, BuildConfig(max_core_nodes=32, max_core_edges=1024,
                                   seed=0))
    ix = pack_index(g, res, chunk=64)

    with tempfile.TemporaryDirectory() as tmp:
        store_dir = f"{tmp}/store"
        ix.save_store(store_dir, block_bytes=4096)
        budget = int(0.05 * segment_bytes(store_dir))

        server = QueryServer(store_path=store_dir, cache_bytes=budget,
                             batch_size=8, cache_entries=0,
                             warm_start=True)
        rng = np.random.default_rng(0)
        sources = rng.choice(g.n, size=N_QUERIES,
                             replace=False).astype(np.int32)
        try:
            results = server.serve_stream(sources)
        finally:
            server.close()

        engine = QueryEngine(ix)
        direct = engine.ssd(sources)
        for i, r in enumerate(results):
            np.testing.assert_array_equal(r.dist, direct[i])
        oracle = dijkstra_reference(g, sources[:4])
        for i in range(4):
            finite = np.isfinite(oracle[i])
            assert np.allclose(results[i].dist[: g.n][finite], oracle[i][finite],
                               rtol=1e-5)

        st = server.stats
        io = server.modeled_io()
        assert st.page_misses > 0, "no real block reads happened"
        assert st.page_hit_rate() < 1.0, \
            f"hit-rate {st.page_hit_rate()} not memory-constrained at 5%"
        assert io.bytes_seq + io.bytes_rand == st.store_bytes_read, \
            "device bytes != actual cache-miss reads (synthetic charge?)"
        print(f"storage smoke OK: {st.requests} queries from a "
              f"{budget}-byte cache ({st.page_hit_rate():.1%} hit rate), "
              f"{st.store_bytes_read/1e6:.2f} MB actually read "
              f"({io.seq_blocks} seq / {io.rand_blocks} rand blocks), "
              f"answers bit-identical to the in-memory engine")


if __name__ == "__main__":
    main()
