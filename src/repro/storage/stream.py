"""Store-backed streaming query execution (DESIGN.md §6).

:class:`StreamingQueryEngine` answers the same batched SSD/SSSP queries
as :class:`~repro.core.query.QueryEngine` but never materializes a
whole :class:`~repro.core.index.SweepPlan`: each sweep walks its
segment file level by level, pulling one ``[M_pad, K_fix]`` slab at a
time through the store's page cache and feeding it to a jitted,
state-donating level step (`QueryEngine._run_plan_stream`).  Peak plan
memory is therefore O(largest level), not O(index), and the
``IOStats`` on the store's :class:`~repro.core.io_sim.BlockDevice`
record the *actual* block reads the query caused (cache misses), not a
synthetic charge.

Answers are bit-identical to the in-memory engine: the level bodies are
the same methods, applied to the same slab values in the same order —
``lax.scan`` over resident levels and a Python loop over streamed
levels compose identical (min, +)/max scatters.  SSSP reconstruction
walks the plans in the order ``plan_b → plan_core → plan_f`` (the
reverse of the distance pass, for cache reuse); the per-plan
max-merges commute, so predecessors stay bit-identical to the
in-memory executor's ``f → core → b`` order (asserted in
tests/test_storage.py).

**Recon pinning** (ROADMAP "recon reuse"; DESIGN.md §6): an SSSP query
re-reads every distance-pass block during reconstruction, so the
distance sweeps pin the levels they stream (``PageCache`` pin leases,
bounded by the pin budget) and reconstruction unpins each level right
after consuming it.  ``plan_b`` is re-read first and is usually still
warm even unpinned; ``plan_f`` — touched a whole sweep earlier, i.e.
exactly the blocks a cyclic-thrash policy would have dropped — is the
one the pins save.  A ``finally`` ledger releases any leftover leases
even when a sweep raises.

``prefetch=True`` streams each plan through the depth-N async
:class:`~repro.storage.pipeline.ReadPipeline`: up to ``queue_depth``
levels' block reads stay in flight (ordered submit/reap on a dedicated
io thread, batched extent preads) and codec decompress-on-fill runs on
a ``decode_workers``-wide pool, so neither the read nor the decode
ever blocks the query thread's jit step.  All cache-state transitions
still happen on the query thread in block order
(``PageCache.begin_fill``), so hit/miss/eviction/byte sequences — and
therefore answers — are bit-identical to the synchronous
``prefetch=False`` path at every depth.  Fill failures (e.g. a CRC
mismatch on a corrupt segment) always surface in the querying thread:
the level generator re-raises them on reap, and if the consumer
abandons the sweep mid-stream the generator's cleanup drains every
in-flight fill so no error is silently swallowed and no placeholder is
left incomplete.  Bounded sweeps (P2P, threshold, kNN, top-k) bypass
the pipeline and read synchronously, so a skipped level provably skips
the device I/O, not just the compute.
"""
from __future__ import annotations

import os
from collections import deque
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import shardlib as sl
from ..core.index import node_levels
from ..core.query import INF, QueryEngine, _knn_select
from ..obs.trace import span_if
from .blockfile import IndexStore
from .pipeline import PipelineStats, ReadPipeline

__all__ = ["StreamingQueryEngine"]


class StreamingQueryEngine(QueryEngine):
    """Batched SSD/SSSP over an :class:`IndexStore`, one level slab at a
    time.

    Supports ``core_mode`` ``"closure"`` and ``"bellman"`` (the jitted
    core searches over the resident tier) and ``"dijkstra"`` (host heap
    over the resident core CSR).  The resident tier — permutations,
    core closure/CSR — stays in memory; the three plan segments stream.
    """

    def __init__(self, store: IndexStore, core_mode: str = "closure",
                 use_pallas: bool = False, eps: float = 0.0,
                 interpret: Optional[bool] = None, prefetch: bool = True,
                 queue_depth: int = 4, decode_workers: int = 2,
                 tracer=None):
        self.store = store
        #: the ServingFleet when the store is sharded (repro/fleet) —
        #: surfaced so servers can report per-shard stats without
        #: reaching through storage internals.
        self.fleet = getattr(store, "fleet", None)
        self.prefetch = bool(prefetch)
        self._init_engine(store.resident, core_mode, use_pallas, eps,
                          interpret)
        self._core_jit = jax.jit(
            lambda dist: self._core_update(dist, self.core_mode))
        # Level steps: state (arg 0) is donated, so the sweep runs with
        # one live state buffer + one level slab.  assoc is an operand
        # of both steps (unused by relax) so they share a signature.
        self._relax_step = jax.jit(
            lambda dist, dst, src, w, assoc, valid:
            self._relax_level(dist, dst, src, w, assoc, valid),
            donate_argnums=0)
        self._recon_step = jax.jit(
            lambda pred, dist, dst, src, w, assoc, valid:
            self._recon_level(pred, dist, dst, src, w, assoc, valid),
            donate_argnums=0)
        # Query-mode steps (DESIGN.md §7).  Same O(1)-trace discipline:
        # each jits once per slab shape; the threshold ``d`` and the
        # range/cut bounds are *operands*, not closure constants, so a
        # new query never re-traces.
        self._relax_rev_step = jax.jit(
            lambda dlab, dst, src, w, assoc, valid:
            self._relax_level_rev(dlab, dst, src, w, assoc, valid),
            donate_argnums=0)
        self._thresh_step = jax.jit(
            lambda dist, d, dst, src, w, assoc, valid: jnp.where(
                (r := self._relax_level(dist, dst, src, w, assoc,
                                        valid)) <= d, r, INF),
            donate_argnums=0)
        self._meet_min = jax.jit(
            lambda fwd, bwd: jnp.min(fwd + bwd, axis=1))
        self._suffix_min = jax.jit(
            lambda fwd, cut: jnp.min(jnp.where(
                jnp.arange(fwd.shape[1])[None, :] >= cut, fwd, INF),
                axis=1))
        self._range_live = jax.jit(
            lambda dist, lo, hi: jnp.any(jnp.isfinite(dist) & (
                jnp.arange(dist.shape[1])[None, :] >= lo) & (
                jnp.arange(dist.shape[1])[None, :] < hi)))
        self._clamp_step = jax.jit(
            lambda dist, d: jnp.where(dist <= d, dist, INF),
            donate_argnums=0)
        self._pipe = (ReadPipeline(store, queue_depth=queue_depth,
                                   decode_workers=decode_workers)
                      if self.prefetch else None)
        if tracer is not None:
            self.set_tracer(tracer)

    # --------------------------------------------------------- observability
    def set_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.trace.Tracer` (DESIGN.md §11) to
        every layer this engine drives: relax spans (``QueryEngine``
        hook), pipeline submit/read/decode/wait spans, cache
        hit/miss/evict instants (``PageCache.on_event``, routed to the
        synthetic ``submit`` track so the query thread's own span
        sequence stays depth-invariant), and modeled-device access
        instants (``BlockDevice.on_access``, ``device`` track).  Pass
        ``None`` to detach everything."""
        self.tracer = tracer
        self._seg_short: dict = {}   # cache-namespace -> short label
        if self._pipe is not None:
            self._pipe.tracer = tracer
        self.store.cache.on_event = (self._on_cache_event
                                     if tracer is not None else None)
        self.store.device.on_access = (self._on_device_access
                                       if tracer is not None else None)

    def _on_cache_event(self, kind: str, key, nbytes: int) -> None:
        tr = self.tracer
        if tr is None:
            return
        if isinstance(key, tuple) and len(key) == 2:
            ns, block = key
            seg = self._seg_short.get(ns)
            if seg is None:   # memoized: this fires per block touch
                seg = self._seg_short[ns] = os.path.basename(str(ns))
            block = int(block)
        else:
            seg, block = str(key), -1
        tr.instant(f"cache.{kind}", track="submit", seg=seg,
                   block=block, bytes=int(nbytes))

    def _on_device_access(self, block_id: int, nbytes: int,
                          seq: bool) -> None:
        tr = self.tracer
        if tr is not None:
            tr.instant("device.read", track="device",
                       block=int(block_id), bytes=int(nbytes),
                       seq=bool(seq))

    def pipeline_stats(self) -> Optional[PipelineStats]:
        """The live :class:`PipelineStats` (overlap/stall metrics), or
        ``None`` when running synchronously (``prefetch=False``)."""
        return self._pipe.stats if self._pipe is not None else None

    # ------------------------------------------------------------- streaming
    def _levels(self, name: str, pin: bool = False,
                unpin_after: bool = False) -> Iterator[tuple]:
        """Yield one plan's level slabs in scan order.

        ``pin=True`` takes a pin lease on every block read (the
        distance pass of an SSSP query); ``unpin_after=True`` releases
        a level's leases right after the consumer finishes with it
        (the reconstruction pass).  With the pipeline, up to
        ``queue_depth`` levels stay in flight: each reap tops the
        window back up before waiting, and reaping re-raises fill
        errors in the querying thread.  The ``finally`` drains every
        in-flight ticket when the consumer abandons the sweep, so a
        failed fill can never be silently lost and no placeholder is
        left incomplete.
        """
        n = self.store.n_real(name)
        if self._pipe is None:
            for lvl in range(n):
                with span_if(self.tracer, "level.read", plan=name,
                             level=lvl):
                    slab = self.store.read_level(name, lvl, pin=pin)
                yield slab
                if unpin_after:
                    self.store.unpin_level(name, lvl)
            return
        pipe = self._pipe
        pipe.begin_sweep()
        tickets: "deque" = deque()
        nxt = 0

        def top_up():
            nonlocal nxt
            while nxt < n and len(tickets) < pipe.queue_depth:
                tickets.append(pipe.submit_level(name, nxt, pin=pin))
                nxt += 1

        try:
            top_up()
            for lvl in range(n):
                ticket = tickets.popleft()
                top_up()
                yield pipe.reap(ticket)
                if unpin_after:
                    self.store.unpin_level(name, lvl)
        finally:
            pipe.drain(tickets)

    def _sweep(self, state: jnp.ndarray, name: str, step,
               pin: bool = False) -> jnp.ndarray:
        return self._run_plan_stream(state, self._levels(name, pin=pin),
                                     step, label=name)

    def _init_dist(self, sources_perm: np.ndarray) -> jnp.ndarray:
        s = sources_perm.shape[0]
        dist = jnp.full((s, self.index.n_pad), INF, jnp.float32)
        dist = dist.at[jnp.arange(s), jnp.asarray(sources_perm)].set(0.0)
        return sl.shard(dist, "batch", None)

    def _apply_core(self, dist: jnp.ndarray) -> jnp.ndarray:
        if not self.index.n_core:
            return dist
        with span_if(self.tracer, "core.search", mode=self.core_mode):
            if self.core_mode == "dijkstra":
                # Paper-faithful host heap over the resident core CSR —
                # the same shared helper the in-memory validation mode
                # uses (QueryEngine._core_dijkstra_host).
                return jnp.asarray(
                    self._core_dijkstra_host(np.array(dist)))
            return self._core_jit(dist)

    def _ssd_stream(self, sources_perm: np.ndarray,
                    pin: bool = False) -> jnp.ndarray:
        dist = self._init_dist(sources_perm)
        dist = self._sweep(dist, "plan_f", self._relax_step, pin=pin)
        dist = self._apply_core(dist)
        return self._sweep(dist, "plan_b", self._relax_step, pin=pin)

    def _unpin_plan(self, name: str) -> None:
        """Release every pin lease a distance sweep may still hold on
        one plan's levels (idempotent; sticky segment pins unaffected)."""
        for lvl in range(self.store.n_real(name)):
            self.store.unpin_level(name, lvl)

    # ---------------------------------------------------------------- public
    def ssd(self, sources: np.ndarray) -> np.ndarray:
        sources = np.asarray(sources, dtype=np.int32)
        dist = self._ssd_stream(self.index.perm[sources])
        return np.asarray(dist)[:, self.index.perm]

    def sssp(self, sources: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        sources = np.asarray(sources, dtype=np.int32)
        try:
            # Distance pass pins the levels it streams: reconstruction
            # re-reads all of them immediately after (recon reuse).
            dist = self._ssd_stream(self.index.perm[sources], pin=True)
            pred = jnp.full((dist.shape[0], self.index.n_pad), -1,
                            jnp.int32)
            # Reverse plan order for cache affinity: plan_b was streamed
            # moments ago, plan_f a whole sweep ago (the pinned one).
            # The per-plan scatter-maxes commute, so pred is
            # bit-identical to the in-memory f -> core -> b order.
            for name in ("plan_b", "plan_core", "plan_f"):
                pred = self._run_plan_stream(
                    pred, self._levels(name, unpin_after=True),
                    lambda p, *slab: self._recon_step(p, dist, *slab),
                    label=name)
        finally:
            for name in ("plan_f", "plan_b"):
                self._unpin_plan(name)
        dist = np.asarray(dist)[:, self.index.perm]
        pred = np.asarray(pred)[:, self.index.perm]
        return dist, pred

    # -------------------------------------------- bounded sweeps (§7)
    def _read(self, name: str, lvl: int):
        """One level slab, read synchronously (bounded sweeps bypass the
        prefetch thread so a skip / early exit provably skips the I/O,
        not just the compute)."""
        with span_if(self.tracer, "level.read", plan=name, level=lvl):
            return tuple(jnp.asarray(a)
                         for a in self.store.read_level(name, lvl))

    def p2p(self, sources: np.ndarray, targets: np.ndarray,
            early_term: bool = True) -> np.ndarray:
        """Point-to-point distances ``dist(sources[i], targets[i])`` by
        meet-in-the-middle (DESIGN.md §7), reading strictly less than a
        full SSD sweep:

        * the forward half skips every ``plan_f`` level below the
          lowest source level (labels there are provably still +inf);
        * the backward-label half walks ``plan_b`` in *reverse* scan
          order (ascending rank), skips its tail below the lowest
          target level, and — with ``early_term`` — stops as soon as
          every row's best meeting distance is <= the suffix-min of its
          (final) forward labels over the ids future levels can still
          touch: backward labels are nonnegative, so no later meet can
          beat the bound.  ``early_term=False`` reads every kept level;
          answers are bit-identical either way.
        """
        sources = np.asarray(sources, dtype=np.int32)
        targets = np.asarray(targets, dtype=np.int32)
        ix = self.index
        src_perm = ix.perm[sources]
        tgt_perm = ix.perm[targets]
        lvl_s = int(node_levels(ix, src_perm).min())
        lvl_t = int(node_levels(ix, tgt_perm).min())

        fwd = self._init_dist(src_perm)
        start_f = int(np.searchsorted(self._level_ids_f, lvl_s,
                                      side="left"))
        for lvl in range(start_f, self.store.n_real("plan_f")):
            fwd = self._relax_step(fwd, *self._read("plan_f", lvl))
        fwd = self._apply_core(fwd)

        bwd = self._init_dist(tgt_perm)
        best = self._meet_min(fwd, bwd)
        keep = np.nonzero(self._level_ids_b >= lvl_t)[0]
        for j in (range(int(keep.max()), -1, -1) if keep.size else ()):
            bwd = self._relax_rev_step(bwd, *self._read("plan_b", j))
            best = self._meet_min(fwd, bwd)
            if early_term and j > 0:
                cut = int(ix.level_ptr[int(self._level_ids_b[j - 1])])
                if bool(jnp.all(best <= self._suffix_min(fwd, cut))):
                    break
        return np.asarray(best)

    def ssd_within(self, sources: np.ndarray, d: float) -> np.ndarray:
        """All distances ``<= d`` (rest ``+inf``), original node order.

        The threshold body clamps labels past ``d`` inside every level
        step, so a level whose *gather range* holds no finite label is
        provably inert — the sweep skips its reads entirely.  Forward
        level ``g`` gathers its own level's ids
        ``[level_ptr[g], level_ptr[g+1])``; backward level ``g``
        gathers strictly-higher ranks ``>= level_ptr[g+1]``.
        """
        sources = np.asarray(sources, dtype=np.int32)
        ix = self.index
        lp = ix.level_ptr
        d = jnp.float32(d)
        dist = self._init_dist(ix.perm[sources])
        dist = jnp.where(dist <= d, dist, INF)   # d < 0: nothing survives
        for lvl in range(self.store.n_real("plan_f")):
            g = int(self._level_ids_f[lvl])
            if not bool(self._range_live(dist, int(lp[g]),
                                         int(lp[g + 1]))):
                continue
            dist = self._thresh_step(dist, d, *self._read("plan_f", lvl))
        dist = self._apply_core(dist)
        dist = jnp.where(dist <= d, dist, INF)   # mask core output
        for lvl in range(self.store.n_real("plan_b")):
            g = int(self._level_ids_b[lvl])
            if not bool(self._range_live(dist, int(lp[g + 1]),
                                         dist.shape[1])):
                continue
            dist = self._thresh_step(dist, d, *self._read("plan_b", lvl))
        return np.asarray(dist)[:, ix.perm]

    def knn(self, sources: np.ndarray, k: int
            ) -> Tuple[np.ndarray, np.ndarray]:
        """The ``k`` nearest nodes of each source (DESIGN.md §7): a
        threshold sweep whose per-row radius *shrinks adaptively*.

        Before each level the radius is the row's kth-smallest current
        label — labels only decrease, so it is always an upper bound on
        the row's final kth distance, and clamping labels past it is
        sound by the same nonnegative-weight argument as
        :meth:`ssd_within` (a top-k node's true chain labels are all
        ``<=`` its final distance ``<=`` the radius, so they always
        survive; only overestimates are erased).  Levels whose gather
        range holds no live label are skipped — reads included, via the
        synchronous bypass.  Returns ``(nodes, dist)``, each ``[S, k]``
        in original node ids: ascending ``(distance, node id)`` with
        the source itself at distance 0; rows with fewer than ``k``
        reachable nodes pad with ``(-1, +inf)``.  Bit-identical to the
        in-memory :meth:`QueryEngine.knn` (full sweep + host top-k).
        """
        sources = np.asarray(sources, dtype=np.int32)
        ix = self.index
        if not 1 <= k <= ix.n:
            raise ValueError(f"k must be in [1, {ix.n}], got {k}")
        lp = ix.level_ptr
        dist = self._init_dist(ix.perm[sources])

        def radius(d):
            # per-row kth-smallest current label, as a [S, 1] operand
            # (broadcasts against [S, n_pad] inside the jitted steps)
            part = np.partition(np.asarray(d), k - 1, axis=1)
            return jnp.asarray(part[:, k - 1:k])

        for lvl in range(self.store.n_real("plan_f")):
            g = int(self._level_ids_f[lvl])
            r = radius(dist)
            dist = self._clamp_step(dist, r)
            if not bool(self._range_live(dist, int(lp[g]),
                                         int(lp[g + 1]))):
                continue
            dist = self._thresh_step(dist, r, *self._read("plan_f", lvl))
        dist = self._apply_core(dist)
        for lvl in range(self.store.n_real("plan_b")):
            g = int(self._level_ids_b[lvl])
            r = radius(dist)
            dist = self._clamp_step(dist, r)
            if not bool(self._range_live(dist, int(lp[g + 1]),
                                         dist.shape[1])):
                continue
            dist = self._thresh_step(dist, r, *self._read("plan_b", lvl))
        return _knn_select(np.asarray(dist)[:, ix.perm], k)

    def _far_slice(self, dist: jnp.ndarray, lo: int,
                   hi: int) -> np.ndarray:
        """Per-row farness contribution of perm-id columns [lo, hi) —
        summed on the host in float64 so integer-valued distances
        accumulate exactly (the top-k prune must never overshoot)."""
        d = np.asarray(dist[:, lo:hi])
        return np.where(np.isfinite(d), d, 0.0).sum(axis=1,
                                                    dtype=np.float64)

    def ssd_bounded(self, sources: np.ndarray, threshold: float
                    ) -> Tuple[Optional[np.ndarray], bool]:
        """SSD that may abandon mid-backward-sweep once every row's
        farness provably exceeds ``threshold`` (the top-k closeness
        prune, DESIGN.md §7).

        The backward sweep finalizes labels level by level descending:
        after the level at graph level ``g``, every id ``>=
        level_ptr[g]`` is final (later levels only scatter lower).  The
        running sum of finite finalized distances is therefore a lower
        bound on each row's farness; when it exceeds ``threshold`` for
        every row the remaining levels go unread.  Returns
        ``(dist_in_original_order, True)`` for a completed sweep —
        bit-identical to :meth:`ssd` — or ``(None, False)``.
        """
        sources = np.asarray(sources, dtype=np.int32)
        ix = self.index
        lp = ix.level_ptr
        dist = self._init_dist(ix.perm[sources])
        for lvl in range(self.store.n_real("plan_f")):
            dist = self._relax_step(dist, *self._read("plan_f", lvl))
        dist = self._apply_core(dist)
        nb = self.store.n_real("plan_b")
        if nb:
            cut = int(lp[int(self._level_ids_b[0]) + 1])
            far = self._far_slice(dist, cut, dist.shape[1])
            if np.all(far > threshold):
                return None, False
            for lvl in range(nb):
                dist = self._relax_step(dist, *self._read("plan_b", lvl))
                new_cut = int(lp[int(self._level_ids_b[lvl])])
                far += self._far_slice(dist, new_cut, cut)
                cut = new_cut
                if lvl + 1 < nb and np.all(far > threshold):
                    return None, False
        return np.asarray(dist)[:, ix.perm], True

    def close(self) -> None:
        if self._pipe is not None:
            self._pipe.close()
        self.store.close()
