"""Store-backed streaming query execution (DESIGN.md §6).

:class:`StreamingQueryEngine` answers the same batched SSD/SSSP queries
as :class:`~repro.core.query.QueryEngine` but never materializes a
whole :class:`~repro.core.index.SweepPlan`: each sweep walks its
segment file level by level, pulling one ``[M_pad, K_fix]`` slab at a
time through the store's page cache and feeding it to a jitted,
state-donating level step (`QueryEngine._run_plan_stream`).  Peak plan
memory is therefore O(largest level), not O(index), and the
``IOStats`` on the store's :class:`~repro.core.io_sim.BlockDevice`
record the *actual* block reads the query caused (cache misses), not a
synthetic charge.

Answers are bit-identical to the in-memory engine: the level bodies are
the same methods, applied to the same slab values in the same order —
``lax.scan`` over resident levels and a Python loop over streamed
levels compose identical (min, +)/max scatters.  SSSP reconstruction
walks the plans in the order ``plan_b → plan_core → plan_f`` (the
reverse of the distance pass, for cache reuse); the per-plan
max-merges commute, so predecessors stay bit-identical to the
in-memory executor's ``f → core → b`` order (asserted in
tests/test_storage.py).

**Recon pinning** (ROADMAP "recon reuse"; DESIGN.md §6): an SSSP query
re-reads every distance-pass block during reconstruction, so the
distance sweeps pin the levels they stream (``PageCache`` pin leases,
bounded by the pin budget) and reconstruction unpins each level right
after consuming it.  ``plan_b`` is re-read first and is usually still
warm even unpinned; ``plan_f`` — touched a whole sweep earlier, i.e.
exactly the blocks a cyclic-thrash policy would have dropped — is the
one the pins save.  A ``finally`` ledger releases any leftover leases
even when a sweep raises.

``prefetch=True`` overlaps the next level's block reads with the
current level's *compute* on a single background thread — the
streaming analogue of read-ahead.  For a v5 codec store the prefetch
thread also runs the decompress-on-fill work, so decode overlaps the
query thread's jit step the same way the read does.  Caveat: fills
(read + CRC + decode) run under the page cache's one lock — by design,
so budget accounting stays exact and disk access serializes like the
modeled one-spindle device — so a query-thread cache *hit* that races
an in-flight prefetch fill waits for that fill; prefetch buys overlap
with compute, not with other cache traffic.  The page cache and
segment readers are thread-safe (that one lock, ``os.pread``), so the
prefetcher needs no extra coordination.  Loader failures (e.g. a CRC mismatch on a corrupt
segment) always surface in the querying thread: the level generator
re-raises the prefetched exception on the next pull, and if the
consumer abandons the sweep mid-stream the generator's cleanup drains
the in-flight future so the error is never silently swallowed.
"""
from __future__ import annotations

import concurrent.futures
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import shardlib as sl
from ..core.query import INF, QueryEngine
from .blockfile import IndexStore

__all__ = ["StreamingQueryEngine"]


class StreamingQueryEngine(QueryEngine):
    """Batched SSD/SSSP over an :class:`IndexStore`, one level slab at a
    time.

    Supports ``core_mode`` ``"closure"`` and ``"bellman"`` (the jitted
    core searches over the resident tier) and ``"dijkstra"`` (host heap
    over the resident core CSR).  The resident tier — permutations,
    core closure/CSR — stays in memory; the three plan segments stream.
    """

    def __init__(self, store: IndexStore, core_mode: str = "closure",
                 use_pallas: bool = False, eps: float = 0.0,
                 interpret: Optional[bool] = None, prefetch: bool = True):
        self.store = store
        self.prefetch = bool(prefetch)
        self._init_engine(store.resident, core_mode, use_pallas, eps,
                          interpret)
        self._core_jit = jax.jit(
            lambda dist: self._core_update(dist, self.core_mode))
        # Level steps: state (arg 0) is donated, so the sweep runs with
        # one live state buffer + one level slab.  assoc is an operand
        # of both steps (unused by relax) so they share a signature.
        self._relax_step = jax.jit(
            lambda dist, dst, src, w, assoc, valid:
            self._relax_level(dist, dst, src, w, assoc, valid),
            donate_argnums=0)
        self._recon_step = jax.jit(
            lambda pred, dist, dst, src, w, assoc, valid:
            self._recon_level(pred, dist, dst, src, w, assoc, valid),
            donate_argnums=0)
        self._pool = (concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="hod-prefetch")
            if self.prefetch else None)

    # ------------------------------------------------------------- streaming
    def _levels(self, name: str, pin: bool = False,
                unpin_after: bool = False) -> Iterator[tuple]:
        """Yield one plan's level slabs in scan order.

        ``pin=True`` takes a pin lease on every block read (the
        distance pass of an SSSP query); ``unpin_after=True`` releases
        a level's leases right after the consumer finishes with it
        (the reconstruction pass).  With prefetching, the next level's
        blocks stay in flight on the background thread; the in-flight
        future is always drained — ``fut.result()`` re-raises loader
        exceptions in the querying thread, and the ``finally`` below
        collects the pending future when the consumer abandons the
        sweep, so a failed prefetch read can never be silently lost.
        """
        n = self.store.n_real(name)
        read = lambda lvl: self.store.read_level(name, lvl, pin=pin)
        if self._pool is None or n <= 1:
            for lvl in range(n):
                yield read(lvl)
                if unpin_after:
                    self.store.unpin_level(name, lvl)
            return
        fut = self._pool.submit(read, 0)
        try:
            for lvl in range(n):
                slab = fut.result()
                fut = (self._pool.submit(read, lvl + 1)
                       if lvl + 1 < n else None)
                yield slab
                if unpin_after:
                    self.store.unpin_level(name, lvl)
        finally:
            # Consumer may abandon the generator mid-sweep (its own
            # exception, or a failed fut.result() above): collect the
            # in-flight future so its error/fd use is not left dangling.
            if fut is not None and not fut.cancel():
                try:
                    fut.exception()
                except concurrent.futures.CancelledError:
                    pass

    def _sweep(self, state: jnp.ndarray, name: str, step,
               pin: bool = False) -> jnp.ndarray:
        return self._run_plan_stream(state, self._levels(name, pin=pin),
                                     step)

    def _init_dist(self, sources_perm: np.ndarray) -> jnp.ndarray:
        s = sources_perm.shape[0]
        dist = jnp.full((s, self.index.n_pad), INF, jnp.float32)
        dist = dist.at[jnp.arange(s), jnp.asarray(sources_perm)].set(0.0)
        return sl.shard(dist, "batch", None)

    def _ssd_stream(self, sources_perm: np.ndarray,
                    pin: bool = False) -> jnp.ndarray:
        dist = self._init_dist(sources_perm)
        dist = self._sweep(dist, "plan_f", self._relax_step, pin=pin)
        if self.index.n_core:
            if self.core_mode == "dijkstra":
                # Paper-faithful host heap over the resident core CSR —
                # the same shared helper the in-memory validation mode
                # uses (QueryEngine._core_dijkstra_host).
                dist = jnp.asarray(self._core_dijkstra_host(np.array(dist)))
            else:
                dist = self._core_jit(dist)
        return self._sweep(dist, "plan_b", self._relax_step, pin=pin)

    def _unpin_plan(self, name: str) -> None:
        """Release every pin lease a distance sweep may still hold on
        one plan's levels (idempotent; sticky segment pins unaffected)."""
        for lvl in range(self.store.n_real(name)):
            self.store.unpin_level(name, lvl)

    # ---------------------------------------------------------------- public
    def ssd(self, sources: np.ndarray) -> np.ndarray:
        sources = np.asarray(sources, dtype=np.int32)
        dist = self._ssd_stream(self.index.perm[sources])
        return np.asarray(dist)[:, self.index.perm]

    def sssp(self, sources: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        sources = np.asarray(sources, dtype=np.int32)
        try:
            # Distance pass pins the levels it streams: reconstruction
            # re-reads all of them immediately after (recon reuse).
            dist = self._ssd_stream(self.index.perm[sources], pin=True)
            pred = jnp.full((dist.shape[0], self.index.n_pad), -1,
                            jnp.int32)
            # Reverse plan order for cache affinity: plan_b was streamed
            # moments ago, plan_f a whole sweep ago (the pinned one).
            # The per-plan scatter-maxes commute, so pred is
            # bit-identical to the in-memory f -> core -> b order.
            for name in ("plan_b", "plan_core", "plan_f"):
                pred = self._run_plan_stream(
                    pred, self._levels(name, unpin_after=True),
                    lambda p, *slab: self._recon_step(p, dist, *slab))
        finally:
            for name in ("plan_f", "plan_b"):
                self._unpin_plan(name)
        dist = np.asarray(dist)[:, self.index.perm]
        pred = np.asarray(pred)[:, self.index.perm]
        return dist, pred

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
        self.store.close()
