"""Store-backed streaming query execution (DESIGN.md §6).

:class:`StreamingQueryEngine` answers the same batched SSD/SSSP queries
as :class:`~repro.core.query.QueryEngine` but never materializes a
whole :class:`~repro.core.index.SweepPlan`: each sweep walks its
segment file level by level, pulling one ``[M_pad, K_fix]`` slab at a
time through the store's page cache and feeding it to a jitted,
state-donating level step (`QueryEngine._run_plan_stream`).  Peak plan
memory is therefore O(largest level), not O(index), and the
``IOStats`` on the store's :class:`~repro.core.io_sim.BlockDevice`
record the *actual* block reads the query caused (cache misses), not a
synthetic charge.

Answers are bit-identical to the in-memory engine: the level bodies are
the same methods, applied to the same slab values in the same order —
``lax.scan`` over resident levels and a Python loop over streamed
levels compose identical (min, +)/max scatters.

``prefetch=True`` overlaps the next level's block reads with the
current level's compute on a single background thread — the streaming
analogue of read-ahead.  The page cache and segment readers are
thread-safe (one lock, ``os.pread``), so the prefetcher needs no extra
coordination: the prefetched slab is handed straight to the compute
loop (its blocks also land in the cache for later sweeps; the compute
loop does not re-fetch them).
"""
from __future__ import annotations

import concurrent.futures
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import shardlib as sl
from ..core.query import INF, QueryEngine
from .blockfile import IndexStore

__all__ = ["StreamingQueryEngine"]


class StreamingQueryEngine(QueryEngine):
    """Batched SSD/SSSP over an :class:`IndexStore`, one level slab at a
    time.

    Supports ``core_mode`` ``"closure"`` and ``"bellman"`` (the jitted
    core searches over the resident tier) and ``"dijkstra"`` (host heap
    over the resident core CSR).  The resident tier — permutations,
    core closure/CSR — stays in memory; the three plan segments stream.
    """

    def __init__(self, store: IndexStore, core_mode: str = "closure",
                 use_pallas: bool = False, eps: float = 0.0,
                 interpret: Optional[bool] = None, prefetch: bool = True):
        self.store = store
        self.prefetch = bool(prefetch)
        self._init_engine(store.resident, core_mode, use_pallas, eps,
                          interpret)
        self._core_jit = jax.jit(
            lambda dist: self._core_update(dist, self.core_mode))
        # Level steps: state (arg 0) is donated, so the sweep runs with
        # one live state buffer + one level slab.  assoc is an operand
        # of both steps (unused by relax) so they share a signature.
        self._relax_step = jax.jit(
            lambda dist, dst, src, w, assoc, valid:
            self._relax_level(dist, dst, src, w, assoc, valid),
            donate_argnums=0)
        self._recon_step = jax.jit(
            lambda pred, dist, dst, src, w, assoc, valid:
            self._recon_level(pred, dist, dst, src, w, assoc, valid),
            donate_argnums=0)
        self._pool = (concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="hod-prefetch")
            if self.prefetch else None)

    # ------------------------------------------------------------- streaming
    def _levels(self, name: str) -> Iterator[tuple]:
        """Yield one plan's level slabs in scan order, optionally keeping
        the next level's blocks in flight on the prefetch thread."""
        n = self.store.n_real(name)
        if self._pool is None or n <= 1:
            for lvl in range(n):
                yield self.store.read_level(name, lvl)
            return
        fut = self._pool.submit(self.store.read_level, name, 0)
        for lvl in range(n):
            slab = fut.result()
            if lvl + 1 < n:
                fut = self._pool.submit(self.store.read_level, name,
                                        lvl + 1)
            yield slab

    def _sweep(self, state: jnp.ndarray, name: str, step) -> jnp.ndarray:
        return self._run_plan_stream(state, self._levels(name), step)

    def _init_dist(self, sources_perm: np.ndarray) -> jnp.ndarray:
        s = sources_perm.shape[0]
        dist = jnp.full((s, self.index.n_pad), INF, jnp.float32)
        dist = dist.at[jnp.arange(s), jnp.asarray(sources_perm)].set(0.0)
        return sl.shard(dist, "batch", None)

    def _ssd_stream(self, sources_perm: np.ndarray) -> jnp.ndarray:
        dist = self._init_dist(sources_perm)
        dist = self._sweep(dist, "plan_f", self._relax_step)
        if self.index.n_core:
            if self.core_mode == "dijkstra":
                # Paper-faithful host heap over the resident core CSR —
                # the same shared helper the in-memory validation mode
                # uses (QueryEngine._core_dijkstra_host).
                dist = jnp.asarray(self._core_dijkstra_host(np.array(dist)))
            else:
                dist = self._core_jit(dist)
        return self._sweep(dist, "plan_b", self._relax_step)

    # ---------------------------------------------------------------- public
    def ssd(self, sources: np.ndarray) -> np.ndarray:
        sources = np.asarray(sources, dtype=np.int32)
        dist = self._ssd_stream(self.index.perm[sources])
        return np.asarray(dist)[:, self.index.perm]

    def sssp(self, sources: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        sources = np.asarray(sources, dtype=np.int32)
        dist = self._ssd_stream(self.index.perm[sources])
        pred = jnp.full((dist.shape[0], self.index.n_pad), -1, jnp.int32)
        for name in ("plan_f", "plan_core", "plan_b"):
            pred = self._run_plan_stream(
                pred, self._levels(name),
                lambda p, *slab: self._recon_step(p, dist, *slab))
        dist = np.asarray(dist)[:, self.index.perm]
        pred = np.asarray(pred)[:, self.index.perm]
        return dist, pred

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
        self.store.close()
