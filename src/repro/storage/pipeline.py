"""Queue-depth-N async read pipeline with off-thread decompression
(DESIGN.md §6).

The sweep visits a segment's levels in a fixed order (the paper's §4
sequential-scan invariant), which makes deep read-ahead safe:
:class:`ReadPipeline` keeps up to ``queue_depth`` levels' block reads
in flight — io_uring-style submit/reap with ordered completion over
the modeled :class:`~repro.core.io_sim.BlockDevice` — and runs codec
CPU work (CRC verify, delta varint decode, f16 widening) on a
``decode_workers``-wide worker pool so a fill never blocks the query
thread's jit step.

Three stages, three execution domains::

    query thread        submit_level(): per-block cache transaction
      (submit)          (hit/miss/eviction/pin/byte counters) AND the
                        modeled-device charge via
                        PageCache.begin_fill(charge=...) — a
                        PendingBlock of the known decoded size is
                        admitted immediately; contiguous missed-block
                        runs become one batched extent pread job
    io thread (1)       ordered preads (SegmentReader.read_frames);
      (read)            hands each frame to...
    decode pool (M)     CRC verify + codec decode
      (decode)          (SegmentReader.decode_frame), completing the
                        PendingBlock in place; a corrupt frame is
                        discarded from the cache and the error
                        re-raises in whichever thread waits

**Determinism.** All counter mutations — cache *and* modeled device —
happen at submit time on the query thread, in the exact block order
the synchronous path uses, so hit/miss/eviction/``bytes_read`` and
seq/random-block sequences are bit-identical at every queue depth
(the ``bytes_read``/device charges use the frame table's ``comp_len``
— known before the read happens).  Charging the device inside
``begin_fill``'s lock (rather than on the io thread, as earlier
revisions did) also makes the compound stats reset atomic:
``PageCache.reset_stats(also=[device.reset, pipeline.stats.reset])``
cannot interleave with a half-charged fill (ISSUE-8 satellite).  The
price: a read that subsequently *fails* has already been charged —
accepted, it is the fault path only.  Only payload materialization is
asynchronous; answers are bit-identical because the slabs are
byte-identical.

**Tracing** (DESIGN.md §11): given a ``tracer``, each submitted level
draws a span id that stitches its story across threads — a
``pipe.submit`` span (synthetic ``submit`` track, so the query
thread's own sequence stays depth-invariant), a ``level.read`` span on
the io thread, ``level.decode`` spans on the decode pool, and a
``level.wait`` span around the reaper's collect.  ``tracer=None``
compiles every hook down to one attribute check.

**Stall accounting.** Per reaped level the pipeline records the
measured consumer compute time and the level's *modeled* device time
(an ``IOStats`` delta around its reads — deterministic), then runs a
small discrete-event simulation of the one-spindle device under the
submit window "level *i* may start once level *i − depth* was reaped":
``stall_model_s`` is the modeled time the consumer would wait on the
device, directly comparable across queue depths because the modeled
I/O is identical.  ``stall_wall_s`` is the measured wait and
``ttfl_s`` the measured time-to-first-level of the first sweep since
the last stats reset.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List

from ..core.io_sim import IOStats
from ..obs.trace import span_if
from .pagecache import PendingBlock

__all__ = ["PipelineStats", "ReadPipeline"]


@dataclasses.dataclass
class PipelineStats:
    levels: int = 0             # levels reaped
    submitted: int = 0          # levels submitted
    stall_model_s: float = 0.0  # modeled consumer wait on the device
    stall_wall_s: float = 0.0   # measured wait for in-flight fills
    compute_s: float = 0.0      # measured consumer time between reaps
    ttfl_s: float = 0.0         # time-to-first-level, first sweep since reset

    def snapshot(self) -> "PipelineStats":
        return dataclasses.replace(self)

    def reset(self) -> None:
        """Zero every counter in place (the pipeline holds a reference
        to this object, so callers reset rather than replace it)."""
        self.__init__()

    def __sub__(self, other: "PipelineStats") -> "PipelineStats":
        return PipelineStats(self.levels - other.levels,
                             self.submitted - other.submitted,
                             self.stall_model_s - other.stall_model_s,
                             self.stall_wall_s - other.stall_wall_s,
                             self.compute_s - other.compute_s,
                             self.ttfl_s - other.ttfl_s)


class _LevelTicket:
    """One submitted level: its cache entries (bytes or in-flight
    :class:`PendingBlock` placeholders), the per-shard-device modeled
    seconds vector of the reads this level owned (computed at submit
    time, before the ticket is visible to anyone; length 1 on a solo
    store, empty for a zero-row level), and the trace span id
    stitching its read/decode/wait events together."""

    __slots__ = ("seg", "name", "lvl", "skip", "entries", "io_s",
                 "span_id")

    def __init__(self, seg, lvl: int, entries: list, skip: int,
                 name: str = "", span_id: int = 0):
        self.seg, self.lvl, self.skip = seg, lvl, skip
        self.name = name
        self.entries = entries
        self.io_s = ()
        self.span_id = span_id

    def collect(self):
        """Wait for every entry, assemble + parse the slab.  Returns
        ``(slab, measured_wait_seconds)``; re-raises a failed fill."""
        t0 = time.perf_counter()
        parts = [e.wait() if isinstance(e, PendingBlock) else e
                 for e in self.entries]
        stall_wall = time.perf_counter() - t0
        buf = self.seg.clip_level(b"".join(parts), self.lvl, self.skip)
        return self.seg.parse_slab(buf, self.lvl), stall_wall

    def drain(self) -> None:
        """Wait out in-flight fills, swallowing their errors — the
        abandon path (the consumer already has its exception; an
        in-flight failure must not be lost *or* double-raised)."""
        for e in self.entries:
            if isinstance(e, PendingBlock):
                try:
                    e.wait()
                except Exception:
                    pass


class ReadPipeline:
    """Submit/reap pipeline over one :class:`IndexStore`'s segments.

    One pipeline serves one sweep at a time (the engine's levels are
    strictly ordered); ``submit_level`` must be called from the query
    thread — that is what keeps cache accounting deterministic — and
    ``reap`` in submission order.
    """

    def __init__(self, store, queue_depth: int = 4,
                 decode_workers: int = 2, tracer=None):
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if decode_workers < 1:
            raise ValueError("decode_workers must be >= 1")
        self.store = store
        self.tracer = tracer
        self.queue_depth = int(queue_depth)
        self.decode_workers = int(decode_workers)
        self.stats = PipelineStats()
        # A fleet-attached store (repro/fleet) brings its own per-shard
        # worker pools and modeled spindles; the pipeline then splits
        # missed-block runs at ownership boundaries and dispatches each
        # run to its owner — N devices genuinely reading in parallel.
        # Fleet pools outlive this pipeline (the fleet shuts them down
        # with the store); solo pools are owned and closed here.
        fleet = getattr(store, "fleet", None)
        self._fleet = fleet
        if fleet is not None:
            self._io_pools = [s.io for s in fleet.shards]
            self._decode_pools = [s.decode for s in fleet.shards]
            self._devs = [s.device for s in fleet.shards]
            self._owner = fleet.owner_of_key
            self._owns_pools = False
        else:
            self._io_pools = [ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="hod-pipe-io")]
            self._decode_pools = [ThreadPoolExecutor(
                max_workers=self.decode_workers,
                thread_name_prefix="hod-pipe-decode")]
            self._devs = [store.device]
            self._owner = None
            self._owns_pools = True
        self._inflight: List = []   # io futures, drained on close
        self.begin_sweep()

    # ------------------------------------------------------------ lifecycle
    def begin_sweep(self) -> None:
        """Reset the per-sweep stall simulation (virtual clocks start
        at the sweep's first submit; the device timeline does not carry
        across sweeps)."""
        self._sim_t = 0.0           # consumer virtual time
        # per-shard device busy-until virtual times (length 1 solo —
        # the vector math then reduces to the original scalar model)
        self._sim_dev = [0.0] * len(self._devs)
        self._reap_virtual: List[float] = []
        now = time.perf_counter()
        self._sweep_t0 = now
        self._last_reap_wall = now
        self._first_reap = True

    def close(self) -> None:
        if self._owns_pools:
            for pool in self._io_pools + self._decode_pools:
                pool.shutdown(wait=True)
        else:
            # Fleet-owned pools keep running; just wait out our jobs.
            for f in self._inflight:
                if not f.done():
                    f.exception()   # wait; errors already in holders
            self._inflight = []

    # --------------------------------------------------------------- submit
    def submit_level(self, name: str, lvl: int,
                     pin: bool = False) -> _LevelTicket:
        """Submit one level's block reads (query thread).  Runs the
        full per-block cache transaction now — in block order — and
        enqueues one batched pread per contiguous missed-block run."""
        seg = self.store.segments[name]
        self.stats.submitted += 1
        tr = self.tracer
        sid = tr.new_id() if tr is not None else 0
        if seg.version >= 4 and seg.extents[lvl][1] == 0:
            return _LevelTicket(seg, lvl, [], 0, name=name,
                                span_id=sid)   # zero-row level
        b0, b1, skip = seg._level_blocks(lvl)
        pin = pin or seg.pin_blocks
        dev = seg.device
        snaps = [(d.stats.seq_blocks, d.stats.rand_blocks)
                 for d in self._devs]
        entries: list = []
        # [(shard, b_lo, [(block, key, holder)...])]: a run breaks on
        # a block-number gap OR a shard-ownership boundary, so each
        # run is one pread against one shard's local extent.
        runs: list = []
        route = self._owner
        with span_if(tr, "pipe.submit", track="submit", plan=name,
                     level=lvl, span=sid, blocks=b1 - b0 + 1):
            for b in range(b0, b1 + 1):
                key = (seg._cache_ns, b)
                size, disk = seg.frame_info(b)
                entry, owner = self.store.cache.begin_fill(
                    key, size, disk, pin=pin,
                    charge=(lambda b=b, d=disk:
                            dev.access_block(seg.base_block + b, d)))
                entries.append(entry)
                if owner:
                    shard = route(key) if route is not None else 0
                    if (runs and runs[-1][0] == shard
                            and runs[-1][2][-1][0] == b - 1):
                        runs[-1][2].append((b, key, entry))
                    else:
                        runs.append((shard, b, [(b, key, entry)]))
        ticket = _LevelTicket(seg, lvl, entries, skip, name=name,
                              span_id=sid)
        ticket.io_s = tuple(
            IOStats(seq_blocks=d.stats.seq_blocks - s0,
                    rand_blocks=d.stats.rand_blocks - r0
                    ).modeled_seconds(block_bytes=dev.block_bytes)
            for d, (s0, r0) in zip(self._devs, snaps))
        if runs:
            by_shard: dict = {}
            for shard, b_lo, owned in runs:
                by_shard.setdefault(shard, []).append((b_lo, owned))
            for shard, shard_runs in by_shard.items():
                self._inflight.append(self._io_pools[shard].submit(
                    self._read_job, seg, ticket, shard_runs,
                    self._decode_pools[shard]))
        return ticket

    def _read_job(self, seg, ticket: _LevelTicket, runs: list,
                  decode_pool: ThreadPoolExecutor) -> None:
        """io thread (per shard): batched extent preads, then fan the
        frames out to the shard's decode pool.  Cache and device
        accounting already happened at submit time — this thread only
        moves bytes."""
        try:
            decode_jobs = []
            with span_if(self.tracer, "level.read", plan=ticket.name,
                         level=ticket.lvl, parent=ticket.span_id,
                         runs=len(runs)):
                for b_lo, owned in runs:
                    try:
                        raw = seg.read_frames(b_lo, owned[-1][0])
                    except Exception as exc:
                        for _b, key, holder in owned:
                            self.store.cache.discard(key, holder)
                            holder.fail(exc)
                        continue
                    for b, key, holder in owned:
                        decode_jobs.append(
                            (seg, b, key, holder,
                             seg.frame_slice(raw, b_lo, b)))
            for job in decode_jobs:
                decode_pool.submit(self._decode_job, *job,
                                   ticket.span_id)
        except BaseException as exc:
            # Never leave a holder unset: every waiter would deadlock.
            for _b_lo, owned in runs:
                for _b, key, holder in owned:
                    if holder.data is None and holder.error is None:
                        self.store.cache.discard(key, holder)
                        holder.fail(exc)

    def _decode_job(self, seg, block: int, key, holder: PendingBlock,
                    raw: bytes, span_id: int = 0) -> None:
        """decode pool: CRC verify + codec decode, completing the
        placeholder.  A corrupt frame is dropped from the cache and the
        error re-raises in the waiting query thread."""
        with span_if(self.tracer, "level.decode", block=block,
                     parent=span_id):
            try:
                data = seg.decode_frame(block, raw)
            except BaseException as exc:
                self.store.cache.discard(key, holder)
                holder.fail(exc)
            else:
                holder.set(data)

    # ----------------------------------------------------------------- reap
    def reap(self, ticket: _LevelTicket):
        """Reap the oldest in-flight level (submission order): wait for
        its fills, parse the slab, and advance the stall simulation."""
        t0 = time.perf_counter()
        compute = t0 - self._last_reap_wall
        with span_if(self.tracer, "level.wait", plan=ticket.name,
                     level=ticket.lvl, span=ticket.span_id):
            slab, stall_wall = ticket.collect()
        # Discrete-event model of the spindle(s) under the depth-N
        # submit window (module docstring).  One busy-until clock per
        # shard device: a level completes when its *slowest* shard's
        # reads land, so fleet stall is the max over shards — spindles
        # work in parallel, which is exactly the fleet speedup story.
        # At one device the vector math is the original scalar model.
        i = len(self._reap_virtual)
        self._sim_t += compute
        window = (self._reap_virtual[i - self.queue_depth]
                  if i >= self.queue_depth else 0.0)
        io_v = (ticket.io_s if ticket.io_s
                else (0.0,) * len(self._sim_dev))
        dev_done = [max(sd, window) + io
                    for sd, io in zip(self._sim_dev, io_v)]
        stall = max(0.0, max(dev_done) - self._sim_t)
        self._sim_t += stall
        self._sim_dev = dev_done
        self._reap_virtual.append(self._sim_t)
        st = self.stats
        st.levels += 1
        st.compute_s += compute
        st.stall_model_s += stall
        st.stall_wall_s += stall_wall
        self._last_reap_wall = time.perf_counter()
        if self._first_reap:
            self._first_reap = False
            if st.ttfl_s == 0.0:
                st.ttfl_s = self._last_reap_wall - self._sweep_t0
        return slab

    def drain(self, tickets) -> None:
        """Abandon path: wait out every in-flight ticket's fills so no
        error is lost and no placeholder is left incomplete (a later
        cache hit on one would otherwise wait forever)."""
        for t in tickets:
            t.drain()
        self._inflight = [f for f in self._inflight if not f.done()]
