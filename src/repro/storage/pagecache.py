"""Bounded-byte page cache over block-file segments (DESIGN.md §6).

The store's unit of I/O is one fixed-size block of a segment file
(`storage/blockfile.py`); the cache's unit of residency is the same
block.  :class:`PageCache` keeps at most ``capacity_bytes`` of blocks
resident and answers every block fetch either from memory (*hit* — no
device charge) or by invoking the caller's loader (*miss* — the loader
reads the block from the segment file and meters it through the shared
:class:`~repro.core.io_sim.BlockDevice`, so ``IOStats`` reflects actual
bytes read).  Format-v5 codec segments *decompress on fill*: the loader
hands back the decompressed block together with the compressed byte
count it read, so the byte budget and residency meter **decompressed**
(usable) bytes while ``bytes_read``/``IOStats`` meter the
**compressed** bytes that actually moved — the hit-rate-vs-budget
tradeoff the ``codec`` column in BENCH_serve measures (DESIGN.md §6).

Four eviction policies:

* ``"lru"`` (default) — strict least-recently-used order;
* ``"clock"`` — second-chance/CLOCK: a hit sets the block's reference
  bit instead of moving it, and the eviction hand skips (and clears)
  referenced blocks once before evicting;
* ``"arc"`` / ``"2q"`` — *scan-resistant* policies for the cyclic
  sweep workload (DESIGN.md §6).  Plain LRU/CLOCK retain **nothing**
  across a sweep whose block footprint exceeds the budget (the classic
  cyclic-scan thrash: every block is evicted moments before it would
  be re-read), so partial budgets buy a 0% hit rate.  Both policies
  here share the same scan-resistant skeleton:

  - **warm fill** — while the budget has free room, cold blocks enter
    the *main* region.  Once full, the main region is frozen against
    scans: a cold block can never evict main-region residents.
  - **window** — cold blocks arriving at a full cache enter a small
    FIFO *window* (``WINDOW_FRAC`` of the budget, always keeping the
    most recent block) that only evicts within itself.  The window
    serves the short-range re-references the affinity block layout
    creates (adjacent levels sharing a boundary block) without letting
    a once-per-sweep scan touch the main region.
  - **ghost-gated admission** — window victims leave a *ghost* (key
    only, no data).  Only a block re-referenced while its ghost is
    live is admitted into the main region, evicting per policy.  On a
    pure cyclic scan the ghosts roll over before the cycle returns,
    so the frozen prefix is never eroded and every sweep re-hits it.

  They differ in the main region itself: ``"2q"`` keeps one LRU list
  (2Q's ``Am``; the window is its ``A1in``, the ghost list its
  ``A1out``), while ``"arc"`` keeps ARC's ``T1``/``T2`` split with
  dual ghost lists ``B1``/``B2`` and the adaptive target ``p``
  (byte-weighted: a ``B1`` ghost hit grows ``p`` by the block's size,
  a ``B2`` hit shrinks it).  These are deliberate deviations from the
  textbook formulations — textbook ARC and full-2Q both degrade to
  LRU-like 0% retention on a cyclic scan larger than the cache (cold
  misses never form ghosts / ghost lists roll over), which is exactly
  the regime this store lives in.  The deltas are documented in
  DESIGN.md §6 and locked in by the trace-driven reference models in
  ``tests/test_cache_policies.py``.

**Pinning** (segment-aware admission, DESIGN.md §6): ``get(...,
pin=True)`` moves the block into a pinned region that eviction never
touches, bounded by ``pin_frac`` of the budget (requests beyond the
pin budget degrade to normal caching — never an error).  The store
pins the small ``plan_core`` segment resident so once-per-sweep
``plan_f`` scans can never evict it, and SSSP reconstruction pins the
levels the distance pass just touched (they are immediately re-read);
:meth:`unpin` releases blocks back to the main region's MRU position.

The cache is shared by every segment of a store and by the read
pipeline (`storage/stream.py` / `storage/pipeline.py`), so all state —
residency map, byte budget, counters — is guarded by one lock.  On the
synchronous :meth:`get` path the lock is *held across the loader
call*: concurrent queries serialize on disk reads, which keeps budget
enforcement exact (resident bytes never exceed ``capacity_bytes``,
pinned included) and matches the one-spindle device model.

**Pipelined fills** (:meth:`begin_fill`, DESIGN.md §6): the async read
pipeline admits a :class:`PendingBlock` placeholder *before* the read
happens — decoded block sizes are known ahead of time (always
``block_bytes``), so every cache-state transition (hit/miss counting,
admission, eviction, pinning, byte metering) runs on the query thread
at submit time, in exactly the block order the synchronous path would
use.  Only the payload (pread + CRC + codec decode, off-thread) is
asynchronous: the worker completes the placeholder in place, and any
consumer — the pipeline's level tickets, or a synchronous :meth:`get`
hit racing an in-flight fill — waits on it *outside* the lock.  Hit /
miss / eviction / ``bytes_read`` sequences are therefore bit-identical
at every queue depth, including depth 1 and the no-pipeline path.  A
failed fill (CRC mismatch) is :meth:`discard`-ed by the worker and the
error re-raises in every waiting thread.

**Observability hook** (DESIGN.md §11): setting :attr:`on_event` to a
callable ``(kind, key, nbytes)`` reports every ``"hit"`` / ``"miss"``
/ ``"evict"`` transition, fired *under the lock* at the exact point
the counters move — so the event order equals the counter order, and
the cross-depth determinism contract extends to the event stream.
The hook must be cheap and must never call back into the cache (the
tracer's buffered ``instant`` qualifies).  ``None`` (default)
disables it at the cost of one attribute check.

**Atomic resets**: pipelined fills charge the shared
:class:`~repro.core.io_sim.BlockDevice` through :meth:`begin_fill`'s
``charge`` callback — under this same lock — and
:meth:`reset_stats`'s ``also=`` callbacks (device reset, pipeline
stats reset) run under it too, so a compound stats reset can never
land *between* a cache counter and its paired device charge, even
with fills in flight (ISSUE-8's reset-raciness fix, regression-tested
in tests/test_pipeline.py).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Hashable, Iterable, Optional

__all__ = ["CacheStats", "PageCache", "PendingBlock", "POLICIES"]

POLICIES = ("lru", "clock", "arc", "2q")


class PendingBlock:
    """Placeholder for a block whose fill is in flight (pipelined read).

    The decoded size is known up front, so the placeholder occupies the
    block's budget immediately (``len()`` reports it); the payload
    arrives later via :meth:`set` (or :meth:`fail`, which re-raises the
    fill error in every waiter).  The object stays in the cache after
    completion — lookups transparently :meth:`wait` on it."""

    __slots__ = ("size", "data", "error", "_done")

    def __init__(self, size: int):
        self.size = int(size)
        self.data: Optional[bytes] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def __len__(self) -> int:
        return self.size

    def set(self, data: bytes) -> None:
        self.data = data
        self._done.set()

    def fail(self, exc: BaseException) -> None:
        self.error = exc
        self._done.set()

    def wait(self) -> bytes:
        """Block until the fill completes; re-raise a failed fill."""
        self._done.wait()
        if self.error is not None:
            raise self.error
        return self.data


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_read: int = 0     # actual "disk" bytes loaders consumed
    peak_bytes: int = 0     # high-water mark of resident bytes
    ghost_hits: int = 0     # misses whose key had a live ghost (arc/2q)
    bytes_filled: int = 0   # decompressed bytes handed back by loaders
    pinned_bytes: int = 0   # gauge: bytes currently pinned resident

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __sub__(self, other: "CacheStats") -> "CacheStats":
        """Counter delta (for per-batch reporting); the gauges (peak,
        pinned bytes) are kept as-is."""
        return CacheStats(self.hits - other.hits,
                          self.misses - other.misses,
                          self.evictions - other.evictions,
                          self.bytes_read - other.bytes_read,
                          self.peak_bytes,
                          self.ghost_hits - other.ghost_hits,
                          self.bytes_filled - other.bytes_filled,
                          self.pinned_bytes)

    def __add__(self, other: "CacheStats") -> "CacheStats":
        """Fleet aggregation (``repro.fleet``): counters sum; the
        gauges sum too — the fleet-wide peak/pinned figure is the sum
        of per-shard residency highs (an upper bound on simultaneous
        residency, the budget-accounting side callers care about)."""
        return CacheStats(self.hits + other.hits,
                          self.misses + other.misses,
                          self.evictions + other.evictions,
                          self.bytes_read + other.bytes_read,
                          self.peak_bytes + other.peak_bytes,
                          self.ghost_hits + other.ghost_hits,
                          self.bytes_filled + other.bytes_filled,
                          self.pinned_bytes + other.pinned_bytes)

    def snapshot(self) -> "CacheStats":
        return dataclasses.replace(self)


class PageCache:
    """Block cache with a hard byte budget and four eviction policies.

    ``capacity_bytes=None`` means unbounded (everything read stays
    resident — the 100%-of-index serving regime); ``capacity_bytes=0``
    disables caching entirely (every fetch is a miss).  A single block
    larger than the whole budget is returned to the caller but never
    cached.  See the module docstring for the ``"arc"``/``"2q"`` state
    machines and the pinning protocol.
    """

    #: fraction of the budget the scan-resistant policies reserve for
    #: the cold-block window (at least the most recent block is always
    #: kept, even when one block exceeds the window share).
    WINDOW_FRAC = 0.125
    #: default fraction of the budget pinned blocks may occupy; pin
    #: requests beyond it degrade to normal (unpinned) caching.  The
    #: per-instance knob is the ``pin_frac`` constructor arg.
    PIN_FRAC = 0.5

    def __init__(self, capacity_bytes: Optional[int] = None,
                 policy: str = "lru", pin_frac: Optional[float] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown eviction policy: {policy!r}")
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0 or None")
        pin_frac = self.PIN_FRAC if pin_frac is None else float(pin_frac)
        if not 0.0 <= pin_frac <= 1.0:
            raise ValueError("pin_frac must be in [0, 1]")
        self.capacity_bytes = capacity_bytes
        self.policy = policy
        self.pin_frac = pin_frac
        self.stats = CacheStats()
        #: optional observer ``(kind, key, nbytes)`` for hit/miss/evict
        #: transitions (module docstring); fired under the lock.
        self.on_event: Optional[Callable[[str, Hashable, int], None]] = \
            None
        self._lock = threading.Lock()
        # lru/clock primary store: key -> bytes, order per policy
        self._blocks: "collections.OrderedDict[Hashable, bytes]" = \
            collections.OrderedDict()
        self._ref: dict = {}        # CLOCK reference bits
        self._bytes = 0             # bytes in _blocks
        # arc/2q regions (head of each OrderedDict evicts first)
        self._win: "collections.OrderedDict[Hashable, bytes]" = \
            collections.OrderedDict()   # cold-block FIFO window
        self._t1: "collections.OrderedDict[Hashable, bytes]" = \
            collections.OrderedDict()   # ARC T1 (warm fill / seen once)
        self._t2: "collections.OrderedDict[Hashable, bytes]" = \
            collections.OrderedDict()   # ARC T2 / 2Q Am (main LRU)
        self._win_bytes = self._t1_bytes = self._t2_bytes = 0
        self._b1: "collections.OrderedDict[Hashable, int]" = \
            collections.OrderedDict()   # ghosts: key -> block size
        self._b2: "collections.OrderedDict[Hashable, int]" = \
            collections.OrderedDict()
        self._b1_bytes = self._b2_bytes = 0
        self._p = 0.0               # ARC adaptive T1 target (bytes)
        # pinned region: excluded from eviction, counted in the budget
        self._pinned: "collections.OrderedDict[Hashable, bytes]" = \
            collections.OrderedDict()
        self._pinned_bytes = 0

    # ------------------------------------------------------------- interface
    def get(self, key: Hashable, load: Callable[[], bytes],
            pin: bool = False) -> bytes:
        """Return the block for ``key``, loading (and caching) on a miss.

        The loader may return either the block ``bytes``, or a
        ``(bytes, disk_bytes)`` pair when filling costs fewer disk
        bytes than it yields — a codec segment's decompress-on-fill
        (DESIGN.md §6): the *decompressed* block is what gets cached
        (so the byte budget meters resident, usable bytes) while
        ``stats.bytes_read`` advances by the *compressed* bytes the
        loader actually read.  ``stats.bytes_filled`` always meters the
        decompressed side.

        ``pin=True`` additionally pins the block (hit or miss) if the
        pin budget allows; pinned blocks are never evicted until
        :meth:`unpin` releases them.

        A hit on a :class:`PendingBlock` (a fill the read pipeline has
        in flight) waits for that fill *outside* the lock and re-raises
        its error, so synchronous traffic composes with pipelined fills
        without double-reading or double-charging.
        """
        with self._lock:
            data = self._peek_hit(key)
            if data is not None:
                self.stats.hits += 1
                if self.on_event is not None:
                    self.on_event("hit", key, len(data))
                if pin:
                    self._try_pin(key)
            else:
                self.stats.misses += 1
                loaded = load()
                if isinstance(loaded, tuple):
                    data, disk_bytes = loaded
                else:
                    data, disk_bytes = loaded, len(loaded)
                self.stats.bytes_read += disk_bytes
                self.stats.bytes_filled += len(data)
                if self.on_event is not None:
                    self.on_event("miss", key, disk_bytes)
                self._admit(key, data, pin)
                self.stats.peak_bytes = max(self.stats.peak_bytes,
                                            self._resident())
                return data
        if isinstance(data, PendingBlock):
            return data.wait()
        return data

    def begin_fill(self, key: Hashable, size: int, disk_bytes: int,
                   pin: bool = False,
                   charge: Optional[Callable[[], None]] = None):
        """Pipelined-fill admission (the read pipeline's submit step).

        Returns ``(entry, owner)``.  On a hit, ``entry`` is the
        resident value (``bytes`` or an in-flight :class:`PendingBlock`)
        and ``owner`` is False.  On a miss, a fresh
        :class:`PendingBlock` of the (known) decoded ``size`` is
        admitted *now* — counters (``bytes_read`` advances by the
        compressed ``disk_bytes``, ``bytes_filled`` by ``size``),
        evictions and pinning all happen here on the calling thread,
        exactly as a synchronous :meth:`get` miss would — and ``owner``
        is True: the caller must read+decode the block and complete the
        placeholder with ``entry.set(data)`` (or ``entry.fail(exc)``
        after :meth:`discard`).  Determinism contract: calling this in
        block order yields hit/miss/eviction/byte sequences
        bit-identical to the synchronous path, at any queue depth.

        ``charge`` (miss only) runs under the lock right after the byte
        counters move — the pipeline charges the shared block device
        here, so the device and cache counters advance *atomically*
        (exactly like the synchronous path, whose loader runs under
        this lock) and a concurrent :meth:`reset_stats` can never split
        them.
        """
        with self._lock:
            data = self._peek_hit(key)
            if data is not None:
                self.stats.hits += 1
                if self.on_event is not None:
                    self.on_event("hit", key, len(data))
                if pin:
                    self._try_pin(key)
                return data, False
            self.stats.misses += 1
            self.stats.bytes_read += disk_bytes
            self.stats.bytes_filled += size
            if charge is not None:
                charge()
            if self.on_event is not None:
                self.on_event("miss", key, disk_bytes)
            holder = PendingBlock(size)
            self._admit(key, holder, pin)
            self.stats.peak_bytes = max(self.stats.peak_bytes,
                                        self._resident())
            return holder, True

    def discard(self, key: Hashable, entry: "PendingBlock") -> None:
        """Drop a failed pipelined fill (decode worker error path): if
        ``entry`` is still what ``key`` resolves to, remove it so later
        traffic re-reads the block instead of re-raising forever.  Call
        *before* ``entry.fail(exc)``."""
        with self._lock:
            if self._pinned.get(key) is entry:
                self._pinned.pop(key)
                self._pinned_bytes -= len(entry)
                self.stats.pinned_bytes = self._pinned_bytes
                return
            region = self._find_region(key)
            if region is None or region[key] is not entry:
                return
            region.pop(key)
            size = len(entry)
            if region is self._blocks:
                self._bytes -= size
                self._ref.pop(key, None)
            elif region is self._win:
                self._win_bytes -= size
            elif region is self._t1:
                self._t1_bytes -= size
            else:
                self._t2_bytes -= size

    def pin(self, key: Hashable) -> bool:
        """Pin an already-resident block (no-op miss). True if pinned."""
        with self._lock:
            if key in self._pinned:
                return True
            if self._find_region(key) is None:
                return False
            return self._try_pin(key)

    def unpin(self, keys: Iterable[Hashable]) -> None:
        """Release pinned blocks back into the main region (MRU end).

        Unknown / never-pinned keys are ignored, so callers can unpin a
        whole level's key list without tracking which pins stuck.
        """
        with self._lock:
            for key in keys:
                data = self._pinned.pop(key, None)
                if data is None:
                    continue
                self._pinned_bytes -= len(data)
                if self.policy in ("lru", "clock"):
                    self._blocks[key] = data
                    self._bytes += len(data)
                    self._ref[key] = True
                else:                       # arc/2q: main-region MRU
                    self._t2[key] = data
                    self._t2_bytes += len(data)
            self.stats.pinned_bytes = self._pinned_bytes

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident()

    @property
    def pinned_bytes(self) -> int:
        with self._lock:
            return self._pinned_bytes

    def pinned_keys(self):
        with self._lock:
            return list(self._pinned.keys())

    def resident_keys(self):
        """Keys currently cached, in eviction order (head evicts first);
        pinned keys (never evicted) come last."""
        with self._lock:
            if self.policy in ("lru", "clock"):
                keys = list(self._blocks.keys())
            else:
                keys = (list(self._win.keys()) + list(self._t1.keys())
                        + list(self._t2.keys()))
            return keys + list(self._pinned.keys())

    def clear(self) -> None:
        with self._lock:
            for d in (self._blocks, self._ref, self._win, self._t1,
                      self._t2, self._b1, self._b2, self._pinned):
                d.clear()
            self._bytes = self._win_bytes = self._t1_bytes = 0
            self._t2_bytes = self._b1_bytes = self._b2_bytes = 0
            self._pinned_bytes = 0
            self.stats.pinned_bytes = 0
            self._p = 0.0

    def reset_stats(self, also: Iterable[Callable[[], object]] = ()
                    ) -> CacheStats:
        """Zero the counters (cache contents stay resident; the
        pinned-bytes gauge carries over).

        ``also`` callbacks (device reset, pipeline-stats reset) run
        *under the cache lock*, making the compound reset atomic with
        respect to in-flight fills: every fill charges its cache
        counters and its device bytes under this same lock
        (:meth:`get`'s loader, :meth:`begin_fill`'s ``charge``), so a
        reset can never land between the two halves of a charge and
        leave the device/cache byte invariant drifted (ISSUE-8).
        """
        with self._lock:
            out, self.stats = self.stats, CacheStats(
                pinned_bytes=self._pinned_bytes)
            for fn in also:
                fn()
            return out

    # ------------------------------------------------------------- internals
    def _resident(self) -> int:
        if self.policy in ("lru", "clock"):
            return self._bytes + self._pinned_bytes
        return (self._win_bytes + self._t1_bytes + self._t2_bytes
                + self._pinned_bytes)

    def _win_cap(self) -> int:
        cap = self.capacity_bytes
        return 0 if cap is None else max(1, int(cap * self.WINDOW_FRAC))

    def _pin_cap(self) -> Optional[int]:
        cap = self.capacity_bytes
        return None if cap is None else int(cap * self.pin_frac)

    def _find_region(self, key: Hashable):
        for d in (self._blocks, self._win, self._t1, self._t2):
            if key in d:
                return d
        return None

    def _peek_hit(self, key: Hashable) -> Optional[bytes]:
        """Resident lookup + the policy's on-hit transition."""
        data = self._pinned.get(key)
        if data is not None:
            return data
        if self.policy == "lru":
            data = self._blocks.get(key)
            if data is not None:
                self._blocks.move_to_end(key)
            return data
        if self.policy == "clock":
            data = self._blocks.get(key)
            if data is not None:
                self._ref[key] = True
            return data
        # arc / 2q
        data = self._win.get(key)
        if data is not None:
            if self.policy == "arc":    # window re-reference: refresh only
                self._win.move_to_end(key)
            return data                 # 2q: A1in hit leaves FIFO order
        data = self._t1.get(key)
        if data is not None:            # ARC: T1 hit promotes to T2
            del self._t1[key]
            self._t1_bytes -= len(data)
            self._t2[key] = data
            self._t2_bytes += len(data)
            return data
        data = self._t2.get(key)
        if data is not None:
            self._t2.move_to_end(key)
            return data
        return None

    def _try_pin(self, key: Hashable) -> bool:
        """Move a resident block into the pinned region (budget allowing)."""
        region = self._find_region(key)
        if region is None:
            return False
        size = len(region[key])
        pin_cap = self._pin_cap()
        if pin_cap is not None and self._pinned_bytes + size > pin_cap:
            return False
        data = region.pop(key)
        if region is self._blocks:
            self._bytes -= size
            self._ref.pop(key, None)
        elif region is self._win:
            self._win_bytes -= size
        elif region is self._t1:
            self._t1_bytes -= size
        else:
            self._t2_bytes -= size
        self._pinned[key] = data
        self._pinned_bytes += size
        self.stats.pinned_bytes = self._pinned_bytes
        return True

    # ---------------------------------------------------------- admission
    def _admit(self, key: Hashable, data: bytes, pin: bool) -> None:
        cap = self.capacity_bytes
        size = len(data)
        if cap == 0:
            return                      # caching disabled
        if cap is not None and size > cap - self._pinned_bytes:
            return                      # cannot fit even alone: don't cache
        if pin:
            pin_cap = self._pin_cap()
            if pin_cap is None or self._pinned_bytes + size <= pin_cap:
                self._unghost(key)
                self._pinned[key] = data
                self._pinned_bytes += size
                self.stats.pinned_bytes = self._pinned_bytes
                self._shrink_for_pin(cap)
                return
            # pin budget exhausted: fall through to normal admission
        if self.policy in ("lru", "clock"):
            self._blocks[key] = data
            self._ref[key] = False      # fresh blocks start unreferenced
            self._bytes += size
            if cap is not None:
                while self._resident() > cap:
                    before = self._bytes
                    self._evict_one_legacy(keep=key)
                    if self._bytes == before:   # nothing evictable left
                        break
            return
        if self.policy == "arc":
            self._admit_arc(key, data, cap)
        else:
            self._admit_2q(key, data, cap)
        self._trim_ghosts(cap)

    def _admit_arc(self, key: Hashable, data: bytes, cap) -> None:
        size = len(data)
        if key in self._b1 or key in self._b2:
            # ghost hit: earn main-region admission, adapt p (bytes)
            self.stats.ghost_hits += 1
            if key in self._b1:
                if cap is not None:
                    self._p = min(float(cap), self._p + size)
            else:
                self._p = max(0.0, self._p - size)
            self._unghost(key)
            self._t2[key] = data
            self._t2_bytes += size
            self._shrink_main(cap, keep=key)
        elif self._main_has_room(size, cap):
            self._t1[key] = data        # warm fill
            self._t1_bytes += size
        else:
            self._win[key] = data       # cold at full: window only
            self._win_bytes += size
            self._shrink_window(cap, keep=key)

    def _admit_2q(self, key: Hashable, data: bytes, cap) -> None:
        size = len(data)
        if key in self._b1:             # A1out ghost hit -> Am
            self.stats.ghost_hits += 1
            self._unghost(key)
            self._t2[key] = data
            self._t2_bytes += size
            self._shrink_main(cap, keep=key)
        elif self._main_has_room(size, cap):
            self._t2[key] = data        # warm fill straight into Am
            self._t2_bytes += size
        else:
            self._win[key] = data       # cold at full: A1in window only
            self._win_bytes += size
            self._shrink_window(cap, keep=key)

    def _main_has_room(self, size: int, cap) -> bool:
        if cap is None:
            return True
        main = self._t1_bytes + self._t2_bytes + self._pinned_bytes
        # Reserve the window's actual occupancy when it exceeds its
        # share (a lone block larger than the share is never trimmed),
        # so a warm fill can never push the total over the budget.
        reserved = max(self._win_cap(), self._win_bytes)
        return main + size <= cap - reserved

    # ----------------------------------------------------------- eviction
    def _unghost(self, key: Hashable) -> None:
        """Drop any ghost entry for ``key`` (a key is never resident and
        ghosted at once, and never in both ghost lists)."""
        if key in self._b1:
            self._b1_bytes -= self._b1.pop(key)
        if key in self._b2:
            self._b2_bytes -= self._b2.pop(key)

    def _ghost(self, ghosts, key: Hashable, size: int) -> None:
        self._unghost(key)
        ghosts[key] = size
        if ghosts is self._b1:
            self._b1_bytes += size
        else:
            self._b2_bytes += size

    def _evict_window(self, keep: Optional[Hashable]) -> bool:
        """Drop the window's oldest entry (never ``keep``) to a B1 ghost."""
        for victim in self._win:
            if victim != keep:
                data = self._win.pop(victim)
                self._win_bytes -= len(data)
                self._ghost(self._b1, victim, len(data))
                self.stats.evictions += 1
                if self.on_event is not None:
                    self.on_event("evict", victim, len(data))
                return True
        return False

    def _evict_main_one(self) -> bool:
        """One main-region eviction per the policy (ghosting the victim)."""
        if self.policy == "arc" and self._t1 \
                and (self._t1_bytes > self._p or not self._t2):
            victim, data = self._t1.popitem(last=False)
            self._t1_bytes -= len(data)
            self._ghost(self._b1, victim, len(data))
        elif self._t2:
            victim, data = self._t2.popitem(last=False)
            self._t2_bytes -= len(data)
            if self.policy == "arc":
                self._ghost(self._b2, victim, len(data))
            # 2q: Am evictions leave no ghost (classic 2Q)
        elif self._t1:
            victim, data = self._t1.popitem(last=False)
            self._t1_bytes -= len(data)
            self._ghost(self._b1, victim, len(data))
        else:
            return False
        self.stats.evictions += 1
        if self.on_event is not None:
            self.on_event("evict", victim, len(data))
        return True

    def _shrink_main(self, cap, keep: Hashable) -> None:
        """Make room after a ghost-hit admission: main first, window last."""
        if cap is None:
            return
        while self._resident() > cap:
            if self._evict_main_one():
                continue
            if not self._evict_window(keep):
                break

    def _shrink_window(self, cap, keep: Hashable) -> None:
        """Trim the window to its share — never touching the main region
        (that is the scan-resistance invariant) and never evicting the
        block just inserted."""
        if cap is None:
            return
        win_cap = self._win_cap()
        while (self._win_bytes > win_cap or self._resident() > cap) \
                and len(self._win) > 1:
            if not self._evict_window(keep):
                break
        # degenerate budgets (window share < one block): keep the exact
        # byte budget by falling back to main-region eviction
        while self._resident() > cap:
            if not self._evict_main_one():
                break

    def _shrink_for_pin(self, cap) -> None:
        """After a pinned insert: evict unpinned blocks (window first)
        until the budget holds; pinned blocks are never victims."""
        if cap is None:
            return
        while self._resident() > cap:
            if self.policy in ("lru", "clock"):
                before = self._bytes
                self._evict_one_legacy(keep=None)
                if self._bytes == before:
                    break
            elif not (self._evict_window(None) or self._evict_main_one()):
                break

    def _trim_ghosts(self, cap) -> None:
        """Ghost lists are byte-capped by the size of the blocks they
        refer to: B1 (and 2Q's A1out) at one budget, B2 at one budget."""
        if cap is None:
            return
        while self._b1_bytes > cap and self._b1:
            _, size = self._b1.popitem(last=False)
            self._b1_bytes -= size
        while self._b2_bytes > cap and self._b2:
            _, size = self._b2.popitem(last=False)
            self._b2_bytes -= size

    def _evict_one_legacy(self, keep: Optional[Hashable]) -> None:
        if self.policy == "lru":
            for victim in self._blocks:
                if victim != keep:
                    break
            else:
                return
        else:                           # CLOCK: second chance
            victim = None
            for _pass in range(2):
                for k in list(self._blocks):
                    if k == keep:
                        continue
                    if self._ref.get(k):
                        self._ref[k] = False        # spare once
                        self._blocks.move_to_end(k)  # advance the hand
                    else:
                        victim = k
                        break
                if victim is not None:
                    break
            if victim is None:
                return
        data = self._blocks.pop(victim)
        self._bytes -= len(data)
        self._ref.pop(victim, None)
        self.stats.evictions += 1
        if self.on_event is not None:
            self.on_event("evict", victim, len(data))
