"""Bounded-byte page cache over block-file segments (DESIGN.md §6).

The store's unit of I/O is one fixed-size block of a segment file
(`storage/blockfile.py`); the cache's unit of residency is the same
block.  :class:`PageCache` keeps at most ``capacity_bytes`` of blocks
resident and answers every block fetch either from memory (*hit* — no
device charge) or by invoking the caller's loader (*miss* — the loader
reads the block from the segment file and meters it through the shared
:class:`~repro.core.io_sim.BlockDevice`, so ``IOStats`` reflects actual
bytes read: sequential when a level scan streams consecutive blocks,
random when cache hits make the miss pattern skip around).

Two eviction policies:

* ``"lru"`` (default) — strict least-recently-used order;
* ``"clock"`` — second-chance/CLOCK: a hit sets the block's reference
  bit instead of moving it, and the eviction hand skips (and clears)
  referenced blocks once before evicting.

The cache is shared by every segment of a store and by the prefetch
thread (`storage/stream.py`), so all state — residency map, byte
budget, counters — is guarded by one lock.  The lock is *held across
the loader call*: concurrent queries serialize on disk reads, which
keeps budget enforcement exact (the resident byte count can never
overshoot between a load and its insertion) and matches the one-spindle
device model.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Hashable, Optional

__all__ = ["CacheStats", "PageCache"]


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_read: int = 0     # fetched via loaders (actual "disk" bytes)
    peak_bytes: int = 0     # high-water mark of resident bytes

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __sub__(self, other: "CacheStats") -> "CacheStats":
        """Counter delta (for per-batch reporting); peak is kept as-is."""
        return CacheStats(self.hits - other.hits,
                          self.misses - other.misses,
                          self.evictions - other.evictions,
                          self.bytes_read - other.bytes_read,
                          self.peak_bytes)

    def snapshot(self) -> "CacheStats":
        return dataclasses.replace(self)


class PageCache:
    """LRU/CLOCK block cache with a hard byte budget.

    ``capacity_bytes=None`` means unbounded (everything read stays
    resident — the 100%-of-index serving regime); ``capacity_bytes=0``
    disables caching entirely (every fetch is a miss).  A single block
    larger than the whole budget is returned to the caller but never
    cached.
    """

    def __init__(self, capacity_bytes: Optional[int] = None,
                 policy: str = "lru"):
        if policy not in ("lru", "clock"):
            raise ValueError(f"unknown eviction policy: {policy!r}")
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0 or None")
        self.capacity_bytes = capacity_bytes
        self.policy = policy
        self.stats = CacheStats()
        self._lock = threading.Lock()
        # key -> block bytes; insertion/recency order per policy
        self._blocks: "collections.OrderedDict[Hashable, bytes]" = \
            collections.OrderedDict()
        self._ref: dict = {}    # CLOCK reference bits
        self._bytes = 0         # running resident total (O(1) budget checks)

    # ------------------------------------------------------------- interface
    def get(self, key: Hashable, load: Callable[[], bytes]) -> bytes:
        """Return the block for ``key``, loading (and caching) on a miss."""
        with self._lock:
            data = self._blocks.get(key)
            if data is not None:
                self.stats.hits += 1
                if self.policy == "lru":
                    self._blocks.move_to_end(key)
                else:
                    self._ref[key] = True
                return data
            self.stats.misses += 1
            data = load()
            self.stats.bytes_read += len(data)
            self._insert(key, data)
            return data

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def resident_keys(self):
        """Keys currently cached, in eviction order (head evicts first)."""
        with self._lock:
            return list(self._blocks.keys())

    def clear(self) -> None:
        with self._lock:
            self._blocks.clear()
            self._ref.clear()
            self._bytes = 0

    def reset_stats(self) -> CacheStats:
        """Zero the counters (cache contents stay resident)."""
        with self._lock:
            out, self.stats = self.stats, CacheStats()
            return out

    # ------------------------------------------------------------- internals
    def _insert(self, key: Hashable, data: bytes) -> None:
        cap = self.capacity_bytes
        if cap is not None and len(data) > cap:
            return                      # cannot fit even alone: don't cache
        self._blocks[key] = data
        self._ref[key] = False          # fresh blocks start unreferenced
        self._bytes += len(data)
        if cap is not None:
            while self._bytes > cap:
                before = self._bytes
                self._evict_one(keep=key)
                if self._bytes == before:   # nothing evictable left
                    break
        self.stats.peak_bytes = max(self.stats.peak_bytes, self._bytes)

    def _evict_one(self, keep: Hashable) -> None:
        if self.policy == "lru":
            for victim in self._blocks:
                if victim != keep:
                    break
            else:
                return
        else:                           # CLOCK: second chance
            victim = None
            for _pass in range(2):
                for k in list(self._blocks):
                    if k == keep:
                        continue
                    if self._ref.get(k):
                        self._ref[k] = False        # spare once
                        self._blocks.move_to_end(k)  # advance the hand
                    else:
                        victim = k
                        break
                if victim is not None:
                    break
            if victim is None:
                return
        self._bytes -= len(self._blocks.pop(victim))
        self._ref.pop(victim, None)
        self.stats.evictions += 1
