"""Disk-resident HoD index store: block segment files (DESIGN.md §6).

A *store* is a directory holding the index in two tiers:

* ``resident.npz`` — the small, always-in-memory tier: permutations,
  level pointers, core closure/CSR, and the legacy chunk arrays.  This
  is exactly the v1 ``.npz`` content (plus store metadata), so the
  memory a store-backed engine must hold is independent of the sweep
  plans' padded envelope;
* ``plan_f.seg`` / ``plan_b.seg`` / ``plan_core.seg`` — one *segment
  file* per :class:`~repro.core.index.SweepPlan`, the tier queries
  stream.  Each segment is a sequence of fixed-size blocks::

      block 0        header: magic, format version (3), block_bytes,
                     n_real/l_pad/m_pad/k_fix/sentinel, footer extent
      blocks 1..     one *slab* per real level, in scan order, each
                     block-aligned and ``blocks_per_level`` long
      footer         JSON per-level extent table [start_block,
                     n_blocks, payload_bytes] (self-description /
                     integrity check — slab geometry is also derivable
                     from the header alone)

  A level slab packs the level's plan slice contiguously —
  ``dst[int32 M] · row_valid[u8 M] · src_idx[int32 M·K] · w[f32 M·K] ·
  assoc[int32 M·K]`` — so a level read is ``blocks_per_level``
  *consecutive* blocks: a full sweep is one sequential scan per segment
  (the paper's §4.5 invariant, now at actual-file granularity), and a
  partially-warm cache turns the misses into random reads.  Only real
  levels are stored; the plan's padding levels (``level_mask`` False)
  are reconstructed from header defaults, bit-exactly.

Every block read goes through a :class:`~repro.storage.pagecache
.PageCache` and — on a miss — is metered through the store's
:class:`~repro.core.io_sim.BlockDevice` with a *global* block id
(segments get disjoint id ranges), so ``IOStats`` classifies the actual
read pattern: consecutive-block level scans count sequential, skips
introduced by cache hits count random.  Open-time header/footer reads
are not charged; only query-time block fetches are.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.index import (FORMAT_VERSION, HoDIndex, SweepPlan,
                          core_scan_bytes, scan_cost_bytes)
from ..core.io_sim import BlockDevice
from .pagecache import PageCache

__all__ = ["IndexStore", "SegmentReader", "save_store", "open_store",
           "load_store", "segment_bytes", "SEGMENT_NAMES",
           "DEFAULT_BLOCK_BYTES"]

MAGIC = b"HODSEG03"
_HEADER = struct.Struct("<8sIIIIIIIIQQ")   # magic, version, block_bytes,
# n_real, l_pad, m_pad, k_fix, sentinel, reserved, footer_off, footer_len
RESIDENT_FILE = "resident.npz"
SEGMENT_NAMES = ("plan_f", "plan_b", "plan_core")
#: paper §2 block size (64 KiB) — the modeled device's unit.
DEFAULT_BLOCK_BYTES = 65536
#: disjoint global-block-id ranges per segment, so the device's
#: seq/random cursor sees a cross-segment switch as one seek.
_SEGMENT_ID_STRIDE = 1 << 40

INF = np.float32(np.inf)


def _level_payload_bytes(m_pad: int, k_fix: int) -> int:
    return m_pad * (4 + 1) + m_pad * k_fix * (4 + 4 + 4)


# --------------------------------------------------------------------- write
def _write_segment(path: str, plan: SweepPlan, sentinel: int,
                   block_bytes: int) -> None:
    if block_bytes < _HEADER.size:
        raise ValueError(f"block_bytes must be >= {_HEADER.size}")
    n_real = plan.n_real_levels
    m_pad, k_fix = plan.m_pad, plan.k_fix
    payload = _level_payload_bytes(m_pad, k_fix)
    bpl = max(1, -(-payload // block_bytes))
    footer = json.dumps({
        "extents": [[1 + l * bpl, bpl, payload] for l in range(n_real)],
        "n_real": n_real,
    }).encode()
    footer_off = block_bytes * (1 + n_real * bpl)
    header = _HEADER.pack(MAGIC, FORMAT_VERSION, block_bytes, n_real,
                          plan.l_pad, m_pad, k_fix, sentinel, 0,
                          footer_off, len(footer))
    with open(path, "wb") as f:
        f.write(header.ljust(block_bytes, b"\0"))
        for lvl in range(n_real):
            slab = b"".join((
                np.ascontiguousarray(plan.dst[lvl], np.int32).tobytes(),
                np.ascontiguousarray(plan.row_valid[lvl],
                                     np.uint8).tobytes(),
                np.ascontiguousarray(plan.src_idx[lvl], np.int32).tobytes(),
                np.ascontiguousarray(plan.w[lvl], np.float32).tobytes(),
                np.ascontiguousarray(plan.assoc[lvl], np.int32).tobytes()))
            assert len(slab) == payload
            f.write(slab.ljust(bpl * block_bytes, b"\0"))
        f.write(footer)


def save_store(ix: HoDIndex, path: str,
               block_bytes: int = DEFAULT_BLOCK_BYTES) -> None:
    """Write ``ix`` as a disk-resident store directory at ``path``.

    The resident tier reuses the ``.npz`` machinery (minus the plan
    arrays); each sweep plan becomes one block segment file.  Per-plan
    compact-payload counts (real rows/edges) ride in the resident file
    so a store-backed server can model the paper-comparable scan cost
    without materializing any plan.
    """
    ix.ensure_plans()
    os.makedirs(path, exist_ok=True)
    plan_stats = {}
    for name in SEGMENT_NAMES:
        p: SweepPlan = getattr(ix, name)
        plan_stats[f"{name}_rows"] = np.int64(p.row_valid.sum())
        plan_stats[f"{name}_edges"] = np.int64(np.isfinite(p.w).sum())
    np.savez_compressed(
        os.path.join(path, RESIDENT_FILE), meta=ix._meta_array(),
        format_version=np.int64(FORMAT_VERSION),
        store=np.bool_(True), block_bytes=np.int64(block_bytes),
        k_cap=np.int64(ix.k_cap),
        **ix.resident_arrays(), **plan_stats)
    for name in SEGMENT_NAMES:
        _write_segment(os.path.join(path, f"{name}.seg"),
                       getattr(ix, name), ix.n, block_bytes)


# ---------------------------------------------------------------------- read
class SegmentReader:
    """One open segment file: header-described slab geometry + cached,
    device-metered block reads (thread-safe via ``os.pread``)."""

    def __init__(self, path: str, base_block: int, device: BlockDevice,
                 cache: PageCache, name: str):
        self.path, self.name = path, name
        self.device, self.cache = device, cache
        self.base_block = base_block
        # Cache keys are namespaced by the segment's absolute path: a
        # PageCache shared between stores (one global memory budget)
        # must never serve one store's blocks to another.
        self._cache_ns = os.path.abspath(path)
        self._fd = os.open(path, os.O_RDONLY)
        try:
            raw = os.pread(self._fd, _HEADER.size, 0)
            (magic, version, self.block_bytes, self.n_real, self.l_pad,
             self.m_pad, self.k_fix, self.sentinel, _res,
             footer_off, footer_len) = _HEADER.unpack(raw)
            if magic != MAGIC:
                raise ValueError(f"{path}: not a HoD segment file "
                                 f"(magic {magic!r})")
            if version > FORMAT_VERSION:
                raise ValueError(f"{path}: segment format v{version} is "
                                 f"newer than this reader "
                                 f"(v{FORMAT_VERSION})")
            self.payload_bytes = _level_payload_bytes(self.m_pad,
                                                      self.k_fix)
            self.blocks_per_level = max(1, -(-self.payload_bytes
                                             // self.block_bytes))
            footer = json.loads(os.pread(self._fd, footer_len, footer_off))
            if footer["n_real"] != self.n_real:
                raise ValueError(
                    f"{path}: footer/header level count mismatch")
            self.extents = footer["extents"]
        except Exception:
            self.close()
            raise

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    # ------------------------------------------------------------- block I/O
    def _load_block(self, block: int) -> bytes:
        data = os.pread(self._fd, self.block_bytes,
                        block * self.block_bytes)
        self.device.access_block(self.base_block + block, len(data))
        return data

    def read_level(self, lvl: int) -> Tuple[np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray,
                                            np.ndarray]:
        """One real level's ``(dst, src_idx, w, assoc, row_valid)`` slab,
        fetched block-by-block through the page cache."""
        if not 0 <= lvl < self.n_real:
            raise IndexError(f"{self.name}: level {lvl} out of range "
                             f"(0..{self.n_real - 1})")
        start, n_blocks, payload = self.extents[lvl]
        parts = [self.cache.get((self._cache_ns, b),
                                lambda b=b: self._load_block(b))
                 for b in range(start, start + n_blocks)]
        buf = b"".join(parts)[:payload]
        m, k = self.m_pad, self.k_fix
        off = 0
        dst = np.frombuffer(buf, np.int32, m, off); off += 4 * m
        valid = np.frombuffer(buf, np.uint8, m, off).astype(bool); off += m
        src = np.frombuffer(buf, np.int32, m * k, off).reshape(m, k)
        off += 4 * m * k
        w = np.frombuffer(buf, np.float32, m * k, off).reshape(m, k)
        off += 4 * m * k
        assoc = np.frombuffer(buf, np.int32, m * k, off).reshape(m, k)
        return dst, src, w, assoc, valid

    def read_plan(self) -> SweepPlan:
        """Materialize the full plan (padding levels reconstructed from
        header defaults) — the non-streaming ``load_store`` path."""
        l_pad, m, k = self.l_pad, self.m_pad, self.k_fix
        if l_pad == 0:
            from ..core.index import _empty_plan
            return _empty_plan(k)
        dst = np.full((l_pad, m), self.sentinel, np.int32)
        src = np.full((l_pad, m, k), self.sentinel, np.int32)
        w = np.full((l_pad, m, k), INF, np.float32)
        assoc = np.full((l_pad, m, k), -1, np.int32)
        row_valid = np.zeros((l_pad, m), bool)
        level_mask = np.zeros((l_pad,), bool)
        for lvl in range(self.n_real):
            d, s, w_l, a, v = self.read_level(lvl)
            dst[lvl], src[lvl], w[lvl], assoc[lvl] = d, s, w_l, a
            row_valid[lvl] = v
            level_mask[lvl] = True
        return SweepPlan(dst=dst, src_idx=src, w=w, assoc=assoc,
                         row_valid=row_valid, level_mask=level_mask)


@dataclasses.dataclass
class _PlanScanStats:
    rows: int
    edges: int


class IndexStore:
    """An open store directory: the resident tier as a plan-less
    :class:`HoDIndex` plus one :class:`SegmentReader` per sweep plan,
    all sharing one page cache and one metering device."""

    def __init__(self, path: str, device: Optional[BlockDevice] = None,
                 cache: Optional[PageCache] = None):
        resident = os.path.join(path, RESIDENT_FILE)
        if not os.path.isfile(resident):
            raise FileNotFoundError(
                f"{path}: not a HoD index store (no {RESIDENT_FILE})")
        self.path = path
        self._plan_scan: Dict[str, _PlanScanStats] = {}
        with np.load(resident) as z:
            self.block_bytes = int(z["block_bytes"])
            self.resident = HoDIndex._from_npz(z)
            for name in SEGMENT_NAMES:
                self._plan_scan[name] = _PlanScanStats(
                    rows=int(z[f"{name}_rows"]),
                    edges=int(z[f"{name}_edges"]))
        if device is not None and device.block_bytes != self.block_bytes:
            raise ValueError(
                f"{path}: metering device block size "
                f"({device.block_bytes}) != store block size "
                f"({self.block_bytes}) — I/O accounting would be wrong")
        self.device = device or BlockDevice(block_bytes=self.block_bytes)
        self.cache = cache if cache is not None else PageCache()
        self.segments: Dict[str, SegmentReader] = {}
        try:
            for i, name in enumerate(SEGMENT_NAMES):
                self.segments[name] = SegmentReader(
                    os.path.join(path, f"{name}.seg"),
                    base_block=i * _SEGMENT_ID_STRIDE, device=self.device,
                    cache=self.cache, name=name)
        except Exception:
            self.close()    # don't leak fds of segments already opened
            raise

    # --------------------------------------------------------------- queries
    def n_real(self, name: str) -> int:
        return self.segments[name].n_real

    def read_level(self, name: str, lvl: int):
        return self.segments[name].read_level(lvl)

    def read_plan(self, name: str) -> SweepPlan:
        return self.segments[name].read_plan()

    # ------------------------------------------------------------ accounting
    def store_bytes(self) -> int:
        """Total on-disk size of the store (resident + segments) — the
        denominator for ``cache_bytes`` budgets."""
        return (os.path.getsize(os.path.join(self.path, RESIDENT_FILE))
                + segment_bytes(self.path))

    def segment_bytes(self) -> int:
        """On-disk size of the streamed tier only (the three segments)."""
        return segment_bytes(self.path)

    def scan_bytes(self, sssp: bool = False,
                   core_mode: str = "closure") -> int:
        """Modeled compact-payload cost of one full sweep — the shared
        :func:`~repro.core.index.scan_cost_bytes` model over the
        persisted row/edge counts, no plan materialization needed."""
        def plan_cost(name: str, include_assoc: bool) -> int:
            st = self._plan_scan[name]
            return scan_cost_bytes(st.rows, st.edges, include_assoc)
        total = plan_cost("plan_f", sssp) + plan_cost("plan_b", sssp)
        if sssp:
            total += plan_cost("plan_core", True)
        return total + core_scan_bytes(self.resident, core_mode)

    def close(self) -> None:
        for seg in self.segments.values():
            seg.close()


def segment_bytes(path: str) -> int:
    """On-disk size of a store's streamed tier (the three segment
    files) — the usual denominator for ``cache_bytes`` budgets; pure
    ``os.path.getsize``, no store open needed."""
    return sum(os.path.getsize(os.path.join(path, f"{name}.seg"))
               for name in SEGMENT_NAMES)


def open_store(path: str, device: Optional[BlockDevice] = None,
               cache: Optional[PageCache] = None) -> IndexStore:
    return IndexStore(path, device=device, cache=cache)


def load_store(path: str) -> HoDIndex:
    """Fully materialize a store back into an in-memory :class:`HoDIndex`
    (plans included, bit-exact) — the compatibility/inspection path; a
    serving deployment streams through :class:`IndexStore` instead."""
    store = IndexStore(path)
    try:
        ix = store.resident
        for name in SEGMENT_NAMES:
            setattr(ix, name, store.read_plan(name))
        return ix
    finally:
        store.close()
