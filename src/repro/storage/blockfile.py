"""Disk-resident HoD index store: block segment files (DESIGN.md §6).

A *store* is a directory holding the index in two tiers:

* ``resident.npz`` — the small, always-in-memory tier: permutations,
  level pointers, core closure/CSR, and the legacy chunk arrays.  This
  is exactly the v1 ``.npz`` content (plus store metadata), so the
  memory a store-backed engine must hold is independent of the sweep
  plans' padded envelope;
* ``plan_f.seg`` / ``plan_b.seg`` / ``plan_core.seg`` — one *segment
  file* per :class:`~repro.core.index.SweepPlan`, the tier queries
  stream.  A v5 segment is a fixed-size *logical* block space stored
  as variable-length compressed frames::

      block 0        header: magic, format version (5), block_bytes,
                     n_real/l_pad/m_pad/k_fix/sentinel, footer extent
      frames 1..     one frame per logical data block, back-to-back:
                     (codec_id u8, comp_len u32, crc32 u32) + payload
                     compressed by the per-block codec
                     (`repro.storage.codecs`: raw / delta / f16)
      footer         JSON per-level extent table [byte_off, byte_len,
                     m_real] (logical offsets) + per-frame table
                     [file_off, comp_len, codec_id, crc] + codec name

  The *logical* stream the extents address is exactly the v4 affinity
  layout: compact level slabs back-to-back at byte granularity, padded
  levels/rows reconstructed from header defaults.  Level addressing,
  cache keys, and the sweep's block-id order are therefore codec-
  independent — only the bytes on disk shrink.  Each frame decodes
  alone (the codec span maps are derived from the extents), so random
  block access never touches a neighbor; a frame that a codec cannot
  shrink is stored raw (``codec_id`` is per frame).

  The v4 *affinity layout* (build-time partitioning, ROADMAP): a level
  slab stores only the level's **real** rows —
  ``dst[int32 m] · src_idx[int32 m·K] · w[f32 m·K] · assoc[int32 m·K]``
  with ``m = m_real ≤ M_pad`` — and consecutive slabs are packed into
  the same block neighborhood instead of each being block-aligned.
  Two effects on a partial cache: the per-sweep block working set
  shrinks by the padding-row envelope (often 2-3x on level-skewed
  graphs), and adjacent levels *share* their boundary block, so every
  level hand-off re-references a just-read block — hits that exist at
  any budget.  Padding rows and padding levels are reconstructed from
  header defaults, bit-exactly.  A full sweep is still one sequential
  scan per segment (the paper's §4.5 invariant): blocks are read in
  ascending id order.  v3 segments (block-aligned full-``M_pad``
  slabs) keep loading.

Every block read goes through a :class:`~repro.storage.pagecache
.PageCache` and — on a miss — is metered through the store's
:class:`~repro.core.io_sim.BlockDevice` with a *global* block id
(segments get disjoint id ranges), so ``IOStats`` classifies the
actual read pattern.  Codec frames *decompress on cache fill*: the
cache holds (and budgets) the decompressed ``block_bytes`` payload,
while the device and ``CacheStats.bytes_read`` are charged the
*compressed* payload bytes the miss actually read — frame and footer
metadata, like the v4 footer, are uncharged.  Misses are integrity-
checked against the frame CRC32 (v4: the footer's per-block CRCs), so
a corrupt segment surfaces as a ``ValueError`` in the querying thread
instead of silent garbage distances.  Open-time header/footer reads
are not charged; only query-time block fetches are.

Segment-aware admission (DESIGN.md §6): ``IndexStore`` marks the
small, repeatedly-re-read segments (``plan_core`` by default) as
*pinned* — their blocks are pinned into the page cache on first read
(within the cache's pin budget), so a once-per-sweep ``plan_f`` scan
can never evict them.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import zlib
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..core.index import (FORMAT_VERSION, HoDIndex, SweepPlan,
                          core_scan_bytes, scan_cost_bytes)
from ..core.io_sim import BlockDevice
from .codecs import (CODEC_IDS, block_spans, decode_block, encode_block,
                     level_spans)
from .pagecache import PageCache

__all__ = ["IndexStore", "SegmentReader", "save_store", "open_store",
           "load_store", "segment_bytes", "segment_logical_bytes",
           "SEGMENT_NAMES", "DEFAULT_BLOCK_BYTES", "DEFAULT_CODEC",
           "PIN_SEGMENTS"]

MAGIC = b"HODSEG05"
_MAGIC_V4 = b"HODSEG04"
_MAGIC_V3 = b"HODSEG03"
_HEADER = struct.Struct("<8sIIIIIIIIQQ")   # magic, version, block_bytes,
# n_real, l_pad, m_pad, k_fix, sentinel, reserved, footer_off, footer_len
#: v5 per-frame header: codec_id (u8), pad, comp_len (u32), crc32 (u32).
_FRAME = struct.Struct("<B3xII")
RESIDENT_FILE = "resident.npz"
SEGMENT_NAMES = ("plan_f", "plan_b", "plan_core")
#: codec a store is written with unless asked otherwise — ``raw`` keeps
#: fills decode-free (the v4-equivalent payload, framed); ``delta``
#: trades decode CPU for compressed reads (`repro.storage.codecs`).
DEFAULT_CODEC = "raw"
#: segments pinned resident by default (segment-aware admission): the
#: core plan is small, read once per SSSP reconstruction, and exactly
#: the kind of hot tier a cyclic ``plan_f`` scan would otherwise evict.
PIN_SEGMENTS = ("plan_core",)
#: paper §2 block size (64 KiB) — the modeled device's unit.
DEFAULT_BLOCK_BYTES = 65536
#: disjoint global-block-id ranges per segment, so the device's
#: seq/random cursor sees a cross-segment switch as one seek.
_SEGMENT_ID_STRIDE = 1 << 40

INF = np.float32(np.inf)


def _trim_rows(plan: SweepPlan, lvl: int, sentinel: int) -> int:
    """Number of leading real rows of a level slab, or ``-1`` when the
    level is not a clean real-prefix + default-padding split (never the
    case for ``pack_index`` plans; kept as a lossless fallback)."""
    valid = plan.row_valid[lvl]
    m_real = int(valid.sum())
    if not (valid[:m_real].all() and not valid[m_real:].any()):
        return -1
    if not ((plan.dst[lvl, m_real:] == sentinel).all()
            and (plan.src_idx[lvl, m_real:] == sentinel).all()
            and np.isinf(plan.w[lvl, m_real:]).all()
            and (plan.assoc[lvl, m_real:] == -1).all()):
        return -1
    return m_real


# --------------------------------------------------------------------- write
def _level_slab(plan: SweepPlan, lvl: int, m_real: int) -> bytes:
    """Serialize one level: compact (real rows only) when ``m_real >= 0``,
    else the full rectangle with an explicit valid vector."""
    if m_real >= 0:
        sl = slice(0, m_real)
        parts = (np.ascontiguousarray(plan.dst[lvl, sl], np.int32),
                 np.ascontiguousarray(plan.src_idx[lvl, sl], np.int32),
                 np.ascontiguousarray(plan.w[lvl, sl], np.float32),
                 np.ascontiguousarray(plan.assoc[lvl, sl], np.int32))
    else:
        parts = (np.ascontiguousarray(plan.dst[lvl], np.int32),
                 np.ascontiguousarray(plan.row_valid[lvl], np.uint8),
                 np.ascontiguousarray(plan.src_idx[lvl], np.int32),
                 np.ascontiguousarray(plan.w[lvl], np.float32),
                 np.ascontiguousarray(plan.assoc[lvl], np.int32))
    return b"".join(p.tobytes() for p in parts)


def _segment_spans(extents, k_fix: int):
    """Typed span map of a segment's whole logical stream (shared by
    the writer and the v5 reader — both derive it from the extents)."""
    spans = []
    for off, length, m_real in extents:
        spans.extend(level_spans(off, length, m_real, k_fix))
    return spans


def _write_segment(path: str, plan: SweepPlan, sentinel: int,
                   block_bytes: int, codec: str = DEFAULT_CODEC) -> None:
    if block_bytes < _HEADER.size:
        raise ValueError(f"block_bytes must be >= {_HEADER.size}")
    if codec not in CODEC_IDS:
        raise ValueError(f"unknown codec {codec!r} "
                         f"(have {sorted(CODEC_IDS)})")
    n_real = plan.n_real_levels
    extents = []
    slabs = []
    off = block_bytes                     # logical data starts at block 1
    for lvl in range(n_real):
        m_real = _trim_rows(plan, lvl, sentinel)
        slab = _level_slab(plan, lvl, m_real)
        extents.append([off, len(slab), m_real])
        slabs.append(slab)
        off += len(slab)
    data = b"".join(slabs)
    pad = (-len(data)) % block_bytes
    data += b"\0" * pad
    n_data_blocks = len(data) // block_bytes
    spans = _segment_spans(extents, plan.k_fix)
    span_starts = [s for _, s, _ in spans]
    frames = []                           # [file_off, comp_len, id, crc]
    frame_blobs = []
    file_off = block_bytes                # frames start after the header
    for i in range(n_data_blocks):
        lo = (i + 1) * block_bytes        # logical window of block i+1
        payload = data[i * block_bytes:(i + 1) * block_bytes]
        codec_id, blob = encode_block(
            codec, payload,
            block_spans(spans, lo, lo + block_bytes, starts=span_starts))
        crc = zlib.crc32(blob)
        frames.append([file_off, len(blob), codec_id, crc])
        frame_blobs.append(_FRAME.pack(codec_id, len(blob), crc) + blob)
        file_off += _FRAME.size + len(blob)
    footer = json.dumps({"extents": extents, "n_real": n_real,
                         "codec": codec, "frames": frames}).encode()
    header = _HEADER.pack(MAGIC, FORMAT_VERSION, block_bytes, n_real,
                          plan.l_pad, plan.m_pad, plan.k_fix, sentinel, 0,
                          file_off, len(footer))
    with open(path, "wb") as f:
        f.write(header.ljust(block_bytes, b"\0"))
        for blob in frame_blobs:
            f.write(blob)
        f.write(footer)


def save_store(ix: HoDIndex, path: str,
               block_bytes: int = DEFAULT_BLOCK_BYTES,
               codec: str = DEFAULT_CODEC) -> None:
    """Write ``ix`` as a disk-resident store directory at ``path``.

    The resident tier reuses the ``.npz`` machinery (minus the plan
    arrays); each sweep plan becomes one v5 block segment file — the
    v4 affinity logical layout (compact level slabs sharing block
    neighborhoods), framed per block by ``codec`` (``"raw"`` /
    ``"delta"`` / ``"f16"``, see `repro.storage.codecs`).  Per-plan
    compact-payload counts (real rows/edges) ride in the resident file
    so a store-backed server can model the paper-comparable scan cost
    without materializing any plan.
    """
    ix.ensure_plans()
    os.makedirs(path, exist_ok=True)
    plan_stats = {}
    for name in SEGMENT_NAMES:
        p: SweepPlan = getattr(ix, name)
        plan_stats[f"{name}_rows"] = np.int64(p.row_valid.sum())
        plan_stats[f"{name}_edges"] = np.int64(np.isfinite(p.w).sum())
    np.savez_compressed(
        os.path.join(path, RESIDENT_FILE), meta=ix._meta_array(),
        format_version=np.int64(FORMAT_VERSION),
        store=np.bool_(True), block_bytes=np.int64(block_bytes),
        codec=np.str_(codec), k_cap=np.int64(ix.k_cap),
        **ix.resident_arrays(), **plan_stats)
    for name in SEGMENT_NAMES:
        _write_segment(os.path.join(path, f"{name}.seg"),
                       getattr(ix, name), ix.n, block_bytes, codec=codec)


# ---------------------------------------------------------------------- read
class SegmentReader:
    """One open segment file: header/footer-described slab geometry +
    cached, CRC-checked, device-metered block reads (thread-safe via
    ``os.pread``).  Reads v5 codec-framed segments plus the v4
    affinity layout and v3 block-aligned segments."""

    def __init__(self, path: str, base_block: int, device: BlockDevice,
                 cache: PageCache, name: str, pin_blocks: bool = False):
        self.path, self.name = path, name
        self.device, self.cache = device, cache
        self.base_block = base_block
        #: pin this segment's blocks into the cache on read (segment-
        #: aware admission; subject to the cache's pin budget).
        self.pin_blocks = bool(pin_blocks)
        # Cache keys are namespaced by the segment's absolute path: a
        # PageCache shared between stores (one global memory budget)
        # must never serve one store's blocks to another.
        self._cache_ns = os.path.abspath(path)
        self._fd = os.open(path, os.O_RDONLY)
        try:
            raw = os.pread(self._fd, _HEADER.size, 0)
            (magic, self.version, self.block_bytes, self.n_real,
             self.l_pad, self.m_pad, self.k_fix, self.sentinel, _res,
             footer_off, footer_len) = _HEADER.unpack(raw)
            if magic not in (MAGIC, _MAGIC_V4, _MAGIC_V3):
                raise ValueError(f"{path}: not a HoD segment file "
                                 f"(magic {magic!r})")
            if self.version > FORMAT_VERSION:
                raise ValueError(f"{path}: segment format "
                                 f"v{self.version} is newer than this "
                                 f"reader (v{FORMAT_VERSION})")
            footer = json.loads(os.pread(self._fd, footer_len, footer_off))
            if footer["n_real"] != self.n_real:
                raise ValueError(
                    f"{path}: footer/header level count mismatch")
            self.extents = footer["extents"]
            self._crcs = footer.get("crcs")   # v4 only (absent in v3)
            #: v5: [file_off, comp_len, codec_id, crc] per data block,
            #: plus the codec the segment was written with
            self._frames = footer.get("frames")
            self.codec = footer.get("codec", "raw")
            self._spans = (_segment_spans(self.extents, self.k_fix)
                           if self.version >= 5 else None)
            #: bisect index into the (sorted) span map, so a cache miss
            #: clips one block's window in O(log L) not O(L)
            self._span_starts = ([s for _, s, _ in self._spans]
                                 if self._spans is not None else None)
        except Exception:
            self.close()
            raise

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    # ------------------------------------------------------------- block I/O
    def frame_info(self, block: int) -> Tuple[int, int]:
        """``(decoded_bytes, disk_bytes)`` of one logical block — known
        from footer metadata alone, *before* any read happens.  This is
        what lets the read pipeline admit a block's budget and charge
        ``bytes_read`` at submit time (`storage/pipeline.py`)."""
        if self.version >= 5:
            return self.block_bytes, self._frames[block - 1][1]
        return self.block_bytes, self.block_bytes

    def read_frames(self, b0: int, b1: int) -> bytes:
        """Raw on-disk bytes of blocks ``b0..b1`` inclusive in **one**
        pread (batched extent read).  v5 frames are written
        back-to-back, so any contiguous block run is one file range;
        v3/v4 blocks are block-aligned.  Slice per block with
        :meth:`frame_slice`; no device charge happens here."""
        if self.version >= 5:
            off0 = self._frames[b0 - 1][0]
            off1, comp_len = self._frames[b1 - 1][:2]
            return os.pread(self._fd, off1 + _FRAME.size + comp_len - off0,
                            off0)
        return os.pread(self._fd, (b1 - b0 + 1) * self.block_bytes,
                        b0 * self.block_bytes)

    def frame_slice(self, buf: bytes, b0: int, block: int) -> bytes:
        """One block's frame bytes out of a ``read_frames(b0, ...)``
        buffer."""
        if self.version >= 5:
            off = self._frames[block - 1][0] - self._frames[b0 - 1][0]
            return buf[off:off + _FRAME.size + self._frames[block - 1][1]]
        off = (block - b0) * self.block_bytes
        return buf[off:off + self.block_bytes]

    def decode_frame(self, block: int, raw: bytes) -> bytes:
        """CRC-verify + decode one block's frame bytes into the decoded
        ``block_bytes`` payload.  Pure CPU — this is the part the read
        pipeline runs on its decode worker pool; a corrupt frame raises
        the same ``ValueError`` the synchronous path does."""
        if self.version >= 5:
            _file_off, comp_len, codec_id, crc = self._frames[block - 1]
            f_codec, f_len, f_crc = _FRAME.unpack_from(raw)
            blob = raw[_FRAME.size:]
            if (len(blob) != comp_len or f_codec != codec_id
                    or f_len != comp_len or f_crc != crc
                    or zlib.crc32(blob) != crc):
                raise ValueError(
                    f"{self.path}: CRC mismatch in block {block} — "
                    "corrupt segment read")
            lo = block * self.block_bytes
            return decode_block(
                codec_id, blob,
                block_spans(self._spans, lo, lo + self.block_bytes,
                            starts=self._span_starts),
                self.block_bytes)
        if self._crcs is not None and 1 <= block <= len(self._crcs):
            if zlib.crc32(raw) != self._crcs[block - 1]:
                raise ValueError(
                    f"{self.path}: CRC mismatch in block {block} — "
                    "corrupt segment read")
        return raw

    def _load_block(self, block: int):
        """Load one logical block for the page cache.

        v5 returns ``(decompressed_payload, compressed_bytes)`` — the
        decompress-on-fill pair the cache budgets/meters respectively;
        v3/v4 return the raw block (read bytes == resident bytes).  The
        device is charged the bytes actually read off "disk" (the
        compressed frame payload; frame/footer metadata is uncharged).
        """
        raw = self.read_frames(block, block)
        data = self.decode_frame(block, raw)
        if self.version >= 5:
            comp_len = self._frames[block - 1][1]
            self.device.access_block(self.base_block + block, comp_len)
            return data, comp_len
        self.device.access_block(self.base_block + block, len(data))
        return data

    def data_blocks(self) -> int:
        """Number of logical data blocks (1..n) in this segment — the
        unit the fleet partitioner splits across shards
        (``repro/fleet/partition.py``)."""
        if self._frames is not None:        # v5: one frame per block
            return len(self._frames)
        last = 0
        for lvl in range(self.n_real):
            last = max(last, self._level_blocks(lvl)[1])
        return last

    def _level_blocks(self, lvl: int) -> Tuple[int, int, int]:
        """(first_block, last_block, offset_of_first_byte_in_first_block)
        of one level's slab."""
        if self.version >= 4:
            off, length, _ = self.extents[lvl]
            b0 = off // self.block_bytes
            b1 = (off + max(length, 1) - 1) // self.block_bytes
            return b0, b1, off - b0 * self.block_bytes
        start, n_blocks, _ = self.extents[lvl]
        return start, start + n_blocks - 1, 0

    def level_keys(self, lvl: int):
        """The page-cache keys of one level's blocks (for pin/unpin)."""
        b0, b1, _ = self._level_blocks(lvl)
        return [(self._cache_ns, b) for b in range(b0, b1 + 1)]

    def clip_level(self, buf: bytes, lvl: int, skip: int) -> bytes:
        """Clip a level's slab bytes out of its joined block payloads
        (shared by the synchronous fetch and the pipeline's assembly)."""
        if self.version >= 4:
            _off, length, _ = self.extents[lvl]
            return buf[skip:skip + length]
        return buf[:self.extents[lvl][2]]

    def _fetch(self, lvl: int, pin: bool) -> bytes:
        """One level's raw slab bytes via the page cache."""
        if self.version >= 4 and self.extents[lvl][1] == 0:
            return b""                  # zero-row level: nothing on disk
        b0, b1, skip = self._level_blocks(lvl)
        pin = pin or self.pin_blocks
        parts = [self.cache.get((self._cache_ns, b),
                                lambda b=b: self._load_block(b), pin=pin)
                 for b in range(b0, b1 + 1)]
        return self.clip_level(b"".join(parts), lvl, skip)

    def read_level(self, lvl: int, pin: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray, np.ndarray]:
        """One real level's ``(dst, src_idx, w, assoc, row_valid)`` slab
        at the full ``[M_pad, K_fix]`` rectangle (padding rows
        reconstructed from header defaults for compact v4 slabs),
        fetched block-by-block through the page cache."""
        if not 0 <= lvl < self.n_real:
            raise IndexError(f"{self.name}: level {lvl} out of range "
                             f"(0..{self.n_real - 1})")
        return self.parse_slab(self._fetch(lvl, pin), lvl)

    def parse_slab(self, buf: bytes, lvl: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray, np.ndarray]:
        """Decode one level's clipped slab bytes into the full
        ``[M_pad, K_fix]`` rectangle (see :meth:`read_level`)."""
        m, k = self.m_pad, self.k_fix
        m_real = self.extents[lvl][2] if self.version >= 4 else -1
        if m_real < 0:          # full rectangle with explicit valid vector
            off = 0
            dst = np.frombuffer(buf, np.int32, m, off); off += 4 * m
            valid = np.frombuffer(buf, np.uint8, m, off).astype(bool)
            off += m
            src = np.frombuffer(buf, np.int32, m * k, off).reshape(m, k)
            off += 4 * m * k
            w = np.frombuffer(buf, np.float32, m * k, off).reshape(m, k)
            off += 4 * m * k
            assoc = np.frombuffer(buf, np.int32, m * k, off).reshape(m, k)
            return dst, src, w, assoc, valid
        # compact slab: real-row prefix + reconstructed default padding
        dst = np.full(m, self.sentinel, np.int32)
        src = np.full((m, k), self.sentinel, np.int32)
        w = np.full((m, k), INF, np.float32)
        assoc = np.full((m, k), -1, np.int32)
        valid = np.zeros(m, bool)
        mr = m_real
        off = 0
        dst[:mr] = np.frombuffer(buf, np.int32, mr, off); off += 4 * mr
        src[:mr] = np.frombuffer(buf, np.int32, mr * k, off).reshape(mr, k)
        off += 4 * mr * k
        w[:mr] = np.frombuffer(buf, np.float32, mr * k, off).reshape(mr, k)
        off += 4 * mr * k
        assoc[:mr] = np.frombuffer(buf, np.int32, mr * k,
                                   off).reshape(mr, k)
        valid[:mr] = True
        return dst, src, w, assoc, valid

    def read_plan(self) -> SweepPlan:
        """Materialize the full plan (padding levels reconstructed from
        header defaults) — the non-streaming ``load_store`` path."""
        l_pad, m, k = self.l_pad, self.m_pad, self.k_fix
        if l_pad == 0:
            from ..core.index import _empty_plan
            return _empty_plan(k)
        dst = np.full((l_pad, m), self.sentinel, np.int32)
        src = np.full((l_pad, m, k), self.sentinel, np.int32)
        w = np.full((l_pad, m, k), INF, np.float32)
        assoc = np.full((l_pad, m, k), -1, np.int32)
        row_valid = np.zeros((l_pad, m), bool)
        level_mask = np.zeros((l_pad,), bool)
        for lvl in range(self.n_real):
            d, s, w_l, a, v = self.read_level(lvl)
            dst[lvl], src[lvl], w[lvl], assoc[lvl] = d, s, w_l, a
            row_valid[lvl] = v
            level_mask[lvl] = True
        return SweepPlan(dst=dst, src_idx=src, w=w, assoc=assoc,
                         row_valid=row_valid, level_mask=level_mask)


@dataclasses.dataclass
class _PlanScanStats:
    rows: int
    edges: int


class IndexStore:
    """An open store directory: the resident tier as a plan-less
    :class:`HoDIndex` plus one :class:`SegmentReader` per sweep plan,
    all sharing one page cache and one metering device.

    ``pin_segments`` names the segments whose blocks are pinned into
    the cache on first read (default: the small ``plan_core`` — see
    :data:`PIN_SEGMENTS`); the cache's pin budget bounds how much can
    stick, so over-subscription degrades gracefully.  ``pin_frac``
    sizes that budget when the store builds its own default cache (it
    is an error to pass both ``cache`` and ``pin_frac`` — configure the
    cache directly instead)."""

    def __init__(self, path: str, device: Optional[BlockDevice] = None,
                 cache: Optional[PageCache] = None,
                 pin_segments: Optional[Sequence[str]] = PIN_SEGMENTS,
                 pin_frac: Optional[float] = None):
        if cache is not None and pin_frac is not None:
            raise ValueError("pass pin_frac on the PageCache itself "
                             "when supplying an explicit cache")
        resident = os.path.join(path, RESIDENT_FILE)
        if not os.path.isfile(resident):
            raise FileNotFoundError(
                f"{path}: not a HoD index store (no {RESIDENT_FILE})")
        self.path = path
        self._plan_scan: Dict[str, _PlanScanStats] = {}
        with np.load(resident) as z:
            self.block_bytes = int(z["block_bytes"])
            self.codec = str(z["codec"]) if "codec" in z else "raw"
            self.resident = HoDIndex._from_npz(z)
            for name in SEGMENT_NAMES:
                self._plan_scan[name] = _PlanScanStats(
                    rows=int(z[f"{name}_rows"]),
                    edges=int(z[f"{name}_edges"]))
        # A device that does not yet know its block size (the fleet's
        # routing façade is configured from store geometry *after* the
        # store opens) adopts the store's; a mismatched one is an error.
        dev_bb = getattr(device, "block_bytes", None)
        if device is not None and dev_bb is not None \
                and dev_bb != self.block_bytes:
            raise ValueError(
                f"{path}: metering device block size "
                f"({device.block_bytes}) != store block size "
                f"({self.block_bytes}) — I/O accounting would be wrong")
        self.device = device or BlockDevice(block_bytes=self.block_bytes)
        self.cache = (cache if cache is not None
                      else PageCache(pin_frac=pin_frac))
        #: back-reference set by ``repro.fleet.ServingFleet`` when this
        #: store's cache/device are fleet routing façades; the read
        #: pipeline uses it to run on the shard workers' pools, and
        #: ``close()`` shuts those workers down with the store.
        self.fleet = None
        pin_set = frozenset(pin_segments or ())
        self.segments: Dict[str, SegmentReader] = {}
        try:
            for i, name in enumerate(SEGMENT_NAMES):
                self.segments[name] = SegmentReader(
                    os.path.join(path, f"{name}.seg"),
                    base_block=i * _SEGMENT_ID_STRIDE, device=self.device,
                    cache=self.cache, name=name,
                    pin_blocks=name in pin_set)
        except Exception:
            self.close()    # don't leak fds of segments already opened
            raise

    # --------------------------------------------------------------- queries
    def n_real(self, name: str) -> int:
        return self.segments[name].n_real

    def read_level(self, name: str, lvl: int, pin: bool = False):
        return self.segments[name].read_level(lvl, pin=pin)

    def unpin_level(self, name: str, lvl: int) -> None:
        """Release a level's pin leases (no-op for blocks whose pin
        never stuck, and for sticky ``pin_segments`` readers).

        The affinity layout makes adjacent levels share their boundary
        block under ONE pin entry, so a shared block's lease is handed
        forward: it is excluded here and released when the *next* level
        is unpinned (or by the sweep-end ledger)."""
        seg = self.segments[name]
        if seg.pin_blocks:
            return      # segment-aware pins are sticky by design
        keys = set(seg.level_keys(lvl))
        if lvl + 1 < seg.n_real:
            keys -= set(seg.level_keys(lvl + 1))
        self.cache.unpin(keys)

    def read_plan(self, name: str) -> SweepPlan:
        return self.segments[name].read_plan()

    # ------------------------------------------------------------ accounting
    def store_bytes(self) -> int:
        """Total on-disk size of the store (resident + segments) — the
        denominator for ``cache_bytes`` budgets."""
        return (os.path.getsize(os.path.join(self.path, RESIDENT_FILE))
                + segment_bytes(self.path))

    def segment_bytes(self) -> int:
        """On-disk size of the streamed tier only (the three segments)."""
        return segment_bytes(self.path)

    def scan_bytes(self, sssp: bool = False,
                   core_mode: str = "closure") -> int:
        """Modeled compact-payload cost of one full sweep — the shared
        :func:`~repro.core.index.scan_cost_bytes` model over the
        persisted row/edge counts, no plan materialization needed."""
        def plan_cost(name: str, include_assoc: bool) -> int:
            st = self._plan_scan[name]
            return scan_cost_bytes(st.rows, st.edges, include_assoc)
        total = plan_cost("plan_f", sssp) + plan_cost("plan_b", sssp)
        if sssp:
            total += plan_cost("plan_core", True)
        return total + core_scan_bytes(self.resident, core_mode)

    def segment_blocks(self) -> Dict[str, int]:
        """Per-segment logical data-block counts — the geometry the
        fleet partitioner splits (``repro/fleet``)."""
        return {name: seg.data_blocks()
                for name, seg in self.segments.items()}

    def close(self) -> None:
        for seg in self.segments.values():
            seg.close()
        fleet = getattr(self, "fleet", None)
        if fleet is not None:
            fleet.shutdown_workers()


def segment_bytes(path: str) -> int:
    """On-disk size of a store's streamed tier (the three segment
    files) — compressed bytes for codec stores; pure
    ``os.path.getsize``, no store open needed.  For sizing a page-cache
    budget use :func:`segment_logical_bytes`: the cache meters
    *decompressed* bytes, so a fraction of the compressed on-disk size
    would silently shrink the effective budget by the compression
    ratio."""
    return sum(os.path.getsize(os.path.join(path, f"{name}.seg"))
               for name in SEGMENT_NAMES)


def segment_logical_bytes(path: str) -> int:
    """Decompressed (cache-side) footprint of a store's streamed tier:
    the data-region bytes a page cache would hold with every block
    resident.  Codec-independent — a ``delta`` store reports exactly
    the same figure as the ``raw`` store of the same index — which
    makes it the right denominator for ``cache_frac``-style budgets.
    Header/footer metadata (never cached) is excluded."""
    total = 0
    for name in SEGMENT_NAMES:
        p = os.path.join(path, f"{name}.seg")
        with open(p, "rb") as f:
            (magic, version, block_bytes, _n_real, _l, _m, _k, _s, _r,
             footer_off, footer_len) = _HEADER.unpack(f.read(_HEADER.size))
            if magic not in (MAGIC, _MAGIC_V4, _MAGIC_V3):
                raise ValueError(f"{p}: not a HoD segment file")
            if version >= 5:
                f.seek(footer_off)
                footer = json.loads(f.read(footer_len))
                total += block_bytes * len(footer["frames"])
            else:
                # v3/v4 store data uncompressed and block-aligned, so
                # the data region [block 1, footer) IS the footprint
                total += max(0, footer_off - block_bytes)
    return total


def open_store(path: str, device: Optional[BlockDevice] = None,
               cache: Optional[PageCache] = None) -> IndexStore:
    return IndexStore(path, device=device, cache=cache)


def load_store(path: str) -> HoDIndex:
    """Fully materialize a store back into an in-memory :class:`HoDIndex`
    (plans included, bit-exact) — the compatibility/inspection path; a
    serving deployment streams through :class:`IndexStore` instead."""
    store = IndexStore(path)
    try:
        ix = store.resident
        for name in SEGMENT_NAMES:
            setattr(ix, name, store.read_plan(name))
        return ix
    finally:
        store.close()
