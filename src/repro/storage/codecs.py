"""Per-block segment codecs (format v5, DESIGN.md §6).

A v5 segment frames every data block as ``(codec_id, comp_len, crc)``
+ compressed payload; this module is the codec registry both the writer
(`blockfile._write_segment`) and the reader (`SegmentReader._load_block`)
go through.  Three codecs:

* ``raw`` — identity (the v4-equivalent payload, just framed);
* ``delta`` — int32 id streams become delta + zigzag varints, float32
  weight streams stay raw (``delta+raw-weights``).  **Lossless**: every
  decoded block is byte-identical to its input, so SSD/SSSP answers
  from a ``delta`` store are bit-identical to a ``raw`` one
  (tests/test_codecs.py asserts both);
* ``f16`` — ids as in ``delta``, plus weight narrowing: a float32
  weight is stored as float16 only when the round trip reproduces it
  exactly or within :data:`F16_EPS_REL` relative error; every other
  weight (including NaN and out-of-f16-range magnitudes) falls back to
  a bit-exact float32 exception slot.  Distances from an ``f16`` store
  therefore agree with the exact engine to ~``L * F16_EPS_REL``
  relative error (L = sweep depth), never worse per edge than the
  documented eps.

**Typed spans.**  A block's payload is an arbitrary byte window of the
affinity-packed logical stream, so the codec is steered by a *span
map* derived from the footer's level extents: each byte range is
tagged ``i32`` (dst/src/assoc id words), ``f32`` (weight words), or
``raw`` (anything untyped: fallback slabs, trailing block padding).
Spans are cut at block boundaries; id/weight fragments that would
split a 4-byte word across two blocks are re-tagged ``raw`` at the
edges, so every block still encodes and decodes independently —
random block access (the page cache's unit) never needs a neighbor.

Per-block fallback: when a codec fails to shrink a block, the writer
keeps the raw payload and stamps the frame ``raw`` — ``codec_id`` is
per *frame*, not per segment, so a store never pays expansion for
incompressible blocks.

Everything here is vectorized numpy (no per-byte Python loops): varint
encode/decode touch each of the ≤5 byte positions once over the whole
word array.
"""
from __future__ import annotations

import bisect
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["CODEC_IDS", "CODEC_NAMES", "F16_EPS_REL", "Span",
           "block_spans", "decode_block", "encode_block", "level_spans",
           "vint_decode", "vint_encode"]

#: codec name -> frame codec_id (stable on-disk values; append-only).
CODEC_IDS: Dict[str, int] = {"raw": 0, "delta": 1, "f16": 2}
CODEC_NAMES: Dict[int, str] = {v: k for k, v in CODEC_IDS.items()}

#: f16 narrowing policy: a weight may be stored as float16 iff the
#: f32→f16→f32 round trip is exact or within this *relative* error
#: (float16 carries ~2^-11 ≈ 4.9e-4 relative precision, so normal-range
#: weights narrow; everything else — NaN, overflow to inf, subnormal
#: precision loss beyond eps — is stored as a bit-exact f32 exception).
F16_EPS_REL = 1e-3

#: span kinds — (kind, start, end) with absolute logical byte offsets.
KIND_I32 = "i32"
KIND_F32 = "f32"
KIND_RAW = "raw"
Span = Tuple[str, int, int]

_U32 = np.dtype("<u4")


# ------------------------------------------------------------- span maps
def level_spans(off: int, length: int, m_real: int,
                k_fix: int) -> List[Span]:
    """Typed spans of one level slab at logical offset ``off``.

    Mirrors ``blockfile._level_slab``: a compact slab (``m_real >= 0``)
    is ``dst[i32 m] · src[i32 m·K] · w[f32 m·K] · assoc[i32 m·K]``; the
    lossless fallback layout (explicit valid vector) is left untyped.
    """
    if length == 0:
        return []
    if m_real < 0:
        return [(KIND_RAW, off, off + length)]
    m, k = m_real, k_fix
    a = off
    spans = [(KIND_I32, a, a + 4 * m)]
    a += 4 * m
    spans.append((KIND_I32, a, a + 4 * m * k))
    a += 4 * m * k
    spans.append((KIND_F32, a, a + 4 * m * k))
    a += 4 * m * k
    spans.append((KIND_I32, a, a + 4 * m * k))
    a += 4 * m * k
    if a != off + length:
        raise ValueError(
            f"slab geometry mismatch: {a - off} != {length} bytes")
    return spans


def block_spans(spans: Sequence[Span], lo: int, hi: int,
                starts: Optional[Sequence[int]] = None) -> List[Span]:
    """Cut a segment's span map down to one block's payload ``[lo, hi)``.

    Returns block-*relative* spans covering ``[0, hi - lo)`` exactly:
    typed spans are clipped to the window and trimmed inward to 4-byte
    word phase (relative to the span's own start), with the clipped
    word fragments — and every untyped gap — emitted as ``raw``.

    ``starts`` is the optional precomputed ``[s for _, s, _ in spans]``
    list: spans are sorted and non-overlapping, so a bisect skips
    straight to the window instead of scanning every span — O(log L +
    spans-in-block) per call, which keeps repeated cache misses cheap
    on deep-level segments (callers on the miss path pass it).
    """
    out: List[Span] = []
    pos = lo
    if starts is not None:
        # first span that could reach into [lo, hi): the one before the
        # first start > lo (it may straddle lo), clamped to 0
        i = max(0, bisect.bisect_right(starts, lo) - 1)
        spans = spans[i:]

    def emit(kind: str, start: int, end: int) -> None:
        nonlocal pos
        if start > pos:
            out.append((KIND_RAW, pos - lo, start - lo))
        if end > start:
            out.append((kind, start - lo, end - lo))
        pos = max(pos, end)

    for kind, s, e in spans:
        if s >= hi:
            break                   # sorted: nothing later can intersect
        a, b = max(s, lo), min(e, hi)
        if a >= b:
            continue
        if kind == KIND_RAW:
            emit(KIND_RAW, a, b)
            continue
        # snap inward to the span's word phase so no i32/f32 word is
        # split across blocks; edge fragments go raw
        wa = s + -(-(a - s) // 4) * 4
        wb = s + ((b - s) // 4) * 4
        if wb <= wa:
            emit(KIND_RAW, a, b)
            continue
        if wa > a:
            emit(KIND_RAW, a, wa)
        emit(kind, wa, wb)
        if b > wb:
            emit(KIND_RAW, wb, b)
    if pos < hi:
        out.append((KIND_RAW, pos - lo, hi - lo))
    return out


# --------------------------------------------------------------- varints
def vint_encode(values: np.ndarray) -> bytes:
    """Zigzag + LEB128-style varint encode an int64 array (vectorized).

    Values must fit zigzag in 35 bits — always true for int32 payloads
    and their first-order deltas (|delta| < 2^32 → zigzag < 2^33).
    """
    v = np.asarray(values, np.int64)
    if v.size == 0:
        return b""
    z = ((v << 1) ^ (v >> 63)).view(np.uint64)
    nb = np.ones(v.size, np.int64)
    for t in (7, 14, 21, 28):
        nb += z >= (np.uint64(1) << np.uint64(t))
    if z.max() >= (1 << 35):
        raise ValueError("varint overflow: value exceeds 35 zigzag bits")
    ends = np.cumsum(nb)
    starts = ends - nb
    out = np.empty(int(ends[-1]), np.uint8)
    for j in range(5):
        m = nb > j
        if not m.any():
            break
        byte = ((z[m] >> np.uint64(7 * j)) & np.uint64(0x7F))
        cont = (nb[m] - 1 > j).astype(np.uint8) << 7
        out[starts[m] + j] = byte.astype(np.uint8) | cont
    return out.tobytes()


def vint_decode(buf: bytes, count: int) -> np.ndarray:
    """Inverse of :func:`vint_encode`: exactly ``count`` int64 values."""
    if count == 0:
        if buf:
            raise ValueError("varint stream has trailing bytes")
        return np.empty(0, np.int64)
    b = np.frombuffer(buf, np.uint8)
    ends = np.flatnonzero((b & 0x80) == 0)
    if ends.size != count or (ends.size and ends[-1] != b.size - 1):
        raise ValueError(
            f"varint stream: {ends.size} terminators for {count} values")
    starts = np.empty(count, np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    lens = ends - starts + 1
    if lens.max() > 5:
        raise ValueError("varint stream: value longer than 5 bytes")
    z = np.zeros(count, np.uint64)
    for j in range(5):
        m = lens > j
        if not m.any():
            break
        z[m] |= ((b[starts[m] + j] & 0x7F).astype(np.uint64)
                 << np.uint64(7 * j))
    return (z >> np.uint64(1)).view(np.int64) ^ -(z & np.uint64(1)
                                                  ).view(np.int64)


# ------------------------------------------------------------ span coding
def _encode_i32(raw: bytes) -> bytes:
    words = np.frombuffer(raw, "<i4").astype(np.int64)
    deltas = np.diff(words, prepend=np.int64(0))
    return vint_encode(deltas)


def _decode_i32(enc: bytes, raw_len: int) -> bytes:
    deltas = vint_decode(enc, raw_len // 4)
    words = np.cumsum(deltas)
    lo, hi = np.iinfo(np.int32).min, np.iinfo(np.int32).max
    if words.size and (words.min() < lo or words.max() > hi):
        raise ValueError("corrupt delta stream: int32 overflow")
    return words.astype("<i4").tobytes()


def _encode_f16(raw: bytes) -> bytes:
    w = np.frombuffer(raw, "<f4")
    with np.errstate(over="ignore", invalid="ignore"):
        back = w.astype(np.float16).astype(np.float32)
        keep = (back == w) | (np.abs(back - w) <= F16_EPS_REL * np.abs(w))
    exc = ~keep
    return b"".join((
        np.array([int(exc.sum())], _U32).tobytes(),
        np.packbits(exc).tobytes(),
        w[keep].astype("<f2").tobytes(),
        np.ascontiguousarray(w[exc], "<f4").tobytes()))


def _decode_f16(enc: bytes, raw_len: int) -> bytes:
    n = raw_len // 4
    n_exc = int(np.frombuffer(enc, _U32, 1, 0)[0])
    bm_len = -(-n // 8)
    exc = np.unpackbits(
        np.frombuffer(enc, np.uint8, bm_len, 4))[:n].astype(bool)
    if int(exc.sum()) != n_exc:
        raise ValueError("corrupt f16 stream: exception count mismatch")
    off = 4 + bm_len
    narrow = np.frombuffer(enc, "<f2", n - n_exc, off)
    off += 2 * (n - n_exc)
    exact = np.frombuffer(enc, "<f4", n_exc, off)
    out = np.empty(n, "<f4")
    out[~exc] = narrow.astype(np.float32)
    out[exc] = exact
    return out.tobytes()


# ------------------------------------------------------------ block frame
def _code_spans(payload: bytes, spans: Iterable[Span],
                weights: str) -> bytes:
    """Encode a block: per span, ``u32 enc_len`` + encoded bytes.

    ``weights`` picks the f32 treatment: ``"raw"`` (lossless delta
    codec) or ``"f16"`` (narrowing).
    """
    parts = []
    for kind, lo, hi in spans:
        raw = payload[lo:hi]
        if kind == KIND_I32:
            enc = _encode_i32(raw)
        elif kind == KIND_F32 and weights == "f16":
            enc = _encode_f16(raw)
        else:
            enc = raw
        parts.append(np.array([len(enc)], _U32).tobytes())
        parts.append(enc)
    return b"".join(parts)


def encode_block(codec: str, payload: bytes,
                 spans: Sequence[Span]) -> Tuple[int, bytes]:
    """Encode one block payload; returns ``(codec_id, blob)``.

    Falls back to ``raw`` framing whenever the requested codec does not
    strictly shrink the payload, so a frame never expands past raw + 0.
    """
    if codec not in CODEC_IDS:
        raise ValueError(f"unknown codec {codec!r} "
                         f"(have {sorted(CODEC_IDS)})")
    if codec != "raw":
        blob = _code_spans(payload, spans,
                           "f16" if codec == "f16" else "raw")
        if len(blob) < len(payload):
            return CODEC_IDS[codec], blob
    return CODEC_IDS["raw"], payload


def decode_block(codec_id: int, blob: bytes, spans: Sequence[Span],
                 raw_len: int) -> bytes:
    """Inverse of :func:`encode_block` for one frame."""
    name = CODEC_NAMES.get(codec_id)
    if name is None:
        raise ValueError(f"unknown frame codec_id {codec_id}")
    if name == "raw":
        if len(blob) != raw_len:
            raise ValueError("corrupt raw frame: length mismatch")
        return blob
    out = []
    off = 0
    for kind, lo, hi in spans:
        enc_len = int(np.frombuffer(blob, _U32, 1, off)[0])
        off += 4
        enc = blob[off:off + enc_len]
        if len(enc) != enc_len:
            raise ValueError("corrupt frame: truncated span")
        off += enc_len
        if kind == KIND_I32:
            out.append(_decode_i32(enc, hi - lo))
        elif kind == KIND_F32 and name == "f16":
            out.append(_decode_f16(enc, hi - lo))
        else:
            if enc_len != hi - lo:
                raise ValueError("corrupt frame: raw span length mismatch")
            out.append(enc)
    data = b"".join(out)
    if len(data) != raw_len or off != len(blob):
        raise ValueError("corrupt frame: decoded length mismatch")
    return data
