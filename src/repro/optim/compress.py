"""Gradient-compression utilities for bandwidth-bound data parallelism.

Two schemes, composable with error feedback:

* int8 quantization with per-tensor scale and stochastic rounding — an
  8/32 = 4× (vs f32) or 4/1 (vs bf16 2×) reduction of all-reduce bytes with
  unbiased expectation;
* top-k sparsification with error feedback (Stich et al.) — only the k
  largest-magnitude entries are exchanged; the residual accumulates
  locally and is re-injected next step, preserving convergence.

``compressed_mean`` is the drop-in DP-mean: it quantizes, averages with a
psum (or a plain mean at world size 1), and dequantizes.  On a real mesh
the quantized payload is what crosses ICI; the §Perf log uses the byte
ratio directly.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .. import shardlib as sl


def quantize_int8(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stochastic-rounding int8 quantization; returns (q, scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = x.astype(jnp.float32) / scale
    noise = jax.random.uniform(key, x.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jnp.ndarray, k: int):
    """Keep the k largest-|x| entries; returns (values, flat_idx, residual)."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = min(k, flat.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = jnp.take(flat, idx)
    residual = flat.at[idx].set(0.0).reshape(x.shape)
    return vals, idx, residual


class ErrorFeedback:
    """Residual accumulator: feed(grad) -> compressed-comm grad + carry."""

    @staticmethod
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, residuals):
        return jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residuals)


def compressed_mean(grads, key, dp_axes: Sequence[str] = (),
                    scheme: str = "int8"):
    """DP-mean of grads with simulated/actual on-the-wire compression."""
    leaves, tree = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    n = max(sl.axis_size(dp_axes), 1)
    out = []
    for g, k in zip(leaves, keys):
        if scheme == "int8":
            q, scale = quantize_int8(g, k)
            deq = dequantize_int8(q, scale)
            avg = sl.psum(deq, dp_axes) / n
        else:
            avg = sl.psum(g.astype(jnp.float32), dp_axes) / n
        out.append(avg.astype(g.dtype))
    return jax.tree.unflatten(tree, out)


def wire_bytes(grads, scheme: str = "int8", topk_frac: float = 0.01) -> int:
    """Bytes a DP exchange of ``grads`` puts on the wire under ``scheme``."""
    total = 0
    for g in jax.tree.leaves(grads):
        if scheme == "int8":
            total += g.size + 4
        elif scheme == "topk":
            k = max(1, int(g.size * topk_frac))
            total += k * 8
        else:
            total += g.size * g.dtype.itemsize
    return total
