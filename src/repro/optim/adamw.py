"""AdamW with decoupled weight decay, global-norm clipping, f32 state.

Pure-pytree implementation (no optax dependency): state is {m, v, count}.
Weight decay is masked off 1-D parameters (norm scales, biases) by default,
the usual LM convention.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.zeros_like, zeros),
                    count=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(params, grads, state: OptState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0,
                 decay_mask: Optional[Callable[[jnp.ndarray], bool]] = None):
    """One AdamW step. ``lr`` may be a scalar or a schedule value."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = jnp.float32(0.0)
    count = state.count + 1
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * jnp.square(g)
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        decay = (weight_decay if (decay_mask(p) if decay_mask is not None
                                  else p.ndim >= 2) else 0.0)
        new_p = p.astype(jnp.float32) - lr * (step + decay
                                              * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m_new, v_new

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), gnorm
