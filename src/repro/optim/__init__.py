from .adamw import (OptState, adamw_init, adamw_update,  # noqa: F401
                    clip_by_global_norm)
from .compress import (ErrorFeedback, compressed_mean,  # noqa: F401
                       dequantize_int8, quantize_int8, topk_sparsify)
from .schedules import cosine_schedule, linear_warmup  # noqa: F401
