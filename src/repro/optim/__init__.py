from .adamw import adamw_init, adamw_update, OptState, clip_by_global_norm  # noqa: F401
from .schedules import cosine_schedule, linear_warmup  # noqa: F401
from .compress import (quantize_int8, dequantize_int8,  # noqa: F401
                       topk_sparsify, ErrorFeedback, compressed_mean)
