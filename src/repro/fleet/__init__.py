"""Sharded serving fleet: partition a store's segments across N
shards with fleet-wide cache accounting (DESIGN.md §13)."""
from .fleet import (FleetCache, FleetDevice, FleetShard, FleetStats,
                    ServingFleet, split_budget)
from .partition import REPLICATED_SEGMENTS, StorePartition

__all__ = ["FleetCache", "FleetDevice", "FleetShard", "FleetStats",
           "ServingFleet", "split_budget", "StorePartition",
           "REPLICATED_SEGMENTS"]
