"""Sharded serving fleet over one block-segment store (DESIGN.md §13).

A :class:`ServingFleet` opens an :class:`~repro.storage.blockfile.IndexStore`
whose ``cache`` and ``device`` are *routing façades*: every page-cache
transaction and every modeled device charge is forwarded to the shard
that owns the block, where a real per-shard
:class:`~repro.storage.pagecache.PageCache` (its slice of the
fleet-wide byte budget) and :class:`~repro.core.io_sim.BlockDevice`
(its own spindle, its own sequential/random cursor) do the work.  The
compute plane — :class:`~repro.storage.stream.StreamingQueryEngine`,
the jitted level steps, the fixed batch shapes — is byte-for-byte the
single-host code: shards partition *storage*, not *math*, which is
what makes bit-identical answers at every N a structural property
rather than a numerical accident.

Thread-backed shard workers: each shard owns a 1-wide io executor
(ordered preads against its local block ranges) and a decode pool.
The read pipeline (``storage/pipeline.py``) splits a level's
missed-block runs at ownership boundaries and dispatches each run to
its owner's pools, so shards genuinely read and decode concurrently —
N spindles in parallel — and a shard-local fault (CRC mismatch, short
read) travels the same discard/fail path back into the query thread
as on a single host.

Budget split: shard ``s`` gets ``ceil(B * owned_s / sum(owned))``
rounded **up** to a whole ``block_bytes`` multiple, where ``owned_s``
is the shard's block footprint *including* the replicated pinned tier
on its materialized home (shard 0).  Footprint-proportional is the
static split that best mirrors how a single global cache distributes
its capacity across the same blocks: an equal ``B / N`` split starves
whichever shard owns the most blocks (observed both ways before this
policy — shard 0 squeezed by the materialized ``plan_core`` copy at
N=4, and the non-core shard starved at N=2 when core compensation
over-corrected — each inflating fleet reads past one host's; both are
regression-gated by the tolerance-free ``N>1 reads no more than N=1``
ordering in ``check_regression.py``).  Rounding up (never down) means
every shard holds at least its proportional share of whole blocks, so
the fleet may hold up to ``N * block_bytes`` more than ``B`` resident
in the worst case — documented, bounded, and metered (``FleetStats``
reports the exact per-shard budgets).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

from ..core.io_sim import BlockDevice, IOStats
from ..storage.blockfile import (IndexStore, PIN_SEGMENTS, SEGMENT_NAMES,
                                 _SEGMENT_ID_STRIDE)
from ..storage.pagecache import CacheStats, PageCache
from .partition import StorePartition

__all__ = ["FleetCache", "FleetDevice", "FleetShard", "FleetStats",
           "ServingFleet", "split_budget"]


def split_budget(total: Optional[int], n_shards: int,
                 block_bytes: int,
                 owned_blocks: Optional[Sequence[int]] = None,
                 floors: Optional[Sequence[int]] = None
                 ) -> List[Optional[int]]:
    """Per-shard cache budgets (module docstring): the fleet budget
    splits *proportional to each shard's owned block footprint* —
    which is how a global cache's capacity ends up distributed across
    the same blocks on a single host, and automatically funds shard
    0's materialized copy of the replicated pinned tier — then rounds
    each share **up** to a whole block.  ``floors[s]`` raises shard
    ``s``'s slice to at least that many bytes (the replicated tier's
    home shard is floored at the tier's footprint: every query sweeps
    the whole tier, so anything smaller guarantees a thrash loop).
    ``None`` (unbounded) splits to all-``None``; a degenerate 1-shard
    fleet keeps the exact budget so it is counter-for-counter
    identical to an unsharded server."""
    if total is None:
        return [None] * n_shards
    if n_shards == 1:
        return [int(total)]
    owned = ([1] * n_shards if owned_blocks is None
             else [max(0, int(b)) for b in owned_blocks])
    weight = sum(owned) or n_shards
    out = []
    for s in range(n_shards):
        share = -(-int(total) * (owned[s] or 1) // weight)
        if floors is not None:
            share = max(share, int(floors[s]))
        rem = share % block_bytes
        out.append(share + (block_bytes - rem) if rem else share)
    return out


class FleetCache:
    """Routing façade with the :class:`PageCache` interface: every
    call forwards to the shard cache that owns the key's block.  Built
    unconfigured so the store can open against it; :meth:`configure`
    wires the partition + shard caches from store geometry."""

    def __init__(self):
        self._part: Optional[StorePartition] = None
        self._caches: List[PageCache] = []
        self._ns_names: Dict[str, str] = {}
        self._on_event = None

    def configure(self, partition: StorePartition,
                  ns_names: Dict[str, str],
                  caches: Sequence[PageCache]) -> None:
        self._part = partition
        self._ns_names = dict(ns_names)
        self._caches = list(caches)

    def owner_of(self, key) -> int:
        ns, block = key
        return self._part.owner(self._ns_names[ns], block)

    def _route(self, key) -> PageCache:
        return self._caches[self.owner_of(key)]

    # ------------------------------------------------- PageCache interface
    def get(self, key, load, pin: bool = False):
        return self._route(key).get(key, load, pin=pin)

    def begin_fill(self, key, size: int, disk_bytes: Optional[int] = None,
                   pin: bool = False, charge=None):
        return self._route(key).begin_fill(key, size, disk_bytes,
                                           pin=pin, charge=charge)

    def discard(self, key, entry) -> None:
        self._route(key).discard(key, entry)

    def unpin(self, keys) -> None:
        by_owner: Dict[int, list] = {}
        for k in keys:
            by_owner.setdefault(self.owner_of(k), []).append(k)
        for owner, ks in by_owner.items():
            self._caches[owner].unpin(ks)

    def clear(self) -> None:
        for c in self._caches:
            c.clear()

    def reset_stats(self, also=()) -> CacheStats:
        """Compound reset: shards 1..N-1 and the caller's ``also``
        callbacks all run inside shard 0's stats lock, preserving the
        no-half-charged-fill atomicity the single-host reset gives
        (shard locks nest in index order, so this cannot deadlock).
        Returns the summed pre-reset stats."""
        olds: List[CacheStats] = []

        def chain():
            for c in self._caches[1:]:
                olds.append(c.reset_stats())
            for cb in also:
                cb()

        old0 = self._caches[0].reset_stats(also=[chain])
        total = old0
        for o in olds:
            total = total + o
        return total

    # ------------------------------------------------------------- metrics
    @property
    def stats(self) -> CacheStats:
        total = CacheStats()
        for c in self._caches:
            total = total + c.stats
        return total

    @property
    def on_event(self):
        return self._on_event

    @on_event.setter
    def on_event(self, hook) -> None:
        self._on_event = hook
        for c in self._caches:
            c.on_event = hook

    @property
    def pin_frac(self):
        return self._caches[0].pin_frac if self._caches else None

    @property
    def resident_bytes(self) -> int:
        return sum(c.resident_bytes for c in self._caches)

    @property
    def pinned_bytes(self) -> int:
        return sum(c.pinned_bytes for c in self._caches)

    def pinned_keys(self):
        out = set()
        for c in self._caches:
            out |= set(c.pinned_keys())
        return out

    def resident_keys(self):
        out = set()
        for c in self._caches:
            out |= set(c.resident_keys())
        return out


class FleetDevice:
    """Routing façade with the :class:`BlockDevice` interface: a
    global block id (``segment_base + block``) decomposes back to
    ``(segment, block)``, routes to the owning shard's device under
    the shard-*local* dense block id — so each shard's
    sequential/random classification sees exactly the scan a host
    holding that range would see."""

    def __init__(self):
        self._part: Optional[StorePartition] = None
        self._ns_names: Dict[str, str] = {}
        self.shard_devices: List[BlockDevice] = []
        self.block_bytes: Optional[int] = None
        self._on_access = None

    def configure(self, partition: StorePartition,
                  devices: Sequence[BlockDevice],
                  block_bytes: int) -> None:
        self._part = partition
        self.shard_devices = list(devices)
        self.block_bytes = int(block_bytes)

    # ----------------------------------------------- BlockDevice interface
    def access_block(self, block_id: int, nbytes: Optional[int] = None
                     ) -> None:
        seg_idx, block = divmod(block_id, _SEGMENT_ID_STRIDE)
        name = SEGMENT_NAMES[seg_idx]
        shard = self._part.owner(name, block)
        local = self._part.local_block(name, block)
        self.shard_devices[shard].access_block(local, nbytes)

    def sequential(self, nbytes: int) -> None:
        self.shard_devices[0].sequential(nbytes)

    def random(self, nbytes: int) -> None:
        self.shard_devices[0].random(nbytes)

    def reset(self) -> IOStats:
        old = self.stats
        for d in self.shard_devices:
            d.reset()
        return old

    # ------------------------------------------------------------- metrics
    @property
    def stats(self) -> IOStats:
        total = IOStats()
        for d in self.shard_devices:
            total = total + d.stats
        return total

    @property
    def on_access(self):
        return self._on_access

    @on_access.setter
    def on_access(self, hook) -> None:
        self._on_access = hook
        for d in self.shard_devices:
            d.on_access = hook


@dataclasses.dataclass
class FleetShard:
    """One serving shard: its cache slice, its modeled spindle, and
    its worker pools (1-wide ordered io + a decode pool)."""
    index: int
    cache: PageCache
    device: BlockDevice
    io: ThreadPoolExecutor
    decode: ThreadPoolExecutor
    budget_bytes: Optional[int]

    def shutdown(self) -> None:
        self.io.shutdown(wait=True)
        self.decode.shutdown(wait=True)


@dataclasses.dataclass
class FleetStats:
    """Point-in-time fleet aggregate: per-shard rows plus the summed
    cache/io stats ``ServerStats.report`` and the bench ``fleet``
    table consume."""
    rows: List[dict]
    cache: CacheStats
    io: IOStats

    def report_lines(self) -> List[str]:
        lines = []
        for r in self.rows:
            budget = (f"{r['budget_bytes'] / 1e6:.1f} MB"
                      if r["budget_bytes"] is not None else "unbounded")
            lines.append(
                f"  shard {r['shard']}: {r['blocks']} blocks, "
                f"budget {budget}, hit rate {r['hit_rate']:.3f} "
                f"({r['hits']}/{r['hits'] + r['misses']}), "
                f"{r['bytes_read'] / 1e6:.1f} MB read, "
                f"io {r['io_model_s'] * 1e3:.2f} ms modeled")
        return lines


class ServingFleet:
    """Open a store sharded N ways on one machine (module docstring).

    The returned fleet owns ``fleet.store`` — an :class:`IndexStore`
    whose cache/device are the routing façades — plus the N
    :class:`FleetShard` workers.  Pass ``fleet.store`` to a
    :class:`StreamingQueryEngine` exactly like a plain store; closing
    the store shuts the shard workers down.

    ``owner_fn`` overrides block placement (tests force degenerate
    layouts with it); ``cache_bytes`` is the *fleet-wide* budget,
    split per shard by :func:`split_budget`.
    """

    def __init__(self, store_path: str, n_shards: int, *,
                 cache_bytes: Optional[int] = None,
                 cache_policy: str = "2q",
                 pin_frac: Optional[float] = None,
                 decode_workers: int = 2,
                 owner_fn: Optional[Callable[[str, int], int]] = None,
                 pin_segments: Optional[Sequence[str]] = PIN_SEGMENTS):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = int(n_shards)
        self.budget_bytes = cache_bytes
        self.cache = FleetCache()
        self.device = FleetDevice()
        self.shards: List[FleetShard] = []
        self._workers_down = False
        store = IndexStore(store_path, device=self.device,
                           cache=self.cache, pin_segments=pin_segments)
        try:
            seg_blocks = store.segment_blocks()
            self.partition = StorePartition(seg_blocks, self.n_shards,
                                            owner_fn=owner_fn)
            owned = [self.partition.shard_blocks(i)
                     for i in range(self.n_shards)]
            repl_bytes = sum(
                seg_blocks[name] * store.block_bytes
                for name in self.partition.replicated
                if name in seg_blocks) if owner_fn is None else 0
            floors = [repl_bytes] + [0] * (self.n_shards - 1)
            budgets = split_budget(cache_bytes, self.n_shards,
                                   store.block_bytes,
                                   owned_blocks=owned, floors=floors)
            self.shard_budget_bytes = budgets
            for i in range(self.n_shards):
                self.shards.append(FleetShard(
                    index=i,
                    cache=PageCache(budgets[i], policy=cache_policy,
                                    pin_frac=pin_frac),
                    device=BlockDevice(block_bytes=store.block_bytes),
                    io=ThreadPoolExecutor(
                        max_workers=1,
                        thread_name_prefix=f"hod-shard{i}-io"),
                    decode=ThreadPoolExecutor(
                        max_workers=decode_workers,
                        thread_name_prefix=f"hod-shard{i}-decode"),
                    budget_bytes=budgets[i]))
            ns_names = {seg._cache_ns: name
                        for name, seg in store.segments.items()}
            self.cache.configure(self.partition, ns_names,
                                 [s.cache for s in self.shards])
            self.device.configure(self.partition,
                                  [s.device for s in self.shards],
                                  store.block_bytes)
            store.fleet = self
            self.store = store
        except Exception:
            self.shutdown_workers()
            store.close()
            raise

    # --------------------------------------------------------------- routing
    def owner_of_key(self, key) -> int:
        """Shard owning a page-cache key — the read pipeline's
        run-splitting hook."""
        return self.cache.owner_of(key)

    # ------------------------------------------------------------ accounting
    def stats(self) -> FleetStats:
        rows = []
        for s in self.shards:
            cs = s.cache.stats
            io = s.device.stats
            rows.append({
                "shard": s.index,
                "blocks": self.partition.shard_blocks(s.index),
                "budget_bytes": s.budget_bytes,
                "hits": cs.hits,
                "misses": cs.misses,
                "hit_rate": cs.hit_rate(),
                "bytes_read": cs.bytes_read,
                "bytes_filled": cs.bytes_filled,
                "io_model_s": io.modeled_seconds(
                    block_bytes=self.store.block_bytes),
            })
        return FleetStats(rows=rows, cache=self.cache.stats,
                          io=self.device.stats)

    # ------------------------------------------------------------- lifecycle
    def shutdown_workers(self) -> None:
        """Idempotent; invoked by ``IndexStore.close()`` via the
        ``store.fleet`` back-reference."""
        if self._workers_down:
            return
        self._workers_down = True
        for s in self.shards:
            s.shutdown()
