"""Fleet smoke check (CI): build → ``save_store`` → serve the checked
in ``configs/serve_fleet.yaml`` mixed workload as an unsharded server
and as N ∈ {1, 2} fleets — asserting the ISSUE-10 acceptance criteria
end to end:

* **bit-identity**: every fleet answer (any N) equals the unsharded
  server's answer for the same request, which itself equals a
  singleton call on the in-memory engine — shards partition storage,
  not math;
* **degenerate fleet**: at N=1 the fleet's aggregate cache counters
  (hits, misses, bytes read, bytes filled) equal the unsharded
  server's exactly — the routing façades add bookkeeping, never
  behavior;
* **real sharding**: at N=2 every shard that owns blocks served
  traffic with a strictly positive hit rate, per-shard bytes sum to
  the fleet aggregate, and the answers stayed bit-identical;
* **shardlib plumbing**: the N=2 leg runs under a live 1-device mesh
  with the ``batch → data`` axis rule, so the fleet path composes
  with ``maybe_shard_map`` data parallelism;
* **artifacts**: set ``FLEET_TRACE_OUT=<path>`` to keep the N=2 leg's
  Chrome trace and ``FLEET_BENCH_OUT=<path>`` for a schema-stamped
  JSON of the per-leg fleet stats (CI uploads both).

    PYTHONPATH=src python -m repro.fleet.smoke
"""
from __future__ import annotations

import asyncio
import json
import os
import tempfile

import numpy as np

from .. import shardlib as sl
from ..config import SERVE_DEFAULTS, Config
from ..core import (BuildConfig, QueryEngine, build_hod,
                    gnm_random_digraph, pack_index)
from ..launch.serve import mixed_request_stream, server_from_config
from ..storage.blockfile import segment_logical_bytes

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def _fleet_config(requests: int = 64) -> Config:
    """The checked-in fleet config (or an inline twin for installed
    trees without ``configs/``), minus the shard count — each leg sets
    its own."""
    cfg_path = os.path.join(_REPO_ROOT, "configs", "serve_fleet.yaml")
    cfg = Config(cfg_path if os.path.exists(cfg_path) else None,
                 defaults=SERVE_DEFAULTS,
                 overrides={"serve": {"requests": requests, "batch": 8}})
    if not cfg.get("serve.mix"):
        cfg.data["serve"].update(
            scheduler="slo", mix={"ssd": 1, "p2p": 3},
            slo={"ssd": {"deadline_ms": 200.0},
                 "p2p": {"deadline_ms": 60.0, "batch": 8}})
        cfg.data.setdefault("store", {}).update(enabled=True,
                                                codec="delta")
    return cfg


def _serve_leg(cfg: Config, store_dir: str, budget: int, stream,
               shards, tracer=None):
    """Serve the mixed stream once; returns (answers, server) with the
    server already closed."""
    cfg.data["serve"]["shards"] = shards
    server = server_from_config(cfg, store_path=store_dir,
                                cache_bytes=budget, tracer=tracer)

    async def drive():
        tasks = [asyncio.create_task(server.submit(*a, mode=m))
                 for m, a in stream]
        await asyncio.sleep(0)
        await server.drain()
        return await asyncio.gather(*tasks)

    try:
        server.warmup()
        answers = asyncio.run(drive())
    finally:
        server.close()
    return answers, server


def main() -> None:
    g = gnm_random_digraph(200, 800, seed=11, weighted=True)
    res = build_hod(g, BuildConfig(max_core_nodes=32,
                                   max_core_edges=1024, seed=0))
    ix = pack_index(g, res, chunk=64)
    cfg = _fleet_config()

    with tempfile.TemporaryDirectory() as tmp:
        store_dir = f"{tmp}/store"
        ix.save_store(store_dir, block_bytes=4096,
                      codec=cfg.get("store.codec", "delta"))
        budget = int(float(cfg.get("store.cache_frac", 0.25))
                     * segment_logical_bytes(store_dir))
        stream = mixed_request_stream(cfg, g.n,
                                      int(cfg.get("serve.requests")),
                                      np.random.default_rng(5))

        # Leg 0 — unsharded reference, itself checked against the
        # in-memory engine (the smoke's ground truth).
        ref, solo = _serve_leg(cfg, store_dir, budget, stream, None)
        eng_mem = QueryEngine(ix)
        for (m, a), r in zip(stream, ref):
            if m == "p2p":
                np.testing.assert_array_equal(
                    r.dist, np.float32(eng_mem.p2p(
                        np.array([a[0]], np.int32),
                        np.array([a[1]], np.int32))[0]))
            else:
                np.testing.assert_array_equal(
                    r.dist, eng_mem.ssd(np.array(a, np.int32))[0])
        solo_cache = solo.store.cache.stats

        # Leg 1 — degenerate fleet: same answers, same counters.
        one, srv1 = _serve_leg(cfg, store_dir, budget, stream, 1)
        for a, b in zip(ref, one):
            np.testing.assert_array_equal(a.dist, b.dist)
        f1 = srv1.fleet_report()
        assert f1 is not None and len(f1.rows) == 1
        for field in ("hits", "misses", "bytes_read", "bytes_filled"):
            got = getattr(f1.cache, field)
            want = getattr(solo_cache, field)
            assert got == want, \
                f"N=1 fleet {field}={got} != unsharded {want} — the " \
                f"routing façade changed cache behavior"

        # Leg 2 — N=2 under a live mesh (the shardlib axis plumbing
        # the serve CLI's --data-parallel uses), with a tracer.
        import jax

        from ..obs import Tracer, validate_chrome_trace
        tracer = Tracer()
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        with sl.axis_rules(mesh, {"batch": "data"}):
            two, srv2 = _serve_leg(cfg, store_dir, budget, stream, 2,
                                   tracer=tracer)
        for a, b in zip(ref, two):
            np.testing.assert_array_equal(a.dist, b.dist)
        f2 = srv2.fleet_report()
        assert f2 is not None and len(f2.rows) == 2
        for row in f2.rows:
            if row["blocks"] == 0:
                continue
            assert row["hit_rate"] > 0.0, \
                f"shard {row['shard']} owns {row['blocks']} blocks " \
                f"but served with a 0.0 hit rate — per-shard budget " \
                f"split or routing regressed"
        assert sum(r["bytes_read"] for r in f2.rows) == \
            f2.cache.bytes_read, "per-shard bytes don't sum to the " \
            "fleet aggregate"

        doc = tracer.chrome()
        problems = validate_chrome_trace(doc)
        assert not problems, f"fleet trace invalid: {problems[:3]}"
        trace_out = os.environ.get("FLEET_TRACE_OUT")
        if trace_out:
            tracer.write_chrome(trace_out)
            print(f"fleet trace written to {trace_out} "
                  f"({len(doc['traceEvents'])} events)")

        bench_out = os.environ.get("FLEET_BENCH_OUT")
        if bench_out:
            from ..obs.metrics import SCHEMA_VERSION
            doc = {"schema_version": SCHEMA_VERSION,
                   "tables": {"fleet_smoke": [
                       {"shards": n,
                        "hit_rate": fs.cache.hit_rate(),
                        "bytes_read": fs.cache.bytes_read,
                        "per_shard": fs.rows}
                       for n, fs in ((1, f1), (2, f2))]}}
            with open(bench_out, "w") as f:
                json.dump(doc, f, indent=1)
            print(f"fleet bench stats written to {bench_out}")

        print(f"fleet smoke OK: {len(stream)} mixed requests, "
              f"unsharded == N=1 == N=2 bit-identical; N=1 counters "
              f"exact (hit rate {f1.cache.hit_rate():.3f}); N=2 "
              f"per-shard hit rates "
              f"{[round(r['hit_rate'], 3) for r in f2.rows]}, "
              f"{f2.cache.bytes_read/1e6:.2f} MB read across "
              f"{len(f2.rows)} shards under a "
              f"{len(jax.devices())}-device mesh")


if __name__ == "__main__":
    main()
