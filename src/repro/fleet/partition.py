"""Block-range partitioning of a store's segments across N shards
(DESIGN.md §13).

The unit of placement is the **logical data block** — the same unit
the page cache budgets and the modeled device meters — so a shard's
byte accounting is exactly the single-host accounting restricted to
the blocks it owns.  Each swept segment (``plan_f``, ``plan_b``) is
split into N *contiguous* block ranges balanced by block count:

* a level sweep visits blocks in ascending order, so a contiguous
  range keeps each shard's device scan modeled-sequential (at most
  N - 1 range crossings per full-segment scan, vs one random seek per
  block under round-robin);
* the owner of a global block is a closed-form ``(b - 1) * N // B``
  (no lookup tables), and the shard-local block id is a simple offset
  so local ids are dense and 1-based exactly like a single-host store.

The pinned ``plan_core`` tier is *replicated*: on a real fleet every
host pins its own copy so core sweeps never cross the network.  The
single-machine emulation materializes the one copy every answer is
computed from on shard 0 and documents the replication factor instead
of multiplying the byte counters — that keeps fleet-aggregate
``bytes_read`` directly comparable to the single-host baseline (the
``N>1 must not read more than N=1`` bench gate).

``owner_fn`` injects a custom placement (tests use it to force
degenerate layouts: every block on one shard, a shard that owns
nothing).  Injected placements fall back to single-host block
numbering since contiguity is no longer guaranteed.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

from ..storage.blockfile import SEGMENT_NAMES, _SEGMENT_ID_STRIDE

__all__ = ["StorePartition", "REPLICATED_SEGMENTS"]

#: segments replicated to every shard rather than range-partitioned
#: (the pinned tier; see module docstring for the emulation story).
REPLICATED_SEGMENTS: Tuple[str, ...] = ("plan_core",)


class StorePartition:
    """Immutable block → shard map for one store's segments.

    ``seg_blocks`` maps segment name → logical data-block count (from
    :meth:`repro.storage.blockfile.IndexStore.segment_blocks`).
    """

    def __init__(self, seg_blocks: Dict[str, int], n_shards: int,
                 replicated: Sequence[str] = REPLICATED_SEGMENTS,
                 owner_fn: Optional[Callable[[str, int], int]] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        unknown = set(seg_blocks) - set(SEGMENT_NAMES)
        if unknown:
            raise ValueError(f"unknown segments: {sorted(unknown)}")
        self.n_shards = int(n_shards)
        self.seg_blocks = dict(seg_blocks)
        self.replicated = frozenset(replicated)
        self._owner_fn = owner_fn
        self._seg_index = {n: i for i, n in enumerate(SEGMENT_NAMES)}

    # ------------------------------------------------------------- placement
    def owner(self, name: str, block: int) -> int:
        """Shard that owns global data block ``block`` (1-based) of
        segment ``name``."""
        n_blocks = self.seg_blocks[name]
        if not 1 <= block <= n_blocks:
            raise ValueError(f"{name}: block {block} out of range "
                             f"(1..{n_blocks})")
        if name in self.replicated:
            return 0            # emulation: the one materialized copy
        if self._owner_fn is not None:
            return self._owner_fn(name, block)
        return (block - 1) * self.n_shards // n_blocks

    def range_start(self, name: str, shard: int) -> int:
        """First global block of ``shard``'s contiguous range (the
        range may be empty when N exceeds the block count).  The ceil
        form is the exact inverse of :meth:`owner`'s
        ``(b - 1) * N // B``: block ``b`` belongs to shard ``s`` iff
        ``ceil(s * B / N) < b <= ceil((s + 1) * B / N)``."""
        return -(-shard * self.seg_blocks[name] // self.n_shards) + 1

    def local_block(self, name: str, block: int) -> int:
        """Shard-local block id: dense, 1-based within the owner's
        range, offset into the owning segment's id space — the same
        ``base + local`` numbering a single-host store uses, so the
        per-shard device's sequential/random classification behaves
        identically."""
        base = self._seg_index[name] * _SEGMENT_ID_STRIDE
        if name in self.replicated or self._owner_fn is not None:
            return base + block     # single-host numbering fallback
        start = self.range_start(name, self.owner(name, block))
        return base + (block - start) + 1

    # ------------------------------------------------------------ accounting
    def shard_blocks(self, shard: int) -> int:
        """Blocks owned by ``shard`` (replicated segments count toward
        their materialized home, shard 0)."""
        total = 0
        for name, n_blocks in self.seg_blocks.items():
            if name in self.replicated or self._owner_fn is not None:
                total += sum(1 for b in range(1, n_blocks + 1)
                             if self.owner(name, b) == shard)
            else:
                total += (self.range_start(name, shard + 1)
                          - self.range_start(name, shard))
        return total

    def describe(self) -> str:
        parts = []
        for name in SEGMENT_NAMES:
            if name not in self.seg_blocks:
                continue
            if name in self.replicated:
                parts.append(f"{name}: replicated "
                             f"({self.seg_blocks[name]} blocks)")
            else:
                ranges = [
                    f"[{self.range_start(name, s)}.."
                    f"{self.range_start(name, s + 1) - 1}]"
                    for s in range(self.n_shards)]
                parts.append(f"{name}: {' '.join(ranges)}")
        return "; ".join(parts)
