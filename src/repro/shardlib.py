"""Logical-axis sharding utilities (MaxText-style axis rules, minimal).

Model code never names mesh axes directly.  It annotates tensors with
*logical* axis names (``shard(x, "batch", "seq", "embed")``) and the active
:class:`AxisRules` context maps logical names to mesh axes.  Outside any
context every helper is a no-op, so the same model code runs on a single
CPU device in tests and under a 512-chip mesh in the dry-run.

``maybe_shard_map`` wraps a per-shard function in ``jax.shard_map`` when a
mesh is active and calls it directly (world size 1) otherwise; model code
that needs *manual* collectives (MoE dispatch, split-KV decode attention,
row-sharded embedding lookup) uses it together with the ``psum``/``axis_size``
helpers below, which likewise degrade to identities without a mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ``shard_map`` graduated from jax.experimental (where its replication
# checker is spelled ``check_rep``) to ``jax.shard_map`` (``check_vma``).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:  # pragma: no cover - exercised on older JAX only
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}

__all__ = [
    "AxisRules", "axis_rules", "current_rules", "current_mesh",
    "logical_to_spec", "shard", "sharding_for", "maybe_shard_map",
    "psum", "pmax", "pmin", "psum_scatter", "all_gather", "axis_size",
    "axis_index",
]

_state = threading.local()


class AxisRules:
    """Mapping from logical axis names to mesh axis names (or tuples)."""

    def __init__(self, mesh: Mesh, rules: Dict[str, Union[str, Tuple[str, ...], None]]):
        self.mesh = mesh
        self.rules = dict(rules)
        # A mesh axis may back at most one logical axis within a single
        # PartitionSpec; the resolver below drops duplicate uses per-tensor.

    def resolve(self, name: Optional[str]):
        if name is None:
            return None
        return self.rules.get(name, None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, Any]):
    prev = getattr(_state, "rules", None)
    _state.rules = AxisRules(mesh, rules)
    try:
        with mesh:
            yield _state.rules
    finally:
        _state.rules = prev


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    r = current_rules()
    return r.mesh if r is not None else None


def logical_to_spec(*names: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    r = current_rules()
    if r is None:
        return P()
    used: set = set()
    parts = []
    for nm in names:
        ax = r.resolve(nm)
        if ax is None:
            parts.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        ax_t = tuple(a for a in ax_t if a not in used and a in r.mesh.axis_names)
        used.update(ax_t)
        if not ax_t:
            parts.append(None)
        elif isinstance(ax, str):
            parts.append(ax_t[0])
        else:
            # Preserve tuple form for tuple-valued rules: PartitionSpec
            # does not normalize ('data',) == 'data' on every JAX version.
            parts.append(ax_t)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(*names: Optional[str]) -> Optional[NamedSharding]:
    r = current_rules()
    if r is None:
        return None
    return NamedSharding(r.mesh, logical_to_spec(*names))


def shard(x: jnp.ndarray, *names: Optional[str]) -> jnp.ndarray:
    """Apply a logical sharding constraint (no-op without an active mesh)."""
    s = sharding_for(*names)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


# ---------------------------------------------------------------------------
# Manual-SPMD helpers: real collectives inside shard_map, identity outside.
# ---------------------------------------------------------------------------

def _axes_tuple(ax) -> Tuple[str, ...]:
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def _live_axes(logical: str) -> Tuple[str, ...]:
    """Mesh axes backing `logical` under the current rules (may be ())."""
    r = current_rules()
    if r is None:
        return ()
    return tuple(a for a in _axes_tuple(r.resolve(logical))
                 if a in r.mesh.axis_names)


def psum(x, axes: Sequence[str]):
    axes = tuple(axes)
    return jax.lax.psum(x, axes) if axes else x


def pmax(x, axes: Sequence[str]):
    axes = tuple(axes)
    return jax.lax.pmax(x, axes) if axes else x


def pmin(x, axes: Sequence[str]):
    """Cross-shard min — the (min, +) semiring's reduction, i.e. how a
    fleet merges per-shard distance rows when the batch axis is sharded
    (DESIGN.md §13)."""
    axes = tuple(axes)
    return jax.lax.pmin(x, axes) if axes else x


def psum_scatter(x, axes: Sequence[str], scatter_dimension: int = 0):
    axes = tuple(axes)
    if not axes:
        return x
    return jax.lax.psum_scatter(x, axes, scatter_dimension=scatter_dimension,
                                tiled=True)


def all_gather(x, axes: Sequence[str], axis: int = 0):
    axes = tuple(axes)
    if not axes:
        return x
    return jax.lax.all_gather(x, axes, axis=axis, tiled=True)


def axis_size(axes: Sequence[str], mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None:
        return 1
    out = 1
    for a in _axes_tuple(tuple(axes)):
        if a in mesh.axis_names:
            out *= mesh.shape[a]
    return out


def axis_index(axes: Sequence[str]):
    axes = tuple(axes)
    if not axes:
        return jnp.int32(0)
    idx = jnp.int32(0)
    for a in axes:
        size = (jax.lax.axis_size(a) if hasattr(jax.lax, "axis_size")
                else jax.lax.psum(1, a))
        idx = idx * size + jax.lax.axis_index(a)
    return idx


def maybe_shard_map(fn: Callable, in_specs, out_specs) -> Callable:
    """``jax.shard_map`` under an active mesh; plain call otherwise.

    in_specs/out_specs are pytrees of PartitionSpec built with
    :func:`logical_to_spec` (already resolved). Without a mesh the function
    runs unmapped — every collective helper above degrades to identity, so
    the math is unchanged at world size 1.
    """
    mesh = current_mesh()
    if mesh is None:
        return fn
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **_SHARD_MAP_KW)
