from .graphs import (make_graph_batch, synth_feature_graph,  # noqa: F401
                     synth_molecule_batch)
from .lm import TokenStream  # noqa: F401
from .recsys import RecsysStream  # noqa: F401
from .sampler import NeighborSampler  # noqa: F401
