"""Graph dataset builders for the GNN cells.

Produces :class:`~repro.models.gnn.common.GraphBatch` instances with the
exact node/edge counts of the assigned shapes.  Geometry-free graphs get a
synthesized geometric frontend (random unit edge vectors + distances) so
SchNet/Equiformer configs run on every shape, per the frontend-stub rule.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..models.gnn.common import GraphBatch


def make_graph_batch(n_nodes: int, n_edges: int, d_feat: int,
                     n_classes: int = 7, seed: int = 0,
                     feat_kind: str = "dense", n_graphs: int = 1,
                     with_geometry: bool = True,
                     train_frac: float = 0.1) -> GraphBatch:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    if feat_kind == "dense":
        feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    else:  # integer atom types
        feat = rng.integers(0, 90, n_nodes).astype(np.int32)
    edge_feat = None
    if with_geometry:
        vec = rng.normal(size=(n_edges, 3)).astype(np.float32)
        vec /= np.linalg.norm(vec, axis=1, keepdims=True) + 1e-9
        vec *= rng.uniform(0.8, 9.0, (n_edges, 1)).astype(np.float32)
        edge_feat = jnp.asarray(vec)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    mask = rng.random(n_nodes) < train_frac
    gid = (None if n_graphs == 1 else
           jnp.asarray(rng.integers(0, n_graphs, n_nodes).astype(np.int32)))
    return GraphBatch(n_nodes=n_nodes, n_graphs=n_graphs,
                      src=jnp.asarray(src), dst=jnp.asarray(dst),
                      node_feat=jnp.asarray(feat), edge_feat=edge_feat,
                      graph_ids=gid,
                      labels=jnp.asarray(labels),
                      train_mask=jnp.asarray(mask))


def synth_feature_graph(name: str, seed: int = 0) -> GraphBatch:
    """Named stand-ins for the assigned full-graph shapes."""
    shapes = {
        "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                              n_classes=7),
        "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140,
                             d_feat=100, n_classes=47),
    }
    return make_graph_batch(seed=seed, **shapes[name])


def bucket_edges_by_dst(g: GraphBatch, n_buckets: int,
                        pad_factor: float = 1.15) -> GraphBatch:
    """Reorder (and pad) edges into contiguous destination ranges.

    Bucket i holds the edges whose dst lies in node range
    [i·N/n_buckets, (i+1)·N/n_buckets), padded with sentinel edges to a
    uniform per-bucket count — the layout required by the §Perf
    ``dst_ranged`` / ``partitioned`` aggregation paths (HoD's
    file-order == traversal-order idea applied to message passing).
    Raises if any bucket exceeds ``pad_factor``× the average (re-bucket
    with a node permutation in that case).
    """
    n = g.n_nodes
    rng_sz = -(-n // n_buckets)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    e = src.shape[0]
    bucket = np.minimum(dst // rng_sz, n_buckets - 1)
    counts = np.bincount(bucket, minlength=n_buckets)
    cap = int(np.ceil(e / n_buckets * pad_factor))
    if counts.max() > cap:
        raise ValueError(f"bucket imbalance {counts.max()} > cap {cap}; "
                         "permute node ids or raise pad_factor")
    order = np.argsort(bucket, kind="stable")
    new_e = cap * n_buckets
    ns = np.full(new_e, n, np.int32)
    nd = np.full(new_e, n, np.int32)
    ef = (np.zeros((new_e,) + g.edge_feat.shape[1:], np.float32)
          if g.edge_feat is not None else None)
    if ef is not None and ef.ndim == 2 and ef.shape[1] == 3:
        ef[:, 2] = 1.0          # unit stub vectors for padding
    src_s, dst_s = src[order], dst[order]
    efe = np.asarray(g.edge_feat)[order] if g.edge_feat is not None else None
    start = 0
    for b in range(n_buckets):
        cnt = counts[b]
        ns[b * cap: b * cap + cnt] = src_s[start: start + cnt]
        nd[b * cap: b * cap + cnt] = dst_s[start: start + cnt]
        if ef is not None:
            ef[b * cap: b * cap + cnt] = efe[start: start + cnt]
        start += cnt
    import dataclasses as _dc
    return _dc.replace(g, src=jnp.asarray(ns), dst=jnp.asarray(nd),
                       edge_feat=jnp.asarray(ef) if ef is not None else None)


def synth_molecule_batch(batch: int = 128, n_nodes: int = 30,
                         n_edges: int = 64, seed: int = 0,
                         n_classes: int = 2) -> GraphBatch:
    """Packed batch of small molecules (block-diagonal edge structure)."""
    rng = np.random.default_rng(seed)
    total_n = batch * n_nodes
    srcs, dsts = [], []
    for g in range(batch):
        s = rng.integers(0, n_nodes, n_edges) + g * n_nodes
        d = rng.integers(0, n_nodes, n_edges) + g * n_nodes
        srcs.append(s)
        dsts.append(d)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    types = rng.integers(0, 20, total_n).astype(np.int32)
    vec = rng.normal(size=(src.shape[0], 3)).astype(np.float32)
    vec /= np.linalg.norm(vec, axis=1, keepdims=True) + 1e-9
    vec *= rng.uniform(0.8, 4.0, (src.shape[0], 1)).astype(np.float32)
    gid = np.repeat(np.arange(batch, dtype=np.int32), n_nodes)
    labels = rng.integers(0, n_classes, batch).astype(np.int32)
    return GraphBatch(n_nodes=total_n, n_graphs=batch,
                      src=jnp.asarray(src), dst=jnp.asarray(dst),
                      node_feat=jnp.asarray(types),
                      edge_feat=jnp.asarray(vec),
                      graph_ids=jnp.asarray(gid),
                      labels=jnp.asarray(labels))
