"""Real fanout neighbor sampler for minibatch GNN training (GraphSAGE).

Samples a k-hop block from a CSR graph: hop 0 = the batch nodes, hop i =
up to ``fanout[i]`` random in-neighbors of each hop-(i-1) node.  The
result is re-indexed to a compact padded :class:`GraphBatch` whose static
shape is the worst case (batch·Πfanout), so the jitted train step compiles
once.  Edges point child → parent (message flows toward the batch nodes).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..models.gnn.common import GraphBatch


@dataclasses.dataclass
class NeighborSampler:
    ptr: np.ndarray       # CSR in-neighbor pointers [N+1]
    nbr: np.ndarray       # CSR in-neighbor ids     [M]
    feats: np.ndarray     # [N, F] node features
    labels: np.ndarray    # [N]
    fanout: Sequence[int] = (15, 10)
    seed: int = 0

    @property
    def max_nodes(self) -> int:
        return 0  # computed per batch size in sample()

    def block_shape(self, batch_nodes: int) -> Tuple[int, int]:
        n = batch_nodes
        tot_n, tot_e = n, 0
        layer = n
        for f in self.fanout:
            layer = layer * f
            tot_e += layer
            tot_n += layer
        return tot_n, tot_e

    def sample(self, batch_ids: np.ndarray, step: int = 0) -> GraphBatch:
        rng = np.random.default_rng((self.seed, step))
        bsz = batch_ids.shape[0]
        max_n, max_e = self.block_shape(bsz)

        # node table: compact local ids; batch nodes first
        local = {int(v): i for i, v in enumerate(batch_ids)}
        order = list(int(v) for v in batch_ids)
        src_l, dst_l = [], []
        frontier = list(int(v) for v in batch_ids)
        for f in self.fanout:
            nxt = []
            for v in frontier:
                lo, hi = self.ptr[v], self.ptr[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(f, deg)
                picks = rng.choice(deg, size=take, replace=False)
                for p in picks:
                    u = int(self.nbr[lo + p])
                    if u not in local:
                        local[u] = len(order)
                        order.append(u)
                        nxt.append(u)
                    src_l.append(local[u])
                    dst_l.append(local[v])
            frontier = nxt

        n_real = len(order)
        e_real = len(src_l)
        feat = np.zeros((max_n, self.feats.shape[1]), np.float32)
        feat[:n_real] = self.feats[order]
        labels = np.zeros(max_n, np.int32)
        labels[:n_real] = self.labels[order]
        mask = np.zeros(max_n, bool)
        mask[:bsz] = True                      # loss only on batch nodes
        src = np.full(max_e, max_n, np.int32)  # sentinel pad
        dst = np.full(max_e, max_n, np.int32)
        src[:e_real] = src_l
        dst[:e_real] = dst_l
        vec = np.zeros((max_e, 3), np.float32)
        vec[:, 2] = 1.0                        # unit stub geometry
        return GraphBatch(n_nodes=max_n, n_graphs=1,
                          src=jnp.asarray(src), dst=jnp.asarray(dst),
                          node_feat=jnp.asarray(feat),
                          edge_feat=jnp.asarray(vec),
                          graph_ids=None,
                          labels=jnp.asarray(labels),
                          train_mask=jnp.asarray(mask))


def csr_from_edges(n: int, src: np.ndarray, dst: np.ndarray):
    """In-neighbor CSR: for each node, the sources of its incoming edges."""
    order = np.argsort(dst, kind="stable")
    dst_s, src_s = dst[order], src[order]
    ptr = np.zeros(n + 1, np.int64)
    np.add.at(ptr, dst_s + 1, 1)
    np.cumsum(ptr, out=ptr)
    return ptr, src_s
