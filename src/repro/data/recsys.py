"""Criteo-like synthetic recsys stream with a planted logistic model.

Dense features ~ lognormal; sparse ids ~ per-field Zipf (hot-head skew
like production traffic); labels drawn from a ground-truth logistic model
over a random projection of (dense, id hash buckets), so AUC has headroom
above 0.5 and training curves are meaningful.  Deterministic in
(seed, step) for resumable pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class RecsysStream:
    batch: int
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 1_000_000
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed + 1234)
        self._w_dense = rng.normal(size=self.n_dense).astype(np.float32)
        self._w_hash = rng.normal(size=(self.n_sparse, 64)).astype(np.float32)

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        dense = rng.lognormal(0.0, 1.0,
                              (self.batch, self.n_dense)).astype(np.float32)
        dense = np.log1p(dense)                       # standard Criteo prep
        z = rng.zipf(1.2, size=(self.batch, self.n_sparse))
        sparse = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        # planted CTR model
        hb = self._w_hash[np.arange(self.n_sparse)[None, :],
                          sparse % 64]                # [B, F]
        logit = dense @ self._w_dense * 0.3 + hb.sum(1) * 0.5 - 1.0
        p = 1.0 / (1.0 + np.exp(-logit))
        labels = (rng.random(self.batch) < p).astype(np.int32)
        return dense, sparse, labels
