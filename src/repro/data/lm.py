"""Deterministic, resumable LM token pipeline.

Batches are a pure function of (seed, step): restart-from-checkpoint
reproduces the exact stream with no persisted iterator state — the
checkpoint manifest only needs the step counter.  Synthetic mode draws
Zipf-distributed tokens with a planted bigram structure (so loss curves
have signal); file mode shards a byte-level corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    path: Optional[str] = None      # byte corpus; synthetic if None

    def __post_init__(self):
        self._corpus = None
        if self.path is not None:
            self._corpus = np.fromfile(self.path, dtype=np.uint8)

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for ``step`` — pure function of (seed, step)."""
        rng = np.random.default_rng((self.seed, step))
        if self._corpus is not None:
            n = self._corpus.shape[0] - self.seq_len - 1
            starts = rng.integers(0, n, size=self.batch)
            toks = np.stack([self._corpus[s: s + self.seq_len + 1]
                             for s in starts]).astype(np.int32)
            return toks[:, :-1], toks[:, 1:]
        # Synthetic: Zipf marginals + deterministic "grammar" y = (3x+7)%V
        # half the time, so a model can learn something.
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        flip = rng.random((self.batch, self.seq_len)) < 0.5
        nxt = (3 * toks[:, :-1] + 7) % self.vocab
        labels = np.where(flip, nxt, toks[:, 1:]).astype(np.int32)
        tokens = toks[:, :-1].copy()
        tokens[:, 1:] = labels[:, :-1]  # teacher-forced continuation
        return tokens, labels

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
