"""Fused edge-relaxation kernel for HoD's level-synchronous sweeps.

TPU adaptation of the sweep hot loop (DESIGN.md §2): the *irregular* part
of a relaxation — gathering ``dist[:, src]`` — is hoisted out of the
kernel as a bulk XLA gather (TPUs handle bulk gathers well and in-kernel
random access poorly).  The HoD index then gives every level a *bucketed*
layout: each destination node of the level has a fixed-width padded list
of K in-edges.  What remains is a dense fused reduction

    out[s, m] = min( cur[s, m],  min_k  gathered[s, m, k] + w[m, k] )

which this kernel performs entirely in VMEM: one pass over the gathered
block, no f32[S,M,K] intermediate ever hits HBM (the pure-jnp version
materializes it).  Grid: (S/bs, M/bm); K is kept whole per block (bounded
by the level's max in-degree bucket).
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import tpu_compiler_params

INF = float("inf")


def _relax_kernel(gathered_ref, w_ref, cur_ref, mask_ref, o_ref):
    g = gathered_ref[...]                     # [bs, bm, K]
    w = w_ref[...]                            # [bm, K]
    cur = cur_ref[...]                        # [bs, bm]
    cand = jnp.minimum(cur, jnp.min(g + w[None, :, :], axis=-1))
    valid = mask_ref[...] != 0                # [1, bm] row-validity mask
    o_ref[...] = jnp.where(valid, cand, cur)


def relax_bucketed_pallas(gathered: jnp.ndarray, w: jnp.ndarray,
                          cur: jnp.ndarray, row_valid: jnp.ndarray, *,
                          bs: int = 8, bm: int = 128,
                          interpret: bool = True) -> jnp.ndarray:
    """gathered: [S, M, K] (dist[:, src[m,k]]); w: [M, K]; cur: [S, M];
    row_valid: [M] bool — False rows pass ``cur`` through untouched.

    The executor scans static-shape plan levels through this one kernel
    instance; masked rows (level padding) carry +inf weights too, so the
    mask and the (min, +) absorption agree.
    """
    s, m, k = gathered.shape
    bs_ = min(bs, s)
    bm_ = min(bm, max(128, m)) if m >= 128 else m
    ss, mm = -(-s // bs_) * bs_, -(-m // bm_) * bm_
    mask = row_valid.astype(jnp.int32)[None, :]        # [1, M]
    if (ss, mm) != (s, m):
        gathered = jnp.pad(gathered, ((0, ss - s), (0, mm - m), (0, 0)),
                           constant_values=INF)
        w = jnp.pad(w, ((0, mm - m), (0, 0)), constant_values=INF)
        cur = jnp.pad(cur, ((0, ss - s), (0, mm - m)), constant_values=INF)
        mask = jnp.pad(mask, ((0, 0), (0, mm - m)), constant_values=0)

    grid = (ss // bs_, mm // bm_)
    out = pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs_, bm_, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm_, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bs_, bm_), lambda i, j: (i, j)),
            pl.BlockSpec((1, bm_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bs_, bm_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ss, mm), cur.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(gathered, w, cur, mask)
    return out[:s, :m]
