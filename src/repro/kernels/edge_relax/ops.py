"""jit'd wrapper: gather (XLA) + fused relax (Pallas)."""
import functools

import jax
import jax.numpy as jnp

from .kernel import relax_bucketed_pallas
from .ref import relax_bucketed_ref


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "interpret"))
def relax_bucketed(dist: jnp.ndarray, src_idx: jnp.ndarray,
                   w: jnp.ndarray, cur: jnp.ndarray,
                   use_pallas: bool = True,
                   interpret: bool = True) -> jnp.ndarray:
    """One level's relaxation over a bucketed in-edge layout.

    dist: [S, N] finalized distances; src_idx: [M, K] source node of each
    (dst-bucketed, padded) in-edge; w: [M, K] lengths (+inf padding);
    cur: [S, M] current values of the level's nodes.  Returns updated cur.
    """
    gathered = dist[:, src_idx.reshape(-1)].reshape(
        dist.shape[0], *src_idx.shape)
    if use_pallas:
        return relax_bucketed_pallas(gathered, w, cur, interpret=interpret)
    return relax_bucketed_ref(gathered, w, cur)


__all__ = ["relax_bucketed", "relax_bucketed_ref"]
