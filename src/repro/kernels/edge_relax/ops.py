"""jit'd wrapper: gather (XLA) + fused relax (Pallas)."""
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import relax_bucketed_pallas
from .ref import relax_bucketed_ref

#: Incremented once per (re)trace of :func:`relax_bucketed` — the Python
#: body of a jitted function only runs on a compile-cache miss.  The
#: serving tests use the delta as a compile-count regression guard: under
#: the SweepPlan executor one SSD query traces the relax exactly once per
#: sweep direction, independent of the graph's level count.
TRACE_COUNT = 0


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "interpret"))
def relax_bucketed(dist: jnp.ndarray, src_idx: jnp.ndarray,
                   w: jnp.ndarray, cur: jnp.ndarray,
                   row_valid: Optional[jnp.ndarray] = None,
                   use_pallas: bool = True,
                   interpret: bool = True) -> jnp.ndarray:
    """One plan level's relaxation over a bucketed in-edge layout.

    dist: [S, N] finalized distances; src_idx: [M, K] source node of each
    (dst-bucketed, padded) in-edge; w: [M, K] lengths (+inf padding);
    cur: [S, M] current values of the level's nodes; row_valid: [M] bool
    (None = all valid) — padding rows of a scanned SweepPlan level pass
    ``cur`` through untouched.  Returns updated cur.
    """
    global TRACE_COUNT
    TRACE_COUNT += 1
    gathered = dist[:, src_idx.reshape(-1)].reshape(
        dist.shape[0], *src_idx.shape)
    if row_valid is None:
        row_valid = jnp.ones(src_idx.shape[0], jnp.bool_)
    if use_pallas:
        return relax_bucketed_pallas(gathered, w, cur, row_valid,
                                     interpret=interpret)
    return relax_bucketed_ref(gathered, w, cur, row_valid)


__all__ = ["relax_bucketed", "relax_bucketed_ref", "TRACE_COUNT"]
