from .ops import relax_bucketed  # noqa: F401
