"""Pure-jnp oracle for the bucketed edge relaxation."""
import jax.numpy as jnp


def relax_bucketed_ref(gathered: jnp.ndarray, w: jnp.ndarray,
                       cur: jnp.ndarray) -> jnp.ndarray:
    """out[s, m] = min(cur[s, m], min_k gathered[s, m, k] + w[m, k]).

    Materializes the [S, M, K] sum — exactly the HBM traffic the Pallas
    kernel avoids.
    """
    return jnp.minimum(cur, jnp.min(gathered + w[None], axis=-1))
