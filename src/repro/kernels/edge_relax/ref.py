"""Pure-jnp oracle for the bucketed edge relaxation."""
from typing import Optional

import jax.numpy as jnp


def relax_bucketed_ref(gathered: jnp.ndarray, w: jnp.ndarray,
                       cur: jnp.ndarray,
                       row_valid: Optional[jnp.ndarray] = None
                       ) -> jnp.ndarray:
    """out[s, m] = min(cur[s, m], min_k gathered[s, m, k] + w[m, k]).

    ``row_valid`` ([M] bool) keeps ``cur`` untouched on padding rows —
    redundant with the +inf padding weights (absorbing under (min, +))
    but kept explicit so masked plan rows cost nothing semantic.

    Materializes the [S, M, K] sum — exactly the HBM traffic the Pallas
    kernel avoids.
    """
    new = jnp.minimum(cur, jnp.min(gathered + w[None], axis=-1))
    if row_valid is None:
        return new
    return jnp.where(row_valid[None, :], new, cur)
