"""Version shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases; kernels import :func:`tpu_compiler_params` so the same
source runs on both sides of the rename.
"""
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    return _CompilerParams(**kwargs)
