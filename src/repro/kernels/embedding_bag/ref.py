"""Pure-jnp oracle for the fused bag-sum."""
import jax.numpy as jnp


def bag_sum_ref(gathered: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """out[b, d] = sum_k gathered[b, k, d] * mask[b, k]."""
    return jnp.sum(gathered * mask[..., None].astype(gathered.dtype), axis=1)
