"""Fused embedding-bag reduction (DLRM hot path).

Same hoisting principle as edge_relax: the ragged gather runs as a bulk
XLA gather; the kernel fuses the masked bag-sum (+ optional per-sample
weights) so the [B, K, D] gathered block is consumed in VMEM instead of
being re-materialized for the reduce.  Grid: (B/bb, D/bd) with K whole.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from .._compat import tpu_compiler_params


def _bag_kernel(g_ref, m_ref, o_ref):
    g = g_ref[...]                      # [bb, K, bd]
    m = m_ref[...]                      # [bb, K]
    o_ref[...] = jnp.sum(g * m[..., None].astype(g.dtype), axis=1)


def bag_sum_pallas(gathered: jnp.ndarray, mask: jnp.ndarray, *,
                   bb: int = 16, bd: int = 128,
                   interpret: bool = True) -> jnp.ndarray:
    """gathered: [B, K, D] rows per bag (padded); mask: [B, K] validity."""
    b, k, d = gathered.shape
    bb_ = min(bb, b)
    bd_ = min(bd, d) if d >= 128 else d
    bbp, ddp = -(-b // bb_) * bb_, -(-d // bd_) * bd_
    if (bbp, ddp) != (b, d):
        gathered = jnp.pad(gathered, ((0, bbp - b), (0, 0), (0, ddp - d)))
        mask = jnp.pad(mask, ((0, bbp - b), (0, 0)))

    grid = (bbp // bb_, ddp // bd_)
    out = pl.pallas_call(
        _bag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb_, k, bd_), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bb_, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb_, bd_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bbp, ddp), gathered.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(gathered, mask)
    return out[:b, :d]
