from .ops import bag_sum  # noqa: F401
