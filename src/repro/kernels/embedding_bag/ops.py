"""jit'd EmbeddingBag: bulk gather + fused masked reduce."""
import functools

import jax
import jax.numpy as jnp

from .kernel import bag_sum_pallas
from .ref import bag_sum_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def bag_sum(table: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray,
            use_pallas: bool = True, interpret: bool = True) -> jnp.ndarray:
    """Multi-hot EmbeddingBag: table [V, D], ids [B, K] (padded), mask
    [B, K] -> [B, D] bag sums."""
    gathered = jnp.take(table, ids, axis=0, fill_value=0)
    if use_pallas:
        return bag_sum_pallas(gathered, mask, interpret=interpret)
    return bag_sum_ref(gathered, mask)


__all__ = ["bag_sum", "bag_sum_ref"]
