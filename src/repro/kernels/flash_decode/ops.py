"""jit'd flash-decoding wrapper."""
import functools

import jax

from .kernel import flash_decode_pallas
from .ref import flash_decode_ref


@functools.partial(jax.jit,
                   static_argnames=("block_k", "use_pallas", "interpret"))
def flash_decode(q, k_cache, v_cache, kv_len, block_k: int = 512,
                 use_pallas: bool = True, interpret: bool = True):
    """One-token GQA over a KV cache; see kernel.py for layout."""
    if use_pallas:
        return flash_decode_pallas(q, k_cache, v_cache, kv_len,
                                   block_k=block_k, interpret=interpret)
    return flash_decode_ref(q, k_cache, v_cache, kv_len)


__all__ = ["flash_decode", "flash_decode_ref"]
