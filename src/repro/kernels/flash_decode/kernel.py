"""Flash-decoding attention kernel: one query token over a long KV cache.

The LM serving hot spot (decode_32k / long_500k cells).  Grid iterates KV
blocks ("arbitrary" — sequential) keeping running (max, sum, acc) softmax
statistics in the output refs; score tiles live only in VMEM.  Batch and
KV-head dims are vmapped outside (the per-(b, kh) problem is
[G, S] × [S, dh] — MXU-shaped after the GQA group dim is folded into
rows).  Length masking uses the block's global offset vs ``kv_len``.

On a real TPU this runs per split-KV shard inside the shard_map of
``attention_decode``; interpret=True validates the same body on CPU.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params

NEG_INF = float("-inf")


def _decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   *, bk: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]                                  # [G, dh]
    k = k_ref[...]                                  # [bk, dh]
    v = v_ref[...]                                  # [bk, dh]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [G, bk]
    pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(pos < kv_len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]                             # [G, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)                         # [G, bk]
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.dot(p.astype(v.dtype), v,
                 preferred_element_type=jnp.float32)  # [G, dh]
    o_ref[...] = o_ref[...] * corr + pv
    m_ref[...] = m_new


def _decode_one(q, k, v, kv_len, *, bk: int, interpret: bool):
    """q: [G, dh] (pre-scaled); k/v: [S, dh]; kv_len: [1] i32."""
    g, dh = q.shape
    s = k.shape[0]
    nk = s // bk
    out, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk),
        grid=(nk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((g, dh), lambda j: (0, 0)),
            pl.BlockSpec((bk, dh), lambda j: (j, 0)),
            pl.BlockSpec((bk, dh), lambda j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((g, dh), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, dh), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(kv_len, q, k, v)
    return out / jnp.maximum(l, 1e-30)


def flash_decode_pallas(q, k_cache, v_cache, kv_len, *, block_k: int = 512,
                        interpret: bool = True):
    """q: [B, H, dh]; caches: [B, S, Kh, dh]; kv_len scalar.

    Returns [B, H, dh].  S is padded to a block multiple with masked tail.
    """
    b, h, dh = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    bk = min(block_k, s)
    pad = (-s) % bk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q = q.reshape(b, kh, g, dh) * (dh ** -0.5)
    kc = k_cache.transpose(0, 2, 1, 3)      # [B, Kh, S, dh]
    vc = v_cache.transpose(0, 2, 1, 3)
    kv_len_arr = jnp.full((1,), kv_len, jnp.int32)

    fn = functools.partial(_decode_one, bk=bk, interpret=interpret)
    out = jax.vmap(jax.vmap(fn, in_axes=(0, 0, 0, None)),
                   in_axes=(0, 0, 0, None))(q, kc, vc, kv_len_arr)
    return out.reshape(b, h, dh)
