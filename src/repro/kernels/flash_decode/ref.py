"""Pure-jnp oracle for flash decoding: full-softmax one-token GQA."""
import jax.numpy as jnp


def flash_decode_ref(q, k_cache, v_cache, kv_len):
    """q: [B, H, dh]; caches: [B, S, Kh, dh]; positions >= kv_len masked."""
    b, h, dh = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, dh) * (dh ** -0.5)
    sc = jnp.einsum("bkgd,bskd->bkgs", qg,
                    k_cache.astype(jnp.float32)).astype(jnp.float32)
    mask = jnp.arange(s) < kv_len
    sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
    p = jnp.exp(sc - sc.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dh)
