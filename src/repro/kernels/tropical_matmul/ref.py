"""Pure-jnp oracle for the tropical (min-plus) matmul.

``out[i, j] = min_k a[i, k] + b[k, j]`` — the core-search primitive: one
application of the precomputed core closure advances every source's
distance vector across the core graph (paper §5.2, closure variant).
"""
import jax.numpy as jnp


def minplus_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Naive O(M·K·N) oracle; materializes the [M, K, N] intermediate."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)
