from .ops import minplus  # noqa: F401
from .ref import minplus_ref  # noqa: F401
