"""jit'd public wrapper for the tropical matmul."""
import functools

import jax
import jax.numpy as jnp

from .kernel import minplus_pallas
from .ref import minplus_ref


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus(a: jnp.ndarray, b: jnp.ndarray, bm: int = 128, bn: int = 128,
            bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """``out[i, j] = min_k a[i, k] + b[k, j]`` via the Pallas kernel.

    ``interpret=True`` on CPU (this container); flip to False on real TPU.
    """
    return minplus_pallas(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)


__all__ = ["minplus", "minplus_ref"]
