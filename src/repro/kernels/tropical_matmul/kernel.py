"""Blocked min-plus matmul as a Pallas TPU kernel.

TPU adaptation notes (vs. the paper's in-memory Dijkstra core search):
the MXU only does (+, ×) contractions, so the (min, +) semiring runs on the
VPU.  We tile exactly like a matmul — grid (M/bm, N/bn, K/bk), the K axis
innermost and "arbitrary" so each (i, j) output tile accumulates a running
elementwise min across K blocks held in VMEM.  Inside a block the K
reduction is sub-chunked (KI=8) so the [bm, KI, bn] broadcast intermediate
stays ~0.5 MB, far under VMEM.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from .._compat import tpu_compiler_params

INF = float("inf")  # python literal: kernels must not capture traced consts
KI = 8  # inner K sub-chunk: [bm, KI, bn] is the largest VMEM intermediate


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    a = a_ref[...]          # [bm, bk]
    b = b_ref[...]          # [bk, bn]

    def body(i, acc):
        a_sub = jax.lax.dynamic_slice_in_dim(a, i * KI, KI, axis=1)
        b_sub = jax.lax.dynamic_slice_in_dim(b, i * KI, KI, axis=0)
        cand = jnp.min(a_sub[:, :, None] + b_sub[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    o_ref[...] = jax.lax.fori_loop(0, bk // KI, body, o_ref[...])


def minplus_pallas(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                   bn: int = 128, bk: int = 128,
                   interpret: bool = True) -> jnp.ndarray:
    """min-plus matmul; operands padded with +inf to block multiples.

    +inf padding is absorbing for (min, +): padded lanes never win.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm_ = min(bm, max(8, -(-m // 8) * 8))
    bn_ = min(bn, max(128, -(-n // 128) * 128))
    bk_ = min(bk, max(KI, -(-k // KI) * KI))

    mm, nn, kk = (-(-m // bm_) * bm_, -(-n // bn_) * bn_, -(-k // bk_) * bk_)
    a = jnp.pad(a, ((0, mm - m), (0, kk - k)), constant_values=INF)
    b = jnp.pad(b, ((0, kk - k), (0, nn - n)), constant_values=INF)

    grid = (mm // bm_, nn // bn_, kk // bk_)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, bk=bk_),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kq: (i, kq)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kq: (kq, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kq: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), a.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
