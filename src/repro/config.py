"""Hierarchical, include-based experiment configuration (DESIGN.md §12).

``launch/serve.py`` and ``benchmarks/serve_throughput.py`` grew an
argparse grid (policy × codec × budget × depth × workload-mix × SLO
class) whose products no flag surface can express declaratively.  A
:class:`Config` replaces that: one nested mapping loaded from a
YAML/JSON file, composed through an ``_include`` chain, with CLI flags
kept as the *last*-precedence override layer:

    defaults  <  include chain (deepest first)  <  the file itself  <
    CLI / explicit overrides

The shape follows the ``archai`` ``common/config.py`` exemplar named
in the ROADMAP (hierarchical dict, include resolution relative to the
including file, dotted-path ``get``), minus its CLI autowiring — our
entrypoints own their argparse surfaces and pass explicitly-set flags
in as the override layer.

Zero dependencies: ``.json`` parses with :mod:`json`; ``.yaml`` uses
PyYAML when importable, else a built-in strict *subset* parser
(indentation-nested mappings, ``- `` list items, scalars, ``#``
comments, flow lists ``[a, b]``) that covers every file under
``configs/``.  Unsupported YAML (anchors, multi-line strings, flow
maps) raises :class:`ConfigError` instead of misparsing.

Validation happens at *parse time* (ISSUE-9): :func:`validate_serve`
rejects out-of-range ``cache_frac``/``pin_frac``/``max_wait_ms``/…
with a message naming the offending key, instead of failing deep
inside ``PageCache`` or asyncio.
"""
from __future__ import annotations

import copy
import json
import os
import re
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = ["Config", "ConfigError", "deep_update", "validate_serve",
           "SERVE_DEFAULTS"]

#: Key whose value names the file(s) this one layers on top of.
INCLUDE_KEY = "_include"


class ConfigError(ValueError):
    """A config file failed to parse, resolve, or validate."""


# ------------------------------------------------------------ YAML subset
_SCALARS = {"null": None, "~": None, "true": True, "false": False,
            "True": True, "False": False}
_NUM_RE = re.compile(r"^-?\d+$")
_FLOAT_RE = re.compile(r"^-?(\d+\.\d*|\.\d+|\d+)([eE][+-]?\d+)?$")


def _scalar(tok: str, where: str):
    tok = tok.strip()
    if tok in _SCALARS:
        return _SCALARS[tok]
    if len(tok) >= 2 and tok[0] == tok[-1] and tok[0] in "'\"":
        return tok[1:-1]
    if _NUM_RE.match(tok):
        return int(tok)
    if _FLOAT_RE.match(tok):
        return float(tok)
    if tok in (".inf", "inf"):
        return float("inf")
    if tok.startswith("&") or tok.startswith("*") or tok.startswith("{"):
        raise ConfigError(f"{where}: unsupported YAML construct {tok!r} "
                          "(anchors/flow maps are outside the built-in "
                          "subset — install PyYAML or use JSON)")
    return tok


def _split_comment(line: str) -> str:
    """Strip a `` # comment`` suffix (quote-aware enough for our files)."""
    out, quote = [], None
    for i, ch in enumerate(line):
        if quote:
            if ch == quote:
                quote = None
        elif ch in "'\"":
            quote = ch
        elif ch == "#" and (i == 0 or line[i - 1] in " \t"):
            break
        out.append(ch)
    return "".join(out).rstrip()


def _parse_yaml_subset(text: str, where: str = "<yaml>") -> dict:
    """Indentation-nested mappings/lists/scalars — see module docstring."""
    lines: List[Tuple[int, str, int]] = []   # (indent, content, lineno)
    for n, raw in enumerate(text.splitlines(), 1):
        line = _split_comment(raw)
        if not line.strip():
            continue
        if line.lstrip().startswith("---"):
            continue
        indent = len(line) - len(line.lstrip(" "))
        if line[indent: indent + 1] == "\t":
            raise ConfigError(f"{where}:{n}: tabs in indentation")
        lines.append((indent, line.strip(), n))

    def parse_block(i: int, indent: int) -> Tuple[Any, int]:
        if i >= len(lines) or lines[i][0] < indent:
            return {}, i
        if lines[i][1].startswith("- "):
            return parse_list(i, lines[i][0])
        return parse_map(i, lines[i][0])

    def parse_list(i: int, indent: int) -> Tuple[list, int]:
        items: list = []
        while i < len(lines) and lines[i][0] == indent \
                and lines[i][1].startswith("- "):
            ind, content, n = lines[i]
            body = content[2:].strip()
            loc = f"{where}:{n}"
            if not body:
                child, i = parse_block(i + 1, indent + 1)
                items.append(child)
            elif ":" in body and not body.startswith(("'", '"', "[")):
                # inline "- key: value" starts a nested mapping item
                sub, i = parse_inline_map_item(i, indent)
                items.append(sub)
            else:
                items.append(_parse_flow_or_scalar(body, loc))
                i += 1
        return items, i

    def parse_inline_map_item(i: int, indent: int) -> Tuple[dict, int]:
        ind, content, n = lines[i]
        key, _, rest = content[2:].partition(":")
        item: dict = {}
        loc = f"{where}:{n}"
        if rest.strip():
            item[key.strip()] = _parse_flow_or_scalar(rest.strip(), loc)
            i += 1
        else:
            child, i = parse_block(i + 1, indent + 3)
            item[key.strip()] = child
        # subsequent keys of the same list item sit 2 deeper
        while i < len(lines) and lines[i][0] == indent + 2 \
                and not lines[i][1].startswith("- "):
            sub, i = parse_map(i, indent + 2)
            item.update(sub)
        return item, i

    def parse_map(i: int, indent: int) -> Tuple[dict, int]:
        out: Dict[str, Any] = {}
        while i < len(lines) and lines[i][0] == indent \
                and not lines[i][1].startswith("- "):
            ind, content, n = lines[i]
            loc = f"{where}:{n}"
            if ":" not in content:
                raise ConfigError(f"{loc}: expected 'key: value', got "
                                  f"{content!r}")
            key, _, rest = content.partition(":")
            key = key.strip()
            if key in out:
                raise ConfigError(f"{loc}: duplicate key {key!r}")
            if rest.strip():
                out[key] = _parse_flow_or_scalar(rest.strip(), loc)
                i += 1
            else:
                child, i = parse_block(i + 1, indent + 1)
                out[key] = child
        if i < len(lines) and lines[i][0] > indent:
            raise ConfigError(f"{where}:{lines[i][2]}: unexpected indent")
        return out, i

    def _parse_flow_or_scalar(tok: str, loc: str):
        if tok.startswith("[") and tok.endswith("]"):
            inner = tok[1:-1].strip()
            if not inner:
                return []
            return [_scalar(t, loc) for t in inner.split(",")]
        return _scalar(tok, loc)

    doc, i = parse_block(0, 0)
    if i != len(lines):
        raise ConfigError(f"{where}:{lines[i][2]}: trailing content at "
                          "top level")
    if not isinstance(doc, dict):
        raise ConfigError(f"{where}: top level must be a mapping")
    return doc


def _load_file(path: str) -> dict:
    try:
        with open(path) as f:
            text = f.read()
    except OSError as exc:
        raise ConfigError(f"cannot read config {path!r}: {exc}") from exc
    if path.endswith(".json"):
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as exc:
            raise ConfigError(f"{path}: invalid JSON: {exc}") from exc
    else:
        try:
            import yaml   # type: ignore
            doc = yaml.safe_load(text)
        except ImportError:
            doc = _parse_yaml_subset(text, where=path)
        except Exception as exc:
            raise ConfigError(f"{path}: invalid YAML: {exc}") from exc
    if doc is None:
        doc = {}
    if not isinstance(doc, dict):
        raise ConfigError(f"{path}: top level must be a mapping, "
                          f"got {type(doc).__name__}")
    return doc


def deep_update(base: dict, over: dict) -> dict:
    """Recursively merge ``over`` into ``base`` (in place, returned).
    Nested dicts merge key-wise; everything else (including lists)
    replaces wholesale — a config that *narrows* a grid must be able
    to drop entries, so lists never concatenate."""
    for k, v in over.items():
        if isinstance(v, dict) and isinstance(base.get(k), dict):
            deep_update(base[k], v)
        else:
            base[k] = copy.deepcopy(v)
    return base


class Config:
    """One resolved, hierarchical configuration mapping.

    ``Config(path, defaults=..., overrides=...)`` loads ``path``
    (YAML/JSON), resolves its ``_include`` chain (paths relative to
    the including file; deepest include = lowest precedence; cycles
    are an error), then layers ``defaults < includes < file <
    overrides``.  ``path=None`` builds from ``defaults``/``overrides``
    alone, so programmatic callers share one code path.

    Access: ``cfg["serve"]["batch"]``, dotted ``cfg.get("serve.batch",
    32)``, ``cfg.sub("serve")`` for a nested :class:`Config` view.
    """

    def __init__(self, path: Optional[str] = None, *,
                 defaults: Optional[dict] = None,
                 overrides: Optional[dict] = None):
        data: dict = copy.deepcopy(defaults) if defaults else {}
        self.path = path
        self.includes: List[str] = []
        if path is not None:
            deep_update(data, self._resolve(path, seen=[]))
        if overrides:
            deep_update(data, overrides)
        self.data = data

    def _resolve(self, path: str, seen: List[str]) -> dict:
        apath = os.path.abspath(path)
        if apath in seen:
            chain = " -> ".join(seen + [apath])
            raise ConfigError(f"circular _include chain: {chain}")
        doc = _load_file(path)
        inc = doc.pop(INCLUDE_KEY, None)
        merged: dict = {}
        if inc is not None:
            incs = [inc] if isinstance(inc, str) else list(inc)
            for rel in incs:
                if not isinstance(rel, str):
                    raise ConfigError(f"{path}: {INCLUDE_KEY} entries "
                                      f"must be paths, got {rel!r}")
                ipath = os.path.join(os.path.dirname(apath), rel)
                deep_update(merged, self._resolve(ipath, seen + [apath]))
                self.includes.append(ipath)
        return deep_update(merged, doc)

    # --------------------------------------------------------- mapping API
    def __getitem__(self, key: str) -> Any:
        return self.data[key]

    def __contains__(self, key: str) -> bool:
        return key in self.data

    def __iter__(self) -> Iterator[str]:
        return iter(self.data)

    def __repr__(self) -> str:
        src = self.path or "<dict>"
        return f"Config({src!r}, {len(self.data)} top-level keys)"

    def get(self, dotted: str, default: Any = None) -> Any:
        """``get("serve.slo.p2p.deadline_ms", 2.0)`` — dotted descent;
        returns ``default`` at the first missing/non-mapping hop."""
        node: Any = self.data
        for part in dotted.split("."):
            if not isinstance(node, dict) or part not in node:
                return default
            node = node[part]
        return node

    def require(self, dotted: str) -> Any:
        """Like :meth:`get` but a missing key is a :class:`ConfigError`
        naming the key and the source file."""
        sentinel = object()
        v = self.get(dotted, sentinel)
        if v is sentinel:
            raise ConfigError(f"missing required config key {dotted!r}"
                              f" (from {self.path or '<dict>'})")
        return v

    def sub(self, dotted: str) -> "Config":
        """Nested mapping as a new :class:`Config` view (empty if
        missing)."""
        node = self.get(dotted, {})
        if not isinstance(node, dict):
            raise ConfigError(f"config key {dotted!r} is not a mapping")
        out = Config()
        out.data = node
        out.path = self.path
        return out

    def to_dict(self) -> dict:
        return copy.deepcopy(self.data)

    def flat(self, prefix: str = "") -> Dict[str, Any]:
        """Dotted-key flattening, for logging / bench-row stamping."""
        out: Dict[str, Any] = {}

        def walk(node: Any, pfx: str) -> None:
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, f"{pfx}.{k}" if pfx else str(k))
            else:
                out[pfx] = node
        walk(self.data, prefix)
        return out


# ------------------------------------------------------- serve validation
#: Defaults the serve CLI / config spine layer under everything else —
#: the single source of truth the argparse surface also prints.
SERVE_DEFAULTS: Dict[str, Any] = {
    "graph": {"kind": "road", "side": 60},
    "serve": {
        "batch": 32, "mode": "ssd", "requests": 200, "rate": 0.0,
        "max_wait_ms": 2.0, "cache_entries": 1024,
        "threshold": 10.0, "k": 10, "use_pallas": False,
        "scheduler": "fifo",        # "fifo" | "slo"
        "slo": {},                  # class -> {deadline_ms, batch?}
        "mix": {},                  # mode -> request share (mixed traffic)
        "shards": None,             # null = unsharded; N >= 1 = fleet
    },
    "store": {
        "enabled": False, "cache_frac": 0.25, "cache_policy": "2q",
        "codec": "raw", "queue_depth": 4, "decode_workers": 2,
        "pin_frac": None, "prefetch": True,
    },
    "obs": {"trace_out": None, "metrics_out": None},
}

_POLICIES = ("lru", "clock", "arc", "2q")
_CODECS = ("raw", "delta", "f16")
_SCHEDULERS = ("fifo", "slo")
#: Accepted ``serve.mode`` spellings: the CLI aliases plus the two
#: internal names (``sssp`` = the --sssp variant of ssd; ``within`` =
#: the server-side name of ``threshold``).  A typo ("kn", "top_k")
#: dies here with the key named, never silently coerced to ssd.
_SERVE_MODES = ("ssd", "sssp", "p2p", "threshold", "within", "topk",
                "knn")


def _check(cond: bool, key: str, got: Any, want: str) -> None:
    if not cond:
        raise ConfigError(f"config key {key!r} = {got!r}: must be {want}")


def validate_serve(cfg: Config) -> Config:
    """Parse-time validation of a serve config (ISSUE-9 satellite):
    every budget fraction, wait, and size is range-checked here with a
    message naming the key — *before* a ``PageCache`` or the asyncio
    scheduler can fail obscurely at depth.  Returns ``cfg``."""
    frac = cfg.get("store.cache_frac")
    _check(isinstance(frac, (int, float)) and 0.0 < float(frac) <= 1.0,
           "store.cache_frac", frac, "a fraction in (0, 1]")
    pin = cfg.get("store.pin_frac")
    _check(pin is None or (isinstance(pin, (int, float))
                           and 0.0 <= float(pin) <= 1.0),
           "store.pin_frac", pin, "null or a fraction in [0, 1]")
    wait = cfg.get("serve.max_wait_ms")
    _check(isinstance(wait, (int, float)) and float(wait) >= 0.0,
           "serve.max_wait_ms", wait, "a non-negative number of ms")
    batch = cfg.get("serve.batch")
    _check(isinstance(batch, int) and batch >= 1,
           "serve.batch", batch, "an integer >= 1")
    entries = cfg.get("serve.cache_entries")
    _check(isinstance(entries, int) and entries >= 0,
           "serve.cache_entries", entries, "an integer >= 0")
    depth = cfg.get("store.queue_depth")
    _check(isinstance(depth, int) and depth >= 1,
           "store.queue_depth", depth, "an integer >= 1")
    workers = cfg.get("store.decode_workers")
    _check(isinstance(workers, int) and workers >= 1,
           "store.decode_workers", workers, "an integer >= 1")
    policy = cfg.get("store.cache_policy")
    _check(policy in _POLICIES, "store.cache_policy", policy,
           f"one of {_POLICIES}")
    codec = cfg.get("store.codec")
    _check(codec in _CODECS, "store.codec", codec, f"one of {_CODECS}")
    sched = cfg.get("serve.scheduler")
    _check(sched in _SCHEDULERS, "serve.scheduler", sched,
           f"one of {_SCHEDULERS}")
    mode = cfg.get("serve.mode")
    _check(mode in _SERVE_MODES, "serve.mode", mode,
           f"one of {_SERVE_MODES}")
    rate = cfg.get("serve.rate")
    _check(isinstance(rate, (int, float)) and float(rate) >= 0.0,
           "serve.rate", rate, "a non-negative req/s rate")
    thr = cfg.get("serve.threshold")
    _check(isinstance(thr, (int, float)) and float(thr) > 0.0,
           "serve.threshold", thr, "a positive distance")
    k = cfg.get("serve.k")
    _check(isinstance(k, int) and k >= 1, "serve.k", k,
           "an integer >= 1")
    shards = cfg.get("serve.shards")
    _check(shards is None or (isinstance(shards, int) and shards >= 1),
           "serve.shards", shards, "null or an integer >= 1 "
           "(serving-fleet shard count)")
    slo = cfg.get("serve.slo", {})
    _check(isinstance(slo, dict), "serve.slo", slo,
           "a {class: {deadline_ms: ...}} mapping")
    for name, spec in slo.items():
        _check(isinstance(spec, dict), f"serve.slo.{name}", spec,
               "a mapping with deadline_ms")
        dl = spec.get("deadline_ms")
        _check(isinstance(dl, (int, float)) and float(dl) > 0.0,
               f"serve.slo.{name}.deadline_ms", dl, "a positive ms "
               "deadline")
        cb = spec.get("batch")
        _check(cb is None or (isinstance(cb, int) and cb >= 1),
               f"serve.slo.{name}.batch", cb, "null or an integer >= 1")
    mix = cfg.get("serve.mix", {})
    _check(isinstance(mix, dict), "serve.mix", mix,
           "a {mode: share} mapping")
    for name, share in mix.items():
        _check(isinstance(share, (int, float)) and float(share) > 0.0,
               f"serve.mix.{name}", share, "a positive share")
    return cfg


def overrides_from_args(args, spec: Sequence[Tuple[str, str]]) -> dict:
    """Build the CLI-override layer from an ``argparse.Namespace``
    parsed with ``argparse.SUPPRESS`` defaults: only flags the user
    actually typed exist as attributes, so only those override the
    config file.  ``spec`` maps attribute -> dotted config key."""
    out: dict = {}
    for attr, dotted in spec:
        if not hasattr(args, attr):
            continue
        node = out
        *parents, leaf = dotted.split(".")
        for p in parents:
            node = node.setdefault(p, {})
        node[leaf] = getattr(args, attr)
    return out
