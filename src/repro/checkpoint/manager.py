"""Sharded, async, restart-safe checkpointing.

Layout per step: ``<dir>/step_<n>/`` containing one ``.npy`` per pytree
leaf (keyed by its flattened tree path) plus ``manifest.json`` recording
the tree structure, shapes/dtypes, mesh shape, data-pipeline step, and a
content checksum.  Writes go to ``step_<n>.tmp`` and are atomically
renamed — a crash mid-write can never corrupt the latest checkpoint, and
restart picks the newest *complete* step.

Restore is **elastic**: leaves are loaded host-side and ``jax.device_put``
with the *target* shardings, so a checkpoint written on a 2×16×16 mesh
restores onto any surviving-host mesh whose axes divide the shapes — the
re-shard is the device_put.  Async mode hands the host copy to a writer
thread so the train loop only blocks for the device→host transfer.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_piece(p) for p in path) or "root"
        out.append((key, leaf))
    return out


def _path_piece(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(tree, directory: str, extra: Optional[Dict] = None) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"leaves": [], "extra": extra or {}}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def load_pytree(directory: str, like, shardings=None,
                verify: bool = True):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs); ``shardings``: matching pytree of NamedShardings
    (or None leaves) applied via device_put — this IS the elastic re-shard."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    keys_like = _flatten_with_paths(like)
    tree_def = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree.leaves(shardings,
                                    is_leaf=lambda x: x is None)
                    if shardings is not None
                    else [None] * len(keys_like))
    leaves = []
    for (key, proto), shd in zip(keys_like, shard_leaves):
        rec = by_key[key]
        arr = np.load(os.path.join(directory, rec["file"]))
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != rec["crc"]:
                raise IOError(f"checksum mismatch for {key}")
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(tree_def, leaves), manifest["extra"]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_last: int = 3
    async_write: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        extra = dict(extra or {})
        extra["step"] = step
        # Device->host copy happens on the caller thread (cheap, blocking);
        # serialization + fsync happen on the writer thread.
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)
        target = os.path.join(self.directory, f"step_{step:08d}")

        def work():
            try:
                save_pytree(host_tree, target, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None, shardings=None):
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        return load_pytree(path, like, shardings)
