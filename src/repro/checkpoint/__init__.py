from .manager import CheckpointManager, load_pytree, save_pytree  # noqa: F401
