"""End-to-end serving driver (the paper's kind of system): a HoD
query server handling an async stream of SSD requests with checkpointed
index, request coalescing, an LRU cache, latency percentiles, and
straggler monitoring.

    PYTHONPATH=src python examples/serve_ssd.py --requests 256
"""
import argparse
import asyncio
import os

import numpy as np

from repro.core import BuildConfig, QueryEngine, grid_road_graph, pack_index
from repro.core.build_fast import build_hod_fast
from repro.core.index import HoDIndex
from repro.ft import StepMonitor
from repro.launch.serve import QueryServer


async def drive(server, sources, rng, mon):
    """Async clients with jittered arrivals, monitored per batch."""
    gaps = rng.exponential(1e-4, sources.shape[0])

    async def one(s, gap):
        await asyncio.sleep(gap)
        return await server.submit(int(s))

    mon.start_step()
    results = await asyncio.gather(
        *[one(s, g) for s, g in zip(sources.tolist(), gaps.tolist())])
    await server.drain()
    verdict = mon.end_step()
    if verdict != "ok":
        print(f"[monitor] {verdict}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--index-path", default="/tmp/hod_road.npz")
    args = ap.parse_args()

    # --- index lifecycle: build once, persist, reload (restart safety) ---
    if os.path.exists(args.index_path):
        ix = HoDIndex.load(args.index_path)
        g = grid_road_graph(side=60, seed=0)
        print(f"loaded index from {args.index_path}")
    else:
        g = grid_road_graph(side=60, seed=0)
        res = build_hod_fast(g, BuildConfig(max_core_nodes=512,
                                            max_core_edges=1 << 15))
        ix = pack_index(g, res)
        ix.save(args.index_path)
        print(f"built + saved index ({ix.index_bytes()/1e6:.1f} MB)")

    engine = QueryEngine(ix, use_pallas=args.use_pallas)
    server = QueryServer(engine, batch_size=args.batch, max_wait_ms=1.0)
    server.warmup()
    mon = StepMonitor()

    rng = np.random.default_rng(0)
    sources = rng.integers(0, g.n, args.requests).astype(np.int32)
    results = asyncio.run(drive(server, sources, rng, mon))

    for r in results:                              # grid: all reachable
        assert np.isfinite(r.dist[: g.n]).all()
    lat_ms = np.array([r.latency_s for r in results]) * 1e3
    st = server.stats
    io = server.modeled_io()
    print(f"served {st.requests} SSD requests in {st.batches} batches "
          f"(batch {args.batch}, {st.cache_hits} cache hits)")
    print(f"per-request: mean {lat_ms.mean():.2f} ms  "
          f"p50 {np.percentile(lat_ms, 50):.2f}  "
          f"p95 {np.percentile(lat_ms, 95):.2f}  "
          f"p99 {np.percentile(lat_ms, 99):.2f} ms")
    print(f"throughput: {st.throughput():.0f} queries/s (engine-busy); "
          f"modeled disk {io.modeled_seconds()*1e3:.1f} ms total")


if __name__ == "__main__":
    main()
