"""End-to-end serving driver (the paper's kind of system): a HoD
query server handling batched SSD/SSSP requests with checkpointed index,
latency percentiles, and straggler monitoring.

    PYTHONPATH=src python examples/serve_ssd.py --requests 256
"""
import argparse
import os
import time

import numpy as np

from repro.core.build_fast import build_hod_fast
from repro.core import (BuildConfig, QueryEngine, 
                        grid_road_graph, pack_index)
from repro.core.index import HoDIndex
from repro.ft import StepMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--index-path", default="/tmp/hod_road.npz")
    args = ap.parse_args()

    # --- index lifecycle: build once, persist, reload (restart safety) ---
    if os.path.exists(args.index_path):
        ix = HoDIndex.load(args.index_path)
        g = grid_road_graph(side=60, seed=0)
        print(f"loaded index from {args.index_path}")
    else:
        g = grid_road_graph(side=60, seed=0)
        res = build_hod_fast(g, BuildConfig(max_core_nodes=512,
                                       max_core_edges=1 << 15))
        ix = pack_index(g, res)
        ix.save(args.index_path)
        print(f"built + saved index ({ix.index_bytes()/1e6:.1f} MB)")

    engine = QueryEngine(ix)
    mon = StepMonitor()

    # --- request loop: batched, monitored --------------------------------
    rng = np.random.default_rng(0)
    all_sources = rng.integers(0, g.n, args.requests).astype(np.int32)
    engine.ssd(all_sources[: args.batch])          # warm / compile
    lats = []
    for lo in range(0, args.requests, args.batch):
        batch = all_sources[lo: lo + args.batch]
        if batch.shape[0] < args.batch:            # keep one compiled shape
            batch = np.pad(batch, (0, args.batch - batch.shape[0]),
                           mode="edge")
        mon.start_step()
        dist = engine.ssd(batch)
        verdict = mon.end_step()
        lats.append(mon.durations[-1] / args.batch)
        if verdict != "ok":
            print(f"[monitor] batch at {lo}: {verdict}")
        assert np.isfinite(dist[:, : g.n]).all()   # grid: all reachable

    lat_ms = np.array(lats) * 1e3
    print(f"served {args.requests} SSD queries (batch {args.batch})")
    print(f"per-query: mean {lat_ms.mean():.2f} ms  "
          f"p50 {np.percentile(lat_ms, 50):.2f}  "
          f"p95 {np.percentile(lat_ms, 95):.2f}  "
          f"p99 {np.percentile(lat_ms, 99):.2f} ms")
    print(f"throughput: {1e3/lat_ms.mean():.0f} queries/s "
          f"(single host, CPU)")


if __name__ == "__main__":
    main()
