"""Quickstart: build a HoD index, answer SSD + SSSP queries, verify.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BuildConfig, QueryEngine, build_hod,
                        dijkstra_reference, grid_road_graph, pack_index)


def main():
    # 1. a weighted directed graph (road-network-like grid)
    g = grid_road_graph(side=40, seed=0)
    print(f"graph: {g.n} nodes, {g.m} edges")

    # 2. preprocessing (paper §4): rank nodes, build shortcuts, pack the
    #    forward/backward/core files
    res = build_hod(g, BuildConfig(max_core_nodes=256,
                                   max_core_edges=1 << 14))
    ix = pack_index(g, res)
    print(f"index: {res.stats.rounds} rounds, core {ix.n_core} nodes, "
          f"{res.stats.shortcuts_added} shortcuts, "
          f"{ix.index_bytes()/1e6:.1f} MB")

    # 3. batched SSD queries (paper §5) — three linear sweeps, no heap
    sources = np.array([0, 555, 1599], dtype=np.int32)
    engine = QueryEngine(ix)
    dist = engine.ssd(sources)
    print(f"dist[0 -> corner] = {dist[0, g.n - 1]}")

    # 4. verify against in-memory Dijkstra
    oracle = dijkstra_reference(g, sources)
    assert np.allclose(dist[:, :g.n], oracle, rtol=1e-5)
    print("matches Dijkstra ✓")

    # 5. SSSP (paper §6): predecessors -> explicit path
    paths = engine.paths(sources[:1], np.array([g.n - 1]))
    print(f"shortest path 0 -> {g.n-1}: {len(paths[0])} hops, "
          f"starts {paths[0][:6]} ...")


if __name__ == "__main__":
    main()
