"""The paper's flagship application (Table 5): estimate closeness
centrality for every node via Eppstein–Wang sampling over batched SSD
queries.

    PYTHONPATH=src python examples/closeness_centrality.py
"""
import time

import numpy as np

from repro.core.build_fast import build_hod_fast
from repro.core import (BuildConfig, QueryEngine, 
                        estimate_closeness, pack_index, power_law_digraph,
                        symmetrize)


def main():
    g = symmetrize(power_law_digraph(3000, 5, seed=0))
    print(f"graph: {g.n} nodes, {g.m} edges (FB-like)")

    t0 = time.perf_counter()
    res = build_hod_fast(g, BuildConfig(max_core_nodes=256,
                                   max_core_edges=1 << 14))
    ix = pack_index(g, res)
    engine = QueryEngine(ix)
    print(f"preprocessing: {time.perf_counter()-t0:.1f}s")

    t0 = time.perf_counter()
    out = estimate_closeness(engine, eps=0.1, batch_size=64)
    print(f"closeness for all {g.n} nodes: {out.k} SSD queries in "
          f"{out.query_seconds:.1f}s ({out.batches} batches)")

    top = np.argsort(-out.closeness)[:5]
    print("top-5 central nodes:", top.tolist())
    print("their closeness:", np.round(out.closeness[top], 4).tolist())

    # sanity: hubs (high degree) should rank central in a power-law graph
    deg = np.diff(g.out_ptr)
    print(f"median degree of top-50 central: "
          f"{np.median(deg[np.argsort(-out.closeness)[:50]]):.0f} "
          f"vs global median {np.median(deg):.0f}")


if __name__ == "__main__":
    main()
