"""Train a small LM (granite-MoE-style reduced config) for a few hundred
steps with the full production loop: checkpointing, restart, monitoring.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.ft import StepMonitor
from repro.models.layers import MoEConfig
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.optim import adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = TransformerConfig(
        name="mini-moe", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=512, head_dim=32, attn_chunk=64, loss_chunk=64,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=128))
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active)")

    params = init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": adamw_init(params)}
    stream = TokenStream(vocab=cfg.vocab, batch=args.batch,
                         seq_len=args.seq, seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep_last=2)
    mon = StepMonitor()

    @jax.jit
    def train_step(state, tokens, labels):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, tokens, labels, cfg))(state["params"])
        lr = cosine_schedule(state["opt"].count, 3e-3, 20, 400)
        p, opt, gnorm = adamw_update(state["params"], grads, state["opt"],
                                     lr)
        return {"params": p, "opt": opt}, loss, gnorm

    start = 0
    if mgr.latest_step() is not None:
        state, extra = mgr.restore(state)
        start = int(extra["step"]) + 1
        print(f"resumed from step {start - 1}")

    first_loss = None
    for step in range(start, args.steps):
        toks, labels = stream.batch_at(step)   # deterministic resume
        mon.start_step()
        state, loss, gnorm = train_step(state, jnp.asarray(toks),
                                        jnp.asarray(labels))
        mon.end_step()
        if first_loss is None:
            first_loss = float(loss)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"gnorm {float(gnorm):.2f}  "
                  f"{mon.median*1e3:.0f} ms/step")
        if (step + 1) % 50 == 0 or step == args.steps - 1:
            mgr.save(step, state)
    mgr.wait()
    print(f"loss: {first_loss:.3f} -> {float(loss):.3f} "
          f"({'improved' if float(loss) < first_loss else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
