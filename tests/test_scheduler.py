"""SLO scheduler + coalescer bugfixes (DESIGN.md §12): deterministic
timer re-arm (the double-wait regression), parameter-aware cache keys
(the staleness regression), per-class deadline accounting, drain/close
liveness, and bit-identical mixed-traffic answers under both admission
policies."""
import asyncio
import types

import numpy as np
import pytest

import repro.launch.serve as serve_mod
from repro.config import SERVE_DEFAULTS, Config, ConfigError
from repro.core import (BuildConfig, QueryEngine, build_hod,
                        gnm_random_digraph, pack_index)
from repro.launch.serve import (ClassSLO, QueryServer,
                                mixed_request_stream, server_from_config)

CFG = BuildConfig(max_core_nodes=32, max_core_edges=1024, seed=0)


@pytest.fixture(scope="module")
def engine():
    g = gnm_random_digraph(150, 600, seed=4)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    return QueryEngine(ix)


def _fake_clock(server, t):
    """Freeze the scheduler's clock (the ``_now`` seam); returns the
    mutable clock object."""
    clock = types.SimpleNamespace(t=t)
    server._now = lambda: clock.t
    return clock


# ------------------------------------------- double-wait regression (fix 1)
def test_flush_due_rearms_for_straggler(engine):
    """A straggler left behind by a full-width take keeps its OWN
    submit-time budget.  Pre-fix, the timer was not re-derived after
    the flush, so the leftover waited for the next arrival (or a fresh
    full max_wait) — ~2x max_wait in the open-loop traces."""
    server = QueryServer(engine, batch_size=2, max_wait_ms=50.0)
    clock = _fake_clock(server, 0.055)

    async def drive():
        loop = asyncio.get_running_loop()
        futs = [loop.create_future() for _ in range(3)]
        server._queues[serve_mod._FIFO] = [
            (41, futs[0], 0.000, "ssd"),     # due (flush-by 0.050)
            (42, futs[1], 0.001, "ssd"),
            (43, futs[2], 0.010, "ssd")]     # not due until 0.060
        server._flush_due()
        assert futs[0].done() and futs[1].done()
        assert not futs[2].done() and server.pending_count() == 1
        # The re-armed deadline is a pure function of the pending set:
        # the straggler's t0 + max_wait, not now + max_wait.
        assert server._timer_deadline == pytest.approx(0.010 + 0.050)
        clock.t = 1.0                        # let the timer find it due
        r = await asyncio.wait_for(futs[2], timeout=10.0)
        assert r.source == 43
    asyncio.run(drive())
    assert server.pending_count() == 0


def test_straggler_keeps_budget_after_size_flush(engine):
    server = QueryServer(engine, batch_size=2, max_wait_ms=40.0)
    clock = _fake_clock(server, 5.0)

    async def drive():
        tasks = [asyncio.create_task(server.submit(s))
                 for s in (51, 52, 53)]
        for _ in range(4):
            await asyncio.sleep(0)
        # 51+52 flushed on the size trigger; 53's timer must already be
        # armed at its own submit-time budget.
        assert server.stats.batches == 1 and server.pending_count() == 1
        assert server._timer_deadline == pytest.approx(5.0 + 0.040)
        clock.t = 6.0
        return await asyncio.gather(*tasks)

    results = asyncio.run(drive())
    assert [r.source for r in results] == [51, 52, 53]


def test_urgent_class_rearms_timer(engine):
    server = QueryServer(engine, batch_size=8, scheduler="slo",
                         modes=("ssd", "p2p"),
                         slo={"ssd": {"deadline_ms": 500.0},
                              "p2p": {"deadline_ms": 50.0}})
    _fake_clock(server, 2.0)

    async def drive():
        t1 = asyncio.create_task(server.submit(61))
        await asyncio.sleep(0)
        assert server._timer_deadline == pytest.approx(2.0 + 0.5)
        t2 = asyncio.create_task(server.submit(1, 2, mode="p2p"))
        await asyncio.sleep(0)
        # the cheaper class's tighter deadline takes over the timer
        assert server._timer_deadline == pytest.approx(2.0 + 0.05)
        await server.drain()
        return await asyncio.gather(t1, t2)

    r1, r2 = asyncio.run(drive())
    assert r1.mode == "ssd" and r2.mode == "p2p"


def test_flush_by_deadline_accounting(engine):
    server = QueryServer(engine, batch_size=4, max_wait_ms=7.0,
                         scheduler="slo", modes=("ssd", "p2p"),
                         slo={"ssd": {"deadline_ms": 100.0}})
    server._exec_ewma["ssd"] = 0.010
    entry = (0, None, 50.0, "ssd")
    assert server._flush_by(entry) == pytest.approx(
        50.0 + 0.100 - server.SLO_HEADROOM * 0.010)
    server._exec_ewma["ssd"] = 10.0          # hopeless deadline ->
    assert server._flush_by(entry) == 50.0   # clamped at submit time
    # a class without an SLO falls back to max_wait_ms
    assert server._flush_by((0, None, 50.0, "p2p")) == pytest.approx(
        50.0 + 0.007)


# --------------------------------------- cache-staleness regression (fix 2)
def test_within_cache_keyed_by_threshold(engine):
    server = QueryServer(engine, batch_size=2, mode="within", within_d=8.0)
    r1 = server.serve_stream(np.array([5], np.int32))[0]
    server.within_d = 3.0                    # reconfigure the live server
    r2 = server.serve_stream(np.array([5], np.int32))[0]
    # pre-fix the LRU replayed the d=8 row; now the key carries d
    assert server.stats.cache_hits == 0 and server.stats.batches == 2
    np.testing.assert_array_equal(
        r2.dist, engine.ssd_within(np.array([5], np.int32), 3.0)[0])
    assert np.isfinite(r2.dist).sum() <= np.isfinite(r1.dist).sum()


def test_knn_cache_keyed_by_k(engine):
    server = QueryServer(engine, batch_size=2, mode="knn", knn_k=3)
    r1 = server.serve_stream(np.array([7], np.int32))[0]
    assert r1.nodes.shape == (3,)
    server.knn_k = 5
    r2 = server.serve_stream(np.array([7], np.int32))[0]
    assert r2.nodes.shape == (5,)            # recomputed, not replayed
    assert server.stats.cache_hits == 0 and server.stats.batches == 2


def test_cache_not_shared_across_modes(engine):
    server = QueryServer(engine, batch_size=2, modes=("ssd", "within"),
                         within_d=4.0)
    full = server.serve_stream(np.array([9], np.int32), mode="ssd")[0]
    clamp = server.serve_stream(np.array([9], np.int32), mode="within")[0]
    assert server.stats.cache_hits == 0 and server.stats.batches == 2
    assert np.isfinite(clamp.dist).sum() <= np.isfinite(full.dist).sum()


# ----------------------------------------------- constructor validation
@pytest.mark.parametrize("kw", [
    dict(batch_size=0), dict(max_wait_ms=-1.0), dict(cache_entries=-1),
    dict(within_d=0.0), dict(knn_k=0), dict(queue_depth=0),
    dict(decode_workers=0), dict(pin_frac=1.5), dict(scheduler="lifo"),
    dict(mode="bogus"), dict(sssp=True, mode="p2p"),
    dict(mode="ssd", modes=("p2p",)), dict(modes=("ssd", "ssd")),
    dict(slo={"p2p": {"deadline_ms": 5.0}}),     # class not admitted
    dict(slo={"ssd": 5.0}),                      # spec not a mapping
    dict(slo={"ssd": {"deadline_ms": -1.0}}),
])
def test_ctor_validation(engine, kw):
    with pytest.raises(ValueError):
        QueryServer(engine, **kw)


def test_ctor_engine_xor_store(engine):
    with pytest.raises(ValueError):
        QueryServer()
    with pytest.raises(ValueError):
        QueryServer(engine, store_path="/tmp/nope")


def test_class_slo_validation():
    with pytest.raises(ValueError):
        ClassSLO(deadline_ms=0.0)
    with pytest.raises(ValueError):
        ClassSLO(deadline_ms=5.0, batch=0)
    assert ClassSLO(deadline_ms=5.0).batch is None


def test_submit_validates_mode_and_target(engine):
    server = QueryServer(engine, batch_size=2)

    async def drive():
        with pytest.raises(ValueError):
            await server.submit(1, mode="p2p")   # not an admitted mode
        with pytest.raises(ValueError):
            await server.submit(1, 2)            # target outside p2p
    asyncio.run(drive())


# ----------------------------------------------------- drain() and close()
def test_drain_answers_everything_and_disarms_timer(engine):
    server = QueryServer(engine, batch_size=64, max_wait_ms=10_000.0)

    async def drive():
        tasks = [asyncio.create_task(server.submit(s))
                 for s in (71, 72, 73)]
        for _ in range(3):
            await asyncio.sleep(0)
        assert server.pending_count() == 3
        assert server._timer is not None         # in-flight flush timer
        await server.drain()
        assert server.pending_count() == 0
        assert server._timer is None and server._timer_deadline is None
        return await asyncio.gather(*tasks)

    results = asyncio.run(drive())
    direct = engine.ssd(np.array([71, 72, 73], np.int32))
    for r, d in zip(results, direct):
        np.testing.assert_array_equal(r.dist, d)


def test_close_fails_pending_futures(engine):
    server = QueryServer(engine, batch_size=64, max_wait_ms=10_000.0)

    async def drive():
        tasks = [asyncio.create_task(server.submit(s)) for s in (81, 82)]
        for _ in range(3):
            await asyncio.sleep(0)
        assert server.pending_count() == 2
        server.close()
        assert server.pending_count() == 0 and server._timer is None
        return await asyncio.gather(*tasks, return_exceptions=True)

    out = asyncio.run(drive())
    assert all(isinstance(e, RuntimeError) for e in out)  # nobody hangs
    assert "closed" in str(out[0])


# ------------------------------------------------- mixed-traffic scheduling
def test_fifo_take_splits_modes_in_arrival_order(engine):
    server = QueryServer(engine, batch_size=4, max_wait_ms=5_000.0,
                         modes=("ssd", "p2p"))

    async def drive():
        tasks = [asyncio.create_task(server.submit(1)),
                 asyncio.create_task(server.submit(2, 3, mode="p2p")),
                 asyncio.create_task(server.submit(2)),
                 asyncio.create_task(server.submit(4, 5, mode="p2p"))]
        return await asyncio.gather(*tasks)

    results = asyncio.run(drive())
    assert server.stats.batches == 2         # one take, two mode groups
    assert [r.mode for r in results] == ["ssd", "p2p", "ssd", "p2p"]
    assert all(r.batched_with == 2 for r in results)
    np.testing.assert_array_equal(
        results[0].dist, engine.ssd(np.array([1], np.int32))[0])
    np.testing.assert_array_equal(
        results[1].dist,
        np.float32(engine.p2p(np.array([2], np.int32),
                              np.array([3], np.int32))[0]))


def test_class_batch_cap_triggers_early_flush(engine):
    server = QueryServer(engine, batch_size=16, max_wait_ms=10_000.0,
                         scheduler="slo",
                         slo={"ssd": {"deadline_ms": 10_000.0,
                                      "batch": 2}})

    async def drive():
        tasks = [asyncio.create_task(server.submit(31)),
                 asyncio.create_task(server.submit(32))]
        for _ in range(3):
            await asyncio.sleep(0)
        assert server.pending_count() == 0   # cap hit: no timer wait
        return await asyncio.gather(*tasks)

    results = asyncio.run(drive())
    assert server.stats.batches == 1
    assert results[0].batched_with == 2
    assert server.stats.padded_slots == 14   # still padded to the jit shape


def test_deadline_miss_accounting(engine):
    server = QueryServer(engine, batch_size=4, max_wait_ms=1.0,
                         scheduler="slo",
                         slo={"ssd": {"deadline_ms": 0.0005}})

    async def drive():
        tasks = [asyncio.create_task(server.submit(s))
                 for s in (21, 22, 23)]
        await asyncio.sleep(0)
        await server.drain()
        return await asyncio.gather(*tasks)

    asyncio.run(drive())
    assert server.stats.deadline_misses == 3     # nothing beats 0.5us
    assert server.metrics.counter("slo.miss.ssd").value == 3
    rows = {r["cls"]: r for r in server.slo_report()}
    assert rows["ssd"]["deadline_misses"] == 3
    assert rows["ssd"]["requests"] == 3
    assert rows["ssd"]["deadline_ms"] == 0.0005


@pytest.mark.parametrize("scheduler", ["fifo", "slo"])
def test_mixed_load_bit_identical_to_unscheduled(engine, scheduler):
    """Property test (ISSUE-9 satellite): whatever the admission policy
    does to batching order, every answer must be bit-identical to a
    singleton engine call on the unscheduled path."""
    cfg = Config(None, defaults=SERVE_DEFAULTS,
                 overrides={"serve": {"mix": {"ssd": 1, "p2p": 3}}})
    stream = mixed_request_stream(cfg, 150, 60,
                                  np.random.default_rng(11), p2p_pool=8)
    slo = ({"p2p": {"deadline_ms": 50.0, "batch": 4},
            "ssd": {"deadline_ms": 200.0}} if scheduler == "slo" else None)
    server = QueryServer(engine, batch_size=8, max_wait_ms=5.0,
                         modes=("ssd", "p2p"), scheduler=scheduler,
                         slo=slo)

    async def drive():
        tasks = [asyncio.create_task(server.submit(*args, mode=m))
                 for m, args in stream]
        await asyncio.sleep(0)
        await server.drain()
        return await asyncio.gather(*tasks)

    results = asyncio.run(drive())
    assert server.stats.requests == len(stream)
    for (m, args), r in zip(stream, results):
        if m == "p2p":
            s, t = args
            oracle = engine.p2p(np.array([s], np.int32),
                                np.array([t], np.int32))[0]
            np.testing.assert_array_equal(r.dist, np.float32(oracle))
        else:
            oracle = engine.ssd(np.array(args, np.int32))[0]
            np.testing.assert_array_equal(r.dist, oracle)
    # the small p2p pool guarantees repeats -> a real cached class
    rows = {r["cls"] for r in server.slo_report()}
    assert {"ssd", "p2p", "p2p.cached"} <= rows


# --------------------------------------------------------- config plumbing
def test_server_from_config_builds_mixed_server(engine):
    cfg = Config(None, defaults=SERVE_DEFAULTS, overrides={
        "serve": {"batch": 8, "scheduler": "slo",
                  "mix": {"ssd": 1, "p2p": 3},
                  "slo": {"p2p": {"deadline_ms": 40.0, "batch": 4}}}})
    server = server_from_config(cfg, engine=engine)
    assert server.modes == ("ssd", "p2p") and server.mode == "ssd"
    assert server.scheduler == "slo" and server.batch_size == 8
    assert server._slo["p2p"] == ClassSLO(deadline_ms=40.0, batch=4)


def test_server_from_config_threshold_alias(engine):
    cfg = Config(None, defaults=SERVE_DEFAULTS,
                 overrides={"serve": {"mode": "threshold",
                                      "threshold": 4.0}})
    server = server_from_config(cfg, engine=engine)
    assert server.mode == "within" and server.within_d == 4.0


def test_server_from_config_topk_builds_ssd_server(engine):
    # regression: `--mode topk` crashed server_from_config with
    # "serve.mix names unknown mode 'topk'" — topk is a batch job
    # driven through core.topk_closeness, its server runs ssd sweeps
    cfg = Config(None, defaults=SERVE_DEFAULTS,
                 overrides={"serve": {"mode": "topk", "k": 3}})
    server = server_from_config(cfg, engine=engine)
    assert server.mode == "ssd" and server.modes == ("ssd",)


def test_server_from_config_rejects_unknown_slo_class(engine):
    # a typo'd SLO class must raise like QueryServer's constructor
    # does, not silently serve that class with no deadline
    cfg = Config(None, defaults=SERVE_DEFAULTS, overrides={
        "serve": {"scheduler": "slo", "mix": {"ssd": 1},
                  "slo": {"p2p": {"deadline_ms": 40.0}}}})
    with pytest.raises(ConfigError, match=r"serve\.slo\.p2p"):
        server_from_config(cfg, engine=engine)
