"""Checkpoint/restart, elastic recovery, straggler detection, data resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import ElasticTrainer, StepMonitor, StragglerPolicy, \
    surviving_mesh

KEY = jax.random.PRNGKey(0)


def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "layers": [{"a": jnp.ones((2, 2))},
                                  {"a": jnp.zeros((2, 2))}]},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = _state()
    mgr.save(7, state)
    restored, extra = mgr.restore(state)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_write=True)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checksum_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = _state()
    mgr.save(1, state)
    # corrupt one leaf on disk
    d = os.path.join(str(tmp_path), "step_00000001")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    arr = arr + 1
    np.save(os.path.join(d, victim), arr)
    with pytest.raises(IOError):
        mgr.restore(state)


def test_crash_mid_write_keeps_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = _state()
    mgr.save(1, state)
    # simulate a crash: leave a stale .tmp directory around
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert mgr.latest_step() == 1
    restored, extra = mgr.restore(state)
    assert extra["step"] == 1


def test_elastic_trainer_recovers_from_injected_failures(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False, keep_last=5)
    crashes = {15: True, 27: True}

    def injector(step):
        if crashes.pop(step, None):
            raise RuntimeError(f"injected failure at step {step}")

    def build(n_devices, restored):
        state = restored if restored is not None else {
            "w": jnp.zeros((4,)), }

        def step_fn(state, step):
            return {"w": state["w"] + 1.0}
        return state, step_fn

    trainer = ElasticTrainer(ckpt=mgr, build=build, total_steps=40,
                             ckpt_every=10, failure_injector=injector)
    state, log = trainer.run(n_devices=1)
    assert log["restarts"] == 2
    # resumed from the latest checkpoint before each crash
    assert log["resumed_from"] == [9, 19]
    # final state reflects all 40 increments despite restarts
    np.testing.assert_allclose(np.asarray(state["w"]), 40.0)


def test_step_monitor_verdicts():
    mon = StepMonitor(StragglerPolicy(straggler_factor=1.5, hang_factor=5.0,
                                      min_samples=3, patience=2))
    for _ in range(5):
        assert mon.observe(1.0) == "ok"
    assert mon.observe(1.6) == "ok"          # first slow step: patience
    assert mon.observe(1.7) == "straggler"   # second: evict
    assert mon.observe(10.0) == "hang"


def test_surviving_mesh_shapes():
    m = surviving_mesh(1, model_parallelism=1)
    assert dict(m.shape) == {"data": 1, "model": 1}
    with pytest.raises(RuntimeError):
        surviving_mesh(1, model_parallelism=2)


def test_data_streams_deterministic_resume():
    from repro.data import RecsysStream, TokenStream
    ts = TokenStream(vocab=128, batch=4, seq_len=16, seed=3)
    a1, b1 = ts.batch_at(10)
    a2, b2 = ts.batch_at(10)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    rs = RecsysStream(batch=8, vocab=100, seed=3)
    x1 = rs.batch_at(5)
    x2 = rs.batch_at(5)
    for u, v in zip(x1, x2):
        np.testing.assert_array_equal(u, v)


def test_neighbor_sampler_block_validity():
    from repro.data.sampler import NeighborSampler, csr_from_edges
    rng = np.random.default_rng(0)
    n, m = 500, 3000
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    ptr, nbr = csr_from_edges(n, src, dst)
    feats = rng.normal(size=(n, 8)).astype(np.float32)
    labels = rng.integers(0, 5, n).astype(np.int32)
    sampler = NeighborSampler(ptr, nbr, feats, labels, fanout=(3, 2))
    batch_ids = rng.choice(n, 16, replace=False)
    block = sampler.sample(batch_ids, step=0)
    max_n, max_e = sampler.block_shape(16)
    assert block.node_feat.shape == (max_n, 8)
    assert block.src.shape == (max_e,)
    # loss mask only on the original batch nodes
    assert int(np.asarray(block.train_mask).sum()) == 16
    # every real edge's endpoints are real nodes
    s = np.asarray(block.src)
    d = np.asarray(block.dst)
    real = s < max_n
    assert np.all(d[real] <= max_n)
    # deterministic in (seed, step)
    block2 = sampler.sample(batch_ids, step=0)
    np.testing.assert_array_equal(np.asarray(block.src),
                                  np.asarray(block2.src))
