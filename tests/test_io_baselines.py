"""Paper-rival baselines (VC-Index, EM-BFS, EM-Dijkstra) + the I/O model."""
import numpy as np

from repro.core import (BuildConfig, build_hod, dijkstra_reference,
                        gnm_random_digraph, pack_index, symmetrize)
from repro.core.baselines import VCIndex, em_bfs, em_dijkstra
from repro.core.io_sim import BlockDevice, IOStats


def _und_graph(n=150, m=400, seed=3):
    return symmetrize(gnm_random_digraph(n, m, seed=seed))


def test_em_dijkstra_correct_and_random_io():
    g = _und_graph()
    dist, io = em_dijkstra(g, 0)
    oracle = dijkstra_reference(g, [0])[0]
    finite = np.isfinite(oracle)
    assert np.allclose(dist[finite], oracle[finite])
    assert io.rand_blocks > 0            # the paper's complaint, visible


def test_em_bfs_correct_unweighted():
    g = symmetrize(gnm_random_digraph(120, 360, seed=5, weighted=False))
    dist, io = em_bfs(g, 0)
    oracle = dijkstra_reference(g, [0])[0]
    finite = np.isfinite(oracle)
    assert np.allclose(dist[finite], oracle[finite])


def test_vc_index_correct():
    g = _und_graph(seed=9)
    vc = VCIndex(g, top_nodes=32)
    dist, _ = vc.ssd(0)
    oracle = dijkstra_reference(g, [0])[0]
    finite = np.isfinite(oracle)
    assert np.allclose(dist[finite], oracle[finite])


def test_hod_io_is_sequential_and_smaller():
    """Paper Table 4's mechanism: HoD queries scan sequentially; EM-Dijk
    issues random reads. Compare modeled I/O time on the same graph."""
    g = _und_graph(n=400, m=1600, seed=1)
    res = build_hod(g, BuildConfig(max_core_nodes=32, max_core_edges=1024))
    ix = pack_index(g, res, chunk=256)
    # HoD query I/O = one scan of F_f + core + F_b
    dev = BlockDevice()
    hod_bytes = (ix.f_src.nbytes + ix.f_w.nbytes + ix.b_src.nbytes
                 + ix.b_w.nbytes + ix.core_closure.nbytes)
    dev.sequential(hod_bytes)
    hod_time = dev.stats.modeled_seconds()
    _, io_em = em_dijkstra(g, 0, cache_blocks=8)
    em_time = io_em.modeled_seconds()
    assert dev.stats.rand_blocks == 0
    assert em_time > hod_time


def test_block_device_accounting():
    dev = BlockDevice(block_bytes=1024)
    dev.sequential(4096)
    assert dev.stats.seq_blocks == 4
    dev.random(100)
    assert dev.stats.rand_blocks == 1
    dev.access_block(5)
    dev.access_block(6)          # consecutive -> sequential
    assert dev.stats.rand_blocks == 2
    assert dev.stats.seq_blocks == 5
    # external sort: in-memory case = 2 passes
    dev2 = BlockDevice()
    dev2.external_sort(1 << 20, mem_bytes=1 << 22)
    assert dev2.stats.bytes_seq == 2 << 20


def test_iostats_addition():
    a = IOStats(1, 2, 3, 4)
    b = IOStats(10, 20, 30, 40)
    c = a + b
    assert (c.seq_blocks, c.rand_blocks, c.bytes_seq, c.bytes_rand) == \
        (11, 22, 33, 44)
