"""The CI bench-regression gate (benchmarks/check_regression.py).

The acceptance criterion is behavioral: identical runs pass, a
doctored baseline (inflated hit rate / throughput, extra rows) fails,
and the CLI exits non-zero on regression.  All in-process — no serving
run needed, the gate is pure row comparison.
"""
import copy
import json
import os
import sys

import pytest

# repo root on sys.path: benchmarks/ is a plain (uninstalled) package
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from benchmarks.check_regression import (  # noqa: E402
    compare, main, resolve_tolerances)

BASELINE = {
    "git_sha": "deadbeef",
    "tables": {
        "serve": [
            {"batch": 1, "queries_per_s": 200.0},
            {"batch": 16, "queries_per_s": 600.0},
        ],
        "store": [
            {"codec": "raw", "cache_frac": 0.25, "policy": "2q",
             "hit_rate": 0.55, "real_bytes": 7_000_000},
            {"codec": "delta", "cache_frac": 0.25, "policy": "2q",
             "hit_rate": 0.55, "real_bytes": 3_500_000},
        ],
        "workloads": [
            {"workload": "ssd", "cache_frac": 0.25, "policy": "2q",
             "hit_rate": 0.56, "real_bytes": 6_800_000,
             "cold_query_bytes": 3_900_000, "queries_per_s": 400.0},
            {"workload": "p2p", "cache_frac": 0.25, "policy": "2q",
             "hit_rate": 0.55, "real_bytes": 7_000_000,
             "cold_query_bytes": 3_400_000, "queries_per_s": 330.0},
        ],
        "queue_depth": [
            {"codec": "raw", "queue_depth": 1, "cache_frac": 0.25,
             "policy": "2q", "hit_rate": 0.55, "real_bytes": 7_000_000,
             "stall_model_s": 0.9, "queries_per_s": 300.0},
            {"codec": "raw", "queue_depth": 4, "cache_frac": 0.25,
             "policy": "2q", "hit_rate": 0.55, "real_bytes": 7_000_000,
             "stall_model_s": 0.4, "queries_per_s": 380.0},
        ],
        "cold_start": [{"load_s": 0.05}],
        "slo": [
            {"cls": "ssd", "policy": "fifo", "requests": 64,
             "p50_ms": 45.0, "p99_ms": 110.0, "deadline_ms": 200.0,
             "deadline_misses": 0, "queries_per_s": 155.0,
             "miss_rate": 0.0, "cheap": False},
            {"cls": "ssd", "policy": "slo", "requests": 64,
             "p50_ms": 60.0, "p99_ms": 190.0, "deadline_ms": 200.0,
             "deadline_misses": 1, "queries_per_s": 145.0,
             "miss_rate": 0.016, "cheap": False},
            {"cls": "p2p", "policy": "fifo", "requests": 190,
             "p50_ms": 40.0, "p99_ms": 97.0, "deadline_ms": 60.0,
             "deadline_misses": 16, "queries_per_s": 155.0,
             "miss_rate": 0.084, "cheap": True},
            {"cls": "p2p", "policy": "slo", "requests": 190,
             "p50_ms": 1.0, "p99_ms": 35.0, "deadline_ms": 60.0,
             "deadline_misses": 0, "queries_per_s": 145.0,
             "miss_rate": 0.0, "cheap": True},
            {"cls": "p2p.cached", "policy": "slo", "requests": 170,
             "p50_ms": 0.5, "p99_ms": 30.0, "deadline_ms": 60.0,
             "deadline_misses": 0, "queries_per_s": 145.0,
             "miss_rate": 0.0, "cheap": True},
        ],
        "latency": [
            {"mode": "ssd", "p50_ms": 10.0, "p99_ms": 40.0,
             "queries_per_s": 400.0, "trace_overhead_frac": 0.01},
            {"mode": "p2p", "p50_ms": 12.0, "p99_ms": 55.0,
             "queries_per_s": 330.0, "trace_overhead_frac": 0.01},
        ],
        "fleet": [
            {"shards": 1, "codec": "raw", "cache_frac": 0.25,
             "policy": "2q", "hit_rate": 0.81, "real_bytes": 786_432,
             "queries_per_s": 1700.0},
            {"shards": 2, "codec": "raw", "cache_frac": 0.25,
             "policy": "2q", "hit_rate": 0.87, "real_bytes": 524_288,
             "queries_per_s": 1750.0},
            {"shards": 4, "codec": "raw", "cache_frac": 0.25,
             "policy": "2q", "hit_rate": 0.93, "real_bytes": 262_144,
             "queries_per_s": 1800.0},
        ],
    },
}


def test_identical_run_passes():
    assert compare(BASELINE, BASELINE) == []


def test_within_tolerance_passes():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["serve"][0]["queries_per_s"] = 170.0   # -15% < 20%
    fresh["tables"]["store"][0]["hit_rate"] = 0.52         # -3pp < 5pp
    fresh["tables"]["store"][0]["real_bytes"] = 7_200_000  # +3% < 10%
    assert compare(BASELINE, fresh) == []


def test_doctored_baseline_fails():
    """Feeding the gate a baseline with inflated numbers must flag the
    honest fresh run as a regression (the CI criterion)."""
    doctored = copy.deepcopy(BASELINE)
    doctored["tables"]["store"][0]["hit_rate"] = 0.99
    doctored["tables"]["serve"][1]["queries_per_s"] = 6000.0
    violations = compare(doctored, BASELINE)
    assert len(violations) == 2
    assert any("hit rate" in v for v in violations)
    assert any("throughput" in v for v in violations)


def test_bytes_read_growth_fails():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["store"][1]["real_bytes"] = 5_000_000  # +43%
    violations = compare(BASELINE, fresh)
    assert violations and "bytes read" in violations[0]
    assert "codec=delta" in violations[0]


def test_missing_row_fails():
    """Silently dropping a benchmark config cannot pass the gate."""
    fresh = copy.deepcopy(BASELINE)
    del fresh["tables"]["serve"][0]
    del fresh["tables"]["store"][1]
    violations = compare(BASELINE, fresh)
    assert len(violations) == 2
    assert all("missing" in v for v in violations)


def test_missing_workload_row_fails():
    """A fresh run that silently drops the P2P workload row (e.g. the
    mode was disabled) must fail the gate (ISSUE-6)."""
    fresh = copy.deepcopy(BASELINE)
    del fresh["tables"]["workloads"][1]
    violations = compare(BASELINE, fresh)
    assert violations == ["workloads[p2p]: row missing from fresh run"]


def test_cold_sweep_bytes_growth_fails():
    """P2P losing its I/O edge — cold sweep footprint ballooning past
    tolerance — is a gated regression, not a silent drift."""
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["workloads"][1]["cold_query_bytes"] = 3_900_000
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "workloads[p2p]" in violations[0]
    assert "cold sweep bytes" in violations[0]


def test_workload_hit_rate_drop_fails():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["workloads"][0]["hit_rate"] = 0.40   # -16pp
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "workloads[ssd]" in violations[0]
    assert "hit rate" in violations[0]


def test_missing_queue_depth_row_fails():
    """Dropping a (codec, depth) cell — say the pipeline sweep stopped
    running depth 4 — must fail the gate (ISSUE-7)."""
    fresh = copy.deepcopy(BASELINE)
    del fresh["tables"]["queue_depth"][1]
    violations = compare(BASELINE, fresh)
    assert violations == ["queue_depth[codec=raw, depth=4]: "
                          "row missing from fresh run"]


def test_queue_depth_overread_fails_without_baseline():
    """The fresh-run determinism invariant needs no baseline numbers:
    a depth-4 row reading even one byte more than the same codec's
    depth-1 row is a violation (read-ahead must not inflate I/O)."""
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["queue_depth"][0]["real_bytes"] = 6_999_999
    violations = compare(fresh, fresh)    # identical docs, still fails
    assert len(violations) == 1
    assert "queue_depth[codec=raw, depth=4]" in violations[0]
    assert "read-ahead must not inflate I/O" in violations[0]


def test_queue_depth_hit_rate_and_bytes_gated():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["queue_depth"][1]["hit_rate"] = 0.40      # -15pp
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1 and "hit rate" in violations[0]
    fresh = copy.deepcopy(BASELINE)
    for row in fresh["tables"]["queue_depth"]:
        row["real_bytes"] = 9_000_000                         # +29%
    violations = compare(BASELINE, fresh)
    assert len(violations) == 2
    assert all("bytes read" in v for v in violations)


def test_extra_fresh_rows_are_ignored():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["store"].append(
        {"codec": "f16", "cache_frac": 0.05, "policy": "2q",
         "hit_rate": 0.1, "real_bytes": 1})
    assert compare(BASELINE, fresh) == []


def test_throughput_check_can_be_skipped():
    doctored = copy.deepcopy(BASELINE)
    doctored["tables"]["serve"][0]["queries_per_s"] = 9e9
    assert compare(doctored, BASELINE, check_throughput=False) == []
    assert compare(doctored, BASELINE)          # on by default


# ---------------------------------------------- latency p99 gate (ISSUE-8)
def test_latency_p99_within_tolerance_passes():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["latency"][0]["p99_ms"] = 55.0      # +38% < 50%
    assert compare(BASELINE, fresh) == []


def test_latency_p99_growth_fails():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["latency"][1]["p99_ms"] = 95.0      # +73%
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "latency[p2p]" in violations[0] and "p99" in violations[0]
    # a looser CI-style tolerance absorbs the same growth
    assert compare(BASELINE, fresh, latency_tol=2.0) == []


def test_missing_latency_row_fails():
    """A fresh run that stops measuring a served mode's latency (say
    the sweep was disabled) must fail the gate, not pass silently."""
    fresh = copy.deepcopy(BASELINE)
    del fresh["tables"]["latency"][0]
    violations = compare(BASELINE, fresh)
    assert violations == ["latency[ssd]: row missing from fresh run"]


# ------------------------------------------- slo scheduler gate (ISSUE-9)
def test_missing_slo_class_row_fails_even_without_baseline_row():
    """A traffic class silently dropping out of the scheduler table is
    a loud failure — including when the baseline never had it: parent
    class rows are required in the fresh run per se."""
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["slo"] = [r for r in fresh["tables"]["slo"]
                              if not (r["cls"] == "p2p"
                                      and r["policy"] == "slo")]
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "slo[cls=p2p, policy=slo]" in violations[0]
    assert "missing" in violations[0]
    # same doc on both sides: the fresh-run presence check still fires
    assert any("missing" in v for v in compare(fresh, fresh))


def test_slo_p99_regression_fails():
    fresh = copy.deepcopy(BASELINE)
    for row in fresh["tables"]["slo"]:
        if row["cls"] == "ssd" and row["policy"] == "slo":
            row["p99_ms"] = 400.0                       # +110% > 50%
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "slo[cls=ssd, policy=slo]" in violations[0]
    assert "p99" in violations[0]
    assert compare(BASELINE, fresh, latency_tol=2.0) == []


def test_slo_cheap_class_invariant_is_baseline_free():
    """The point of the scheduler: cheap-class p99 under ``slo`` must
    be *strictly* below the fifo baseline's — gated on the fresh run
    alone, so identical doctored documents still fail."""
    doc = copy.deepcopy(BASELINE)
    for row in doc["tables"]["slo"]:
        if row["cls"] == "p2p" and row["policy"] == "slo":
            row["p99_ms"] = 97.0                # == fifo: not a win
    violations = compare(doc, doc)
    assert len(violations) == 1
    assert "slo[cls=p2p]" in violations[0]
    assert "strictly below" in violations[0]


def test_slo_cached_subrows_are_informational():
    """``.cached``/``.cold`` membership depends on arrival timing, so a
    sub-row vanishing from the fresh run is not a violation."""
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["slo"] = [r for r in fresh["tables"]["slo"]
                              if "." not in r["cls"]]
    assert compare(BASELINE, fresh) == []


def test_slo_throughput_parity_gated():
    fresh = copy.deepcopy(BASELINE)
    for row in fresh["tables"]["slo"]:
        if row["policy"] == "slo" and "." not in row["cls"]:
            row["queries_per_s"] = 100.0                # -31% > 20%
    violations = compare(BASELINE, fresh)
    assert len(violations) == 2
    assert all("throughput" in v for v in violations)
    assert compare(BASELINE, fresh, check_throughput=False) == []


# -------------------------------------------- fleet gate (ISSUE-10)
def test_missing_fleet_shard_row_fails():
    """A shard count silently dropping out of the fleet table — say
    the sweep stopped running N=4 — must fail the gate."""
    fresh = copy.deepcopy(BASELINE)
    del fresh["tables"]["fleet"][2]
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "fleet[shards=4]" in violations[0]
    assert "missing" in violations[0]


def test_fleet_hit_rate_drop_fails():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["fleet"][1]["hit_rate"] = 0.70      # -17pp > 5pp
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "fleet[shards=2]" in violations[0]
    assert "hit rate" in violations[0]


def test_fleet_bytes_growth_fails():
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["fleet"][0]["real_bytes"] = 1_000_000   # +27%
    violations = compare(BASELINE, fresh)
    assert len(violations) == 1
    assert "fleet[shards=1]" in violations[0]
    assert "bytes read" in violations[0]


def test_fleet_overread_fails_without_baseline():
    """The no-I/O-inflation ordering is a fresh-run invariant with no
    tolerance: an N=2 row reading even one byte more than the N=1 row
    fails, including on identical doctored documents."""
    doc = copy.deepcopy(BASELINE)
    doc["tables"]["fleet"][1]["real_bytes"] = 786_433
    violations = compare(doc, doc)
    assert len(violations) == 1
    assert "fleet[shards=2]" in violations[0]
    assert "sharding must not inflate I/O" in violations[0]


# ----------------------------------- gate-config tolerances (ISSUE-10)
def _args(**kw):
    import argparse
    return argparse.Namespace(**kw)


def test_gate_tolerances_default_config_argv_precedence(tmp_path):
    from benchmarks.check_regression import (BYTES_TOL, HIT_RATE_TOL,
                                             LATENCY_TOL,
                                             THROUGHPUT_TOL)
    # no config, no flags: module defaults
    tols = resolve_tolerances(_args(config=None))
    assert tols == {"hit_rate_tol": HIT_RATE_TOL,
                    "throughput_tol": THROUGHPUT_TOL,
                    "bytes_tol": BYTES_TOL,
                    "latency_tol": LATENCY_TOL}
    # a gate: section overrides defaults …
    cfg = tmp_path / "gate.yaml"
    cfg.write_text("gate:\n  throughput_tol: 0.6\n  latency_tol: 2.0\n")
    tols = resolve_tolerances(_args(config=str(cfg)))
    assert tols["throughput_tol"] == 0.6
    assert tols["latency_tol"] == 2.0
    assert tols["hit_rate_tol"] == HIT_RATE_TOL     # untouched knob
    # … and an explicit argv flag overrides the config
    tols = resolve_tolerances(_args(config=str(cfg),
                                    throughput_tol=0.33))
    assert tols["throughput_tol"] == 0.33
    assert tols["latency_tol"] == 2.0


def test_gate_config_rejects_unknown_keys(tmp_path):
    cfg = tmp_path / "gate.yaml"
    cfg.write_text("gate:\n  throughput_toll: 0.6\n")
    with pytest.raises(SystemExit, match="unknown gate key"):
        resolve_tolerances(_args(config=str(cfg)))


def test_checked_in_gate_config_loads():
    """The committed configs/bench_serve.yaml gate: section must parse
    and only loosen the wall-clock knobs (CI runner jitter), keeping
    the deterministic counters tight."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo, "configs", "bench_serve.yaml")
    tols = resolve_tolerances(_args(config=path))
    assert tols["throughput_tol"] >= 0.5
    assert tols["latency_tol"] >= 1.0
    assert tols["hit_rate_tol"] <= 0.10
    assert tols["bytes_tol"] <= 0.10


def test_cli_config_flag(tmp_path, capsys):
    """--config wires the gate: section end to end: a p99 growth that
    fails at module defaults passes under the loose CI tolerances."""
    fresh = copy.deepcopy(BASELINE)
    fresh["tables"]["latency"][1]["p99_ms"] = 95.0      # +73% > 50%
    bp, fp = tmp_path / "baseline.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(BASELINE))
    fp.write_text(json.dumps(fresh))
    cfg = tmp_path / "gate.yaml"
    cfg.write_text("gate:\n  latency_tol: 2.0\n")
    argv = ["--baseline", str(bp), "--fresh", str(fp)]
    assert main(argv) == 1
    assert main(argv + ["--config", str(cfg)]) == 0
    assert main(argv + ["--config", str(cfg),
                        "--latency-tol", "0.5"]) == 1
    capsys.readouterr()


# --------------------------------------------- schema drift (ISSUE-8)
def test_schema_is_v3_and_v2_baseline_demands_regeneration():
    """ISSUE-10 bumped the schema for the fleet table: the code must
    expect v3, and a v2-era baseline must stop the comparison with the
    loud regenerate-the-baseline violation."""
    from repro.obs.metrics import SCHEMA_VERSION
    assert SCHEMA_VERSION == 3
    base = copy.deepcopy(BASELINE)
    base["schema_version"] = 2
    fresh = copy.deepcopy(BASELINE)
    fresh["schema_version"] = 3
    violations = compare(base, fresh)
    assert violations
    assert all("schema drift" in v for v in violations)
    assert any("regenerate the baseline" in v for v in violations)



def test_schema_version_mismatch_fails_loudly():
    from repro.obs.metrics import SCHEMA_VERSION
    base = copy.deepcopy(BASELINE)
    base["schema_version"] = SCHEMA_VERSION
    fresh = copy.deepcopy(BASELINE)
    fresh["schema_version"] = SCHEMA_VERSION + 1
    violations = compare(base, fresh)
    assert len(violations) >= 1
    assert all("schema drift" in v for v in violations)
    # matching stamps compare normally
    fresh["schema_version"] = SCHEMA_VERSION
    assert compare(base, fresh) == []


def test_unstamped_fresh_document_fails_against_stamped_baseline():
    base = copy.deepcopy(BASELINE)
    base["schema_version"] = 1
    violations = compare(base, BASELINE)        # fresh has no stamp
    assert len(violations) == 1
    assert "schema drift" in violations[0]
    assert "regenerate the baseline" in violations[0]


def test_missing_field_reports_drift_not_keyerror():
    """A baseline row predating a field (old schema, no stamp) must
    produce a readable schema-drift violation, not a KeyError crash."""
    base = copy.deepcopy(BASELINE)
    del base["tables"]["latency"][0]["p99_ms"]
    violations = compare(base, BASELINE)
    assert len(violations) == 1
    assert "schema drift" in violations[0]
    assert "'p99_ms'" in violations[0]
    assert "regenerate the baseline" in violations[0]


@pytest.mark.parametrize("doctor,code", [(False, 0), (True, 1)])
def test_cli_exit_codes(tmp_path, capsys, doctor, code):
    baseline = copy.deepcopy(BASELINE)
    if doctor:
        baseline["tables"]["store"][0]["hit_rate"] = 0.99
    bp, fp = tmp_path / "baseline.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(baseline))
    fp.write_text(json.dumps(BASELINE))
    assert main(["--baseline", str(bp), "--fresh", str(fp)]) == code
    out = capsys.readouterr().out
    assert ("FAIL" in out) == doctor
