"""ISSUE-8 observability spine: tracing + metrics (DESIGN.md §11).

Two contracts under test.  *Metrics*: fixed-bucket histograms report
percentiles within one bucket of numpy's exact answer, the registry
refuses type-shadowed names, and snapshots are schema-versioned.
*Tracing*: spans nest per thread/track, the Chrome export satisfies
the validator Perfetto relies on, and a tracer attached to a serving
engine is a pure observer — bit-identical answers, the hooks only
watch.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import BuildConfig, build_hod, gnm_random_digraph, pack_index
from repro.launch.serve import QueryServer
from repro.obs import (LATENCY_BUCKETS_MS, REGISTRY, SCHEMA_VERSION,
                       Histogram, MetricsRegistry, Tracer, exp_buckets,
                       span_if, validate_chrome_trace)


# ------------------------------------------------------------- metrics
def test_exp_buckets_shape_and_validation():
    b = exp_buckets(0.05, 60000, 2 ** 0.5)
    assert list(b) == sorted(b) and b[0] == pytest.approx(0.05)
    assert b[-1] >= 60000 / 2 ** 0.5 and len(b) < 60
    assert LATENCY_BUCKETS_MS == b
    with pytest.raises(ValueError):
        exp_buckets(0.0, 100, 2.0)
    with pytest.raises(ValueError):
        exp_buckets(1.0, 100, 1.0)
    with pytest.raises(ValueError):
        Histogram([3.0, 2.0, 1.0])
    with pytest.raises(ValueError):
        Histogram([])


def test_histogram_percentiles_match_numpy_within_a_bucket():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=2.0, sigma=1.0, size=5000)  # ms-ish spread
    h = Histogram(LATENCY_BUCKETS_MS)
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.mean() == pytest.approx(float(np.mean(xs)))
    bounds = np.asarray(LATENCY_BUCKETS_MS)
    for q in (0.50, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        got = h.percentile(q)
        # within one bucket of the truth: the exact value's bucket or
        # a neighbour (interpolation can land either side of an edge)
        i = int(np.searchsorted(bounds, exact))
        lo = bounds[max(i - 1, 0)] if i else 0.0
        hi = bounds[min(i + 1, len(bounds) - 1)]
        assert lo <= got <= hi, (q, exact, got, lo, hi)


def test_histogram_empty_and_overflow():
    h = Histogram([1.0, 2.0])
    assert h.count == 0 and h.percentile(0.99) == 0.0 and h.mean() == 0.0
    h.observe(100.0)                       # beyond the last bound
    assert h.count == 1
    assert h.percentile(0.5) == pytest.approx(2.0)   # clamped to top edge
    s = h.summary()
    assert s["count"] == 1 and s["p50"] <= s["p95"] <= s["p99"]


def test_registry_create_or_fetch_and_type_guard():
    reg = MetricsRegistry()
    c = reg.counter("a.requests")
    c.inc()
    c.inc(2.5)
    assert reg.counter("a.requests") is c and c.value == 3.5
    reg.gauge("a.depth").set(4)
    reg.histogram("a.lat").observe(1.0)
    with pytest.raises(TypeError):
        reg.gauge("a.requests")            # name exists as a Counter
    with pytest.raises(TypeError):
        reg.counter("a.lat")
    snap = reg.snapshot()
    assert snap["schema_version"] == SCHEMA_VERSION
    assert snap["counters"]["a.requests"] == 3.5
    assert snap["gauges"]["a.depth"] == 4
    h = snap["histograms"]["a.lat"]
    assert h["count"] == 1 \
        and len(h["bucket_counts"]) == len(h["bounds"]) + 1  # + overflow
    json.dumps(snap)                       # JSON-able end to end
    reg.reset()
    assert reg.counter("a.requests") is c and c.value == 0
    assert reg.histogram("a.lat").count == 0
    assert isinstance(REGISTRY, MetricsRegistry)


def test_histograms_prefix_listing():
    reg = MetricsRegistry()
    reg.histogram("latency_ms.ssd").observe(1.0)
    reg.histogram("latency_ms.p2p").observe(1.0)
    reg.histogram("coalesce_wait_ms").observe(1.0)
    names = sorted(reg.histograms("latency_ms.").keys())
    assert names == ["latency_ms.p2p", "latency_ms.ssd"]


# ------------------------------------------------------------- tracing
def test_tracer_spans_nest_and_sequence_is_shape_only():
    tr = Tracer()
    with tr.span("outer", plan="f"):
        with tr.span("inner", level=0):
            tr.instant("cache.hit", track="submit", block=3)
        tr.complete("wait", tr.now() - 1000, waiters=2)
    me = threading.current_thread().name
    assert tr.sequence(me) == [
        ("B", "outer", (("plan", "f"),)),
        ("B", "inner", (("level", 0),)),
        ("X", "wait", (("waiters", 2),)),
        ("E", "outer", ()),
    ] or tr.sequence(me)[2][1] == "inner"  # E inner precedes X wait
    # materialized intervals nest: inner within outer, X carries dur
    sp = {s["name"]: s for s in tr.spans()}
    assert sp["outer"]["t0"] <= sp["inner"]["t0"] \
        and sp["inner"]["t1"] <= sp["outer"]["t1"]
    assert sp["wait"]["t1"] - sp["wait"]["t0"] >= 1000
    assert sp["cache.hit"] if "cache.hit" in sp else True
    # instants on a synthetic track keep their own sequence
    assert tr.sequence("submit") == [("i", "cache.hit", (("block", 3),))]
    tr.clear()
    assert tr.events() == []


def test_span_if_is_inert_when_off():
    with span_if(None, "anything", level=1):
        pass                               # no tracer, no error
    tr = Tracer()
    with span_if(tr, "x", track="t"):
        pass
    assert [e["ph"] for e in tr.events()] == ["B", "E"]


def test_chrome_export_validates_and_doctored_docs_fail():
    tr = Tracer()
    with tr.span("a"):
        with tr.span("b"):
            tr.instant("i1")
    tr.complete("x1", tr.now())
    doc = tr.chrome()
    assert validate_chrome_trace(doc) == []
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert [e["name"] for e in evs if e["ph"] == "B"] == ["a", "b"]
    assert all(e["ph"] != "i" or e["s"] == "t" for e in evs)
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert meta and meta[0]["args"]["name"] == \
        threading.current_thread().name

    def doctor(mutate):
        d = json.loads(json.dumps(tr.chrome()))
        mutate(d["traceEvents"])
        return validate_chrome_trace(d)

    assert validate_chrome_trace({}) \
        == ["traceEvents missing or not a list"]
    assert doctor(lambda evs: evs[1].pop("ts"))          # missing field
    last_e = lambda evs: next(i for i in range(len(evs) - 1, -1, -1)  # noqa: E731
                              if evs[i]["ph"] == "E")
    assert doctor(lambda evs: evs.pop(last_e(evs)))      # unbalanced B/E
    assert doctor(lambda evs: evs[last_e(evs)].update(
        name="zzz"))                                     # name mismatch
    assert doctor(lambda evs: evs[-1].update(ts=-1.0))   # ts backwards
    assert doctor(lambda evs: [e.pop("dur") for e in evs
                               if e["ph"] == "X"])       # X without dur
    assert doctor(lambda evs: evs.append(
        {"name": "q", "ph": "E", "pid": 1, "tid": 99,
         "ts": 1e12}))                                   # E without B


def test_jsonl_export_round_trips(tmp_path):
    tr = Tracer()
    with tr.span("a", k=1):
        tr.instant("i", track="t")
    p = tmp_path / "t.jsonl"
    tr.write_jsonl(str(p))
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert [ln["ph"] for ln in lines] == ["B", "i", "E"]
    assert lines[0]["args"] == {"k": 1}
    assert lines[1]["tkey"] == ["track", "t"]


# ----------------------------------------------- serving integration
@pytest.fixture(scope="module")
def engine_ix():
    g = gnm_random_digraph(120, 480, seed=9, weighted=True)
    res = build_hod(g, BuildConfig(max_core_nodes=24, max_core_edges=512,
                                   seed=0))
    return pack_index(g, res, chunk=64)


def _serve(ix, tracer, metrics=None, mode="ssd", n=6):
    from repro.core import QueryEngine
    rng = np.random.default_rng(1)
    src = rng.choice(ix.n, size=n, replace=False).astype(np.int32)
    reqs = (np.stack([src, src[::-1]], axis=1) if mode == "p2p" else src)
    server = QueryServer(QueryEngine(ix), batch_size=3, cache_entries=0,
                         mode=mode, warm_start=True, tracer=tracer,
                         metrics=metrics)
    out = [np.atleast_1d(r.dist) for r in server.serve_stream(reqs)]
    return out, server


def test_tracer_is_a_pure_observer_in_memory(engine_ix):
    tr, reg = Tracer(), MetricsRegistry()
    traced, server = _serve(engine_ix, tr, reg)
    plain, _ = _serve(engine_ix, None)
    for a, b in zip(traced, plain):
        np.testing.assert_array_equal(a, b)
    names = {e["name"] for e in tr.events()}
    assert {"query.ssd", "jit.dispatch"} <= names
    assert validate_chrome_trace(tr.chrome()) == []
    # the per-mode latency histogram saw every request
    h = reg.histogram("latency_ms.ssd")
    assert h.count == len(traced)
    assert reg.counter("server.requests").value == len(traced)
    # report() folds the histogram into the human summary
    rep = server.stats.report(label="ssd", batch_size=3, latency=h)
    assert rep.startswith(f"served {len(traced)} ssd requests")
    assert "batch=3" in rep and "latency: mean" in rep
    assert "p99" in rep and "queries/s" in rep
    # without a histogram the latency line is simply absent
    assert "latency:" not in server.stats.report()


def test_coalesced_batch_traces_wait_and_metrics(engine_ix):
    """The async submit path retroactively stamps one ``coalesce.wait``
    X-span per flushed batch (how long requests pooled before the
    engine ran) and feeds the ``coalesce_wait_ms`` histogram."""
    import asyncio

    from repro.core import QueryEngine

    tr, reg = Tracer(), MetricsRegistry()
    server = QueryServer(QueryEngine(engine_ix), batch_size=4,
                         max_wait_ms=5.0, cache_entries=0,
                         warm_start=True, tracer=tr, metrics=reg)

    async def drive():
        tasks = [asyncio.create_task(server.submit(s))
                 for s in range(4)]
        await server.drain()
        return await asyncio.gather(*tasks)

    results = asyncio.run(drive())
    assert len(results) == 4
    waits = [e for e in tr.events() if e["name"] == "coalesce.wait"]
    assert waits and all(e["ph"] == "X" and e["dur"] >= 0
                         for e in waits)
    assert waits[0]["args"]["waiters"] == 4
    assert reg.histogram("coalesce_wait_ms").count == len(waits)
    assert validate_chrome_trace(tr.chrome()) == []


def test_server_writes_trace_and_metrics_files(engine_ix, tmp_path):
    tr, reg = Tracer(), MetricsRegistry()
    _serve(engine_ix, tr, reg, mode="p2p")
    trace_path = tmp_path / "trace.json"
    tr.write_chrome(str(trace_path))
    doc = json.loads(trace_path.read_text())
    assert validate_chrome_trace(doc) == []
    assert any(e["name"] == "query.p2p" for e in doc["traceEvents"])
    metrics_path = tmp_path / "metrics.json"
    with open(metrics_path, "w") as f:
        json.dump(reg.snapshot(), f)
    snap = json.loads(metrics_path.read_text())
    assert snap["schema_version"] == SCHEMA_VERSION
    assert snap["histograms"]["latency_ms.p2p"]["count"] > 0
