"""repro.storage: block segment files, the bounded-byte page cache, and
store-backed streaming queries (DESIGN.md §6).

Covers the ISSUE-3 acceptance criteria: store round trips are bit-exact,
a streaming engine under a 5% cache budget answers bit-identically to
the in-memory engine, and the server's IOStats come from actual block
reads (cache misses), not the synthetic charge path.
"""
import os
import tempfile
import threading

import numpy as np
import pytest

from repro.core import (BuildConfig, QueryEngine, build_hod,
                        dijkstra_reference, gnm_random_digraph, pack_index)
from repro.core.index import FORMAT_VERSION, HoDIndex
from repro.launch.serve import QueryServer
from repro.storage import IndexStore, PageCache, StreamingQueryEngine

CFG = BuildConfig(max_core_nodes=32, max_core_edges=1024, seed=0)
PLANS = ("plan_f", "plan_b", "plan_core")


@pytest.fixture(scope="module")
def packed():
    g = gnm_random_digraph(150, 600, seed=4, weighted=True)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    return g, ix


@pytest.fixture(scope="module")
def store_dir(packed):
    _, ix = packed
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "store")
        ix.save_store(path, block_bytes=1024)
        yield path


# ------------------------------------------------------------- page cache
def _loader(payload: bytes):
    return lambda: payload


def test_pagecache_lru_eviction_order():
    cache = PageCache(capacity_bytes=3 * 100)
    for key in ("a", "b", "c"):
        cache.get(key, _loader(b"x" * 100))
    cache.get("a", _loader(b"!"))             # refresh a: b is now LRU
    cache.get("d", _loader(b"x" * 100))       # evicts b, not a
    assert cache.resident_keys() == ["c", "a", "d"]
    assert cache.stats.evictions == 1
    assert cache.stats.hits == 1 and cache.stats.misses == 4


def test_pagecache_clock_second_chance():
    cache = PageCache(capacity_bytes=3 * 100, policy="clock")
    for key in ("a", "b", "c"):
        cache.get(key, _loader(b"x" * 100))
    cache.get("a", _loader(b"!"))             # sets a's reference bit
    cache.get("d", _loader(b"x" * 100))       # a is spared, b evicted
    keys = cache.resident_keys()
    assert "a" in keys and "b" not in keys and "d" in keys
    assert cache.stats.evictions == 1


def test_pagecache_byte_budget_and_oversized_blocks():
    cache = PageCache(capacity_bytes=250)
    cache.get("a", _loader(b"x" * 100))
    cache.get("b", _loader(b"x" * 100))
    cache.get("big", _loader(b"x" * 300))     # larger than budget: uncached
    assert cache.resident_bytes <= 250
    assert "big" not in cache.resident_keys()
    assert cache.stats.peak_bytes <= 250
    assert cache.get("a", _loader(b"?")) == b"x" * 100   # still resident


def test_pagecache_budget_enforced_under_concurrent_readers():
    cache = PageCache(capacity_bytes=1000)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 64, size=(8, 200))
    errors = []

    def worker(i):
        try:
            for k in keys[i]:
                data = cache.get(int(k), _loader(bytes([k % 251]) * 100))
                assert data == bytes([k % 251]) * 100
                assert cache.resident_bytes <= 1000
        except Exception as exc:                       # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert cache.stats.peak_bytes <= 1000
    assert cache.stats.hits + cache.stats.misses == 8 * 200


def test_pagecache_zero_capacity_disables_caching():
    cache = PageCache(capacity_bytes=0)
    cache.get("a", _loader(b"x" * 10))
    cache.get("a", _loader(b"x" * 10))
    assert cache.stats.misses == 2 and cache.stats.hits == 0


# ------------------------------------------------------------ block store
def test_store_roundtrip_bitexact(packed, store_dir):
    _, ix = packed
    ix2 = HoDIndex.load(store_dir)            # dir -> load_store delegation
    assert ix2.format_version == FORMAT_VERSION == 5
    np.testing.assert_array_equal(ix.perm, ix2.perm)
    np.testing.assert_array_equal(ix.f_w, ix2.f_w)
    np.testing.assert_array_equal(ix.core_closure, ix2.core_closure)
    for field in PLANS:
        a, b = getattr(ix, field), getattr(ix2, field)
        for part in ("dst", "src_idx", "w", "assoc", "row_valid",
                     "level_mask"):
            np.testing.assert_array_equal(getattr(a, part),
                                          getattr(b, part))


def test_store_level_reads_match_plan_slices(packed, store_dir):
    _, ix = packed
    store = IndexStore(store_dir)
    try:
        for name in PLANS:
            plan = getattr(ix, name)
            assert store.n_real(name) == plan.n_real_levels
            for lvl in range(store.n_real(name)):
                dst, src, w, assoc, valid = store.read_level(name, lvl)
                np.testing.assert_array_equal(dst, plan.dst[lvl])
                np.testing.assert_array_equal(src, plan.src_idx[lvl])
                np.testing.assert_array_equal(w, plan.w[lvl])
                np.testing.assert_array_equal(assoc, plan.assoc[lvl])
                np.testing.assert_array_equal(valid, plan.row_valid[lvl])
    finally:
        store.close()


def test_store_rejects_garbage_segment(tmp_path, packed):
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024)
    seg = os.path.join(path, "plan_f.seg")
    with open(seg, "r+b") as f:
        f.write(b"NOTMAGIC")
    with pytest.raises(ValueError, match="not a HoD segment"):
        IndexStore(path)


def test_store_rejects_mismatched_device_block_size(store_dir):
    from repro.core.io_sim import BlockDevice
    with pytest.raises(ValueError, match="block size"):
        IndexStore(store_dir, device=BlockDevice(block_bytes=65536))


def test_store_scan_bytes_matches_plan_accounting(packed, store_dir):
    _, ix = packed
    store = IndexStore(store_dir)
    try:
        for sssp in (False, True):
            expect = (ix.plan_f.scan_bytes(include_assoc=sssp)
                      + ix.plan_b.scan_bytes(include_assoc=sssp)
                      + (ix.plan_core.scan_bytes(True) if sssp else 0)
                      + ix.core_closure.nbytes)
            assert store.scan_bytes(sssp=sssp) == expect
    finally:
        store.close()


# ------------------------------------------------------- streaming engine
def test_streaming_engine_bit_identical_at_5pct_cache(packed, store_dir):
    g, ix = packed
    probe = IndexStore(store_dir)
    budget = int(0.05 * probe.segment_bytes())
    probe.close()
    store = IndexStore(store_dir, cache=PageCache(budget))
    seng = StreamingQueryEngine(store)
    eng = QueryEngine(ix)
    try:
        sources = np.array([3, 1, 4, 15, 92], dtype=np.int32)
        np.testing.assert_array_equal(eng.ssd(sources), seng.ssd(sources))
        d_m, p_m = eng.sssp(sources)
        d_s, p_s = seng.sssp(sources)
        np.testing.assert_array_equal(d_m, d_s)
        np.testing.assert_array_equal(p_m, p_s)
        # real I/O happened and was metered through the device
        io = store.device.stats
        assert store.cache.stats.misses > 0
        assert io.bytes_seq + io.bytes_rand == store.cache.stats.bytes_read
        assert store.cache.stats.hit_rate() < 1.0
    finally:
        seng.close()


def test_shared_pagecache_never_crosses_stores(packed, store_dir, tmp_path):
    """Two stores sharing one PageCache (a single global memory budget)
    must not serve each other's blocks — keys are namespaced by the
    segment file's absolute path."""
    g, ix = packed
    g2 = gnm_random_digraph(90, 360, seed=77, weighted=True)
    ix2 = pack_index(g2, build_hod(g2, CFG), chunk=64)
    path2 = str(tmp_path / "store2")
    ix2.save_store(path2, block_bytes=1024)

    shared = PageCache()      # unbounded: maximizes cross-hit opportunity
    s1 = StreamingQueryEngine(IndexStore(store_dir, cache=shared),
                              prefetch=False)
    s2 = StreamingQueryEngine(IndexStore(path2, cache=shared),
                              prefetch=False)
    try:
        src1 = np.array([0, 5], dtype=np.int32)
        src2 = np.array([0, 5], dtype=np.int32)
        np.testing.assert_array_equal(QueryEngine(ix).ssd(src1),
                                      s1.ssd(src1))
        np.testing.assert_array_equal(QueryEngine(ix2).ssd(src2),
                                      s2.ssd(src2))
        # interleave to force both stores through the warm shared cache
        np.testing.assert_array_equal(QueryEngine(ix).ssd(src1),
                                      s1.ssd(src1))
    finally:
        s1.close()
        s2.close()


def test_streaming_engine_no_prefetch_same_answers(packed, store_dir):
    g, _ = packed
    seng = StreamingQueryEngine(IndexStore(store_dir), prefetch=False)
    try:
        sources = np.array([0, 7], dtype=np.int32)
        oracle = dijkstra_reference(g, sources)
        dist = seng.ssd(sources)
        for i in range(2):
            finite = np.isfinite(oracle[i])
            assert np.allclose(dist[i, : g.n][finite], oracle[i][finite],
                               rtol=1e-5)
    finally:
        seng.close()


def test_streaming_core_modes_match_inmemory(packed, store_dir):
    _, ix = packed
    sources = np.array([0, 5, 9], dtype=np.int32)
    for mode in ("closure", "bellman", "dijkstra"):
        seng = StreamingQueryEngine(IndexStore(store_dir), core_mode=mode)
        try:
            np.testing.assert_array_equal(
                QueryEngine(ix, core_mode=mode).ssd(sources),
                seng.ssd(sources))
        finally:
            seng.close()


# ------------------------------------------------------ store-backed server
def test_server_store_backed_matches_engine_and_meters_real_io(
        packed, store_dir):
    g, ix = packed
    probe = IndexStore(store_dir)
    budget = int(0.05 * probe.segment_bytes())
    probe.close()
    server = QueryServer(store_path=store_dir, cache_bytes=budget,
                         batch_size=8, cache_entries=0, warm_start=True)
    sources = np.arange(16, dtype=np.int32)
    try:
        results = server.serve_stream(sources)
    finally:
        server.close()
    direct = QueryEngine(ix).ssd(sources)
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.dist, direct[i])
    st = server.stats
    io = server.modeled_io()
    assert st.page_misses > 0 and st.page_hit_rate() < 1.0
    # IOStats reflect actual cache-miss reads, not the synthetic charge
    assert io.bytes_seq + io.bytes_rand == st.store_bytes_read
    assert len(server.batch_io) == st.batches
    assert sum(b.real_bytes for b in server.batch_io) == st.store_bytes_read


def test_server_rejects_engine_plus_store(packed, store_dir):
    _, ix = packed
    with pytest.raises(ValueError, match="not both"):
        QueryServer(QueryEngine(ix), store_path=store_dir)
    with pytest.raises(ValueError, match="engine or a store_path"):
        QueryServer()


def test_npz_load_closes_handle_and_accepts_mmap_mode(packed, tmp_path):
    _, ix = packed
    path = str(tmp_path / "ix.npz")
    ix.save(path)
    ix2 = HoDIndex.load(path, mmap_mode="r")
    np.testing.assert_array_equal(ix.perm, ix2.perm)
    np.testing.assert_array_equal(ix.plan_f.w, ix2.plan_f.w)
    # the NpzFile was closed on exit: loading is side-effect free enough
    # to re-open and even delete the file immediately (a leaked handle
    # keeps the zip open)
    os.unlink(path)


# ------------------------------------------------- scan-resistant caching
class _RecordingCache(PageCache):
    """Unbounded cache that records the block access trace (key, size)."""

    def __init__(self):
        super().__init__(None)
        self.trace = []

    def get(self, key, load, pin=False):
        loaded = []
        data = super().get(key, lambda: loaded.append(1) or load(),
                           pin=pin)
        self.trace.append((key, len(data)))
        return data


def _sweep_trace(store_dir):
    """The block trace of one full SSD sweep (forward + backward)."""
    rec = _RecordingCache()
    seng = StreamingQueryEngine(IndexStore(store_dir, cache=rec),
                                prefetch=False)
    try:
        seng.ssd(np.array([0], dtype=np.int32))
    finally:
        seng.close()
    return rec.trace


def _replay(policy, budget, trace):
    cache = PageCache(budget, policy=policy)
    for pass_rates in range(2):
        for key, size in trace:
            cache.get(key, lambda: b"\0" * size)
    return cache.stats.hit_rate()


def test_cyclic_sweep_regression_at_25pct_budget(packed, store_dir):
    """The tentpole's win, locked in by tier-1: one full sweep's block
    trace replayed twice at a 25% budget.

    * On the *deduplicated* trace (one access per distinct block — the
      PR-3 block-aligned layout's access pattern) LRU and CLOCK hit 0%:
      the classic cyclic-scan thrash the BENCH_serve rows documented.
      The scan-resistant ARC/2Q retain a frozen prefix and re-hit it.
    * On the real v4 affinity trace (adjacent levels share boundary
      blocks) every policy gets the intra-sweep hits, and ARC/2Q add
      cross-sweep retention on top of LRU.
    """
    from repro.storage import segment_bytes
    trace = _sweep_trace(store_dir)
    assert len(trace) > len(set(k for k, _ in trace)), \
        "affinity layout should make adjacent levels share blocks"
    budget = int(0.25 * segment_bytes(store_dir))

    # deduplicated trace = pure cyclic scan (the legacy access pattern)
    seen, pure = set(), []
    for key, size in trace:
        if key not in seen:
            seen.add(key)
            pure.append((key, size))
    assert _replay("lru", budget, pure) == 0.0      # documented baseline
    assert _replay("clock", budget, pure) == 0.0
    for policy in ("arc", "2q"):
        assert _replay(policy, budget, pure) > 0.0, policy

    # real affinity trace: scan-resistant policies beat LRU
    lru_rate = _replay("lru", budget, trace)
    for policy in ("arc", "2q"):
        rate = _replay(policy, budget, trace)
        assert rate > 0.0 and rate >= lru_rate, (policy, rate, lru_rate)


def test_affinity_layout_shrinks_segments(packed, tmp_path):
    """v4 compact slabs must strictly undercut the padded-rectangle
    envelope whenever a plan has padding rows (every real graph)."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024)
    shrank = False
    for name in PLANS:
        plan = getattr(ix, name)
        real_rows = int(plan.row_valid.sum())
        slots = plan.n_real_levels * plan.m_pad
        padded = slots * (4 + plan.k_fix * 12)
        seg = os.path.getsize(os.path.join(path, f"{name}.seg"))
        # header/footer overhead is ~2 blocks; only plans with a real
        # padding envelope must strictly undercut the rectangle
        if slots and real_rows < 0.8 * slots:
            assert seg < padded, (name, seg, padded)
            shrank = True
    assert shrank, "no plan exercised the compact layout"


def test_plan_core_segment_is_pinned_resident(packed, store_dir):
    """Segment-aware admission: plan_core blocks are pinned on first
    read, so a full plan_f scan can never evict them."""
    from repro.storage import segment_bytes
    budget = int(0.25 * segment_bytes(store_dir))
    store = IndexStore(store_dir, cache=PageCache(budget, policy="2q"))
    try:
        for lvl in range(store.n_real("plan_core")):
            store.read_level("plan_core", lvl)
        pinned = set(store.cache.pinned_keys())
        assert pinned, "plan_core blocks were not pinned"
        for _ in range(2):                      # two adversarial scans
            for lvl in range(store.n_real("plan_f")):
                store.read_level("plan_f", lvl)
        assert pinned <= set(store.cache.pinned_keys())
        # re-reading plan_core causes zero new misses
        before = store.cache.stats.misses
        for lvl in range(store.n_real("plan_core")):
            store.read_level("plan_core", lvl)
        assert store.cache.stats.misses == before
    finally:
        store.close()


def test_sssp_recon_pins_are_released(packed, store_dir):
    """The recon pin protocol must not leak leases: after an SSSP query
    only the sticky plan_core pins remain."""
    from repro.storage import segment_bytes
    budget = int(0.25 * segment_bytes(store_dir))
    store = IndexStore(store_dir, cache=PageCache(budget, policy="2q"))
    seng = StreamingQueryEngine(store, prefetch=False)
    try:
        seng.sssp(np.array([0, 3], dtype=np.int32))
        core_keys = set()
        for lvl in range(store.n_real("plan_core")):
            core_keys |= set(store.segments["plan_core"].level_keys(lvl))
        leftover = set(store.cache.pinned_keys()) - core_keys
        assert not leftover, f"leaked pin leases: {leftover}"
    finally:
        seng.close()


# ------------------------------------------------------ fault propagation
@pytest.mark.parametrize("prefetch", [False, True])
def test_corrupt_segment_read_raises_in_query_thread(packed, tmp_path,
                                                     prefetch):
    """A corrupt block must surface as an exception in the querying
    thread — including when the read happens on the prefetch thread —
    never as silent garbage distances."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024)
    seg = os.path.join(path, "plan_f.seg")
    # flip bytes in the middle of a data block (past the header block)
    with open(seg, "r+b") as f:
        f.seek(2 * 1024 + 100)
        f.write(b"\xde\xad\xbe\xef" * 8)
    seng = StreamingQueryEngine(IndexStore(path), prefetch=prefetch)
    try:
        with pytest.raises(ValueError, match="CRC mismatch"):
            seng.ssd(np.array([0], dtype=np.int32))
    finally:
        seng.close()


def test_abandoned_prefetch_future_is_drained(packed, store_dir):
    """If the consumer abandons a sweep mid-stream, the in-flight
    prefetch future is collected (no dangling read against a closed
    fd, no swallowed exception)."""
    seng = StreamingQueryEngine(IndexStore(store_dir), prefetch=True)
    try:
        gen = seng._levels("plan_f")
        next(gen)                   # level 0 consumed, level 1 in flight
        gen.close()                 # abandon: finally must drain cleanly
    finally:
        seng.close()


# --------------------------------------------------- v3/v4 segment compat
def _forge_v4_segment(path, plan, sentinel, block_bytes):
    """Replicate the PR-4 (v4) affinity segment writer: compact level
    slabs back-to-back at byte granularity, per-block CRCs in the
    footer, no codec frames."""
    import json as _json
    import struct as _struct
    import zlib as _zlib
    header_s = _struct.Struct("<8sIIIIIIIIQQ")
    n_real = plan.n_real_levels
    extents, slabs = [], []
    off = block_bytes
    for lvl in range(n_real):
        valid = plan.row_valid[lvl]
        m_real = int(valid.sum())
        assert valid[:m_real].all() and not valid[m_real:].any()
        assert (plan.dst[lvl, m_real:] == sentinel).all() and \
            (np.isinf(plan.w[lvl, m_real:])).all()
        sl = slice(0, m_real)
        slab = b"".join((
            np.ascontiguousarray(plan.dst[lvl, sl], np.int32).tobytes(),
            np.ascontiguousarray(plan.src_idx[lvl, sl],
                                 np.int32).tobytes(),
            np.ascontiguousarray(plan.w[lvl, sl], np.float32).tobytes(),
            np.ascontiguousarray(plan.assoc[lvl, sl],
                                 np.int32).tobytes()))
        extents.append([off, len(slab), m_real])
        slabs.append(slab)
        off += len(slab)
    data = b"".join(slabs)
    data += b"\0" * ((-len(data)) % block_bytes)
    n_blocks = len(data) // block_bytes
    crcs = [_zlib.crc32(data[i * block_bytes:(i + 1) * block_bytes])
            for i in range(n_blocks)]
    footer = _json.dumps({"extents": extents, "n_real": n_real,
                          "crcs": crcs}).encode()
    footer_off = block_bytes * (1 + n_blocks)
    header = header_s.pack(b"HODSEG04", 4, block_bytes, n_real,
                           plan.l_pad, plan.m_pad, plan.k_fix, sentinel,
                           0, footer_off, len(footer))
    with open(path, "wb") as f:
        f.write(header.ljust(block_bytes, b"\0"))
        f.write(data)
        f.write(footer)


def _forge_v3_segment(path, plan, sentinel, block_bytes):
    """Replicate the PR-3 (v3) block-aligned segment writer."""
    import json as _json
    import struct as _struct
    header_s = _struct.Struct("<8sIIIIIIIIQQ")
    m_pad, k_fix = plan.m_pad, plan.k_fix
    n_real = plan.n_real_levels
    payload = m_pad * (4 + 1) + m_pad * k_fix * (4 + 4 + 4)
    bpl = max(1, -(-payload // block_bytes))
    footer = _json.dumps({
        "extents": [[1 + lv * bpl, bpl, payload] for lv in range(n_real)],
        "n_real": n_real,
    }).encode()
    footer_off = block_bytes * (1 + n_real * bpl)
    header = header_s.pack(b"HODSEG03", 3, block_bytes, n_real,
                           plan.l_pad, m_pad, k_fix, sentinel, 0,
                           footer_off, len(footer))
    with open(path, "wb") as f:
        f.write(header.ljust(block_bytes, b"\0"))
        for lvl in range(n_real):
            slab = b"".join((
                np.ascontiguousarray(plan.dst[lvl], np.int32).tobytes(),
                np.ascontiguousarray(plan.row_valid[lvl],
                                     np.uint8).tobytes(),
                np.ascontiguousarray(plan.src_idx[lvl],
                                     np.int32).tobytes(),
                np.ascontiguousarray(plan.w[lvl], np.float32).tobytes(),
                np.ascontiguousarray(plan.assoc[lvl],
                                     np.int32).tobytes()))
            f.write(slab.ljust(bpl * block_bytes, b"\0"))
        f.write(footer)


def test_v3_block_aligned_segments_still_load(packed, tmp_path):
    """A store written by the PR-3 layout (block-aligned full-M_pad
    slabs, no CRCs) keeps loading bit-exactly through the v4 reader."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024)
    for name in PLANS:
        _forge_v3_segment(os.path.join(path, f"{name}.seg"),
                          getattr(ix, name), ix.n, 1024)
    ix2 = HoDIndex.load(path)
    for field in PLANS:
        a, b = getattr(ix, field), getattr(ix2, field)
        for part in ("dst", "src_idx", "w", "assoc", "row_valid",
                     "level_mask"):
            np.testing.assert_array_equal(getattr(a, part),
                                          getattr(b, part))
    sources = np.array([0, 7], dtype=np.int32)
    seng = StreamingQueryEngine(IndexStore(path), prefetch=False)
    try:
        np.testing.assert_array_equal(QueryEngine(ix).ssd(sources),
                                      seng.ssd(sources))
    finally:
        seng.close()


def test_v4_affinity_segments_still_load(packed, tmp_path):
    """A store written by the PR-4 layout (compact affinity slabs,
    footer CRCs, no codec frames) keeps loading bit-exactly through
    the v5 reader."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024)
    for name in PLANS:
        _forge_v4_segment(os.path.join(path, f"{name}.seg"),
                          getattr(ix, name), ix.n, 1024)
    ix2 = HoDIndex.load(path)
    for field in PLANS:
        a, b = getattr(ix, field), getattr(ix2, field)
        for part in ("dst", "src_idx", "w", "assoc", "row_valid",
                     "level_mask"):
            np.testing.assert_array_equal(getattr(a, part),
                                          getattr(b, part))


def test_format_compat_matrix_v1_to_v5(packed, tmp_path):
    """Every artifact generation next to a v5 store answers the same
    queries bit-identically: v1/v2 ``.npz`` files, v3 block-aligned and
    v4 affinity segments, and v5 ``raw``/``delta`` codec stores."""
    g, ix = packed
    sources = np.array([0, 7, 100], dtype=np.int32)
    want = QueryEngine(ix).ssd(sources)

    def check(ix_loaded):
        np.testing.assert_array_equal(
            QueryEngine(ix_loaded).ssd(sources), want)

    # v1/v2 monolithic .npz
    path = str(tmp_path / "ix.npz")
    ix.save(path)
    with np.load(path) as z:
        full = {k: z[k] for k in z.files if k != "format_version"}
    v1 = {k: v for k, v in full.items()
          if k != "k_cap" and not k.startswith(("pf_", "pb_", "pc_"))}
    np.savez_compressed(str(tmp_path / "v1.npz"), **v1)
    with pytest.warns(UserWarning, match="old-format"):
        check(HoDIndex.load(str(tmp_path / "v1.npz")))
    np.savez_compressed(str(tmp_path / "v2.npz"),
                        format_version=np.int64(2), **full)
    check(HoDIndex.load(str(tmp_path / "v2.npz")))

    # v3/v4/v5 stores (v3/v4 segments forged over a fresh store dir)
    for version, forge in ((3, _forge_v3_segment),
                           (4, _forge_v4_segment), (5, None)):
        sdir = str(tmp_path / f"store_v{version}")
        ix.save_store(sdir, block_bytes=1024)
        if forge is not None:
            for name in PLANS:
                forge(os.path.join(sdir, f"{name}.seg"),
                      getattr(ix, name), ix.n, 1024)
        check(HoDIndex.load(sdir))
        seng = StreamingQueryEngine(IndexStore(sdir), prefetch=False)
        try:
            np.testing.assert_array_equal(seng.ssd(sources), want)
        finally:
            seng.close()
    delta_dir = str(tmp_path / "store_v5_delta")
    ix.save_store(delta_dir, block_bytes=1024, codec="delta")
    check(HoDIndex.load(delta_dir))


# The hypothesis random-graph streaming-equivalence property lives in
# tests/test_hod_property.py (run everywhere via the hypsupport
# fallback), the policy conformance harness in
# tests/test_cache_policies.py.
