"""Differential oracle harness for every query mode (DESIGN.md §7).

Randomized directed, integer-weighted graphs run through both the
engine under test and the pure-Python Dijkstra oracle
(``tests/oracle.py``); agreement is asserted *exactly* — integer
weights make every distance a small integer, representable without
rounding in f32, f16, and the oracle's f64 alike.  Covered: full SSD
rows, SSSP tree validity, point-to-point, distance-threshold, k-nearest
nodes, and top-k closeness; in-memory and store-backed at 5% / 25%
page-cache budgets over the raw / delta / f16 codecs; plus the P2P
early-termination I/O guarantee and the O(1)-trace accounting of the
new mode bodies.
"""
import os

import numpy as np
import pytest

from hypsupport import given, settings, st
from oracle import ShortestPathOracle
from repro.core import (BuildConfig, QueryEngine, build_hod,
                        gnm_random_digraph, pack_index, topk_closeness)
from repro.core.index import node_levels
from repro.kernels.edge_relax import ops
from repro.storage import IndexStore, PageCache, StreamingQueryEngine

# A small pool of prebuilt graphs: strategies draw (pool index, query
# params), so randomized examples vary queries freely while index
# builds amortize across every property in the module.
POOL = ((40, 160, 1), (60, 300, 2), (90, 250, 3), (50, 450, 5))
CFG = BuildConfig(max_core_nodes=16, max_core_edges=512, seed=0)
_BUNDLES = {}


def bundle(idx: int):
    if idx not in _BUNDLES:
        n, m, seed = POOL[idx]
        g = gnm_random_digraph(n, m, seed=seed, weighted=True)
        ix = pack_index(g, build_hod(g, CFG), chunk=32)
        _BUNDLES[idx] = (g, ix, QueryEngine(ix), ShortestPathOracle(g))
    return _BUNDLES[idx]


graph_idx = st.integers(0, len(POOL) - 1)
query_seed = st.integers(0, 2**31 - 1)


def _nodes(rng: np.random.Generator, n: int, k: int) -> np.ndarray:
    return rng.integers(0, n, size=k).astype(np.int32)


# --------------------------------------------------------- in-memory modes
@settings(max_examples=10, deadline=None)
@given(graph_idx, query_seed)
def test_ssd_matches_oracle(idx, seed):
    g, _, eng, orc = bundle(idx)
    sources = _nodes(np.random.default_rng(seed), g.n, 4)
    dist = eng.ssd(sources)
    for i, s in enumerate(sources.tolist()):
        np.testing.assert_array_equal(dist[i, :g.n], orc.ssd(s))


@settings(max_examples=6, deadline=None)
@given(graph_idx, query_seed)
def test_sssp_trees_are_valid(idx, seed):
    g, _, eng, orc = bundle(idx)
    sources = _nodes(np.random.default_rng(seed), g.n, 3)
    dist, pred = eng.sssp(sources)
    for i, s in enumerate(sources.tolist()):
        orc.check_sssp(s, dist[i, :g.n], pred[i, :g.n])


@settings(max_examples=10, deadline=None)
@given(graph_idx, query_seed)
def test_p2p_matches_oracle(idx, seed):
    g, _, eng, orc = bundle(idx)
    rng = np.random.default_rng(seed)
    s, t = _nodes(rng, g.n, 6), _nodes(rng, g.n, 6)
    got = eng.p2p(s, t)
    want = [orc.p2p(a, b) for a, b in zip(s.tolist(), t.tolist())]
    np.testing.assert_array_equal(got, np.array(want, np.float32))


@settings(max_examples=10, deadline=None)
@given(graph_idx, query_seed, st.integers(0, 20))
def test_threshold_matches_oracle(idx, seed, d):
    g, _, eng, orc = bundle(idx)
    sources = _nodes(np.random.default_rng(seed), g.n, 4)
    got = eng.ssd_within(sources, float(d))
    for i, s in enumerate(sources.tolist()):
        np.testing.assert_array_equal(got[i, :g.n], orc.within(s, d))


@settings(max_examples=10, deadline=None)
@given(graph_idx, query_seed, st.integers(1, 12))
def test_knn_matches_oracle(idx, seed, k):
    g, _, eng, orc = bundle(idx)
    sources = _nodes(np.random.default_rng(seed), g.n, 4)
    nodes, dist = eng.knn(sources, k)
    for i, s in enumerate(sources.tolist()):
        wn, wd = orc.knn(s, k)
        np.testing.assert_array_equal(nodes[i], wn)
        np.testing.assert_array_equal(dist[i], np.array(wd, np.float32))


@settings(max_examples=6, deadline=None)
@given(graph_idx, st.integers(1, 12), query_seed)
def test_topk_closeness_matches_oracle(idx, k, seed):
    g, _, eng, orc = bundle(idx)
    tk = topk_closeness(eng, k, batch_size=16, seed=seed)
    want = orc.topk_closeness(k)
    assert tk.nodes.tolist() == [v for _, v in want]
    np.testing.assert_array_equal(tk.farness,
                                  np.array([f for f, _ in want]))


# ------------------------------------------------------- store-backed modes
@pytest.fixture(scope="module", params=["raw", "delta", "f16"])
def store_path(request, tmp_path_factory):
    _, ix, _, _ = bundle(1)
    path = os.path.join(tmp_path_factory.mktemp("oracle_store"),
                        f"store_{request.param}")
    ix.save_store(path, block_bytes=1024, codec=request.param)
    return path


@pytest.mark.parametrize("budget_frac", [0.05, 0.25])
def test_store_backed_modes_match_oracle(store_path, budget_frac):
    g, ix, eng, orc = bundle(1)
    from repro.storage import segment_logical_bytes
    budget = int(budget_frac * segment_logical_bytes(store_path))
    seng = StreamingQueryEngine(
        IndexStore(store_path, cache=PageCache(budget)))
    try:
        rng = np.random.default_rng(7)
        s, t = _nodes(rng, g.n, 4), _nodes(rng, g.n, 4)
        dist = seng.ssd(s)
        for i, src in enumerate(s.tolist()):
            np.testing.assert_array_equal(dist[i, :g.n], orc.ssd(src))
        np.testing.assert_array_equal(
            seng.p2p(s, t),
            np.array([orc.p2p(a, b)
                      for a, b in zip(s.tolist(), t.tolist())],
                     np.float32))
        within = seng.ssd_within(s, 9.0)
        for i, src in enumerate(s.tolist()):
            np.testing.assert_array_equal(within[i, :g.n],
                                          orc.within(src, 9.0))
        nn, nd = seng.knn(s, 6)
        for i, src in enumerate(s.tolist()):
            wn, wd = orc.knn(src, 6)
            np.testing.assert_array_equal(nn[i], wn)
            np.testing.assert_array_equal(nd[i],
                                          np.array(wd, np.float32))
        tk = topk_closeness(seng, 8, batch_size=16, seed=0)
        want = orc.topk_closeness(8)
        assert tk.nodes.tolist() == [v for _, v in want]
        np.testing.assert_array_equal(tk.farness,
                                      np.array([f for f, _ in want]))
    finally:
        seng.close()


def test_p2p_reads_fewer_bytes_than_full_sweep(tmp_path):
    """The meet-in-the-middle guarantee, measured: a store-backed P2P
    query's actual block reads undercut the same source's full SSD
    sweep, and disabling early termination never changes the answer."""
    g, ix, _, orc = bundle(1)
    path = os.path.join(tmp_path, "store")
    ix.save_store(path, block_bytes=1024)
    # capacity 0 disables caching: every level read hits the device, so
    # byte deltas compare sweep footprints exactly.
    store = IndexStore(path, cache=PageCache(0))
    seng = StreamingQueryEngine(store, prefetch=False)
    try:
        def bytes_of(fn):
            st0 = store.device.stats
            before = st0.bytes_seq + st0.bytes_rand
            out = fn()
            return out, (st0.bytes_seq + st0.bytes_rand - before)

        # endpoints at level > 0, so both halves provably skip levels
        lvl = node_levels(ix, np.arange(ix.n))[ix.perm]
        cand = np.nonzero((lvl > 0) & (lvl < ix.n_levels))[0]
        s = cand[:2].astype(np.int32)
        t = cand[-2:].astype(np.int32)
        full, ssd_bytes = bytes_of(lambda: seng.ssd(s))
        p2p, p2p_bytes = bytes_of(lambda: seng.p2p(s, t))
        p2p_ne, ne_bytes = bytes_of(
            lambda: seng.p2p(s, t, early_term=False))
        want = full[np.arange(2), t]
        np.testing.assert_array_equal(p2p, want)
        np.testing.assert_array_equal(p2p_ne, want)
        np.testing.assert_array_equal(
            want, [orc.p2p(a, b) for a, b in zip(s.tolist(), t.tolist())])
        assert p2p_bytes < ssd_bytes, (p2p_bytes, ssd_bytes)
        assert p2p_bytes <= ne_bytes
    finally:
        seng.close()


# --------------------------------------------------------- trace accounting
def test_new_modes_add_constant_traces():
    """P2P and threshold bodies ride the same single-scan executor: the
    relax-kernel trace count stays O(1) per mode, independent of the
    graph's level count (the guard that protects the static-shape plan
    design, test_serving.py's compile-count test extended to modes)."""
    counts, levels = [], []
    for idx in (0, 1):
        g, ix, _, _ = bundle(idx)
        eng = QueryEngine(ix)      # fresh engine: count its traces only
        ops.relax_bucketed.clear_cache()
        before = ops.TRACE_COUNT
        srcs = np.arange(4, dtype=np.int32)
        tgts = srcs + 1
        eng.ssd(srcs)
        eng.p2p(srcs, tgts)
        eng.ssd_within(srcs, 9.0)
        counts.append(ops.TRACE_COUNT - before)
        levels.append(ix.n_levels)
        before = ops.TRACE_COUNT   # steady state: repeats never retrace
        eng.p2p(srcs + 1, tgts)
        eng.ssd_within(srcs + 1, 5.0)
        assert ops.TRACE_COUNT == before
        assert eng._p2p_jit._cache_size() == 1
        assert eng._within_jit._cache_size() == 1
    assert levels[0] != levels[1], "pool graphs must differ in levels"
    # ssd + p2p + within share relax traces per [M_pad, K_fix] envelope;
    # a handful total, never one per level
    assert all(1 <= c <= 6 for c in counts), (counts, levels)
    assert all(c < lv for c, lv in zip(counts, levels))
