"""Property-test support: real ``hypothesis`` when installed, a small
deterministic fallback runner otherwise.

CI installs the dev extra, so properties there get real hypothesis —
full generation breadth, shrinking, and the deadline machinery.  In
environments without it (the perpetual "1 skipped" this replaces), the
fallback runs each property over a reduced, seeded sample of examples:
no shrinking, but the invariants are still exercised on every run
instead of being skipped wholesale.

Only the subset of the hypothesis API the suite uses is mirrored:
``given`` (positional strategies mapped to the trailing parameters, so
pytest fixtures keep working), ``settings(max_examples=, deadline=)``,
and ``strategies.integers/booleans/lists/composite``.
"""
from __future__ import annotations

try:                                    # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    #: fallback cap: properties declare CI-sized max_examples; without
    #: the real engine a reduced deterministic sample keeps tier-1 fast.
    FALLBACK_MAX_EXAMPLES = 8

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng: "random.Random"):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(sample)

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def sample(rng):
                    draw = lambda s: s.example(rng)
                    return fn(draw, *args, **kwargs)
                return _Strategy(sample)
            return build

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        """Record the example budget; deadline/health checks are the
        real engine's concern and are accepted-and-ignored here."""
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        """Map strategies onto the trailing positional parameters (the
        hypothesis convention), leaving leading pytest fixtures alone."""
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            n_fix = len(names) - len(strategies)
            fixture_params = list(sig.parameters.values())[:n_fix]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # pytest passes fixtures by keyword; bind any positional
                # args to names too, then fill the trailing (strategy)
                # parameters with drawn values.
                bound = dict(zip(names, args))
                bound.update(kwargs)
                declared = getattr(wrapper, "_hyp_max_examples",
                                   getattr(fn, "_hyp_max_examples", 10))
                n = min(declared, FALLBACK_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    for name, strat in zip(names[n_fix:], strategies):
                        bound[name] = strat.example(rng)
                    fn(**bound)

            wrapper.__signature__ = sig.replace(parameters=fixture_params)
            return wrapper
        return deco
