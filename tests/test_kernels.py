"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- tropical
@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (4, 7, 9), (8, 128, 128), (64, 130, 257), (128, 128, 384),
    (33, 65, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_tropical_matmul(m, k, n, dtype):
    from repro.kernels.tropical_matmul.ops import minplus, minplus_ref
    a = jnp.asarray(RNG.uniform(0, 10, (m, k)), dtype)
    b = jnp.asarray(RNG.uniform(0, 10, (k, n)), dtype)
    # inject +inf (unreachable) entries — absorbing element
    a = a.at[0, 0].set(jnp.inf)
    out = minplus(a, b)
    ref = minplus_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


# --------------------------------------------------------------- edge_relax
@pytest.mark.parametrize("s,n,m,k", [
    (1, 10, 3, 1), (4, 100, 37, 5), (8, 300, 128, 9), (3, 64, 200, 2),
])
def test_edge_relax(s, n, m, k):
    from repro.kernels.edge_relax.ops import relax_bucketed
    dist = jnp.asarray(RNG.uniform(0, 10, (s, n)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, n, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.uniform(0, 3, (m, k)), jnp.float32)
    if k > 1:  # padding lanes
        w = w.at[:, -1].set(jnp.inf)
    cur = jnp.asarray(RNG.uniform(0, 20, (s, m)), jnp.float32)
    a = relax_bucketed(dist, src, w, cur, use_pallas=True)
    b = relax_bucketed(dist, src, w, cur, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("s,n,m,k", [(4, 100, 37, 5), (3, 64, 200, 2)])
def test_edge_relax_row_validity_mask(s, n, m, k):
    """Masked (padding) rows of a scanned plan level pass ``cur`` through
    untouched, in both the Pallas kernel and the jnp oracle."""
    from repro.kernels.edge_relax.ops import relax_bucketed
    dist = jnp.asarray(RNG.uniform(0, 10, (s, n)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, n, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.uniform(0, 3, (m, k)), jnp.float32)
    cur = jnp.asarray(RNG.uniform(0, 20, (s, m)), jnp.float32)
    # row 0 is masked AND would win (zero weights): the mask must suppress it
    w = w.at[0].set(0.0)
    valid = jnp.asarray(RNG.random(m) < 0.6).at[0].set(False)
    a = relax_bucketed(dist, src, w, cur, row_valid=valid, use_pallas=True)
    b = relax_bucketed(dist, src, w, cur, row_valid=valid, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    inval = ~np.asarray(valid)
    np.testing.assert_array_equal(np.asarray(a)[:, inval],
                                  np.asarray(cur)[:, inval])


# ------------------------------------------------------------ embedding_bag
@pytest.mark.parametrize("v,d,b,k", [
    (10, 8, 3, 2), (50, 24, 9, 6), (100, 128, 32, 4), (7, 64, 17, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag(v, d, b, k, dtype):
    from repro.kernels.embedding_bag.ops import bag_sum
    tab = jnp.asarray(RNG.normal(size=(v, d)), dtype)
    ids = jnp.asarray(RNG.integers(0, v, (b, k)), jnp.int32)
    mask = jnp.asarray(RNG.random((b, k)) < 0.7)
    a = bag_sum(tab, ids, mask, use_pallas=True)
    b_ = bag_sum(tab, ids, mask, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b_, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


# ------------------------------------------------------------- flash_decode
@pytest.mark.parametrize("b,h,kh,dh,smax,kv_len,blk", [
    (1, 4, 4, 16, 64, 1, 32),
    (2, 8, 2, 16, 96, 17, 32),
    (2, 8, 8, 32, 128, 128, 64),
    (1, 16, 4, 64, 256, 200, 128),
])
def test_flash_decode(b, h, kh, dh, smax, kv_len, blk):
    from repro.kernels.flash_decode.ops import flash_decode, flash_decode_ref
    q = jnp.asarray(RNG.normal(size=(b, h, dh)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(b, smax, kh, dh)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(b, smax, kh, dh)), jnp.float32)
    a = flash_decode(q, kc, vc, kv_len, block_k=blk, use_pallas=True)
    r = flash_decode_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=2e-5)


def test_flash_decode_bf16_cache():
    from repro.kernels.flash_decode.ops import flash_decode, flash_decode_ref
    q = jnp.asarray(RNG.normal(size=(2, 8, 32)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.bfloat16)
    vc = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.bfloat16)
    a = flash_decode(q, kc, vc, 100, block_k=64)
    r = flash_decode_ref(q, kc, vc, 100)
    np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                               rtol=2e-2, atol=2e-2)


def test_minplus_matches_core_search():
    """The Pallas tropical matmul plugs into QueryEngine (use_pallas=True)
    and must give identical SSD results."""
    from repro.core import (BuildConfig, QueryEngine, build_hod,
                            gnm_random_digraph, pack_index)
    g = gnm_random_digraph(150, 600, seed=9)
    res = build_hod(g, BuildConfig(max_core_nodes=32, max_core_edges=1024))
    ix = pack_index(g, res, chunk=64)
    srcs = np.array([0, 75], dtype=np.int32)
    d_ref = QueryEngine(ix, use_pallas=False).ssd(srcs)
    d_pal = QueryEngine(ix, use_pallas=True).ssd(srcs)
    np.testing.assert_allclose(d_ref, d_pal, rtol=1e-6)
