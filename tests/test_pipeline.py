"""ISSUE-7 read pipeline: queue-depth-N async block reads with
off-thread decompression (DESIGN.md §6).

The design invariant under test everywhere here is *submit-time
determinism*: every cache-state transition (hit/miss/eviction/pin/byte
counters) happens on the query thread when a level is submitted, in
the exact block order the synchronous path uses, so queue depth can
change only *when* payload bytes materialize — never which blocks are
read, what the answers are, or who gets charged.
"""
import os
import tempfile
import threading

import numpy as np
import pytest

from repro.core import BuildConfig, build_hod, gnm_random_digraph, pack_index
from repro.storage import (IndexStore, PageCache, PendingBlock,
                           StreamingQueryEngine, segment_bytes)

CFG = BuildConfig(max_core_nodes=32, max_core_edges=1024, seed=0)


@pytest.fixture(scope="module")
def packed():
    g = gnm_random_digraph(150, 600, seed=4, weighted=True)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    return g, ix


@pytest.fixture(scope="module")
def store_dir(packed):
    _, ix = packed
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "store")
        ix.save_store(path, block_bytes=1024, codec="delta")
        yield path


def _engine(store_dir, budget_frac=0.25, **kw):
    budget = int(budget_frac * segment_bytes(store_dir))
    store = IndexStore(store_dir,
                       cache=PageCache(budget, policy="2q"))
    return StreamingQueryEngine(store, **kw)


# -------------------------------------------------- PendingBlock admission
def test_begin_fill_admits_placeholder_and_coalesces():
    cache = PageCache(capacity_bytes=1000)
    holder, owner = cache.begin_fill("k", size=100, disk_bytes=40)
    assert owner and isinstance(holder, PendingBlock)
    assert len(holder) == 100
    # a second filler sees the in-flight placeholder as a hit: no
    # double admission, no double charge
    again, owner2 = cache.begin_fill("k", size=100, disk_bytes=40)
    assert again is holder and not owner2
    st = cache.stats
    assert (st.misses, st.hits) == (1, 1)
    assert st.bytes_read == 40 and st.bytes_filled == 100

    # a concurrent get() blocks until the owner completes the fill
    got = []
    t = threading.Thread(
        target=lambda: got.append(cache.get("k", lambda: b"!")))
    t.start()
    holder.set(b"x" * 100)
    t.join(timeout=5)
    assert got == [b"x" * 100]
    assert cache.stats.hits == 2          # the waiter hit the placeholder


def test_begin_fill_failed_fill_is_discarded_and_reraises():
    cache = PageCache(capacity_bytes=1000)
    holder, owner = cache.begin_fill("k", size=100, disk_bytes=100)
    assert owner
    boom = ValueError("CRC mismatch in block 7")
    cache.discard("k", holder)
    holder.fail(boom)
    with pytest.raises(ValueError, match="CRC mismatch"):
        holder.wait()
    # the key is gone: the next reader re-loads instead of hitting the
    # poisoned placeholder
    assert "k" not in cache.resident_keys()
    assert cache.get("k", lambda: b"y" * 100) == b"y" * 100


def test_discard_ignores_replaced_entry():
    """discard() is identity-matched: it must not evict a *different*
    (newer) entry that reused the key."""
    cache = PageCache(capacity_bytes=1000)
    holder, _ = cache.begin_fill("k", size=100, disk_bytes=100)
    cache.discard("k", holder)
    cache.get("k", lambda: b"z" * 100)      # fresh, real entry
    cache.discard("k", holder)              # stale handle: no-op
    assert "k" in cache.resident_keys()


# ------------------------------------------------------ pin_frac plumbing
def test_pin_frac_ctor_validation_and_gauge():
    with pytest.raises(ValueError):
        PageCache(1000, pin_frac=1.5)
    with pytest.raises(ValueError):
        PageCache(1000, pin_frac=-0.1)

    cache = PageCache(1000, pin_frac=0.0)   # pinning disabled
    cache.get("a", lambda: b"x" * 100, pin=True)
    assert cache.pinned_keys() == []
    assert cache.stats.pinned_bytes == 0

    cache = PageCache(1000, pin_frac=1.0)
    cache.get("a", lambda: b"x" * 100, pin=True)
    assert cache.pinned_keys() == ["a"]
    assert cache.stats.pinned_bytes == 100
    cache.unpin("a")
    assert cache.stats.pinned_bytes == 0


def test_index_store_pin_frac_plumbs_and_conflicts(store_dir):
    store = IndexStore(store_dir, pin_frac=0.25)
    try:
        assert store.cache.pin_frac == 0.25
    finally:
        store.close()
    with pytest.raises(ValueError):
        IndexStore(store_dir, cache=PageCache(1000), pin_frac=0.25)


# ------------------------------------------------- depth-N determinism
def _cache_counters(store):
    st = store.cache.stats
    return (st.hits, st.misses, st.bytes_read, st.bytes_filled,
            st.evictions)


@pytest.mark.parametrize("depth", [2, 8])
def test_cache_sequence_identical_across_depths(packed, store_dir, depth):
    """Hit/miss/eviction/byte counters are decided at submit time in
    block order, so every queue depth reproduces depth 1 exactly."""
    sources = np.array([0, 3, 7], dtype=np.int32)
    outs = {}
    for d in (1, depth):
        seng = _engine(store_dir, queue_depth=d)
        try:
            seng.ssd(sources)
            seng.ssd(sources)       # a warm pass exercises the hit path
            outs[d] = _cache_counters(seng.store)
        finally:
            seng.close()
    assert outs[depth] == outs[1]


def test_answers_bitidentical_pipeline_vs_sync(packed, store_dir):
    sources = np.array([0, 3, 7, 11], dtype=np.int32)
    targets = sources[::-1].copy()
    seng = _engine(store_dir, queue_depth=4, decode_workers=2)
    sync = _engine(store_dir, prefetch=False)
    try:
        np.testing.assert_array_equal(seng.ssd(sources),
                                      sync.ssd(sources))
        dp, pp = seng.sssp(sources)
        ds, ps = sync.sssp(sources)
        np.testing.assert_array_equal(dp, ds)
        np.testing.assert_array_equal(pp, ps)
        np.testing.assert_array_equal(seng.p2p(sources, targets),
                                      sync.p2p(sources, targets))
        nn, nd = seng.knn(sources, 5)
        sn, sd = sync.knn(sources, 5)
        np.testing.assert_array_equal(nn, sn)
        np.testing.assert_array_equal(nd, sd)
    finally:
        seng.close()
        sync.close()


def test_pipeline_stats_live_and_resettable(packed, store_dir):
    seng = _engine(store_dir, queue_depth=4)
    try:
        ps = seng.pipeline_stats()
        assert ps is not None
        seng.ssd(np.array([0], dtype=np.int32))
        assert ps.levels > 0 and ps.submitted >= ps.levels
        assert ps.ttfl_s > 0.0
        assert ps.stall_model_s >= 0.0 and ps.stall_wall_s >= 0.0
        ps.reset()
        assert ps.levels == 0 and ps.ttfl_s == 0.0
    finally:
        seng.close()
    assert _engine(store_dir, prefetch=False).pipeline_stats() is None


# ---------------------------------------------------- fault propagation
def test_decode_worker_crc_error_raises_in_query_thread(packed, tmp_path):
    """A corrupt frame is detected on a *decode-pool* thread at depth 4;
    the error must surface in the querying thread, and the poisoned
    placeholder must not stay resident."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024, codec="delta")
    seg = os.path.join(path, "plan_f.seg")
    with open(seg, "r+b") as f:
        f.seek(2 * 1024 + 100)
        f.write(b"\xde\xad\xbe\xef" * 8)
    seng = StreamingQueryEngine(IndexStore(path), queue_depth=4,
                                decode_workers=2)
    try:
        with pytest.raises(ValueError, match="CRC mismatch"):
            seng.ssd(np.array([0], dtype=np.int32))
        # the failure is repeatable, not one-shot: the bad block was
        # discarded, so a retry re-reads and re-raises instead of
        # hitting a stuck placeholder
        with pytest.raises(ValueError, match="CRC mismatch"):
            seng.ssd(np.array([0], dtype=np.int32))
    finally:
        seng.close()


def test_abandon_mid_pipeline_drains_without_leaking(packed, store_dir):
    """Abandoning a sweep with queue_depth levels in flight must wait
    out their fills (no incomplete placeholder left resident — a later
    hit would block forever) and leak no pin leases."""
    seng = _engine(store_dir, queue_depth=4)
    try:
        gen = seng._levels("plan_f", pin=True)
        next(gen)                    # level 0 reaped, 3 more in flight
        gen.close()                  # finally-block drains the tickets
        # every resident entry materialized (wait() below cannot hang)
        for ns_key in list(seng.store.cache.resident_keys()):
            data = seng.store.cache.get(ns_key, lambda: b"")
            assert not isinstance(data, PendingBlock)
        # the abandoned sweep's pin leases are returned by unpin_level
        # bookkeeping on the store side; a full query still answers
        # bit-identically afterwards
        for lvl in range(seng.store.n_real("plan_f")):
            seng.store.unpin_level("plan_f", lvl)
        sources = np.array([0, 5], dtype=np.int32)
        sync = _engine(store_dir, prefetch=False)
        try:
            np.testing.assert_array_equal(seng.ssd(sources),
                                          sync.ssd(sources))
        finally:
            sync.close()
    finally:
        seng.close()


# ------------------------------------------------- tracing determinism
def test_trace_sequence_identical_across_depths(packed, store_dir):
    """ISSUE-8: tracing routes pipelined submit-side events to the
    synthetic "submit" track and reap/relax spans to the query thread,
    so the span/attr *sequence* on both tracks is a function of the
    query alone — identical across runs and queue depths (depth moves
    timestamps, never the shape)."""
    from repro.obs import Tracer

    sources = np.array([0, 3, 7], dtype=np.int32)
    me = threading.current_thread().name
    seqs = {}
    for run, depth in (("d1a", 1), ("d1b", 1), ("d4", 4)):
        tr = Tracer()
        seng = _engine(store_dir, queue_depth=depth)
        seng.set_tracer(tr)
        try:
            seng.ssd(sources)
            seng.ssd(sources)       # warm pass: hit-path events too
        finally:
            seng.close()
        seqs[run] = (tr.sequence(me), tr.sequence("submit"))
    assert seqs["d1a"][0], "no query-thread events traced"
    assert seqs["d1a"][1], "no submit-track events traced"
    assert seqs["d1a"] == seqs["d1b"], \
        "two identical runs traced different sequences"
    assert seqs["d4"] == seqs["d1a"], \
        "queue depth changed the traced span/attr sequence"


# --------------------------------------------------- stats-reset racing
def test_atomic_reset_keeps_cache_device_consistent(packed, store_dir):
    """ISSUE-8 satellite: ``reset_stats(also=[device.reset])`` zeroes
    the cache counters and the device meter under the one cache lock,
    and every miss charges the device inside that same lock at submit
    time — so a reset can never land *between* a cache-stat update and
    its device charge.  Hammer resets while depth-4 sweeps run, then
    check the bytes invariant holds exactly at quiescence."""
    seng = _engine(store_dir, queue_depth=4, decode_workers=2)
    sync = _engine(store_dir, prefetch=False)
    cache = seng.store.cache
    dev = seng.store.device
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            cache.reset_stats(also=[dev.reset])

    t = threading.Thread(target=hammer, name="reset-hammer")
    sources = np.array([0, 3, 7, 11], dtype=np.int32)
    try:
        expect = sync.ssd(sources)
        t.start()
        for _ in range(3):
            np.testing.assert_array_equal(seng.ssd(sources), expect)
    finally:
        stop.set()
        t.join(timeout=10)
        sync.close()
    try:
        # hammer stopped: reset once more, run a quiescent sweep — the
        # device's metered bytes must equal the cache's miss reads
        # exactly (no charge ever separated from its counter update)
        cache.reset_stats(also=[dev.reset])
        np.testing.assert_array_equal(seng.ssd(sources), expect)
        st, io = cache.stats, dev.stats
        assert st.bytes_read == io.bytes_seq + io.bytes_rand, \
            f"cache read {st.bytes_read} B but device metered " \
            f"{io.bytes_seq + io.bytes_rand} B after the reset race"
        assert st.misses > 0, "reset evicted data (it must zero stats " \
            "only)"
    finally:
        seng.close()


def test_queue_depth_validation(store_dir):
    with pytest.raises(ValueError):
        _engine(store_dir, queue_depth=0)
    with pytest.raises(ValueError):
        _engine(store_dir, queue_depth=4, decode_workers=0)
