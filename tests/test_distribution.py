"""Distribution layer: shard_map paths == unmapped math at world size 1,
rule resolution, mesh-context training, dry-run cell builders."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.shardlib as sl
from repro.launch.mesh import (make_smoke_mesh, rules_gnn, rules_recsys,
                               rules_serve_lm, rules_train_lm)

KEY = jax.random.PRNGKey(0)


def _smoke_rules(mesh):
    r = rules_train_lm(mesh)
    r.update(rules_gnn(mesh))
    r.update({"rows": "model", "cand": ("data",)})
    return r


def test_logical_spec_resolution():
    mesh = make_smoke_mesh()
    with sl.axis_rules(mesh, rules_train_lm(mesh)):
        assert sl.logical_to_spec("batch", "seq", None) == P(("data",),
                                                             "model")
        assert sl.logical_to_spec(None, None) == P()
        # duplicate mesh axis use is dropped for later names
        assert sl.logical_to_spec("heads", "mlp") == P("model")


def test_moe_block_matches_unmapped():
    from repro.models.layers import MoEConfig, moe_block
    rng = np.random.default_rng(0)
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16)
    d = 32
    x = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(d, 8)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(8, d, 16)), jnp.float32) * 0.1
    wu = jnp.asarray(rng.normal(size=(8, d, 16)), jnp.float32) * 0.1
    wd = jnp.asarray(rng.normal(size=(8, 16, d)), jnp.float32) * 0.1
    y0, aux0 = moe_block(x, router, wg, wu, wd, cfg)
    mesh = make_smoke_mesh()
    with sl.axis_rules(mesh, _smoke_rules(mesh)):
        y1, aux1 = jax.jit(
            lambda *a: moe_block(*a, cfg))(x, router, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
    np.testing.assert_allclose(float(aux0), float(aux1), rtol=1e-5)


def test_attention_decode_matches_unmapped():
    from repro.models.layers import attention_decode
    rng = np.random.default_rng(0)
    b, h, kh, dh, s = 2, 4, 2, 16, 64
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, kh, dh)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, kh, dh)), jnp.float32)
    o0, k0, v0 = attention_decode(q, kc, vc, kn, vn, jnp.int32(40))
    mesh = make_smoke_mesh()
    with sl.axis_rules(mesh, rules_serve_lm(mesh, b)):
        o1, k1, v1 = jax.jit(attention_decode)(q, kc, vc, kn, vn,
                                               jnp.int32(40))
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(k0), np.asarray(k1), atol=1e-6)


def test_embedding_lookup_matches_unmapped():
    from repro.models.dlrm import embedding_lookup
    rng = np.random.default_rng(0)
    tables = jnp.asarray(rng.normal(size=(4, 64, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 64, (6, 4)), jnp.int32)
    y0 = embedding_lookup(tables, ids)
    mesh = make_smoke_mesh()
    with sl.axis_rules(mesh, rules_recsys(mesh, 6)):
        y1 = jax.jit(embedding_lookup)(tables, ids)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)
    # oracle
    ref = jnp.stack([tables[t][ids[:, t]] for t in range(4)], axis=1)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(ref), atol=1e-6)


def test_lm_train_step_under_mesh():
    """The full train step (loss+grads+adamw) runs under a live mesh
    context with the same rules the dry-run uses."""
    from repro.launch.steps import build_cell
    mesh = make_smoke_mesh()
    with sl.axis_rules(mesh, rules_train_lm(mesh)):
        cell = build_cell("granite-moe-1b-a400m", "train_4k", smoke=True)
        state, metrics = jax.jit(cell.fn, donate_argnums=(0,))(*cell.args)
    assert np.isfinite(float(metrics["loss"]))


def test_cells_have_consistent_sharding_trees():
    """Abstract cells: in_shardings tree must match the args tree."""
    from repro.launch.steps import build_cell, rules_for
    mesh = make_smoke_mesh()
    for arch, shape in [("glm4-9b", "train_4k"),
                        ("qwen3-moe-30b-a3b", "decode_32k"),
                        ("gcn-cora", "ogb_products"),
                        ("dlrm-rm2", "retrieval_cand")]:
        with sl.axis_rules(mesh, rules_for(arch, shape, mesh)):
            cell = build_cell(arch, shape, smoke=False)
            jax.tree.structure(cell.args)  # must not raise
            # structures align leaf-for-leaf
            a_leaves = jax.tree.leaves(cell.args)
            s_leaves = jax.tree.leaves(
                cell.in_shardings,
                is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            assert len(a_leaves) == len(s_leaves), (arch, shape)


def test_gradient_compression_identity_at_world_one():
    from repro.optim import compressed_mean
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    out = compressed_mean(grads, KEY, dp_axes=(), scheme="none")
    for k in grads:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(grads[k]))
    out8 = compressed_mean(grads, KEY, dp_axes=(), scheme="int8")
    for k in grads:
        err = np.abs(np.asarray(out8[k]) - np.asarray(grads[k])).max()
        scale = np.abs(np.asarray(grads[k])).max() / 127.0
        assert err <= scale * 1.01   # within one quantization step
