"""The roofline's HLO analyzer: loop trip counts, collectives, dot flops."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def test_scan_flops_multiplied_by_trip_count():
    m = 128

    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((m, m), jnp.float32)).compile()
    r = analyze(c.as_text())
    expected = 10 * 2 * m ** 3
    assert abs(r["flops"] - expected) / expected < 1e-3


def test_nested_loops_multiply():
    m = 64

    def f(x):
        def inner(c, _):
            return c @ x, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((m, m), jnp.float32)).compile()
    r = analyze(c.as_text())
    expected = 15 * 2 * m ** 3
    assert abs(r["flops"] - expected) / expected < 1e-3


def test_collectives_in_loops_counted():
    m = 128
    mesh = jax.make_mesh((1,), ("x",))

    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "x") + c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    from repro.shardlib import _SHARD_MAP_KW, _shard_map
    with mesh:
        g = _shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                       out_specs=jax.sharding.PartitionSpec(),
                       **_SHARD_MAP_KW)
        c = jax.jit(g).lower(
            jax.ShapeDtypeStruct((m, m), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["collectives"]["all-reduce"] == 7 * m * m * 4


def test_dot_flops_with_batch_dims():
    b, m, k, n = 4, 32, 48, 16

    def f(x, y):
        return jnp.einsum("bmk,bkn->bmn", x, y)

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((b, m, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k, n), jnp.float32)).compile()
    r = analyze(c.as_text())
    expected = 2 * b * m * k * n
    assert abs(r["flops"] - expected) / expected < 0.05


def test_bytes_by_class_present():
    def f(x):
        return jax.nn.relu(x @ x)
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert set(r["bytes_by_class"]) == {
        "dot", "elementwise", "gather_scatter", "copy_layout", "collective",
        "other"}
    assert r["bytes_by_class"]["dot"] > 0
