"""QueryServer: batching, padding, LRU cache, async coalescing, modeled
I/O amortization, and the sharded batch axis."""
import asyncio

import numpy as np
import pytest

import repro.shardlib as sl
from repro.core import (BuildConfig, QueryEngine, build_hod,
                        dijkstra_reference, gnm_random_digraph, pack_index)
from repro.launch.serve import QueryServer

CFG = BuildConfig(max_core_nodes=32, max_core_edges=1024, seed=0)


@pytest.fixture(scope="module")
def engine():
    g = gnm_random_digraph(150, 600, seed=4)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix)
    eng._graph = g  # stash for oracle checks
    return eng


def test_serve_stream_matches_engine(engine):
    server = QueryServer(engine, batch_size=8)
    sources = np.array([3, 1, 4, 1, 5, 9, 2, 6, 5, 3], dtype=np.int32)
    results = server.serve_stream(sources)
    assert [r.source for r in results] == sources.tolist()
    direct = engine.ssd(np.unique(sources))
    by_src = {int(s): direct[i] for i, s in enumerate(np.unique(sources))}
    for r in results:
        np.testing.assert_array_equal(r.dist, by_src[r.source])
    assert server.stats.requests == 10


def test_padding_keeps_one_compiled_shape(engine):
    server = QueryServer(engine, batch_size=16)
    server.serve_stream(np.array([1, 2, 3], dtype=np.int32))
    assert server.stats.batches == 1
    assert server.stats.padded_slots == 13   # 16 - 3 real sources


def test_lru_cache_hits_and_eviction(engine):
    server = QueryServer(engine, batch_size=4, cache_entries=4)
    server.serve_stream(np.array([0, 1, 2, 3], dtype=np.int32))
    assert server.stats.cache_hits == 0
    server.serve_stream(np.array([0, 1, 2, 3], dtype=np.int32))
    assert server.stats.cache_hits == 4      # all repeats served from cache
    assert server.stats.batches == 1         # no second engine call
    # 4 new sources evict the old entries (capacity 4)
    server.serve_stream(np.array([10, 11, 12, 13], dtype=np.int32))
    server.serve_stream(np.array([0], dtype=np.int32))
    assert server.stats.batches == 3         # 0 was evicted -> re-executed


def test_cache_disabled(engine):
    server = QueryServer(engine, batch_size=2, cache_entries=0)
    server.serve_stream(np.array([5, 5], dtype=np.int32))
    server.serve_stream(np.array([5, 5], dtype=np.int32))
    assert server.stats.cache_hits == 0
    assert server.stats.batches == 2


def test_modeled_io_amortizes_with_batch_size(engine):
    sources = np.arange(32, dtype=np.int32)
    per_query = {}
    for b in (1, 8):
        server = QueryServer(engine, batch_size=b, cache_entries=0)
        server.serve_stream(sources)
        io = server.modeled_io()
        per_query[b] = io.modeled_seconds() / server.stats.requests
        assert io.rand_blocks == 0           # scans only — the paper's point
    assert per_query[8] < per_query[1] / 4   # near-linear amortization


def test_sssp_mode_returns_predecessors(engine):
    server = QueryServer(engine, batch_size=4, sssp=True)
    results = server.serve_stream(np.array([0, 7], dtype=np.int32))
    dist, pred = engine.sssp(np.array([0, 7], dtype=np.int32))
    for i, r in enumerate(results):
        assert r.pred is not None
        np.testing.assert_array_equal(r.dist, dist[i])
        np.testing.assert_array_equal(r.pred, pred[i])


def test_async_submit_coalesces(engine):
    server = QueryServer(engine, batch_size=4, max_wait_ms=5.0)

    async def drive():
        tasks = [asyncio.create_task(server.submit(s))
                 for s in [1, 2, 3, 4, 5, 6]]
        await server.drain()
        return await asyncio.gather(*tasks)

    results = asyncio.run(drive())
    assert server.stats.requests == 6
    # first four coalesced into one full batch; the rest drained
    assert results[0].batched_with == 4
    direct = engine.ssd(np.array([1, 2, 3, 4, 5, 6], dtype=np.int32))
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.dist, direct[i])


def test_async_partial_flush_on_timeout(engine):
    server = QueryServer(engine, batch_size=64, max_wait_ms=1.0)

    async def drive():
        return await server.submit(9)   # alone: must not wait forever

    r = asyncio.run(drive())
    assert r.source == 9 and server.stats.batches == 1
    np.testing.assert_array_equal(
        r.dist, engine.ssd(np.array([9], dtype=np.int32))[0])


def test_async_cache_hit_resolves_immediately(engine):
    server = QueryServer(engine, batch_size=2, max_wait_ms=1.0)

    async def drive():
        a = await server.submit(11)
        b = await server.submit(11)
        return a, b

    a, b = asyncio.run(drive())
    assert not a.cached and b.cached
    np.testing.assert_array_equal(a.dist, b.dist)


def test_async_poisoned_batch_fails_all_riders(engine):
    """An out-of-range source must fail its whole batch with an exception
    instead of stranding co-rider futures forever."""
    server = QueryServer(engine, batch_size=2, max_wait_ms=1.0)

    async def drive():
        good = asyncio.create_task(server.submit(1))
        bad = asyncio.create_task(server.submit(10**9))   # >> n
        return await asyncio.gather(good, bad, return_exceptions=True)

    results = asyncio.run(asyncio.wait_for(drive(), timeout=30))
    assert all(isinstance(r, Exception) for r in results)


def test_serve_stream_io_bytes_sum_matches_device(engine):
    """Per-request io_bytes shares (with duplicates uncharged) must sum to
    exactly what the BlockDevice metered."""
    server = QueryServer(engine, batch_size=4, cache_entries=0)
    results = server.serve_stream(np.array([5, 5, 6, 7], dtype=np.int32))
    assert sum(r.io_bytes for r in results) == \
        pytest.approx(server._sweep_bytes)
    assert server.modeled_io().bytes_seq == server._sweep_bytes


def test_sharded_batch_axis_matches_unsharded(engine):
    """Under a mesh with rules binding "batch", sweeps run data-parallel
    over sources and must produce identical distances (world size 1)."""
    import jax

    sources = np.array([0, 3, 5, 7], dtype=np.int32)
    plain = engine.ssd(sources)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    eng2 = QueryEngine(engine.index)
    with sl.axis_rules(mesh, {"batch": "data"}):
        sharded = eng2.ssd(sources)
    np.testing.assert_array_equal(plain, sharded)


def test_compile_count_independent_of_levels():
    """Regression guard for the SweepPlan executor's O(1) trace claim:
    a use_pallas=True SSD query traces the bucketed relax once per sweep
    direction — NOT once per level — so the trace count must not change
    between graphs with different level counts, and a repeat query with
    the same batch shape must compile nothing at all."""
    from repro.core import build_hod, grid_road_graph, pack_index
    from repro.kernels.edge_relax import ops

    counts, levels = [], []
    for side in (7, 14):
        g = grid_road_graph(side, seed=0)
        res = build_hod(g, CFG)
        ix = pack_index(g, res, chunk=64)
        eng = QueryEngine(ix, use_pallas=True)
        ops.relax_bucketed.clear_cache()   # isolate this engine's traces
        before = ops.TRACE_COUNT
        eng.ssd(np.arange(4, dtype=np.int32))
        counts.append(ops.TRACE_COUNT - before)
        levels.append(ix.n_levels)
        before = ops.TRACE_COUNT           # steady state: no retrace
        eng.ssd(np.arange(4, dtype=np.int32) + 1)
        assert ops.TRACE_COUNT == before
        assert eng._ssd_jit._cache_size() == 1
    assert levels[0] != levels[1], "graphs must differ in level count"
    # at most one relax trace per sweep direction (forward/backward plans
    # with identical [M_pad, K_fix] envelopes dedupe to a single trace);
    # the pre-plan executor traced once per LEVEL (~n_levels_f+n_levels_b)
    assert all(1 <= c <= 2 for c in counts), (counts, levels)
    assert all(c < lv for c, lv in zip(counts, levels))


def test_warm_start_compiles_at_construction(engine):
    server = QueryServer(engine, batch_size=4, warm_start=True)
    assert server.stats.batches == 0      # warmup stats were reset
    results = server.serve_stream(np.array([1, 2, 3, 4], dtype=np.int32))
    assert len(results) == 4 and server.stats.batches == 1
    np.testing.assert_array_equal(
        results[0].dist, engine.ssd(np.array([1], dtype=np.int32))[0])


def test_server_results_match_oracle(engine):
    g = engine._graph
    sources = np.array([2, 40, 77], dtype=np.int32)
    server = QueryServer(engine, batch_size=3)
    results = server.serve_stream(sources)
    oracle = dijkstra_reference(g, sources)
    for r, orc in zip(results, oracle):
        finite = np.isfinite(orc)
        assert np.allclose(r.dist[:g.n][finite], orc[finite], rtol=1e-5)


def test_knn_mode_serves_nodes_and_distances(engine):
    """--mode knn answers carry [k] node ids + distances that match the
    engine's knn rows exactly, through both the execute path and the
    LRU row cache (QueryResult.nodes must survive the round trip)."""
    k = 5
    server = QueryServer(engine, batch_size=4, mode="knn", knn_k=k,
                         cache_entries=8)
    sources = np.array([3, 1, 4, 1], dtype=np.int32)
    want_nodes, want_dist = engine.knn(np.unique(sources), k)
    by_src = {int(s): (want_nodes[i], want_dist[i])
              for i, s in enumerate(np.unique(sources))}
    for results in (server.serve_stream(sources),
                    server.serve_stream(sources)):   # 2nd pass: LRU hits
        for r in results:
            wn, wd = by_src[r.source]
            assert r.pred is None
            assert r.nodes.shape == r.dist.shape == (k,)
            np.testing.assert_array_equal(r.nodes, wn)
            np.testing.assert_array_equal(r.dist, wd)
    assert server.stats.cache_hits == 4
    assert server.stats.batches == 1     # repeats never re-executed
