"""Per-block segment codecs (format v5, DESIGN.md §6).

Covers the ISSUE-5 acceptance criteria at the codec layer:

* encode/decode identity for every lossless path (``raw`` everywhere,
  ``delta`` everywhere, ``f16`` on id spans) — property-tested over
  random block payloads, span layouts, and block boundaries;
* the documented ``f16`` eps policy: narrowed weights within
  ``F16_EPS_REL`` relative error, out-of-policy weights bit-exact;
* store-level conformance: a ``delta`` store answers SSD/SSSP
  **bit-identically** to raw/in-memory, an ``f16`` store within eps,
  and decompress-on-fill accounting (cache budgets decompressed bytes,
  device/``bytes_read`` meter compressed bytes).
"""
import os

import numpy as np
import pytest

from hypsupport import given, settings, st
from repro.core import (BuildConfig, QueryEngine, build_hod,
                        gnm_random_digraph, pack_index)
from repro.storage import (IndexStore, PageCache, StreamingQueryEngine,
                           segment_bytes)
from repro.storage.codecs import (CODEC_IDS, F16_EPS_REL, KIND_F32,
                                  KIND_I32, KIND_RAW, block_spans,
                                  decode_block, encode_block, level_spans,
                                  vint_decode, vint_encode)

CFG = BuildConfig(max_core_nodes=32, max_core_edges=1024, seed=0)


@pytest.fixture(scope="module")
def packed():
    g = gnm_random_digraph(150, 600, seed=4, weighted=True)
    res = build_hod(g, CFG)
    return g, pack_index(g, res, chunk=64)


# ----------------------------------------------------------------- varints
def test_varint_roundtrip_extremes():
    vals = np.array([0, 1, -1, 127, -128, 2**31 - 1, -2**31,
                     2**32 - 1, -(2**32) + 1], np.int64)
    out = vint_decode(vint_encode(vals), vals.size)
    np.testing.assert_array_equal(out, vals)


def test_varint_empty_and_malformed():
    assert vint_encode(np.empty(0, np.int64)) == b""
    np.testing.assert_array_equal(vint_decode(b"", 0),
                                  np.empty(0, np.int64))
    with pytest.raises(ValueError):
        vint_decode(b"\x00\x00", 1)       # trailing terminator
    with pytest.raises(ValueError):
        vint_decode(b"\x80", 1)           # unterminated value
    with pytest.raises(ValueError):
        vint_decode(b"\x00", 2)           # too few values


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=0,
                max_size=200))
def test_varint_roundtrip_property(vals):
    arr = np.asarray(vals, np.int64)
    deltas = np.diff(arr, prepend=np.int64(0))
    out = vint_decode(vint_encode(deltas), deltas.size)
    np.testing.assert_array_equal(out, deltas)


# --------------------------------------------------------------- span maps
def test_level_spans_cover_slab_exactly():
    m, k = 7, 3
    length = 4 * m + 3 * 4 * m * k
    spans = level_spans(100, length, m, k)
    assert spans[0][1] == 100 and spans[-1][2] == 100 + length
    for (_, _, e), (_, s, _) in zip(spans, spans[1:]):
        assert e == s
    kinds = [s[0] for s in spans]
    assert kinds == [KIND_I32, KIND_I32, KIND_F32, KIND_I32]
    # fallback layout stays untyped; empty levels produce nothing
    assert level_spans(100, length, -1, k) == [(KIND_RAW, 100,
                                                 100 + length)]
    assert level_spans(100, 0, 0, k) == []


def test_block_spans_word_phase_at_unaligned_boundaries():
    """A block boundary that splits an i32 word must shed the fragments
    as raw so each block still decodes alone."""
    spans = [(KIND_I32, 10, 50), (KIND_F32, 50, 90)]
    # block [0, 32): i32 words at 10+4i -> last whole word ends at 46>32
    bs = block_spans(spans, 0, 32)
    assert bs[0] == (KIND_RAW, 0, 10)
    assert (KIND_I32, 10, 30) in bs           # 5 whole words
    assert bs[-1] == (KIND_RAW, 30, 32)       # split word -> raw edge
    # coverage is exact and gap-free for any cut, and the bisect fast
    # path (precomputed starts, the cache-miss path) agrees exactly
    starts = [s for _, s, _ in spans]
    for lo, hi in ((0, 32), (32, 64), (64, 96), (0, 96), (33, 61)):
        cover = block_spans(spans, lo, hi)
        assert cover == block_spans(spans, lo, hi, starts=starts)
        assert cover[0][1] == 0 and cover[-1][2] == hi - lo
        for (_, _, e), (_, s, _) in zip(cover, cover[1:]):
            assert e == s


@st.composite
def _block_case(draw):
    """Random payload + span layout + block size."""
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    n_spans = draw(st.integers(1, 5))
    spans, parts, off = [], [], draw(st.integers(0, 9))
    parts.append(rng.bytes(off))
    start = off
    for _ in range(n_spans):
        kind = (KIND_I32, KIND_F32, KIND_RAW)[draw(st.integers(0, 2))]
        if kind == KIND_RAW:
            nb = draw(st.integers(0, 40))
            parts.append(rng.bytes(nb))
        else:
            n = draw(st.integers(0, 30))
            nb = 4 * n
            if kind == KIND_I32:
                lo = draw(st.integers(-5, 5)) * 100
                parts.append(np.sort(rng.integers(
                    lo, lo + 2000, n)).astype("<i4").tobytes())
            else:
                parts.append((rng.random(n).astype("<f4") * 50).tobytes())
        if nb:
            spans.append((kind, start, start + nb))
        start += nb
    payload = b"".join(parts)
    block = draw(st.integers(16, 96))
    return payload, spans, block


@settings(max_examples=40, deadline=None)
@given(_block_case())
def test_codec_roundtrip_property(case):
    """Random blocks × all codecs: lossless codecs reproduce the bytes
    exactly; f16 reproduces non-weight bytes exactly and weights within
    the documented eps."""
    payload, spans, block = case
    pad = (-len(payload)) % block
    payload += b"\0" * pad
    for codec in ("raw", "delta", "f16"):
        out = bytearray()
        for lo in range(0, len(payload), block):
            chunk = payload[lo:lo + block]
            bs = block_spans(spans, lo, lo + block)
            cid, blob = encode_block(codec, chunk, bs)
            assert len(blob) <= len(chunk)      # raw fallback bounds it
            out += decode_block(cid, blob, bs, len(chunk))
        out = bytes(out)
        if codec == "f16":
            mism = [i for i in range(len(payload))
                    if out[i] != payload[i]]
            for kind, s, e in spans:
                if kind != KIND_F32:
                    assert not [i for i in mism if s <= i < e]
            for kind, s, e in spans:
                if kind == KIND_F32:
                    w0 = np.frombuffer(payload[s:e], "<f4")
                    w1 = np.frombuffer(out[s:e], "<f4")
                    assert (np.abs(w1 - w0)
                            <= F16_EPS_REL * np.abs(w0) + 1e-12).all()
        else:
            assert out == payload, codec


def test_unknown_codec_and_corrupt_frames_raise():
    payload = np.arange(16, dtype="<i4").tobytes()
    spans = [(KIND_I32, 0, len(payload))]
    with pytest.raises(ValueError, match="unknown codec"):
        encode_block("zstd", payload, spans)
    with pytest.raises(ValueError, match="unknown frame codec_id"):
        decode_block(99, payload, spans, len(payload))
    cid, blob = encode_block("delta", payload, spans)
    with pytest.raises(ValueError):
        decode_block(cid, blob[:-2], spans, len(payload))
    with pytest.raises(ValueError, match="length mismatch"):
        decode_block(CODEC_IDS["raw"], payload[:-4], spans,
                       len(payload))


# --------------------------------------------------------- store conformance
@pytest.mark.parametrize("codec", ["delta", "f16"])
def test_codec_store_serves_correctly(packed, tmp_path, codec):
    """SSD/SSSP from a codec store: bit-identical under ``delta``
    (lossless), within the documented eps under ``f16``."""
    _, ix = packed
    raw_dir, c_dir = str(tmp_path / "raw"), str(tmp_path / codec)
    ix.save_store(raw_dir, block_bytes=1024)
    ix.save_store(c_dir, block_bytes=1024, codec=codec)
    assert segment_bytes(c_dir) < segment_bytes(raw_dir)

    eng = QueryEngine(ix)
    sources = np.array([3, 1, 4, 15, 92], dtype=np.int32)
    budget = int(0.25 * segment_bytes(raw_dir))
    store = IndexStore(c_dir, cache=PageCache(budget, policy="2q"))
    seng = StreamingQueryEngine(store)
    try:
        dist = seng.ssd(sources)
        if codec == "delta":
            np.testing.assert_array_equal(eng.ssd(sources), dist)
            d_m, p_m = eng.sssp(sources)
            d_s, p_s = seng.sssp(sources)
            np.testing.assert_array_equal(d_m, d_s)
            np.testing.assert_array_equal(p_m, p_s)
        else:
            # per-edge narrowing error <= eps compounds along a path of
            # at most n relaxations: a loose multiple of eps bounds it
            assert np.allclose(dist, eng.ssd(sources), rtol=50 *
                               F16_EPS_REL, equal_nan=True)
        # decompress-on-fill accounting: the device and bytes_read
        # meter compressed bytes, fills meter decompressed bytes
        cs = store.cache.stats
        io = store.device.stats
        assert io.bytes_seq + io.bytes_rand == cs.bytes_read
        assert cs.bytes_filled > cs.bytes_read
        assert cs.bytes_filled == cs.misses * 1024
    finally:
        seng.close()


def test_codec_store_same_budget_same_hit_sequence(packed, tmp_path):
    """The logical block space is codec-independent, so at equal
    decompressed budgets the raw and delta stores see the identical
    hit/miss sequence — compression only changes bytes moved."""
    _, ix = packed
    raw_dir, d_dir = str(tmp_path / "raw"), str(tmp_path / "delta")
    ix.save_store(raw_dir, block_bytes=1024)
    ix.save_store(d_dir, block_bytes=1024, codec="delta")
    budget = int(0.25 * segment_bytes(raw_dir))
    sources = np.array([0, 7, 33], dtype=np.int32)
    stats = {}
    for name, path in (("raw", raw_dir), ("delta", d_dir)):
        store = IndexStore(path, cache=PageCache(budget, policy="2q"))
        seng = StreamingQueryEngine(store, prefetch=False)
        try:
            seng.ssd(sources)
        finally:
            seng.close()
        stats[name] = store.cache.stats
    assert stats["raw"].hits == stats["delta"].hits
    assert stats["raw"].misses == stats["delta"].misses
    assert stats["raw"].bytes_filled == stats["delta"].bytes_filled
    assert stats["delta"].bytes_read < stats["raw"].bytes_read


def test_segment_logical_bytes_is_codec_independent(packed, tmp_path):
    """The cache-budget denominator must not shrink with the codec:
    ``segment_logical_bytes`` (decompressed footprint) is identical for
    raw and delta stores of the same index, while ``segment_bytes``
    (on-disk) shrinks."""
    from repro.storage import segment_logical_bytes
    _, ix = packed
    raw_dir, d_dir = str(tmp_path / "raw"), str(tmp_path / "delta")
    ix.save_store(raw_dir, block_bytes=1024)
    ix.save_store(d_dir, block_bytes=1024, codec="delta")
    assert segment_logical_bytes(raw_dir) == segment_logical_bytes(d_dir)
    assert segment_bytes(d_dir) < segment_bytes(raw_dir)
    # the logical footprint is the data region: within header/footer +
    # frame-header overhead of the raw on-disk size
    assert (0.8 * segment_bytes(raw_dir) < segment_logical_bytes(raw_dir)
            <= segment_bytes(raw_dir))


def test_corrupt_codec_frame_raises_in_query_thread(packed, tmp_path):
    """Bit flips inside a compressed frame must fail the frame CRC on
    the next cache miss, not decode to garbage."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024, codec="delta")
    seg = os.path.join(path, "plan_f.seg")
    with open(seg, "r+b") as f:
        f.seek(1024 + 40)                   # inside the first frame
        f.write(b"\xde\xad\xbe\xef")
    seng = StreamingQueryEngine(IndexStore(path), prefetch=False)
    try:
        with pytest.raises(ValueError, match="CRC mismatch"):
            seng.ssd(np.array([0], dtype=np.int32))
    finally:
        seng.close()
