"""Property-based HoD correctness (random graphs vs the Dijkstra oracle).

Runs under real ``hypothesis`` when installed (the CI/dev-extra path:
full generation breadth + shrinking) and under the deterministic
fallback runner in ``tests/hypsupport.py`` otherwise — the properties
execute either way instead of skipping.  The ``deadline=None``
settings mark the slow properties: each example builds an index and
jit-compiles, far beyond hypothesis's default per-example deadline.
"""
import numpy as np

from hypsupport import given, settings, st
from repro.core import (BuildConfig, QueryEngine, build_hod,  # noqa: E402
                        dijkstra_reference, from_edges)


@st.composite
def random_graphs(draw):
    n = draw(st.integers(8, 60))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.integers(1, 9, m).astype(np.float64)
    keep = src != dst
    return n, src[keep], dst[keep], w[keep], seed


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_property_hod_matches_dijkstra(data):
    n, src, dst, w, seed = data
    if src.size == 0:
        return
    g = from_edges(n, src, dst, w)
    cfg = BuildConfig(max_core_nodes=8, max_core_edges=256, seed=seed % 7)
    res = build_hod(g, cfg)
    from repro.core import pack_index
    ix = pack_index(g, res, chunk=32)
    sources = np.array([0, n // 2, n - 1], dtype=np.int32)
    oracle = dijkstra_reference(g, sources)
    d = QueryEngine(ix).ssd(sources)[:, :n]
    finite = np.isfinite(oracle)
    assert np.allclose(d[finite], oracle[finite], rtol=1e-5)
    assert np.all(np.isinf(d[~finite]))


@settings(max_examples=15, deadline=None)
@given(random_graphs(), st.booleans())
def test_property_plan_executor_ssd_sssp_matches_dijkstra(data, use_pallas):
    """The SweepPlan executor (both kernels) answers SSD exactly like the
    Dijkstra oracle, and its SSSP predecessors unfold into length-correct
    paths — on arbitrary random digraphs, which include isolated nodes
    (empty sweep levels) and unreachable targets."""
    n, src, dst, w, seed = data
    g = from_edges(n, src, dst, w)
    cfg = BuildConfig(max_core_nodes=8, max_core_edges=256, seed=seed % 7)
    res = build_hod(g, cfg)
    from repro.core import pack_index
    ix = pack_index(g, res, chunk=32)
    sources = np.array([0, n - 1], dtype=np.int32)
    oracle = dijkstra_reference(g, sources)
    eng = QueryEngine(ix, use_pallas=use_pallas)
    d = eng.ssd(sources)[:, :n]
    finite = np.isfinite(oracle)
    assert np.allclose(d[finite], oracle[finite], rtol=1e-5)
    assert np.all(np.isinf(d[~finite]))

    dist, pred = eng.sssp(sources)
    adj = {}
    for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for i, s in enumerate(sources.tolist()):
        for t in range(n):
            if not np.isfinite(oracle[i, t]) or t == s:
                assert t == s or pred[i, t] == -1
                continue
            cur, total, hops = t, 0.0, 0
            while cur != s:
                p = int(pred[i, cur])
                assert p >= 0 and (p, cur) in adj, (s, t, cur)
                total += adj[(p, cur)]
                cur = p
                hops += 1
                assert hops <= n
            assert np.isclose(total, oracle[i, t], rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(random_graphs())
def test_property_save_load_query_equivalence(tmp_path_factory, data):
    """save → load → query answers bit-identically to the in-memory
    index (the persisted plan IS the executed layout)."""
    n, src, dst, w, seed = data
    g = from_edges(n, src, dst, w)
    res = build_hod(g, BuildConfig(max_core_nodes=8, max_core_edges=256))
    from repro.core import pack_index
    from repro.core.index import HoDIndex
    ix = pack_index(g, res, chunk=32)
    path = str(tmp_path_factory.mktemp("fmt") / "ix.npz")
    ix.save(path)
    ix2 = HoDIndex.load(path)
    sources = np.array([0, n // 2], dtype=np.int32)
    np.testing.assert_array_equal(QueryEngine(ix).ssd(sources),
                                  QueryEngine(ix2).ssd(sources))


@settings(max_examples=6, deadline=None)
@given(random_graphs())
def test_property_streaming_store_matches_inmemory(tmp_path_factory, data):
    """A store-backed streaming engine under a tiny page-cache budget
    answers SSD and SSSP bit-identically to the in-memory SweepPlan
    executor — on arbitrary random digraphs (empty levels, unreachable
    targets, all-core corners included)."""
    from repro.core import pack_index
    from repro.storage import IndexStore, PageCache, StreamingQueryEngine

    n, src, dst, w, seed = data
    g = from_edges(n, src, dst, w)
    res = build_hod(g, BuildConfig(max_core_nodes=8, max_core_edges=256))
    ix = pack_index(g, res, chunk=32)
    path = str(tmp_path_factory.mktemp("store") / "store")
    ix.save_store(path, block_bytes=512)
    store = IndexStore(path, cache=PageCache(2048))
    seng = StreamingQueryEngine(store, prefetch=False)
    try:
        sources = np.array([0, n // 2, n - 1], dtype=np.int32)
        eng = QueryEngine(ix)
        np.testing.assert_array_equal(eng.ssd(sources), seng.ssd(sources))
        d_m, p_m = eng.sssp(sources)
        d_s, p_s = seng.sssp(sources)
        np.testing.assert_array_equal(d_m, d_s)
        np.testing.assert_array_equal(p_m, p_s)
    finally:
        seng.close()


@settings(max_examples=10, deadline=None)
@given(random_graphs())
def test_property_shortcut_lengths_never_shorter(data):
    """Augmentation soundness: added shortcuts can only match (never beat)
    true distances — the invariant behind §4.1's 'retaining e is safe'."""
    n, src, dst, w, seed = data
    if src.size == 0:
        return
    g = from_edges(n, src, dst, w)
    res = build_hod(g, BuildConfig(max_core_nodes=8, max_core_edges=256))
    oracle = dijkstra_reference(g, np.arange(n, dtype=np.int32))
    for v in res.removal_order:
        for (u, ww, _) in res.f_adj[v]:
            assert ww >= oracle[v, u] - 1e-9
        for (u, ww, _) in res.b_adj[v]:
            assert ww >= oracle[u, v] - 1e-9
