"""Per-architecture smoke: reduced config, one real step, shapes + no NaNs.

Covers every runnable (arch × shape) cell at reduced scale — the full
configs are exercised (abstractly) by the dry-run only.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_cells
from repro.launch.steps import build_cell

CELLS, SKIPPED = all_cells()


def test_skip_list_matches_assignment():
    """long_500k must be skipped exactly for the pure full-attention archs
    and must run for gemma3 (5:1 local:global)."""
    skipped_archs = {a for a, s, _ in SKIPPED if s == "long_500k"}
    assert skipped_archs == {"glm4-9b", "command-r-35b",
                             "granite-moe-1b-a400m", "qwen3-moe-30b-a3b"}
    assert ("gemma3-12b", "long_500k") in CELLS
    assert len(CELLS) + len(SKIPPED) == 40


@pytest.mark.parametrize("arch,shape", CELLS,
                         ids=[f"{a}-{s}" for a, s in CELLS])
def test_cell_smoke(arch, shape):
    cell = build_cell(arch, shape, smoke=True)
    out = cell.fn(*cell.args)
    for leaf in jax.tree.leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf))), (arch, shape)
    # train cells must produce a scalar loss
    if cell.kind == "train":
        _, metrics = out
        assert metrics["loss"].shape == ()


def test_param_counts_match_published_sizes():
    """Full configs land in the advertised parameter range."""
    from repro.configs import get_arch
    expected = {
        "glm4-9b": (8e9, 11e9),
        # the assigned dims (40L·d8192·64H·ff22528·v256k tied) compute to
        # 30.3B; the "35B" marketing count includes extra width not in the
        # assignment — the assigned config is definitive here.
        "command-r-35b": (28e9, 38e9),
        "gemma3-12b": (10e9, 14e9),
        "granite-moe-1b-a400m": (0.9e9, 1.5e9),
        "qwen3-moe-30b-a3b": (27e9, 33e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_arch(arch).CONFIG
        n = cfg.param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active params
    q = get_arch("qwen3-moe-30b-a3b").CONFIG
    assert 2e9 <= q.active_param_count() <= 4.5e9   # "a3b"
    g = get_arch("granite-moe-1b-a400m").CONFIG
    assert 0.25e9 <= g.active_param_count() <= 0.6e9  # "a400m"
