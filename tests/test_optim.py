"""Optimizer, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, dequantize_int8, quantize_int8,
                         topk_sparsify)

KEY = jax.random.PRNGKey(0)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw_update(params, g, opt, 5e-2,
                                      weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(gn), 10.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert np.isclose(total, 1.0, rtol=1e-5)


def test_weight_decay_masks_1d():
    params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    opt = adamw_init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(params, zero_g, opt, 1.0, weight_decay=0.5)
    assert float(new_p["w"][0, 0]) < 1.0        # decayed
    assert float(new_p["scale"][0]) == 1.0      # masked


def test_cosine_schedule_shape():
    s = cosine_schedule(jnp.int32(0), 1.0, 10, 100)
    assert float(s) == 0.0
    s_peak = cosine_schedule(jnp.int32(10), 1.0, 10, 100)
    assert float(s_peak) > 0.9
    s_end = cosine_schedule(jnp.int32(100), 1.0, 10, 100)
    assert float(s_end) <= 0.11


def test_int8_quantization_bounds():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    q, scale = quantize_int8(x, KEY)
    deq = dequantize_int8(q, scale)
    # stochastic rounding: |error| < 1.5 quantization steps
    assert float(jnp.abs(deq - x).max()) <= float(scale) * 1.5
    # stochastic rounding is unbiased in expectation
    errs = []
    for i in range(32):
        qi, si = quantize_int8(x, jax.random.PRNGKey(i))
        errs.append(np.asarray(dequantize_int8(qi, si) - x))
    # (deterministic rounding would bias up to 0.5 steps uniformly; the
    # 32-sample mean of unbiased noise stays well under that everywhere)
    mean_err = np.abs(np.mean(errs, axis=0)).max()
    assert mean_err < float(scale) * 0.5


def test_topk_error_feedback_recovers_signal():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    vals, idx, residual = topk_sparsify(x, 32)
    # sparsified + residual reconstructs exactly
    recon = jnp.zeros_like(x).at[idx].set(vals) + residual
    np.testing.assert_allclose(np.asarray(recon), np.asarray(x), atol=1e-6)
    # EF conservation: sent + residual == sum of all gradients, exactly —
    # nothing is ever lost, only delayed (Stich et al.'s key invariant).
    carried = jnp.zeros_like(x)
    sent = jnp.zeros_like(x)
    for _ in range(16):
        g = x + carried
        vals, idx, carried = topk_sparsify(g, 32)
        sent = sent.at[idx].add(vals)
    np.testing.assert_allclose(np.asarray(sent + carried),
                               np.asarray(x) * 16, rtol=1e-4, atol=1e-3)
    # and the residual is bounded (entries do get flushed eventually)
    assert float(jnp.abs(carried).max()) < 16 * float(jnp.abs(x).max())


def test_wire_bytes_accounting():
    from repro.optim.compress import wire_bytes
    g = {"w": jnp.zeros((1000,), jnp.float32)}
    assert wire_bytes(g, "none") == 4000
    assert wire_bytes(g, "int8") == 1004
    assert wire_bytes(g, "topk", topk_frac=0.01) == 80
