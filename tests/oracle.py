"""Pure-Python shortest-path oracle for differential testing.

Deliberately shares *nothing* with the engine under test: adjacency
lists built straight off the graph's CSR, a binary-heap Dijkstra in
float64, and derived quantities (P2P, distance-threshold, farness,
top-k closeness) computed from those distances the obvious way.  On
integer edge weights (``gnm_random_digraph(weighted=True)`` draws
1..10) every distance is an exact small integer, so the engine's f32
sweeps must match the oracle's f64 heap *bit for bit* — the
differential tests assert exact equality, not tolerance.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple


class ShortestPathOracle:
    """Single-source truths for one digraph, memoized per source."""

    def __init__(self, g):
        self.n = int(g.n)
        self.adj: List[List[Tuple[int, float]]] = [[] for _ in
                                                   range(self.n)]
        src, dst, w = g.edge_list()
        for a, b, wt in zip(src.tolist(), dst.tolist(), w.tolist()):
            self.adj[a].append((int(b), float(wt)))
        self.edge_w: Dict[Tuple[int, int], float] = {
            (int(a), int(b)): float(wt)
            for a, b, wt in zip(src.tolist(), dst.tolist(), w.tolist())}
        self._ssd_memo: Dict[int, List[float]] = {}

    # ------------------------------------------------------------- queries
    def ssd(self, s: int) -> List[float]:
        s = int(s)
        memo = self._ssd_memo.get(s)
        if memo is not None:
            return memo
        dist = [math.inf] * self.n
        dist[s] = 0.0
        heap = [(0.0, s)]
        while heap:
            d, u = heapq.heappop(heap)
            if d > dist[u]:
                continue
            for v, wt in self.adj[u]:
                nd = d + wt
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        self._ssd_memo[s] = dist
        return dist

    def p2p(self, s: int, t: int) -> float:
        return self.ssd(s)[int(t)]

    def within(self, s: int, d: float) -> List[float]:
        return [x if x <= d else math.inf for x in self.ssd(s)]

    def farness(self, s: int) -> float:
        return sum(x for x in self.ssd(s) if math.isfinite(x))

    def knn(self, s: int, k: int) -> Tuple[List[int], List[float]]:
        """The ``k`` nearest nodes of ``s``, ordered by ``(distance,
        node id)`` — the same tie-break convention as
        ``QueryEngine.knn`` — padded with ``(-1, inf)`` slots when
        fewer than ``k`` nodes are reachable.  The source itself (at
        distance 0) counts as its own nearest node."""
        ranked = sorted((d, v) for v, d in enumerate(self.ssd(s))
                        if math.isfinite(d))[:k]
        nodes = [v for _, v in ranked] + [-1] * (k - len(ranked))
        dists = [d for d, _ in ranked] + [math.inf] * (k - len(ranked))
        return nodes, dists

    def topk_closeness(self, k: int,
                       candidates: Optional[Sequence[int]] = None
                       ) -> List[Tuple[float, int]]:
        """The ``k`` smallest ``(farness, node)`` pairs, node id breaking
        ties — the same convention as ``core.closeness.topk_closeness``."""
        cand = range(self.n) if candidates is None else candidates
        ranked = sorted((self.farness(int(v)), int(v)) for v in cand)
        return ranked[:k]

    # ------------------------------------------------------------ checkers
    def check_sssp(self, s: int, dist, pred) -> None:
        """Validate one SSSP row: distances exact, and predecessors
        unfold into real-edge paths whose lengths telescope to ``dist``
        (any shortest-path tree is admissible, so the *tree* is checked
        for validity, not equality with a particular oracle tree)."""
        want = self.ssd(s)
        for v in range(self.n):
            got = float(dist[v])
            assert (got == want[v]) or (math.isinf(got)
                                        and math.isinf(want[v])), \
                f"dist[{v}] = {got}, oracle {want[v]}"
            p = int(pred[v])
            if v == s or math.isinf(want[v]):
                assert p == -1, f"pred[{v}] = {p}, expected -1"
                continue
            assert p >= 0, f"reachable node {v} has no predecessor"
            wt = self.edge_w.get((p, v))
            assert wt is not None, f"pred edge ({p}, {v}) not in G"
            assert want[p] + wt == want[v], \
                f"pred edge ({p}, {v}) is not tight"
