"""Declarative config spine (DESIGN.md §12): the built-in YAML-subset
parser, the ``_include`` chain, precedence (defaults < includes < file
< CLI overrides), parse-time validation, and the serve CLI override
layer."""
import argparse
import os

import numpy as np
import pytest

from repro.config import (SERVE_DEFAULTS, Config, ConfigError,
                          _parse_yaml_subset, deep_update,
                          overrides_from_args, validate_serve)
from repro.launch.serve import (_CLI_SPEC, build_arg_parser,
                                load_serve_config, mixed_request_stream)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------ YAML subset parser
def test_yaml_subset_scalars_and_comments():
    doc = _parse_yaml_subset(
        "a: 1            # int\n"
        "b: -2.5\n"
        "c: 1e3\n"
        "d: true\n"
        "e: null\n"
        "f: 'quoted # not a comment'\n"
        "g: .inf\n"
        "h: plain string\n")
    assert doc == {"a": 1, "b": -2.5, "c": 1000.0, "d": True, "e": None,
                   "f": "quoted # not a comment", "g": float("inf"),
                   "h": "plain string"}
    assert isinstance(doc["a"], int) and isinstance(doc["c"], float)


def test_yaml_subset_nested_maps_and_lists():
    doc = _parse_yaml_subset(
        "serve:\n"
        "  slo:\n"
        "    p2p:\n"
        "      deadline_ms: 60.0\n"
        "      batch: 8\n"
        "grid:\n"
        "  - [0.05, 2q]\n"
        "  - [1.0, lru]\n"
        "depths: [1, 2, 4]\n"
        "jobs:\n"
        "  - name: a\n"
        "    n: 1\n"
        "  - name: b\n"
        "    n: 2\n")
    assert doc["serve"]["slo"]["p2p"] == {"deadline_ms": 60.0, "batch": 8}
    assert doc["grid"] == [[0.05, "2q"], [1.0, "lru"]]
    assert doc["depths"] == [1, 2, 4]
    assert doc["jobs"] == [{"name": "a", "n": 1}, {"name": "b", "n": 2}]


@pytest.mark.parametrize("text, what", [
    ("a: &anchor 1\n", "anchor"),
    ("a: {b: 1}\n", "flow map"),
    ("a: 1\na: 2\n", "duplicate key"),
    ("a:\n\tb: 1\n", "tab indentation"),
    ("- just\n- a list\n", "non-mapping top level"),
])
def test_yaml_subset_rejects_unsupported(text, what):
    with pytest.raises(ConfigError):
        _parse_yaml_subset(text)


def test_checked_in_configs_parse_and_validate():
    cfg = Config(os.path.join(REPO, "configs", "serve_mixed.yaml"),
                 defaults=SERVE_DEFAULTS)
    assert len(cfg.includes) == 1            # serve_base.yaml
    assert cfg.get("serve.scheduler") == "slo"
    assert cfg.get("serve.mix") == {"ssd": 1, "p2p": 3}
    assert cfg.get("serve.slo.p2p.deadline_ms") == 60.0
    assert cfg.get("serve.slo.p2p.batch") == 8
    assert cfg.get("store.enabled") is False  # include-chain key survives
    validate_serve(cfg)

    bench = Config(os.path.join(REPO, "configs", "bench_serve.yaml"))
    assert bench.get("bench.batch_sizes") == [1, 16, 128]
    assert bench.get("bench.store.cache_grid")[0] == [0.05, "2q"]
    assert bench.get("bench.slo.classes.ssd.deadline_ms") == 200.0


# ------------------------------------------------- include chain resolution
def test_include_chain_precedence(tmp_path):
    (tmp_path / "base.yaml").write_text(
        "serve:\n  batch: 4\n  rate: 1.0\n")
    (tmp_path / "child.yaml").write_text(
        "_include: base.yaml\nserve:\n  batch: 8\n")
    cfg = Config(str(tmp_path / "child.yaml"),
                 defaults={"serve": {"batch": 1, "rate": 0.0, "keep": 7}},
                 overrides={"serve": {"rate": 9.0}})
    assert cfg.get("serve.batch") == 8       # file beats its include
    assert cfg.get("serve.rate") == 9.0      # override beats the file
    assert cfg.get("serve.keep") == 7        # defaults survive the layers
    assert cfg.includes == [str(tmp_path / "base.yaml")]


def test_include_resolved_relative_to_including_file(tmp_path):
    (tmp_path / "base.yaml").write_text("a: 1\n")
    sub = tmp_path / "sub"
    sub.mkdir()
    (sub / "inner.yaml").write_text("_include: ../base.yaml\nb: 2\n")
    cfg = Config(str(sub / "inner.yaml"))
    assert cfg.get("a") == 1 and cfg.get("b") == 2


def test_include_cycle_is_an_error(tmp_path):
    (tmp_path / "a.yaml").write_text("_include: b.yaml\n")
    (tmp_path / "b.yaml").write_text("_include: a.yaml\n")
    with pytest.raises(ConfigError, match="circular"):
        Config(str(tmp_path / "a.yaml"))


def test_missing_include_is_an_error(tmp_path):
    (tmp_path / "c.yaml").write_text("_include: nope.yaml\n")
    with pytest.raises(ConfigError, match="cannot read"):
        Config(str(tmp_path / "c.yaml"))


def test_deep_update_merges_dicts_replaces_lists():
    base = {"a": {"l": [1, 2, 3], "keep": 1}, "top": 0}
    deep_update(base, {"a": {"l": [9]}})
    assert base == {"a": {"l": [9], "keep": 1}, "top": 0}


# ----------------------------------------------------------- accessors
def test_get_require_sub_flat():
    cfg = Config(None, defaults={"serve": {"slo": {"p2p":
                                                  {"deadline_ms": 60.0}}}})
    assert cfg.get("serve.slo.p2p.deadline_ms") == 60.0
    assert cfg.get("serve.slo.knn.deadline_ms", 5.0) == 5.0
    with pytest.raises(ConfigError, match="serve.missing"):
        cfg.require("serve.missing")
    assert cfg.sub("serve.slo").get("p2p.deadline_ms") == 60.0
    assert cfg.flat() == {"serve.slo.p2p.deadline_ms": 60.0}


# ------------------------------------------------- parse-time validation
def test_validate_serve_defaults_pass():
    cfg = Config(None, defaults=SERVE_DEFAULTS)
    assert validate_serve(cfg) is cfg


@pytest.mark.parametrize("overrides, key", [
    ({"store": {"cache_frac": 0.0}}, "store.cache_frac"),
    ({"store": {"cache_frac": 1.5}}, "store.cache_frac"),
    ({"store": {"pin_frac": -0.1}}, "store.pin_frac"),
    ({"serve": {"max_wait_ms": -1.0}}, "serve.max_wait_ms"),
    ({"serve": {"batch": 0}}, "serve.batch"),
    ({"serve": {"cache_entries": -1}}, "serve.cache_entries"),
    ({"store": {"queue_depth": 0}}, "store.queue_depth"),
    ({"store": {"decode_workers": 0}}, "store.decode_workers"),
    ({"store": {"cache_policy": "fifo"}}, "store.cache_policy"),
    ({"store": {"codec": "zip"}}, "store.codec"),
    ({"serve": {"scheduler": "lifo"}}, "serve.scheduler"),
    ({"serve": {"mode": "kn"}}, "serve.mode"),
    ({"serve": {"mode": "top_k"}}, "serve.mode"),
    ({"serve": {"rate": -1.0}}, "serve.rate"),
    ({"serve": {"threshold": 0.0}}, "serve.threshold"),
    ({"serve": {"k": 0}}, "serve.k"),
    ({"serve": {"slo": {"ssd": {"deadline_ms": -1.0}}}},
     "serve.slo.ssd.deadline_ms"),
    ({"serve": {"slo": {"ssd": {}}}}, "serve.slo.ssd.deadline_ms"),
    ({"serve": {"slo": {"ssd": {"deadline_ms": 5.0, "batch": 0}}}},
     "serve.slo.ssd.batch"),
    ({"serve": {"mix": {"ssd": 0.0}}}, "serve.mix.ssd"),
])
def test_validate_serve_names_the_offending_key(overrides, key):
    cfg = Config(None, defaults=SERVE_DEFAULTS, overrides=overrides)
    with pytest.raises(ConfigError, match=key.replace(".", r"\.")):
        validate_serve(cfg)


def test_overrides_from_args_only_typed_flags():
    ns = argparse.Namespace(batch=7, cache_frac=0.5)   # SUPPRESS: no others
    assert overrides_from_args(ns, _CLI_SPEC) == {
        "serve": {"batch": 7}, "store": {"cache_frac": 0.5}}


# ----------------------------------------------------------- CLI layering
@pytest.mark.parametrize("argv", [
    ["--cache-frac", "1.5"], ["--cache-frac", "0"],
    ["--pin-frac", "1.1"], ["--pin-frac", "-0.1"],
    ["--max-wait-ms", "-1"], ["--batch", "0"],
    ["--threshold", "0"], ["--k", "0"], ["--queue-depth", "0"],
])
def test_cli_rejects_bad_values_at_parse_time(argv, capsys):
    with pytest.raises(SystemExit):
        build_arg_parser().parse_args(argv)
    assert "out of range" in capsys.readouterr().err or True


def test_cli_defaults_and_explicit_flags():
    ap = build_arg_parser()
    cfg = load_serve_config(ap.parse_args([]))
    assert cfg.get("serve.batch") == SERVE_DEFAULTS["serve"]["batch"]
    cfg = load_serve_config(ap.parse_args(["--batch", "5",
                                           "--scheduler", "slo"]))
    assert cfg.get("serve.batch") == 5
    assert cfg.get("serve.scheduler") == "slo"


def test_cli_overrides_config_file(tmp_path):
    path = tmp_path / "serve.yaml"
    path.write_text("serve:\n  batch: 5\n  scheduler: slo\n")
    ap = build_arg_parser()
    cfg = load_serve_config(ap.parse_args(
        ["--config", str(path), "--batch", "9"]))
    assert cfg.get("serve.batch") == 9        # explicit flag wins
    assert cfg.get("serve.scheduler") == "slo"  # untyped flag defers


def test_no_prefetch_flag_inverts_into_config():
    ap = build_arg_parser()
    cfg = load_serve_config(ap.parse_args(["--no-prefetch"]))
    assert cfg.get("store.prefetch") is False
    assert load_serve_config(ap.parse_args([])).get("store.prefetch") is True


# ------------------------------------------------------ mixed-stream helper
def test_mixed_request_stream_deterministic_shares():
    cfg = Config(None, defaults=SERVE_DEFAULTS,
                 overrides={"serve": {"mix": {"ssd": 1, "p2p": 3}}})
    a = mixed_request_stream(cfg, 100, 200, np.random.default_rng(3),
                             p2p_pool=4)
    b = mixed_request_stream(cfg, 100, 200, np.random.default_rng(3),
                             p2p_pool=4)
    assert a == b                            # same rng -> same stream
    frac = sum(m == "p2p" for m, _ in a) / len(a)
    assert 0.6 < frac < 0.9                  # ~3/4 share
    pairs = {args for m, args in a if m == "p2p"}
    assert 1 <= len(pairs) <= 4              # drawn from the small pool
    assert all(s != t for s, t in pairs)


def test_mixed_request_stream_tiny_graph_never_empties_p2p_pool():
    # regression: on tiny graphs the self-pair filter could drop every
    # sampled pair, and the first p2p request then raised ValueError
    # from rng.integers(0, 0); the pool must resample instead
    cfg = Config(None, defaults=SERVE_DEFAULTS,
                 overrides={"serve": {"mix": {"p2p": 1}}})
    for seed in range(20):
        stream = mixed_request_stream(cfg, 2, 8,
                                      np.random.default_rng(seed),
                                      p2p_pool=2)
        assert len(stream) == 8
        assert all(m == "p2p" and s != t for m, (s, t) in stream)
