"""HoD end-to-end correctness vs the Dijkstra oracle.

Property-based tests live in test_hod_property.py behind an importorskip
on ``hypothesis`` (a dev extra), so this module always collects.
"""
import numpy as np
import pytest as _pytest

from repro.core import (BuildConfig, QueryEngine, build_hod,
                        dijkstra_reference, gnm_random_digraph,
                        grid_road_graph, pack_index, power_law_digraph,
                        symmetrize)
from repro.core.build_fast import build_hod_fast

CFG = BuildConfig(max_core_nodes=48, max_core_edges=2048, seed=0)

BUILDERS = {"reference": build_hod, "vectorized": build_hod_fast}


def _check_graph(g, sources, core_modes=("closure", "bellman", "dijkstra"),
                 chunk=128, builder=build_hod):
    res = builder(g, CFG)
    ix = pack_index(g, res, chunk=chunk)
    oracle = dijkstra_reference(g, sources)
    for mode in core_modes:
        eng = QueryEngine(ix, core_mode=mode)
        d = eng.ssd(sources)[:, :g.n]
        finite = np.isfinite(oracle)
        assert np.allclose(d[finite], oracle[finite], rtol=1e-5), mode
        assert np.all(np.isinf(d[~finite])), mode
    return ix, res


@_pytest.fixture(params=list(BUILDERS), ids=list(BUILDERS))
def builder(request):
    return BUILDERS[request.param]


def test_gnm_directed(builder):
    g = gnm_random_digraph(250, 1000, seed=7)
    _check_graph(g, np.arange(6, dtype=np.int32) * 40, builder=builder)


def test_grid_road(builder):
    g = grid_road_graph(15, seed=3)
    _check_graph(g, np.array([0, 7, 100, 224], dtype=np.int32),
                 builder=builder)


def test_power_law_weighted(builder):
    g = power_law_digraph(300, 3, seed=5, weighted=True)
    _check_graph(g, np.array([0, 10, 299], dtype=np.int32), builder=builder)


def test_undirected_symmetrized(builder):
    g = symmetrize(gnm_random_digraph(150, 450, seed=11))
    _check_graph(g, np.array([0, 50, 149], dtype=np.int32), builder=builder)


def test_vectorized_build_rank_invariants():
    g = gnm_random_digraph(300, 1200, seed=2)
    res = build_hod_fast(g, CFG)
    rank = res.rank
    for v in res.removal_order:
        for (other, _, _) in res.f_adj[v]:
            assert rank[other] > rank[v]
        for (other, _, _) in res.b_adj[v]:
            assert rank[other] > rank[v]


def test_rank_invariants():
    """Paper §4.5: F_f/F_b edges strictly up-rank; file order == rank order;
    no two same-rank adjacent nodes."""
    g = gnm_random_digraph(200, 900, seed=2)
    res = build_hod(g, CFG)
    rank = res.rank
    for v in res.removal_order:
        for (other, _, _) in res.f_adj[v]:
            assert rank[other] > rank[v]
        for (other, _, _) in res.b_adj[v]:
            assert rank[other] > rank[v]
    # removal order is round-major => ranks are non-decreasing in file order
    ranks_in_order = [rank[v] for v in res.removal_order]
    assert ranks_in_order == sorted(ranks_in_order)


def test_sssp_paths_are_valid_shortest_paths():
    g = gnm_random_digraph(200, 800, seed=13)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=128)
    eng = QueryEngine(ix)
    sources = np.array([0, 5], dtype=np.int32)
    dist, pred = eng.sssp(sources)
    oracle = dijkstra_reference(g, sources)
    # adjacency for edge-length lookup
    adj = {}
    src, dst, w = g.edge_list()
    for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for i, s in enumerate(sources.tolist()):
        for t in range(0, g.n, 17):
            if not np.isfinite(oracle[i, t]) or t == s:
                continue
            # walk back via predecessors; total length must equal dist
            cur, total, hops = t, 0.0, 0
            while cur != s:
                p = int(pred[i, cur])
                assert p >= 0, (s, t, cur)
                assert (p, cur) in adj, "predecessor edge not in G"
                total += adj[(p, cur)]
                cur = p
                hops += 1
                assert hops <= g.n
            assert np.isclose(total, oracle[i, t], rtol=1e-5)


def test_index_save_load_roundtrip(tmp_path):
    g = gnm_random_digraph(120, 500, seed=21)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    path = str(tmp_path / "hod_index.npz")
    ix.save(path)
    from repro.core.index import HoDIndex
    ix2 = HoDIndex.load(path)
    src = np.array([3, 77], dtype=np.int32)
    d1 = QueryEngine(ix).ssd(src)
    d2 = QueryEngine(ix2).ssd(src)
    assert np.array_equal(d1, d2)


def test_batched_equals_single():
    g = gnm_random_digraph(150, 600, seed=4)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix)
    batch = eng.ssd(np.array([1, 2, 3], dtype=np.int32))
    for i, s in enumerate([1, 2, 3]):
        single = eng.ssd(np.array([s], dtype=np.int32))
        assert np.array_equal(batch[i], single[0])


def test_pallas_sweeps_match_reference():
    """use_pallas=True routes the forward/backward sweeps through the
    bucketed Pallas kernel (interpret mode on CPU) and must agree with the
    pure-jnp chunk sweeps AND the Dijkstra oracle on weighted digraphs."""
    for n, m, seed in [(120, 500, 0), (200, 900, 1), (150, 400, 2)]:
        g = gnm_random_digraph(n, m, seed=seed, weighted=True)
        res = build_hod(g, CFG)
        ix = pack_index(g, res, chunk=64)
        sources = np.array([0, n // 3, n - 1], dtype=np.int32)
        oracle = dijkstra_reference(g, sources)
        d_jnp = QueryEngine(ix, use_pallas=False).ssd(sources)[:, :n]
        d_pal = QueryEngine(ix, use_pallas=True).ssd(sources)[:, :n]
        finite = np.isfinite(oracle)
        assert np.allclose(d_pal[finite], oracle[finite], atol=1e-4,
                           rtol=1e-5)
        assert np.all(np.isinf(d_pal[~finite]))
        np.testing.assert_allclose(d_pal, d_jnp, rtol=1e-6)


def test_sssp_pallas_paths_valid():
    """SSSP reconstruction on top of Pallas-swept distances still unfolds
    into length-correct paths."""
    g = gnm_random_digraph(150, 700, seed=17)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    sources = np.array([3], dtype=np.int32)
    oracle = dijkstra_reference(g, sources)
    eng = QueryEngine(ix, use_pallas=True)
    targets = [t for t in range(0, g.n, 13) if np.isfinite(oracle[0, t])]
    paths = eng.paths(np.repeat(sources, len(targets)),
                      np.asarray(targets, dtype=np.int32))
    adj = {}
    src, dst, w = g.edge_list()
    for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for t, path in zip(targets, paths):
        assert path is not None and path[0] == 3 and path[-1] == t
        total = sum(adj[(a, b)] for a, b in zip(path, path[1:]))
        assert np.isclose(total, oracle[0, t], rtol=1e-5)


def test_sssp_nonzero_eps_tolerates_float_ties():
    """eps > 0 widens the tightness test: reconstruction must still give
    valid (length-correct within eps slack) paths on float-heavy weights."""
    rng = np.random.default_rng(5)
    n, m = 120, 600
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.uniform(0.1, 1.0, m)
    keep = src != dst
    from repro.core import from_edges
    g = from_edges(n, src[keep], dst[keep], w[keep])
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix, eps=1e-5)
    sources = np.array([0, 7], dtype=np.int32)
    dist, pred = eng.sssp(sources)
    oracle = dijkstra_reference(g, sources)
    adj = {}
    es, ed, ew = g.edge_list()
    for a, b, ww in zip(es.tolist(), ed.tolist(), ew.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for i, s in enumerate(sources.tolist()):
        for t in range(0, n, 11):
            if not np.isfinite(oracle[i, t]) or t == s:
                continue
            cur, total, hops = t, 0.0, 0
            while cur != s:
                p = int(pred[i, cur])
                assert p >= 0 and (p, cur) in adj
                total += adj[(p, cur)]
                cur = p
                hops += 1
                assert hops <= n
            # eps-relaxed tightness admits near-ties; the unfolded path can
            # be longer than optimal by at most ~eps·(1+dist) per hop
            assert total <= oracle[i, t] + 1e-4 * (hops + 1)


def test_sssp_unreachable_targets():
    """Disconnected targets: dist inf, pred -1, paths() returns None."""
    from repro.core import from_edges
    # two components: 0-1-2 chain and 3-4 chain
    g = from_edges(6, np.array([0, 1, 3]), np.array([1, 2, 4]),
                   np.array([1.0, 1.0, 1.0]))
    res = build_hod(g, BuildConfig(max_core_nodes=4, max_core_edges=64))
    ix = pack_index(g, res, chunk=16)
    for use_pallas in (False, True):
        eng = QueryEngine(ix, use_pallas=use_pallas)
        dist, pred = eng.sssp(np.array([0], dtype=np.int32))
        assert np.isinf(dist[0, 3]) and np.isinf(dist[0, 4]) \
            and np.isinf(dist[0, 5])
        assert pred[0, 3] == -1 and pred[0, 4] == -1 and pred[0, 5] == -1
        paths = eng.paths(np.array([0, 0], dtype=np.int32),
                          np.array([2, 4], dtype=np.int32))
        assert paths[0] == [0, 1, 2]
        assert paths[1] is None


def test_closeness_estimation_runs():
    from repro.core import estimate_closeness
    g = grid_road_graph(10, seed=1)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix)
    out = estimate_closeness(eng, k_override=16, batch_size=8)
    assert out.closeness.shape == (g.n,)
    assert np.all(np.isfinite(out.closeness))
    assert out.k == 16
