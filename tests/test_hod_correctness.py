"""HoD end-to-end correctness vs the Dijkstra oracle.

Property-based tests live in test_hod_property.py behind an importorskip
on ``hypothesis`` (a dev extra), so this module always collects.
"""
import numpy as np
import pytest as _pytest

from repro.core import (BuildConfig, QueryEngine, build_hod,
                        dijkstra_reference, gnm_random_digraph,
                        grid_road_graph, pack_index, power_law_digraph,
                        symmetrize)
from repro.core.build_fast import build_hod_fast

CFG = BuildConfig(max_core_nodes=48, max_core_edges=2048, seed=0)

BUILDERS = {"reference": build_hod, "vectorized": build_hod_fast}


def _check_graph(g, sources, core_modes=("closure", "bellman", "dijkstra"),
                 chunk=128, builder=build_hod):
    res = builder(g, CFG)
    ix = pack_index(g, res, chunk=chunk)
    oracle = dijkstra_reference(g, sources)
    for mode in core_modes:
        eng = QueryEngine(ix, core_mode=mode)
        d = eng.ssd(sources)[:, :g.n]
        finite = np.isfinite(oracle)
        assert np.allclose(d[finite], oracle[finite], rtol=1e-5), mode
        assert np.all(np.isinf(d[~finite])), mode
    return ix, res


@_pytest.fixture(params=list(BUILDERS), ids=list(BUILDERS))
def builder(request):
    return BUILDERS[request.param]


def test_gnm_directed(builder):
    g = gnm_random_digraph(250, 1000, seed=7)
    _check_graph(g, np.arange(6, dtype=np.int32) * 40, builder=builder)


def test_grid_road(builder):
    g = grid_road_graph(15, seed=3)
    _check_graph(g, np.array([0, 7, 100, 224], dtype=np.int32),
                 builder=builder)


def test_power_law_weighted(builder):
    g = power_law_digraph(300, 3, seed=5, weighted=True)
    _check_graph(g, np.array([0, 10, 299], dtype=np.int32), builder=builder)


def test_undirected_symmetrized(builder):
    g = symmetrize(gnm_random_digraph(150, 450, seed=11))
    _check_graph(g, np.array([0, 50, 149], dtype=np.int32), builder=builder)


def test_vectorized_build_rank_invariants():
    g = gnm_random_digraph(300, 1200, seed=2)
    res = build_hod_fast(g, CFG)
    rank = res.rank
    for v in res.removal_order:
        for (other, _, _) in res.f_adj[v]:
            assert rank[other] > rank[v]
        for (other, _, _) in res.b_adj[v]:
            assert rank[other] > rank[v]


def test_rank_invariants():
    """Paper §4.5: F_f/F_b edges strictly up-rank; file order == rank order;
    no two same-rank adjacent nodes."""
    g = gnm_random_digraph(200, 900, seed=2)
    res = build_hod(g, CFG)
    rank = res.rank
    for v in res.removal_order:
        for (other, _, _) in res.f_adj[v]:
            assert rank[other] > rank[v]
        for (other, _, _) in res.b_adj[v]:
            assert rank[other] > rank[v]
    # removal order is round-major => ranks are non-decreasing in file order
    ranks_in_order = [rank[v] for v in res.removal_order]
    assert ranks_in_order == sorted(ranks_in_order)


def test_sssp_paths_are_valid_shortest_paths():
    g = gnm_random_digraph(200, 800, seed=13)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=128)
    eng = QueryEngine(ix)
    sources = np.array([0, 5], dtype=np.int32)
    dist, pred = eng.sssp(sources)
    oracle = dijkstra_reference(g, sources)
    # adjacency for edge-length lookup
    adj = {}
    src, dst, w = g.edge_list()
    for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for i, s in enumerate(sources.tolist()):
        for t in range(0, g.n, 17):
            if not np.isfinite(oracle[i, t]) or t == s:
                continue
            # walk back via predecessors; total length must equal dist
            cur, total, hops = t, 0.0, 0
            while cur != s:
                p = int(pred[i, cur])
                assert p >= 0, (s, t, cur)
                assert (p, cur) in adj, "predecessor edge not in G"
                total += adj[(p, cur)]
                cur = p
                hops += 1
                assert hops <= g.n
            assert np.isclose(total, oracle[i, t], rtol=1e-5)


def test_index_save_load_roundtrip(tmp_path):
    g = gnm_random_digraph(120, 500, seed=21)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    path = str(tmp_path / "hod_index.npz")
    ix.save(path)
    from repro.core.index import HoDIndex
    ix2 = HoDIndex.load(path)
    src = np.array([3, 77], dtype=np.int32)
    d1 = QueryEngine(ix).ssd(src)
    d2 = QueryEngine(ix2).ssd(src)
    assert np.array_equal(d1, d2)


def test_batched_equals_single():
    g = gnm_random_digraph(150, 600, seed=4)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix)
    batch = eng.ssd(np.array([1, 2, 3], dtype=np.int32))
    for i, s in enumerate([1, 2, 3]):
        single = eng.ssd(np.array([s], dtype=np.int32))
        assert np.array_equal(batch[i], single[0])


def test_pallas_sweeps_match_reference():
    """use_pallas=True routes the forward/backward sweeps through the
    bucketed Pallas kernel (interpret mode on CPU) and must agree with the
    pure-jnp chunk sweeps AND the Dijkstra oracle on weighted digraphs."""
    for n, m, seed in [(120, 500, 0), (200, 900, 1), (150, 400, 2)]:
        g = gnm_random_digraph(n, m, seed=seed, weighted=True)
        res = build_hod(g, CFG)
        ix = pack_index(g, res, chunk=64)
        sources = np.array([0, n // 3, n - 1], dtype=np.int32)
        oracle = dijkstra_reference(g, sources)
        d_jnp = QueryEngine(ix, use_pallas=False).ssd(sources)[:, :n]
        d_pal = QueryEngine(ix, use_pallas=True).ssd(sources)[:, :n]
        finite = np.isfinite(oracle)
        assert np.allclose(d_pal[finite], oracle[finite], atol=1e-4,
                           rtol=1e-5)
        assert np.all(np.isinf(d_pal[~finite]))
        np.testing.assert_allclose(d_pal, d_jnp, rtol=1e-6)


def test_sssp_pallas_paths_valid():
    """SSSP reconstruction on top of Pallas-swept distances still unfolds
    into length-correct paths."""
    g = gnm_random_digraph(150, 700, seed=17)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    sources = np.array([3], dtype=np.int32)
    oracle = dijkstra_reference(g, sources)
    eng = QueryEngine(ix, use_pallas=True)
    targets = [t for t in range(0, g.n, 13) if np.isfinite(oracle[0, t])]
    paths = eng.paths(np.repeat(sources, len(targets)),
                      np.asarray(targets, dtype=np.int32))
    adj = {}
    src, dst, w = g.edge_list()
    for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for t, path in zip(targets, paths):
        assert path is not None and path[0] == 3 and path[-1] == t
        total = sum(adj[(a, b)] for a, b in zip(path, path[1:]))
        assert np.isclose(total, oracle[0, t], rtol=1e-5)


def test_sssp_nonzero_eps_tolerates_float_ties():
    """eps > 0 widens the tightness test: reconstruction must still give
    valid (length-correct within eps slack) paths on float-heavy weights."""
    rng = np.random.default_rng(5)
    n, m = 120, 600
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.uniform(0.1, 1.0, m)
    keep = src != dst
    from repro.core import from_edges
    g = from_edges(n, src[keep], dst[keep], w[keep])
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix, eps=1e-5)
    sources = np.array([0, 7], dtype=np.int32)
    dist, pred = eng.sssp(sources)
    oracle = dijkstra_reference(g, sources)
    adj = {}
    es, ed, ew = g.edge_list()
    for a, b, ww in zip(es.tolist(), ed.tolist(), ew.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for i, s in enumerate(sources.tolist()):
        for t in range(0, n, 11):
            if not np.isfinite(oracle[i, t]) or t == s:
                continue
            cur, total, hops = t, 0.0, 0
            while cur != s:
                p = int(pred[i, cur])
                assert p >= 0 and (p, cur) in adj
                total += adj[(p, cur)]
                cur = p
                hops += 1
                assert hops <= n
            # eps-relaxed tightness admits near-ties; the unfolded path can
            # be longer than optimal by at most ~eps·(1+dist) per hop
            assert total <= oracle[i, t] + 1e-4 * (hops + 1)


def test_sssp_unreachable_targets():
    """Disconnected targets: dist inf, pred -1, paths() returns None."""
    from repro.core import from_edges
    # two components: 0-1-2 chain and 3-4 chain
    g = from_edges(6, np.array([0, 1, 3]), np.array([1, 2, 4]),
                   np.array([1.0, 1.0, 1.0]))
    res = build_hod(g, BuildConfig(max_core_nodes=4, max_core_edges=64))
    ix = pack_index(g, res, chunk=16)
    for use_pallas in (False, True):
        eng = QueryEngine(ix, use_pallas=use_pallas)
        dist, pred = eng.sssp(np.array([0], dtype=np.int32))
        assert np.isinf(dist[0, 3]) and np.isinf(dist[0, 4]) \
            and np.isinf(dist[0, 5])
        assert pred[0, 3] == -1 and pred[0, 4] == -1 and pred[0, 5] == -1
        paths = eng.paths(np.array([0, 0], dtype=np.int32),
                          np.array([2, 4], dtype=np.int32))
        assert paths[0] == [0, 1, 2]
        assert paths[1] is None


def _plan_engines(ix, **kw):
    return [QueryEngine(ix, use_pallas=False, **kw),
            QueryEngine(ix, use_pallas=True, **kw)]


def test_plan_executor_single_node_graph():
    """n=1, no edges: one level, empty core, all-padding plans."""
    from repro.core import from_edges
    g = from_edges(1, np.array([], dtype=int), np.array([], dtype=int),
                   np.array([], dtype=float))
    res = build_hod(g, BuildConfig(max_core_nodes=4, max_core_edges=64))
    ix = pack_index(g, res, chunk=16)
    for eng in _plan_engines(ix):
        d = eng.ssd(np.array([0], dtype=np.int32))
        assert d[0, 0] == 0.0
        dist, pred = eng.sssp(np.array([0], dtype=np.int32))
        assert dist[0, 0] == 0.0 and pred[0, 0] == -1


def test_plan_executor_all_core_graph():
    """max_rounds=0 removes nothing: empty f/b plans, core-only search,
    SSSP reconstruction rides the core plan alone."""
    from repro.core import from_edges
    g = from_edges(5, np.array([0, 1, 2, 3]), np.array([1, 2, 3, 4]),
                   np.array([1.0, 2.0, 1.0, 3.0]))
    res = build_hod(g, BuildConfig(max_core_nodes=16, max_core_edges=256,
                                   max_rounds=0))
    ix = pack_index(g, res, chunk=16)
    assert ix.n_levels == 0 and ix.n_core == g.n
    assert ix.plan_f.l_pad == 0 and ix.plan_b.l_pad == 0
    oracle = dijkstra_reference(g, [0])
    for eng in _plan_engines(ix):
        d = eng.ssd(np.array([0], dtype=np.int32))[:, :g.n]
        np.testing.assert_allclose(d, oracle, rtol=1e-6)
        assert eng.paths(np.array([0]), np.array([4]))[0] == [0, 1, 2, 3, 4]


def test_plan_executor_empty_level_graph():
    """Isolated nodes form a level that contributes no backward edges:
    the plan must mask it and queries must still match the oracle."""
    from repro.core import from_edges
    g = from_edges(8, np.array([0, 1]), np.array([1, 2]),
                   np.array([1.0, 1.0]))
    res = build_hod(g, BuildConfig(max_core_nodes=2, max_core_edges=64))
    ix = pack_index(g, res, chunk=16)
    sources = np.array([0, 5], dtype=np.int32)
    oracle = dijkstra_reference(g, sources)
    finite = np.isfinite(oracle)
    for eng in _plan_engines(ix):
        d = eng.ssd(sources)[:, :g.n]
        np.testing.assert_allclose(d[finite], oracle[finite], rtol=1e-6)
        assert np.all(np.isinf(d[~finite]))
        dist, pred = eng.sssp(sources)
        assert np.all(pred[1, :g.n] == -1)   # isolated source: no preds
        assert eng.paths(np.array([0]), np.array([2]))[0] == [0, 1, 2]


def test_sssp_dijkstra_core_mode():
    """Regression: sssp() under core_mode="dijkstra" must route through
    the host-Dijkstra core search before reconstruction — the jit'd
    pipeline skips the core phase for this mode, which used to yield
    inf distances and empty predecessors."""
    from repro.core import from_edges
    # all-core chain: the whole query IS the core search
    g = from_edges(5, np.array([0, 1, 2, 3]), np.array([1, 2, 3, 4]),
                   np.array([1.0, 2.0, 1.0, 3.0]))
    res = build_hod(g, BuildConfig(max_core_nodes=16, max_core_edges=256,
                                   max_rounds=0))
    eng = QueryEngine(pack_index(g, res, chunk=16), core_mode="dijkstra")
    dist, pred = eng.sssp(np.array([0], dtype=np.int32))
    np.testing.assert_allclose(dist[0, :g.n], [0.0, 1.0, 3.0, 4.0, 7.0])
    assert eng.paths(np.array([0]), np.array([4]))[0] == [0, 1, 2, 3, 4]
    # and on a generic graph it matches the default-mode reconstruction
    g2 = gnm_random_digraph(120, 500, seed=31, weighted=True)
    res2 = build_hod(g2, CFG)
    ix2 = pack_index(g2, res2, chunk=64)
    src = np.array([0, 60], dtype=np.int32)
    d_ref, p_ref = QueryEngine(ix2).sssp(src)
    d_dij, p_dij = QueryEngine(ix2, core_mode="dijkstra").sssp(src)
    np.testing.assert_allclose(d_dij, d_ref, rtol=1e-5)
    np.testing.assert_array_equal(p_dij, p_ref)


def test_save_load_query_equivalence_pallas_sssp(tmp_path):
    """Persisted plans answer bit-identical SSD/SSSP through both
    executor kernels after a save→load round trip."""
    from repro.core.index import HoDIndex
    g = gnm_random_digraph(140, 560, seed=23, weighted=True)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    path = str(tmp_path / "ix.npz")
    ix.save(path)
    ix2 = HoDIndex.load(path)
    src = np.array([2, 70, 139], dtype=np.int32)
    for use_pallas in (False, True):
        e1 = QueryEngine(ix, use_pallas=use_pallas)
        e2 = QueryEngine(ix2, use_pallas=use_pallas)
        np.testing.assert_array_equal(e1.ssd(src), e2.ssd(src))
        d1, p1 = e1.sssp(src)
        d2, p2 = e2.sssp(src)
        np.testing.assert_array_equal(d1, d2)
        np.testing.assert_array_equal(p1, p2)


def test_closeness_estimation_runs():
    from repro.core import estimate_closeness
    g = grid_road_graph(10, seed=1)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix)
    out = estimate_closeness(eng, k_override=16, batch_size=8)
    assert out.closeness.shape == (g.n,)
    assert np.all(np.isfinite(out.closeness))
    assert out.k == 16
