"""HoD end-to-end correctness vs the Dijkstra oracle (+ hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import pytest as _pytest

from repro.core import (BuildConfig, QueryEngine, build_hod,
                        dijkstra_reference, from_edges, gnm_random_digraph,
                        grid_road_graph, pack_index, power_law_digraph,
                        symmetrize)
from repro.core.build_fast import build_hod_fast

CFG = BuildConfig(max_core_nodes=48, max_core_edges=2048, seed=0)

BUILDERS = {"reference": build_hod, "vectorized": build_hod_fast}


def _check_graph(g, sources, core_modes=("closure", "bellman", "dijkstra"),
                 chunk=128, builder=build_hod):
    res = builder(g, CFG)
    ix = pack_index(g, res, chunk=chunk)
    oracle = dijkstra_reference(g, sources)
    for mode in core_modes:
        eng = QueryEngine(ix, core_mode=mode)
        d = eng.ssd(sources)[:, :g.n]
        finite = np.isfinite(oracle)
        assert np.allclose(d[finite], oracle[finite], rtol=1e-5), mode
        assert np.all(np.isinf(d[~finite])), mode
    return ix, res


@_pytest.fixture(params=list(BUILDERS), ids=list(BUILDERS))
def builder(request):
    return BUILDERS[request.param]


def test_gnm_directed(builder):
    g = gnm_random_digraph(250, 1000, seed=7)
    _check_graph(g, np.arange(6, dtype=np.int32) * 40, builder=builder)


def test_grid_road(builder):
    g = grid_road_graph(15, seed=3)
    _check_graph(g, np.array([0, 7, 100, 224], dtype=np.int32),
                 builder=builder)


def test_power_law_weighted(builder):
    g = power_law_digraph(300, 3, seed=5, weighted=True)
    _check_graph(g, np.array([0, 10, 299], dtype=np.int32), builder=builder)


def test_undirected_symmetrized(builder):
    g = symmetrize(gnm_random_digraph(150, 450, seed=11))
    _check_graph(g, np.array([0, 50, 149], dtype=np.int32), builder=builder)


def test_vectorized_build_rank_invariants():
    g = gnm_random_digraph(300, 1200, seed=2)
    res = build_hod_fast(g, CFG)
    rank = res.rank
    for v in res.removal_order:
        for (other, _, _) in res.f_adj[v]:
            assert rank[other] > rank[v]
        for (other, _, _) in res.b_adj[v]:
            assert rank[other] > rank[v]


def test_rank_invariants():
    """Paper §4.5: F_f/F_b edges strictly up-rank; file order == rank order;
    no two same-rank adjacent nodes."""
    g = gnm_random_digraph(200, 900, seed=2)
    res = build_hod(g, CFG)
    rank = res.rank
    for v in res.removal_order:
        for (other, _, _) in res.f_adj[v]:
            assert rank[other] > rank[v]
        for (other, _, _) in res.b_adj[v]:
            assert rank[other] > rank[v]
    # removal order is round-major => ranks are non-decreasing in file order
    ranks_in_order = [rank[v] for v in res.removal_order]
    assert ranks_in_order == sorted(ranks_in_order)


def test_sssp_paths_are_valid_shortest_paths():
    g = gnm_random_digraph(200, 800, seed=13)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=128)
    eng = QueryEngine(ix)
    sources = np.array([0, 5], dtype=np.int32)
    dist, pred = eng.sssp(sources)
    oracle = dijkstra_reference(g, sources)
    # adjacency for edge-length lookup
    adj = {}
    src, dst, w = g.edge_list()
    for a, b, ww in zip(src.tolist(), dst.tolist(), w.tolist()):
        adj[(a, b)] = min(adj.get((a, b), np.inf), ww)
    for i, s in enumerate(sources.tolist()):
        for t in range(0, g.n, 17):
            if not np.isfinite(oracle[i, t]) or t == s:
                continue
            # walk back via predecessors; total length must equal dist
            cur, total, hops = t, 0.0, 0
            while cur != s:
                p = int(pred[i, cur])
                assert p >= 0, (s, t, cur)
                assert (p, cur) in adj, "predecessor edge not in G"
                total += adj[(p, cur)]
                cur = p
                hops += 1
                assert hops <= g.n
            assert np.isclose(total, oracle[i, t], rtol=1e-5)


def test_index_save_load_roundtrip(tmp_path):
    g = gnm_random_digraph(120, 500, seed=21)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    path = str(tmp_path / "hod_index.npz")
    ix.save(path)
    from repro.core.index import HoDIndex
    ix2 = HoDIndex.load(path)
    src = np.array([3, 77], dtype=np.int32)
    d1 = QueryEngine(ix).ssd(src)
    d2 = QueryEngine(ix2).ssd(src)
    assert np.array_equal(d1, d2)


def test_batched_equals_single():
    g = gnm_random_digraph(150, 600, seed=4)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix)
    batch = eng.ssd(np.array([1, 2, 3], dtype=np.int32))
    for i, s in enumerate([1, 2, 3]):
        single = eng.ssd(np.array([s], dtype=np.int32))
        assert np.array_equal(batch[i], single[0])


@st.composite
def random_graphs(draw):
    n = draw(st.integers(8, 60))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.integers(1, 9, m).astype(np.float64)
    keep = src != dst
    return n, src[keep], dst[keep], w[keep], seed


@settings(max_examples=25, deadline=None)
@given(random_graphs())
def test_property_hod_matches_dijkstra(data):
    n, src, dst, w, seed = data
    if src.size == 0:
        return
    g = from_edges(n, src, dst, w)
    cfg = BuildConfig(max_core_nodes=8, max_core_edges=256, seed=seed % 7)
    res = build_hod(g, cfg)
    ix = pack_index(g, res, chunk=32)
    sources = np.array([0, n // 2, n - 1], dtype=np.int32)
    oracle = dijkstra_reference(g, sources)
    d = QueryEngine(ix).ssd(sources)[:, :n]
    finite = np.isfinite(oracle)
    assert np.allclose(d[finite], oracle[finite], rtol=1e-5)
    assert np.all(np.isinf(d[~finite]))


@settings(max_examples=10, deadline=None)
@given(random_graphs())
def test_property_shortcut_lengths_never_shorter(data):
    """Augmentation soundness: added shortcuts can only match (never beat)
    true distances — the invariant behind §4.1's 'retaining e is safe'."""
    n, src, dst, w, seed = data
    if src.size == 0:
        return
    g = from_edges(n, src, dst, w)
    res = build_hod(g, BuildConfig(max_core_nodes=8, max_core_edges=256))
    oracle = dijkstra_reference(g, np.arange(n, dtype=np.int32))
    for v in res.removal_order:
        for (u, ww, _) in res.f_adj[v]:
            assert ww >= oracle[v, u] - 1e-9
        for (u, ww, _) in res.b_adj[v]:
            assert ww >= oracle[u, v] - 1e-9


def test_closeness_estimation_runs():
    from repro.core import estimate_closeness
    g = grid_road_graph(10, seed=1)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    eng = QueryEngine(ix)
    out = estimate_closeness(eng, k_override=16, batch_size=8)
    assert out.closeness.shape == (g.n,)
    assert np.all(np.isfinite(out.closeness))
    assert out.k == 16
