"""§Perf optimized variants must be numerically equivalent to baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GraphBatch

KEY = jax.random.PRNGKey(0)


def test_attention_opt_matches_baseline():
    from repro.models.layers import attention_causal, attention_causal_opt
    rng = np.random.default_rng(0)
    for (b, t, h, kh, dh, chunk) in [(2, 48, 8, 2, 16, 16),
                                     (1, 65, 4, 4, 8, 32),
                                     (2, 64, 16, 8, 16, 16)]:
        q = jnp.asarray(rng.normal(size=(b, t, h, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, t, kh, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, t, kh, dh)), jnp.float32)
        a = attention_causal(q, k, v, chunk=chunk)
        o = attention_causal_opt(q, k, v, chunk=chunk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(o), atol=5e-3)


def test_attention_opt_in_model():
    from repro.models.transformer import (TransformerConfig, init_params,
                                          loss_fn)
    cfg = TransformerConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab=256,
                            attn_chunk=16, loss_chunk=32)
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 64), 0, 256)
    l0 = loss_fn(p, toks, toks, cfg)
    l1 = loss_fn(p, toks, toks, dataclasses.replace(cfg, attn_opt=True))
    assert abs(float(l0) - float(l1)) < 2e-2


def _graph(rng, n=64, e=256):
    return GraphBatch(
        n_nodes=n, n_graphs=1,
        src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        node_feat=jnp.asarray(rng.normal(size=(n, 20)), jnp.float32),
        edge_feat=jnp.asarray(rng.normal(size=(e, 3)), jnp.float32),
        labels=jnp.asarray(rng.integers(0, 5, n), jnp.int32),
        train_mask=jnp.ones(n, bool))


def test_partitioned_layout_matches_baseline():
    from repro.models.gnn import gcn, gin, schnet
    rng = np.random.default_rng(0)
    g = _graph(rng)
    cases = [
        (gcn, gcn.GCNConfig(d_in=20, n_classes=5)),
        (gin, gin.GINConfig(d_in=20, n_classes=5, node_level=True,
                            n_layers=2)),
        (schnet, schnet.SchNetConfig(d_in=20, n_rbf=16, n_targets=5,
                                     n_interactions=2)),
    ]
    for mod, cfg in cases:
        p = mod.init_params(KEY, cfg)
        a = mod.forward(p, g, cfg)
        b = mod.forward(p, g, dataclasses.replace(
            cfg, edge_layout="partitioned"))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_dst_ranged_layout_matches_baseline():
    from repro.data.graphs import bucket_edges_by_dst
    from repro.models.gnn import equiformer_v2 as eq
    rng = np.random.default_rng(0)
    g = _graph(rng)
    cfg = eq.EquiformerV2Config(d_in=20, n_layers=2, d_hidden=16, l_max=2,
                                m_max=1, n_heads=2, n_rbf=8, n_targets=5)
    p = eq.init_params(KEY, cfg)
    base = eq.forward(p, g, dataclasses.replace(cfg, edge_chunk=64))
    # bucket the same edges into 4 dst ranges; padded count per bucket
    gb = bucket_edges_by_dst(g, 4, pad_factor=2.0)
    per = gb.src.shape[0] // 4
    ranged = eq.forward(p, gb, dataclasses.replace(
        cfg, edge_chunk=per, edge_layout="dst_ranged"))
    np.testing.assert_allclose(np.asarray(base), np.asarray(ranged),
                               atol=1e-4)


def test_bucket_edges_preserves_multiset():
    from repro.data.graphs import bucket_edges_by_dst
    rng = np.random.default_rng(3)
    g = _graph(rng, n=32, e=100)
    gb = bucket_edges_by_dst(g, 4, pad_factor=2.0)
    real = np.asarray(gb.src) < g.n_nodes
    pairs_a = sorted(zip(np.asarray(g.src).tolist(),
                         np.asarray(g.dst).tolist()))
    pairs_b = sorted(zip(np.asarray(gb.src)[real].tolist(),
                         np.asarray(gb.dst)[real].tolist()))
    assert pairs_a == pairs_b
    # each bucket's real dsts fall in its range
    per = gb.src.shape[0] // 4
    rng_sz = -(-g.n_nodes // 4)
    d = np.asarray(gb.dst)
    for b in range(4):
        blk = d[b * per:(b + 1) * per]
        blk = blk[blk < g.n_nodes]
        assert np.all((blk >= b * rng_sz) & (blk < (b + 1) * rng_sz))
