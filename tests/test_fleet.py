"""ISSUE-10 sharded serving fleet: routing edge cases (DESIGN.md §13).

The structural claim under test: a fleet partitions *storage*, not
*math* — so answers are bit-identical to a single host at every shard
count and under every degenerate block layout, and a shard-local
fault travels the same path back into the query thread as a
single-host fault would.
"""
import os
import tempfile

import numpy as np
import pytest

from repro import shardlib as sl
from repro.core import (BuildConfig, build_hod, gnm_random_digraph,
                        pack_index)
from repro.fleet import (REPLICATED_SEGMENTS, ServingFleet,
                         StorePartition, split_budget)
from repro.storage import (IndexStore, PageCache, StreamingQueryEngine,
                           segment_bytes)

CFG = BuildConfig(max_core_nodes=32, max_core_edges=1024, seed=0)


@pytest.fixture(scope="module")
def packed():
    g = gnm_random_digraph(150, 600, seed=4, weighted=True)
    res = build_hod(g, CFG)
    ix = pack_index(g, res, chunk=64)
    return g, ix


@pytest.fixture(scope="module")
def store_dir(packed):
    _, ix = packed
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "store")
        ix.save_store(path, block_bytes=1024, codec="delta")
        yield path


def _solo_engine(store_dir, budget):
    store = IndexStore(store_dir, cache=PageCache(budget, policy="2q"))
    return StreamingQueryEngine(store, queue_depth=4)


def _fleet_engine(store_dir, n, budget, **kw):
    fleet = ServingFleet(store_dir, n, cache_bytes=budget, **kw)
    return StreamingQueryEngine(fleet.store, queue_depth=4), fleet


# ------------------------------------------------------------ partition
def test_partition_ranges_are_contiguous_and_balanced():
    part = StorePartition({"plan_f": 10, "plan_b": 7, "plan_core": 3}, 4)
    for name, n_blocks in (("plan_f", 10), ("plan_b", 7)):
        owners = [part.owner(name, b) for b in range(1, n_blocks + 1)]
        assert owners == sorted(owners)          # contiguous ranges
        assert set(owners) == set(range(4))      # every shard owns some
        counts = [owners.count(s) for s in range(4)]
        assert max(counts) - min(counts) <= 1    # balanced by count
        # local ids are dense and 1-based within each shard's range
        for s in range(4):
            locals_ = [part.local_block(name, b) % (1 << 40)
                       for b in range(1, n_blocks + 1)
                       if part.owner(name, b) == s]
            assert locals_ == list(range(1, len(locals_) + 1))
    # the pinned tier is replicated: materialized home is shard 0
    assert "plan_core" in REPLICATED_SEGMENTS
    assert all(part.owner("plan_core", b) == 0 for b in (1, 2, 3))
    assert "replicated" in part.describe()


def test_partition_rejects_out_of_range_blocks():
    part = StorePartition({"plan_f": 5}, 2)
    with pytest.raises(ValueError, match="out of range"):
        part.owner("plan_f", 0)
    with pytest.raises(ValueError, match="out of range"):
        part.owner("plan_f", 6)
    with pytest.raises(ValueError, match="unknown segments"):
        StorePartition({"bogus": 5}, 2)


def test_partition_empty_shard_when_n_exceeds_blocks():
    part = StorePartition({"plan_f": 2}, 4)
    owners = {part.owner("plan_f", b) for b in (1, 2)}
    assert len(owners) == 2
    empty = set(range(4)) - owners
    assert empty                                 # some shards own nothing
    for s in empty:
        assert part.shard_blocks(s) == 0


def test_split_budget():
    assert split_budget(None, 3, 1024) == [None, None, None]
    # degenerate fleet keeps the exact budget (counter parity with an
    # unsharded server depends on it)
    assert split_budget(10_001, 1, 1024) == [10_001]
    # N>1 rounds UP to whole blocks, never down
    per = split_budget(10_000, 3, 1024)
    assert per == [4096, 4096, 4096]
    assert all(b % 1024 == 0 and b * 3 >= 10_000 for b in per)
    # budget is proportional to owned footprint (replicated segments
    # count toward shard 0, so its materialized core copy is funded by
    # its larger share rather than a side-channel)
    prop = split_budget(12_000, 2, 1024, owned_blocks=[3, 1])
    assert prop == [9216, 3072]  # ceil of 9000 / 3000 to whole blocks
    # a shard that owns nothing still gets a nominal slice (it serves
    # no traffic, so the slice is never resident)
    assert split_budget(12_000, 2, 1024, owned_blocks=[4, 0]) \
        == [12288, 3072]
    # a floor raises a shard's slice (the replicated tier's home must
    # hold the whole tier or every query thrashes it) without touching
    # the others
    assert split_budget(12_000, 2, 1024, owned_blocks=[3, 1],
                        floors=[10_000, 0]) == [10_240, 3072]


# ------------------------------------------------------ degenerate fleets
def test_n1_fleet_matches_plain_server(store_dir):
    budget = int(0.25 * segment_bytes(store_dir))
    srcs = np.arange(0, 150, 7, dtype=np.int32)
    solo = _solo_engine(store_dir, budget)
    feng, fleet = _fleet_engine(store_dir, 1, budget)
    try:
        want = solo.ssd(srcs)
        got = feng.ssd(srcs)
        np.testing.assert_array_equal(want, got)
        ss, fs = solo.store.cache.stats, fleet.store.cache.stats
        for field in ("hits", "misses", "bytes_read", "bytes_filled"):
            assert getattr(fs, field) == getattr(ss, field), field
    finally:
        solo.close()
        feng.close()
    assert fleet._workers_down      # engine close shut the shard workers


def test_all_blocks_on_one_shard_still_bit_identical(store_dir):
    """owner_fn forces every partitioned block onto shard 0: shard 1
    is pure dead weight, but routing through it must not change a
    single answer, and it must see zero traffic."""
    budget = int(0.25 * segment_bytes(store_dir))
    srcs = np.arange(0, 150, 11, dtype=np.int32)
    solo = _solo_engine(store_dir, budget)
    feng, fleet = _fleet_engine(store_dir, 2, budget,
                                owner_fn=lambda name, block: 0)
    try:
        np.testing.assert_array_equal(solo.ssd(srcs), feng.ssd(srcs))
        idle = fleet.shards[1].cache.stats
        assert (idle.hits, idle.misses, idle.bytes_read) == (0, 0, 0)
        assert fleet.shards[0].cache.stats.misses > 0
    finally:
        solo.close()
        feng.close()


def test_sources_landing_on_empty_shard(packed, tmp_path):
    """More shards than any segment has blocks: the tail shards own
    empty ranges.  Every source — including ones whose sweep would hash
    to those shards — must still answer bit-identically."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=16384, codec="delta")
    probe = IndexStore(path)
    n = max(probe.segment_blocks().values()) + 1
    probe.close()
    budget = int(0.25 * segment_bytes(path))
    srcs = np.arange(0, 150, 5, dtype=np.int32)
    solo = _solo_engine(path, budget)
    feng, fleet = _fleet_engine(path, n, budget)
    try:
        assert any(fleet.partition.shard_blocks(s) == 0
                   for s in range(n)), "want at least one empty shard"
        np.testing.assert_array_equal(solo.ssd(srcs), feng.ssd(srcs))
        stats = fleet.stats()
        assert sum(r["bytes_read"] for r in stats.rows) == \
            stats.cache.bytes_read
        for r in stats.rows:
            if r["blocks"] == 0:
                assert r["hits"] + r["misses"] == 0
    finally:
        solo.close()
        feng.close()


# ----------------------------------------------------- fault propagation
def test_shard_worker_crc_error_raises_in_query_thread(packed, tmp_path):
    """A corrupt frame decoded on a *shard's* decode pool at N=2 must
    surface in the querying thread exactly like the single-host
    pipeline fault (test_pipeline), and stay repeatable — the poisoned
    placeholder is discarded, not stuck."""
    _, ix = packed
    path = str(tmp_path / "store")
    ix.save_store(path, block_bytes=1024, codec="delta")
    seg = os.path.join(path, "plan_f.seg")
    with open(seg, "r+b") as f:
        f.seek(2 * 1024 + 100)
        f.write(b"\xde\xad\xbe\xef" * 8)
    feng, _ = _fleet_engine(path, 2, None, decode_workers=2)
    try:
        with pytest.raises(ValueError, match="CRC mismatch"):
            feng.ssd(np.array([0], dtype=np.int32))
        with pytest.raises(ValueError, match="CRC mismatch"):
            feng.ssd(np.array([0], dtype=np.int32))
    finally:
        feng.close()


# ------------------------------------------------------------- shardlib
def test_pmin_identity_without_axes_and_under_1_device_mesh():
    import jax

    from jax.sharding import PartitionSpec as P

    x = np.array([3.0, 1.0, 2.0], np.float32)
    np.testing.assert_array_equal(sl.pmin(x, ()), x)
    mesh = jax.make_mesh((1,), ("data",))
    with sl.axis_rules(mesh, {"batch": "data"}):
        out = sl.maybe_shard_map(
            lambda v: sl.pmin(v, ("data",)),
            in_specs=(P("data"),), out_specs=P())(x)
    np.testing.assert_array_equal(np.asarray(out), x)
