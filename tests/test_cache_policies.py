"""Trace-driven page-cache policy conformance (DESIGN.md §6).

A pure-python reference model re-implements the documented state
machines of all four ``PageCache`` policies — LRU, CLOCK, and the
scan-resistant ARC/2Q (window + warm-fill + ghost-gated admission) —
with plain lists.  Randomized and adversarial (cyclic-scan) block
traces are replayed through both the production cache and the model,
asserting hit/miss/eviction counters, resident bytes, and the resident
key set match *exactly* after every access.  A hypothesis property
(real engine in CI, deterministic fallback otherwise — see
``hypsupport``) extends the same check to arbitrary traces and
budgets.

The policy-behavior tests at the bottom lock in the tentpole's win:
on a pure cyclic scan larger than the budget, LRU/CLOCK retain nothing
(the documented 0% baseline) while ARC/2Q keep a frozen prefix
resident — plus the pinning protocol's guarantees.
"""
import numpy as np
import pytest

from hypsupport import given, settings, st
from repro.storage import PageCache
from repro.storage.pagecache import POLICIES

BS = 64     # nominal block size for trace generators


# ----------------------------------------------------------- reference model
class RefCache:
    """Independent reference implementation of the PageCache policies.

    Plain lists, index 0 evicts first; no locks, no loader plumbing —
    just the documented state machines (module docstring of
    ``repro/storage/pagecache.py``).
    """

    WINDOW_FRAC = 0.125

    def __init__(self, capacity, policy):
        assert policy in POLICIES
        self.cap = capacity
        self.policy = policy
        self.hits = self.misses = self.evictions = 0
        self.entries = []           # lru/clock: [key, size, ref]
        self.win, self.t1, self.t2 = [], [], []     # arc/2q: [key, size]
        self.b1, self.b2 = [], []                   # ghosts: [key, size]
        self.p = 0.0

    # -- bookkeeping helpers
    @staticmethod
    def _bytes(lst):
        return sum(e[1] for e in lst)

    def resident_bytes(self):
        if self.policy in ("lru", "clock"):
            return self._bytes(self.entries)
        return (self._bytes(self.win) + self._bytes(self.t1)
                + self._bytes(self.t2))

    def resident_keys(self):
        if self.policy in ("lru", "clock"):
            return [e[0] for e in self.entries]
        return [e[0] for e in self.win + self.t1 + self.t2]

    def _win_cap(self):
        return max(1, int(self.cap * self.WINDOW_FRAC))

    def _find(self, lst, key):
        for i, e in enumerate(lst):
            if e[0] == key:
                return i
        return None

    def _unghost(self, key):
        for lst in (self.b1, self.b2):
            i = self._find(lst, key)
            if i is not None:
                del lst[i]

    def _ghost(self, lst, key, size):
        self._unghost(key)
        lst.append([key, size])

    def _trim_ghosts(self):
        if self.cap is None:
            return
        while self._bytes(self.b1) > self.cap:
            self.b1.pop(0)
        while self._bytes(self.b2) > self.cap:
            self.b2.pop(0)

    # -- evictions
    def _evict_window(self, keep):
        for i, (k, s) in enumerate(self.win):
            if k != keep:
                del self.win[i]
                self._ghost(self.b1, k, s)
                self.evictions += 1
                return True
        return False

    def _evict_main_one(self):
        if self.policy == "arc" and self.t1 \
                and (self._bytes(self.t1) > self.p or not self.t2):
            k, s = self.t1.pop(0)
            self._ghost(self.b1, k, s)
        elif self.t2:
            k, s = self.t2.pop(0)
            if self.policy == "arc":
                self._ghost(self.b2, k, s)
        elif self.t1:
            k, s = self.t1.pop(0)
            self._ghost(self.b1, k, s)
        else:
            return False
        self.evictions += 1
        return True

    def _shrink_main(self, keep):
        if self.cap is None:
            return
        while self.resident_bytes() > self.cap:
            if self._evict_main_one():
                continue
            if not self._evict_window(keep):
                break

    def _shrink_window(self, keep):
        if self.cap is None:
            return
        wc = self._win_cap()
        while (self._bytes(self.win) > wc
               or self.resident_bytes() > self.cap) and len(self.win) > 1:
            if not self._evict_window(keep):
                break
        while self.resident_bytes() > self.cap:
            if not self._evict_main_one():
                break

    def _main_has_room(self, size):
        if self.cap is None:
            return True
        main = self._bytes(self.t1) + self._bytes(self.t2)
        reserved = max(self._win_cap(), self._bytes(self.win))
        return main + size <= self.cap - reserved

    # -- legacy (lru/clock) eviction
    def _evict_legacy(self, keep):
        if self.policy == "lru":
            for i, e in enumerate(self.entries):
                if e[0] != keep:
                    del self.entries[i]
                    self.evictions += 1
                    return
            return
        for _pass in range(2):          # CLOCK: second chance
            victim = None
            for k in [e[0] for e in self.entries]:      # pass snapshot
                i = self._find(self.entries, k)
                if k == keep:
                    continue
                if self.entries[i][2]:
                    self.entries[i][2] = False          # spare once
                    self.entries.append(self.entries.pop(i))
                else:
                    victim = i
                    break
            if victim is not None:
                del self.entries[victim]
                self.evictions += 1
                return

    # -- the access path
    def access(self, key, size):
        """One block fetch; returns True on a hit."""
        if self.policy in ("lru", "clock"):
            i = self._find(self.entries, key)
            if i is not None:
                self.hits += 1
                if self.policy == "lru":
                    self.entries.append(self.entries.pop(i))
                else:
                    self.entries[i][2] = True
                return True
            self.misses += 1
            if self.cap == 0 or (self.cap is not None and size > self.cap):
                return False
            self.entries.append([key, size, False])
            if self.cap is not None:
                while self.resident_bytes() > self.cap:
                    before = self.resident_bytes()
                    self._evict_legacy(keep=key)
                    if self.resident_bytes() == before:
                        break
            return False
        # arc / 2q
        i = self._find(self.win, key)
        if i is not None:
            self.hits += 1
            if self.policy == "arc":    # refresh recency; 2Q: FIFO stays
                self.win.append(self.win.pop(i))
            return True
        i = self._find(self.t1, key)
        if i is not None:               # ARC: T1 hit promotes to T2
            self.hits += 1
            self.t2.append(self.t1.pop(i))
            return True
        i = self._find(self.t2, key)
        if i is not None:
            self.hits += 1
            self.t2.append(self.t2.pop(i))
            return True
        self.misses += 1
        if self.cap == 0 or (self.cap is not None and size > self.cap):
            return False
        in_b1 = self._find(self.b1, key) is not None
        in_b2 = self._find(self.b2, key) is not None
        if in_b1 or (self.policy == "arc" and in_b2):
            if self.policy == "arc":
                if in_b1:
                    if self.cap is not None:
                        self.p = min(float(self.cap), self.p + size)
                else:
                    self.p = max(0.0, self.p - size)
            self._unghost(key)
            self.t2.append([key, size])
            self._shrink_main(keep=key)
        elif self._main_has_room(size):
            if self.policy == "arc":
                self.t1.append([key, size])     # ARC warm fill -> T1
            else:
                self.t2.append([key, size])     # 2Q warm fill -> Am
        else:
            self.win.append([key, size])
            self._shrink_window(keep=key)
        self._trim_ghosts()
        return False


# ------------------------------------------------------------ trace replay
def replay_and_compare(policy, capacity, trace):
    """Replay ``trace`` = [(key, size), ...] through PageCache and
    RefCache, asserting exact agreement after every access."""
    cache = PageCache(capacity, policy=policy)
    ref = RefCache(capacity, policy)
    for step, (key, size) in enumerate(trace):
        loaded = []
        data = cache.get(key, lambda: loaded.append(1) or b"\0" * size)
        impl_hit = not loaded
        ref_hit = ref.access(key, size)
        ctx = (policy, capacity, step, key)
        assert len(data) == size, ctx
        assert impl_hit == ref_hit, f"hit divergence at {ctx}"
        assert cache.stats.hits == ref.hits, ctx
        assert cache.stats.misses == ref.misses, ctx
        assert cache.stats.evictions == ref.evictions, ctx
        assert cache.resident_bytes == ref.resident_bytes(), ctx
        assert sorted(map(str, cache.resident_keys())) \
            == sorted(map(str, ref.resident_keys())), ctx
        if capacity is not None:
            assert cache.resident_bytes <= capacity, ctx
    return cache, ref


def cyclic_trace(n_blocks, passes=2, size=BS):
    return [(k, size) for _ in range(passes) for k in range(n_blocks)]


def boundary_trace(n_blocks, passes=2, size=BS):
    """Affinity-layout style: 3-block levels sharing boundary blocks
    (… b,b+1,b+2 | b+2,b+3,b+4 | …), cycled ``passes`` times."""
    one = []
    b = 0
    while b < n_blocks - 2:
        one += [(b, size), (b + 1, size), (b + 2, size)]
        b += 2
    return one * passes


BUDGET_GRID = (0, 5 * BS, 10 * BS, 1000 * BS, None)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("capacity", BUDGET_GRID)
def test_conformance_cyclic_and_boundary_traces(policy, capacity):
    replay_and_compare(policy, capacity, cyclic_trace(40, passes=3))
    replay_and_compare(policy, capacity, boundary_trace(40, passes=3))


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_conformance_randomized_traces(policy, seed):
    rng = np.random.default_rng(seed)
    size_of = rng.integers(1, 3 * BS, size=24)   # fixed size per block id
    keys = rng.integers(0, 24, size=400)
    trace = [(int(k), int(size_of[k])) for k in keys]
    for capacity in (7 * BS, 30 * BS, None):
        replay_and_compare(policy, capacity, trace)


@pytest.mark.parametrize("policy", POLICIES)
def test_conformance_skewed_trace(policy):
    """Zipf-ish mix: a hot set re-referenced inside long scans — the
    regime where ghost admission and ARC's adaptation actually fire."""
    rng = np.random.default_rng(7)
    trace = []
    for i in range(600):
        if rng.random() < 0.3:
            trace.append((int(rng.integers(0, 4)), BS))        # hot
        else:
            trace.append((100 + i % 50, BS))                   # scan
    replay_and_compare(policy, 8 * BS, trace)


# The property: arbitrary traces and budgets never diverge from the
# model (and never overshoot the byte budget).  Slow under the real
# engine only in generation breadth; deadline=None marks it exempt
# from the per-example deadline.
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 14), min_size=0, max_size=120),
       st.integers(0, 40),
       st.integers(0, 3))
def test_property_conformance_arbitrary_traces(keys, cap_blocks, pol_idx):
    policy = POLICIES[pol_idx]
    capacity = cap_blocks * BS if cap_blocks else 0
    # deterministic per-key sizes (not all equal: exercises byte logic)
    trace = [(k, BS + 7 * (k % 5)) for k in keys]
    replay_and_compare(policy, capacity, trace)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=0, max_size=80),
       st.integers(0, 3))
def test_property_conformance_unbounded_budget(keys, pol_idx):
    trace = [(k, BS) for k in keys]
    cache, ref = replay_and_compare(POLICIES[pol_idx], None, trace)
    # unbounded: every distinct key stays resident, nothing ever evicts
    assert cache.stats.evictions == 0
    assert sorted(set(k for k, _ in trace)) \
        == sorted(set(cache.resident_keys()))


# --------------------------------------------------- policy behavior locks
def hit_rate_per_pass(policy, capacity, trace_pass, passes=3):
    """Replay one pass repeatedly; per-pass hit rates (stats reset
    between passes, residency kept)."""
    cache = PageCache(capacity, policy=policy)
    rates = []
    for _ in range(passes):
        cache.reset_stats()
        for key, size in trace_pass:
            cache.get(key, lambda: b"\0" * size)
        rates.append(cache.stats.hit_rate())
    return rates


def test_cyclic_scan_lru_clock_baseline_is_zero():
    """The documented baseline: a cyclic scan 4x the budget leaves
    LRU/CLOCK with a 0.0 hit rate on every pass — each block is evicted
    moments before its re-read (PR-3's BENCH_serve rows)."""
    one_pass = cyclic_trace(40, passes=1)
    for policy in ("lru", "clock"):
        assert hit_rate_per_pass(policy, 10 * BS, one_pass) \
            == [0.0, 0.0, 0.0]


def test_cyclic_scan_arc_2q_retain_frozen_prefix():
    """Scan resistance: after the cold pass, ARC/2Q re-hit their frozen
    warm-fill prefix on every subsequent cyclic pass."""
    one_pass = cyclic_trace(40, passes=1)
    for policy in ("arc", "2q"):
        rates = hit_rate_per_pass(policy, 10 * BS, one_pass)
        assert rates[0] == 0.0                      # cold fill
        assert rates[1] > 0.15, (policy, rates)     # ~budget - window
        assert rates[2] >= rates[1] - 1e-9, (policy, rates)  # stable


def test_pinned_blocks_survive_adversarial_scan():
    for policy in POLICIES:
        cache = PageCache(10 * BS, policy=policy)
        cache.get("pinme", lambda: b"\0" * BS, pin=True)
        assert "pinme" in cache.pinned_keys()
        for key, size in cyclic_trace(100, passes=2):
            cache.get(key, lambda: b"\0" * size)
        # still answered from memory, never evicted
        loaded = []
        cache.get("pinme", lambda: loaded.append(1) or b"\0" * BS)
        assert not loaded, policy
        assert cache.resident_bytes <= 10 * BS


def test_pin_budget_caps_pinning_and_degrades_gracefully():
    cache = PageCache(10 * BS, policy="2q")
    for i in range(10):                 # pin cap = PIN_FRAC (50%) = 5 blocks
        cache.get(("p", i), lambda: b"\0" * BS, pin=True)
    assert cache.pinned_bytes <= int(10 * BS * PageCache.PIN_FRAC)
    assert len(cache.pinned_keys()) == 5
    # the overflow blocks were still cached (normal admission)
    assert cache.resident_bytes > cache.pinned_bytes


def test_unpin_releases_back_to_policy_and_is_idempotent():
    for policy in POLICIES:
        cache = PageCache(10 * BS, policy=policy)
        cache.get("a", lambda: b"\0" * BS, pin=True)
        cache.unpin(["a", "never-seen"])        # unknown keys ignored
        assert cache.pinned_keys() == []
        assert "a" in cache.resident_keys()     # back in the main region
        cache.unpin(["a"])                      # idempotent
        # now evictable again: a big adversarial scan pushes it out
        for key, size in cyclic_trace(60, passes=2):
            cache.get(key, lambda: b"\0" * size)
        assert cache.resident_bytes <= 10 * BS


def test_pin_via_existing_resident_block():
    cache = PageCache(10 * BS, policy="arc")
    cache.get("a", lambda: b"\0" * BS)
    assert cache.pin("a") is True
    assert cache.pin("missing") is False
    assert "a" in cache.pinned_keys()
