"""Index ``.npz`` format versioning + SweepPlan serialization.

v2+ files persist the static-shape sweep plans (DESIGN.md §5); v1 files
(chunk arrays only) must still load — rebuilding the plans on the fly
with a warning — and answer identical queries.  v3 marks the store
generation, v4 the affinity segment layout, v5 the codec-framed
segments (same ``.npz`` keys throughout; the disk-resident block store
lives in `repro.storage` and is covered by tests/test_storage.py and
tests/test_codecs.py).
"""
import numpy as np
import pytest

from repro.core import (BuildConfig, QueryEngine, build_hod,
                        gnm_random_digraph, pack_index)
from repro.core.index import FORMAT_VERSION, HoDIndex

CFG = BuildConfig(max_core_nodes=32, max_core_edges=1024, seed=0)


@pytest.fixture(scope="module")
def packed():
    g = gnm_random_digraph(130, 520, seed=8, weighted=True)
    res = build_hod(g, CFG)
    return g, pack_index(g, res, chunk=64)


def _as_legacy_v1(path: str, legacy_path: str) -> None:
    """Strip every v2-only key, forging the pre-plan file layout."""
    z = np.load(path)
    v1 = {k: z[k] for k in z.files
          if k not in ("format_version", "k_cap")
          and not k.startswith(("pf_", "pb_", "pc_"))}
    np.savez_compressed(legacy_path, **v1)


def test_saved_file_is_stamped_current_version(packed, tmp_path):
    _, ix = packed
    path = str(tmp_path / "ix.npz")
    ix.save(path)
    with np.load(path) as z:
        assert int(z["format_version"]) == FORMAT_VERSION == 5
        for pre in ("pf", "pb", "pc"):
            for part in ("dst", "src", "w", "assoc", "valid", "mask"):
                assert f"{pre}_{part}" in z.files


def test_roundtrip_preserves_plans_bitexact(packed, tmp_path):
    _, ix = packed
    path = str(tmp_path / "ix.npz")
    ix.save(path)
    ix2 = HoDIndex.load(path)
    assert ix2.format_version == FORMAT_VERSION and ix2.k_cap == ix.k_cap
    for field in ("plan_f", "plan_b", "plan_core"):
        a, b = getattr(ix, field), getattr(ix2, field)
        np.testing.assert_array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.src_idx, b.src_idx)
        np.testing.assert_array_equal(a.w, b.w)
        np.testing.assert_array_equal(a.assoc, b.assoc)
        np.testing.assert_array_equal(a.row_valid, b.row_valid)
        np.testing.assert_array_equal(a.level_mask, b.level_mask)


def test_legacy_v1_file_loads_with_warning_and_rebuilds(packed, tmp_path):
    _, ix = packed
    path = str(tmp_path / "ix.npz")
    legacy = str(tmp_path / "ix_v1.npz")
    ix.save(path)
    _as_legacy_v1(path, legacy)

    with pytest.warns(UserWarning, match="old-format"):
        ix_old = HoDIndex.load(legacy)
    assert ix_old.format_version == 1
    # the on-the-fly rebuild reproduces the packed plans exactly
    for field in ("plan_f", "plan_b", "plan_core"):
        a, b = getattr(ix, field), getattr(ix_old, field)
        np.testing.assert_array_equal(a.dst, b.dst)
        np.testing.assert_array_equal(a.src_idx, b.src_idx)
        np.testing.assert_array_equal(a.w, b.w)
        np.testing.assert_array_equal(a.assoc, b.assoc)

    # and a v2 load raises no warning at all
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        HoDIndex.load(path)


@pytest.mark.parametrize("version", [2, 3, 4])
def test_older_plan_file_still_loads_without_warning(packed, tmp_path,
                                                     version):
    """v2/v3/v4 files (plans serialized, pre-codec stamps) load
    silently and keep their plans — the store, affinity, and codec
    generations only added formats."""
    _, ix = packed
    path = str(tmp_path / "ix.npz")
    old = str(tmp_path / f"ix_v{version}.npz")
    ix.save(path)
    with np.load(path) as z:
        data = {k: z[k] for k in z.files if k != "format_version"}
    np.savez_compressed(old, format_version=np.int64(version), **data)

    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        ix2 = HoDIndex.load(old)
    assert ix2.format_version == version
    np.testing.assert_array_equal(ix.plan_f.w, ix2.plan_f.w)
    src = np.array([0, 64], dtype=np.int32)
    np.testing.assert_array_equal(QueryEngine(ix).ssd(src),
                                  QueryEngine(ix2).ssd(src))


def test_legacy_and_v2_answer_identical_queries(packed, tmp_path):
    g, ix = packed
    path = str(tmp_path / "ix.npz")
    legacy = str(tmp_path / "ix_v1.npz")
    ix.save(path)
    _as_legacy_v1(path, legacy)
    with pytest.warns(UserWarning):
        ix_old = HoDIndex.load(legacy)
    ix_new = HoDIndex.load(path)
    src = np.array([0, 40, 129], dtype=np.int32)
    for use_pallas in (False, True):
        d_old = QueryEngine(ix_old, use_pallas=use_pallas).ssd(src)
        d_new = QueryEngine(ix_new, use_pallas=use_pallas).ssd(src)
        np.testing.assert_array_equal(d_old, d_new)
    s_old = QueryEngine(ix_old).sssp(src)
    s_new = QueryEngine(ix_new).sssp(src)
    np.testing.assert_array_equal(s_old[0], s_new[0])
    np.testing.assert_array_equal(s_old[1], s_new[1])
