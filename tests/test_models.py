"""Model-level correctness: decode==forward, MoE, GNN equivariance, DLRM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import MoEConfig, attention_causal, attention_window
from repro.models.transformer import (TransformerConfig, decode_step,
                                      forward, init_params, lm_head_weight,
                                      loss_fn, make_cache, prefill)

KEY = jax.random.PRNGKey(0)


def _dense_cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=256, attn_chunk=16, loss_chunk=32)
    base.update(kw)
    return TransformerConfig(**base)


def test_attention_causal_matches_naive():
    rng = np.random.default_rng(0)
    b, t, h, kh, dh = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, t, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, dh)), jnp.float32)
    out = attention_causal(q, k, v, chunk=16)
    # naive oracle
    qg = q.reshape(b, t, kh, h // kh, dh) * dh ** -0.5
    sc = jnp.einsum("btkgd,bskd->bkgts", qg, k)
    mask = jnp.tril(jnp.ones((t, t), bool))
    sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    ref = jnp.einsum("bkgts,bskd->btkgd", p, v).reshape(b, t, h, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_attention_window_matches_masked_full():
    rng = np.random.default_rng(1)
    b, t, h, kh, dh, w = 2, 64, 4, 2, 16, 16
    q = jnp.asarray(rng.normal(size=(b, t, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, dh)), jnp.float32)
    out = attention_window(q, k, v, w)
    qg = q.reshape(b, t, kh, h // kh, dh) * dh ** -0.5
    sc = jnp.einsum("btkgd,bskd->bkgts", qg, k)
    i = jnp.arange(t)
    mask = (i[:, None] >= i[None, :]) & (i[:, None] - i[None, :] < w)
    sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    ref = jnp.einsum("bkgts,bskd->btkgd", p, v).reshape(b, t, h, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("cfg", [
    _dense_cfg(),
    # capacity_factor high enough that no token drops: decode and forward
    # then agree exactly (capacity dropping is load-dependent by design)
    _dense_cfg(name="moe", d_ff=0,
               moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                             capacity_factor=8.0)),
    TransformerConfig(name="gem", n_layers=6, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=256, attn_chunk=16,
                      loss_chunk=32, sliding_window=16,
                      local_global_period=3, subquadratic=True),
], ids=["dense", "moe", "local_global"])
def test_prefill_decode_match_forward(cfg):
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    logits_pre, caches = prefill(p, toks, cfg)
    x, _ = forward(p, toks, cfg)
    w = lm_head_weight(p, cfg).astype(cfg.compute_dtype)
    ref_pre = (x[:, -1] @ w).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(ref_pre),
                               atol=1e-3)
    # one decode step vs forward on the extended sequence
    cache_full = make_cache(cfg, 2, 80)
    caches_f = jax.tree.map(
        lambda full, part: full.at[:, :, :part.shape[2]].set(part)
        if full.shape[2] > part.shape[2] else part, cache_full, caches)
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)
    logits_dec, _ = decode_step(p, caches_f, nxt, jnp.int32(64), cfg)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    x2, _ = forward(p, toks2, cfg)
    ref = (x2[:, -1] @ w).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(ref),
                               atol=1e-3)


def test_lm_training_reduces_loss():
    cfg = _dense_cfg(n_layers=2, vocab=64, loss_chunk=16)
    from repro.optim import adamw_init, adamw_update
    p = init_params(KEY, cfg)
    opt = adamw_init(p)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 64, (4, 33)), jnp.int32)

    @jax.jit
    def step(p, opt):
        loss, g = jax.value_and_grad(
            lambda p: loss_fn(p, toks[:, :-1], toks[:, 1:], cfg))(p)
        p, opt, _ = adamw_update(p, g, opt, 1e-2, weight_decay=0.0)
        return p, opt, loss

    losses = []
    for _ in range(30):
        p, opt, loss = step(p, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_moe_aux_loss_and_balance():
    cfg = _dense_cfg(name="moe", d_ff=0,
                     moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                   router_aux_coef=0.1))
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    _, aux = forward(p, toks, cfg)
    assert float(aux) > 0.0     # aux loss present
    g = jax.grad(lambda p: loss_fn(p, toks, toks, cfg))(p)
    for pos in range(len(g["layers"])):
        assert float(jnp.abs(g["layers"][pos]["router"]).sum()) > 0


def test_gnn_equivariance_and_chunking():
    from repro.models.gnn import equiformer_v2 as eq
    from repro.models.gnn.common import GraphBatch
    rng = np.random.default_rng(0)
    n, e = 30, 100
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    vec = np.asarray(rng.normal(size=(e, 3)), np.float32)
    cfg = eq.EquiformerV2Config(n_layers=2, d_hidden=32, l_max=4, m_max=2,
                                n_heads=4, n_rbf=16)
    p = eq.init_params(KEY, cfg)
    feat = jnp.asarray(rng.integers(0, 10, n), jnp.int32)

    def out_for(v):
        g = GraphBatch(n_nodes=n, n_graphs=1, src=src, dst=dst,
                       node_feat=feat, edge_feat=jnp.asarray(v, jnp.float32),
                       graph_ids=jnp.zeros(n, jnp.int32))
        return eq.predict(p, g, cfg)

    o1 = out_for(vec)
    th1, th2 = 0.73, 0.41
    rz = np.array([[np.cos(th1), -np.sin(th1), 0],
                   [np.sin(th1), np.cos(th1), 0], [0, 0, 1]], np.float32)
    ry = np.array([[np.cos(th2), 0, np.sin(th2)], [0, 1, 0],
                   [-np.sin(th2), 0, np.cos(th2)]], np.float32)
    o2 = out_for(vec @ (rz @ ry).T)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)

    # rotation matrices are orthogonal representations
    from repro.models.gnn.equiformer_v2 import _edge_rotations
    rots = _edge_rotations(jnp.asarray(vec), 4)
    for l, r in enumerate(rots):
        eye = jnp.einsum("eij,ekj->eik", r, r)
        assert float(jnp.abs(eye - jnp.eye(2 * l + 1)).max()) < 1e-5


@pytest.mark.parametrize("arch", ["gcn-cora", "gin-tu", "schnet",
                                  "equiformer-v2"])
def test_gnn_chunked_equals_unchunked(arch):
    from repro.launch.steps import GNN_MODULES
    from repro.models.gnn.common import GraphBatch
    rng = np.random.default_rng(0)
    n, e = 50, 200
    g = GraphBatch(
        n_nodes=n, n_graphs=1,
        src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        node_feat=jnp.asarray(rng.normal(size=(n, 20)), jnp.float32),
        edge_feat=jnp.asarray(rng.normal(size=(e, 3)), jnp.float32),
        labels=jnp.asarray(rng.integers(0, 5, n), jnp.int32),
        train_mask=jnp.ones(n, bool))
    mod = GNN_MODULES[arch]
    if arch == "gcn-cora":
        from repro.models.gnn.gcn import GCNConfig as C
        cfg = C(d_in=20, n_classes=5)
    elif arch == "gin-tu":
        from repro.models.gnn.gin import GINConfig as C
        cfg = C(d_in=20, n_classes=5, node_level=True, n_layers=2)
    elif arch == "schnet":
        from repro.models.gnn.schnet import SchNetConfig as C
        cfg = C(d_in=20, n_rbf=16, n_targets=5, n_interactions=2)
    else:
        from repro.models.gnn.equiformer_v2 import EquiformerV2Config as C
        cfg = C(d_in=20, n_layers=2, d_hidden=16, l_max=2, m_max=1,
                n_heads=2, n_rbf=8, n_targets=5)
    p = mod.init_params(KEY, cfg)
    a = mod.forward(p, g, cfg)
    b = mod.forward(p, g, dataclasses.replace(cfg, edge_chunk=33))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_dlrm_embedding_bag_and_retrieval():
    from repro.models import dlrm
    rng = np.random.default_rng(0)
    cfg = dlrm.DLRMConfig(vocab_per_table=500)
    p = dlrm.init_params(KEY, cfg)
    tab = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = jnp.asarray([3, 4, 7, 1, 1, 2], jnp.int32)
    offs = jnp.asarray([0, 2, 5, 6], jnp.int32)
    out = dlrm.embedding_bag(tab, ids, offs, 3)
    ref = jnp.stack([tab[3] + tab[4], tab[7] + 2 * tab[1], tab[2]])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)

    dense = jnp.asarray(rng.normal(size=(1, 13)), jnp.float32)
    sparse = jnp.asarray(rng.integers(0, 500, (1, 26)), jnp.int32)
    cand = jnp.arange(500, dtype=jnp.int32)
    v, i = dlrm.retrieval_scores(p, dense, sparse, cand, cfg, top_k=10)
    u = dlrm.user_vector(p, dense, sparse, cfg)[0]
    ref_scores = p["tables"][0] @ u
    order = np.argsort(-np.asarray(ref_scores))[:10]
    assert np.array_equal(np.asarray(i), order)


def test_dlrm_training_reduces_loss():
    from repro.models import dlrm
    from repro.optim import adamw_init, adamw_update
    from repro.data.recsys import RecsysStream
    cfg = dlrm.DLRMConfig(vocab_per_table=1000)
    p = dlrm.init_params(KEY, cfg)
    opt = adamw_init(p)
    stream = RecsysStream(batch=256, vocab=1000)

    @jax.jit
    def step(p, opt, dense, sparse, y):
        loss, g = jax.value_and_grad(
            lambda p: dlrm.loss_fn(p, dense, sparse, y, cfg))(p)
        p, opt, _ = adamw_update(p, g, opt, 1e-2, weight_decay=0.0)
        return p, opt, loss

    losses = []
    for s in range(25):
        d, sp, y = stream.batch_at(s)
        p, opt, loss = step(p, opt, jnp.asarray(d), jnp.asarray(sp),
                            jnp.asarray(y))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
