"""Table 4 — average SSD query time: HoD vs VC-Index vs EM-BFS vs EM-Dijk.

Two columns per method where meaningful: measured CPU seconds in this
container, and modeled disk seconds from the BlockDevice (the paper's
regime — 2013 commodity HDD).  The paper's claim: HoD ≥ 10× faster than
VC-Index; EM methods orders of magnitude behind.
"""
import time

import numpy as np

from repro.core.baselines import em_bfs, em_dijkstra

from .common import build_hod_cached, dataset_suite, fmt_row, time_hod_query
from .table3_index_size import vc_cached


def run(n_queries: int = 16):
    print("\n== Table 4: avg SSD query time (ms measured / ms modeled-disk) ==")
    print(fmt_row(["dataset", "HoD", "VC-Index", "EM-BFS", "EM-Dijk",
                   "VC/HoD"]))
    rows = []
    for name, g in dataset_suite(undirected=True).items():
        art = build_hod_cached(name, g)
        hod_t, hod_io = time_hod_query(art, g, n_queries=n_queries)
        vc = vc_cached(name, g)
        rng = np.random.default_rng(0)
        srcs = rng.integers(0, g.n, 3)
        t0 = time.perf_counter()
        vc_io = 0.0
        for s in srcs:
            _, io = vc.ssd(int(s))
            vc_io += io.modeled_seconds()
        vc_t = (time.perf_counter() - t0) / len(srcs)
        vc_io /= len(srcs)
        weighted = bool((g.out_w != g.out_w[0]).any()) if g.m else False
        if not weighted:
            t0 = time.perf_counter()
            _, io_b = em_bfs(g, int(srcs[0]))
            bfs_t = time.perf_counter() - t0
            bfs = f"{bfs_t*1e3:.0f}/{io_b.modeled_seconds()*1e3:.0f}"
        else:
            bfs = "-"
        t0 = time.perf_counter()
        _, io_d = em_dijkstra(g, int(srcs[0]))
        dij_t = time.perf_counter() - t0
        print(fmt_row([
            name, f"{hod_t*1e3:.1f}/{hod_io*1e3:.0f}",
            f"{vc_t*1e3:.0f}/{vc_io*1e3:.0f}", bfs,
            f"{dij_t*1e3:.0f}/{io_d.modeled_seconds()*1e3:.0f}",
            f"{vc_t/max(hod_t,1e-9):.0f}x"]))
        rows.append({"dataset": name, "hod_s": hod_t,
                     "hod_modeled_io_s": hod_io, "vc_s": vc_t,
                     "vc_modeled_io_s": vc_io, "em_dijkstra_s": dij_t,
                     "em_dijkstra_modeled_io_s": io_d.modeled_seconds()})
    return rows
