"""Table 5 — closeness-estimation wall time (Eppstein–Wang, ε=0.1).

total = preprocessing + k·per-query, k = ln n / ε².  HoD additionally
*runs* the estimation end-to-end (batched) on the smallest dataset to
validate the projection against a measured number.
"""
import math
import time

from repro.core.closeness import estimate_closeness

from .common import build_hod_cached, dataset_suite, fmt_row, time_hod_query
from .table4_query_time import run as _  # noqa: F401 (shared cache warmup)


def run():
    print("\n== Table 5: closeness estimation, projected total (s) ==")
    print(fmt_row(["dataset", "k", "HoD(total)", "HoD(measured)",
                   "VC-Index(proj)"]))
    from .table3_index_size import vc_cached
    rows = []
    for name, g in dataset_suite(undirected=True).items():
        art = build_hod_cached(name, g)
        k = int(math.ceil(math.log(g.n) / 0.01))
        hod_q, _io = time_hod_query(art, g, n_queries=16)
        hod_total = art.build_seconds + k * hod_q
        measured = ""
        if g.n <= 5000:
            t0 = time.perf_counter()
            estimate_closeness(art.engine, eps=0.1, batch_size=64)
            measured = f"{art.build_seconds + time.perf_counter()-t0:.1f}"
        vc = vc_cached(name, g)
        t0 = time.perf_counter()
        vc.ssd(0)
        vc_q = time.perf_counter() - t0
        vc_total = vc.build_seconds + k * vc_q
        print(fmt_row([name, k, f"{hod_total:.1f}", measured or "-",
                       f"{vc_total:.1f}"]))
        rows.append((name, k, hod_total, vc_total))
    return rows
