"""Table 3 — index space consumption: HoD vs VC-Index."""
from repro.core.baselines import VCIndex

from .common import build_hod_cached, dataset_suite, fmt_row

_VC_CACHE = {}


def vc_cached(name, g):
    if name not in _VC_CACHE:
        _VC_CACHE[name] = VCIndex(g, top_nodes=256)
    return _VC_CACHE[name]


def run():
    print("\n== Table 3: index size (MB; paper: GB) ==")
    print(fmt_row(["dataset", "graph", "HoD", "VC-Index"]))
    rows = []
    for name, g in dataset_suite(undirected=True).items():
        art = build_hod_cached(name, g)
        vc = vc_cached(name, g)
        print(fmt_row([name, f"{g.nbytes()/1e6:.1f}",
                       f"{art.index_bytes/1e6:.1f}",
                       f"{vc.index_bytes()/1e6:.1f}"]))
        rows.append((name, art.index_bytes, vc.index_bytes()))
    return rows
