"""CI bench-regression gate: fail the build when a fresh serve run
regresses against the committed ``BENCH_serve.json`` baseline.

Compared per row, matched on stable keys:

* ``serve`` rows (key: ``batch``) — measured throughput must stay
  within ``--throughput-tol`` (default −20%) of the baseline's
  ``queries_per_s``;
* ``store`` rows (key: ``codec, cache_frac, policy``) — the page-cache
  ``hit_rate`` must stay within ``--hit-rate-tol`` (default −5pp,
  *absolute*), and ``real_bytes`` (actual segment bytes read —
  compressed bytes on codec stores) must not grow by more than
  ``--bytes-tol`` (default +10%);
* ``workloads`` rows (key: ``workload`` — ``ssd`` / ``p2p`` /
  ``mixed``, ISSUE-6) — same ``hit_rate`` / ``real_bytes`` checks as
  store rows, plus ``cold_query_bytes`` (the cold single-query sweep
  footprint, deterministic) must not grow past ``--bytes-tol``: a P2P
  sweep that stops saving I/O over the full sweep fails here;
* ``queue_depth`` rows (key: ``codec, queue_depth``, ISSUE-7) — same
  ``hit_rate`` / ``real_bytes`` checks, and a *fresh-run* invariant
  with no tolerance at all: at each codec, every depth > 1 row must
  read no more compressed bytes than the depth-1 row.  The pipeline's
  determinism design makes these equal; a deeper queue that reads
  extra bytes (speculative over-read, double-charged fills) fails
  regardless of what the baseline says;
* ``latency`` rows (key: ``mode``, ISSUE-8) — per-mode ``p99_ms`` must
  not grow by more than ``--latency-tol`` (default +50%; wall-time,
  so CI passes a looser value, like the throughput gate);
* ``fleet`` rows (key: ``shards``, ISSUE-10) — the sharded-fleet
  table.  A baseline shard-count row missing from the fresh run FAILS
  (a fleet width that stopped being benchmarked cannot pass); the
  fleet-aggregate ``hit_rate`` / ``real_bytes`` are gated by the same
  tolerances as single-host store rows.  A *fresh-run* invariant with
  no tolerance mirrors the in-bench assert: no ``shards > 1`` row may
  read more bytes than the ``shards == 1`` row — the table runs the
  raw codec precisely so this is a pure function of miss counts, and
  sharding must not inflate I/O;
* ``slo`` rows (key: ``cls, policy``, ISSUE-9) — the mixed-traffic
  scheduler table.  The four parent class rows (``ssd``/``p2p`` ×
  ``fifo``/``slo``) must exist in the fresh run *regardless of the
  baseline* (a scheduler that silently drops a traffic class cannot
  pass), their ``p99_ms`` is gated by ``--latency-tol`` and their
  wall-clock ``queries_per_s`` by ``--throughput-tol``; the
  ``.cached``/``.cold`` sub-rows are informational (membership
  depends on arrival timing, so they are not presence-checked).  A
  second *fresh-run* invariant mirrors the in-bench assert: for every
  ``cheap`` class, the ``slo`` policy's p99 must be strictly below
  the ``fifo`` baseline's — the whole point of the scheduler.

**Schema drift fails loudly** (ISSUE-8): documents are stamped with
``repro.obs.metrics.SCHEMA_VERSION`` by ``benchmarks/run.py``.  A
version mismatch — fresh vs the code's expected version, or baseline
vs fresh — stops the comparison with an explicit "regenerate the
baseline" violation, and a row missing an expected field is reported
the same way instead of crashing with a KeyError.

Hit rate and bytes-read are deterministic for a fixed graph, layout,
codec, and policy, so their tolerances only absorb intentional
layout/codec drift — a thrashing cache or a codec that stopped
shrinking reads fails loudly.  Throughput is machine-dependent: the
default −20% suits same-machine comparisons; CI compares against a
baseline committed from a different machine and passes a looser
``--throughput-tol`` (see .github/workflows/ci.yml) so the gate
catches collapses, not runner jitter.

A baseline row with no matching fresh row is itself a violation
(silently dropping a benchmark config cannot pass the gate); fresh
rows absent from the baseline (e.g. a newly added codec) are ignored.

    PYTHONPATH=src python -m benchmarks.check_regression \\
        --baseline baseline.json --fresh BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

HIT_RATE_TOL = 0.05     # absolute percentage points
THROUGHPUT_TOL = 0.20   # relative
BYTES_TOL = 0.10        # relative
LATENCY_TOL = 0.50      # relative p99 growth (wall-time)

REGEN_HINT = ("regenerate the baseline: PYTHONPATH=src python -m "
              "benchmarks.run --tables serve")

try:
    from repro.obs.metrics import SCHEMA_VERSION as EXPECTED_SCHEMA
except ImportError:     # stand-alone use without src on the path
    EXPECTED_SCHEMA = None


def _store_key(row: dict) -> tuple:
    return (row.get("codec", "raw"), row["cache_frac"], row["policy"])


def _schema_violations(baseline: dict, fresh: dict) -> List[str]:
    """Loud schema-drift failures (ISSUE-8) — any mismatch between the
    code's expected snapshot schema, the fresh document, and the
    committed baseline stops the row comparison entirely."""
    out: List[str] = []
    bv = baseline.get("schema_version")
    fv = fresh.get("schema_version")
    if (EXPECTED_SCHEMA is not None and fv is not None
            and fv != EXPECTED_SCHEMA):
        out.append(f"schema drift: fresh document schema_version {fv} "
                   f"!= expected {EXPECTED_SCHEMA} — rerun the bench "
                   "with this code version")
    if bv is not None and fv is None:
        out.append("schema drift: baseline carries schema_version "
                   f"{bv} but the fresh document has none — "
                   + REGEN_HINT)
    elif bv is not None and fv is not None and bv != fv:
        out.append(f"schema drift: baseline schema_version {bv} != "
                   f"fresh {fv} — " + REGEN_HINT)
    return out


def compare(baseline: dict, fresh: dict,
            hit_rate_tol: float = HIT_RATE_TOL,
            throughput_tol: float = THROUGHPUT_TOL,
            bytes_tol: float = BYTES_TOL,
            latency_tol: float = LATENCY_TOL,
            check_throughput: bool = True) -> List[str]:
    """Violation messages for ``fresh`` vs ``baseline`` (empty = pass).

    Both arguments are ``BENCH_serve.json`` documents (the full
    ``{"tables": {...}}`` schema or a bare tables dict).
    """
    out = _schema_violations(baseline, fresh)
    if out:
        return out
    try:
        return _compare_tables(
            baseline.get("tables", baseline),
            fresh.get("tables", fresh), hit_rate_tol, throughput_tol,
            bytes_tol, latency_tol, check_throughput)
    except KeyError as exc:
        return [f"schema drift: bench row missing field "
                f"{exc.args[0]!r} — " + REGEN_HINT]


def _compare_tables(base_t: dict, fresh_t: dict, hit_rate_tol: float,
                    throughput_tol: float, bytes_tol: float,
                    latency_tol: float,
                    check_throughput: bool) -> List[str]:
    out: List[str] = []

    fresh_serve = {r["batch"]: r for r in fresh_t.get("serve", ())}
    for row in base_t.get("serve", ()):
        got = fresh_serve.get(row["batch"])
        if got is None:
            out.append(f"serve[batch={row['batch']}]: row missing "
                       "from fresh run")
            continue
        if not check_throughput:
            continue
        floor = (1.0 - throughput_tol) * row["queries_per_s"]
        if got["queries_per_s"] < floor:
            out.append(
                f"serve[batch={row['batch']}]: throughput "
                f"{got['queries_per_s']:.0f} q/s < "
                f"{floor:.0f} (baseline {row['queries_per_s']:.0f} "
                f"- {throughput_tol:.0%})")

    fresh_store = {_store_key(r): r for r in fresh_t.get("store", ())}
    for row in base_t.get("store", ()):
        key = _store_key(row)
        name = (f"store[codec={key[0]}, cache={key[1]:.0%}, "
                f"policy={key[2]}]")
        got = fresh_store.get(key)
        if got is None:
            out.append(f"{name}: row missing from fresh run")
            continue
        floor = row["hit_rate"] - hit_rate_tol
        if got["hit_rate"] < floor:
            out.append(
                f"{name}: hit rate {got['hit_rate']:.3f} < "
                f"{floor:.3f} (baseline {row['hit_rate']:.3f} "
                f"- {hit_rate_tol:.0%}pp)")
        ceil = (1.0 + bytes_tol) * row["real_bytes"]
        if got["real_bytes"] > max(ceil, row["real_bytes"]):
            out.append(
                f"{name}: bytes read {got['real_bytes']} > "
                f"{ceil:.0f} (baseline {row['real_bytes']} "
                f"+ {bytes_tol:.0%})")

    fresh_qd = {(r.get("codec", "raw"), r["queue_depth"]): r
                for r in fresh_t.get("queue_depth", ())}
    for row in base_t.get("queue_depth", ()):
        key = (row.get("codec", "raw"), row["queue_depth"])
        name = f"queue_depth[codec={key[0]}, depth={key[1]}]"
        got = fresh_qd.get(key)
        if got is None:
            out.append(f"{name}: row missing from fresh run")
            continue
        floor = row["hit_rate"] - hit_rate_tol
        if got["hit_rate"] < floor:
            out.append(
                f"{name}: hit rate {got['hit_rate']:.3f} < "
                f"{floor:.3f} (baseline {row['hit_rate']:.3f} "
                f"- {hit_rate_tol:.0%}pp)")
        ceil = (1.0 + bytes_tol) * row["real_bytes"]
        if got["real_bytes"] > max(ceil, row["real_bytes"]):
            out.append(
                f"{name}: bytes read {got['real_bytes']} > "
                f"{ceil:.0f} (baseline {row['real_bytes']} "
                f"+ {bytes_tol:.0%})")
    # Fresh-run determinism invariant (no baseline, no tolerance):
    # read-ahead must never read more than the synchronous depth-1 run.
    depth1 = {k[0]: r for k, r in fresh_qd.items()
              if r["queue_depth"] == 1}
    for (codec, depth), row in sorted(fresh_qd.items(),
                                      key=lambda kv: kv[0]):
        base1 = depth1.get(codec)
        if depth == 1 or base1 is None:
            continue
        if row["real_bytes"] > base1["real_bytes"]:
            out.append(
                f"queue_depth[codec={codec}, depth={depth}]: read "
                f"{row['real_bytes']} bytes > depth-1's "
                f"{base1['real_bytes']} — read-ahead must not inflate "
                "I/O")

    # fleet table (ISSUE-10): baseline shard counts are required, the
    # aggregate counters gate like store rows, and the no-I/O-inflation
    # ordering is a fresh-run invariant with no tolerance.
    fresh_fleet = {r["shards"]: r for r in fresh_t.get("fleet", ())}
    for row in base_t.get("fleet", ()):
        name = f"fleet[shards={row['shards']}]"
        got = fresh_fleet.get(row["shards"])
        if got is None:
            out.append(f"{name}: shard-count row missing from fresh "
                       "run — a fleet width stopped being benchmarked")
            continue
        floor = row["hit_rate"] - hit_rate_tol
        if got["hit_rate"] < floor:
            out.append(
                f"{name}: fleet hit rate {got['hit_rate']:.3f} < "
                f"{floor:.3f} (baseline {row['hit_rate']:.3f} "
                f"- {hit_rate_tol:.0%}pp)")
        ceil = (1.0 + bytes_tol) * row["real_bytes"]
        if got["real_bytes"] > max(ceil, row["real_bytes"]):
            out.append(
                f"{name}: bytes read {got['real_bytes']} > "
                f"{ceil:.0f} (baseline {row['real_bytes']} "
                f"+ {bytes_tol:.0%})")
    solo = fresh_fleet.get(1)
    if solo is not None:
        for n, row in sorted(fresh_fleet.items()):
            if n > 1 and row["real_bytes"] > solo["real_bytes"]:
                out.append(
                    f"fleet[shards={n}]: read {row['real_bytes']} "
                    f"bytes > shards=1's {solo['real_bytes']} — "
                    "sharding must not inflate I/O")

    fresh_wl = {r["workload"]: r for r in fresh_t.get("workloads", ())}
    for row in base_t.get("workloads", ()):
        name = f"workloads[{row['workload']}]"
        got = fresh_wl.get(row["workload"])
        if got is None:
            out.append(f"{name}: row missing from fresh run")
            continue
        floor = row["hit_rate"] - hit_rate_tol
        if got["hit_rate"] < floor:
            out.append(
                f"{name}: hit rate {got['hit_rate']:.3f} < "
                f"{floor:.3f} (baseline {row['hit_rate']:.3f} "
                f"- {hit_rate_tol:.0%}pp)")
        for field, label in (("real_bytes", "bytes read"),
                             ("cold_query_bytes", "cold sweep bytes")):
            ceil = (1.0 + bytes_tol) * row[field]
            if got[field] > max(ceil, row[field]):
                out.append(
                    f"{name}: {label} {got[field]} > {ceil:.0f} "
                    f"(baseline {row[field]} + {bytes_tol:.0%})")

    # slo table (ISSUE-9): parent class rows are required in the fresh
    # run even with no baseline; the cheap-class p99 ordering is a
    # fresh-run invariant with no tolerance.
    fresh_slo = {(r["cls"], r["policy"]): r
                 for r in fresh_t.get("slo", ())}
    if "slo" in fresh_t or "slo" in base_t:
        classes = sorted({r["cls"]
                          for t in (base_t.get("slo", ()),
                                    fresh_t.get("slo", ()))
                          for r in t if "." not in r["cls"]})
        # every class must be reported under BOTH policies — a class
        # seen only under fifo means the scheduler dropped it (and
        # vice versa), so the pairing is required, not row-by-row
        for key in [(c, p) for c in classes for p in ("fifo", "slo")]:
            if key not in fresh_slo:
                out.append(f"slo[cls={key[0]}, policy={key[1]}]: class "
                           "row missing from fresh run — a traffic "
                           "class stopped being served/reported")
        cheap = sorted({r["cls"] for r in fresh_t.get("slo", ())
                        if r.get("cheap") and "." not in r["cls"]})
        if not cheap and fresh_t.get("slo"):
            out.append("slo: no cheap-class rows in the fresh run — "
                       "the mixed workload lost its cheap traffic "
                       "class")
        for cls in cheap:
            slo_row = fresh_slo.get((cls, "slo"))
            fifo_row = fresh_slo.get((cls, "fifo"))
            if slo_row is None or fifo_row is None:
                continue    # missing-row violation already recorded
            if slo_row["p99_ms"] >= fifo_row["p99_ms"]:
                out.append(
                    f"slo[cls={cls}]: scheduler p99 "
                    f"{slo_row['p99_ms']:.2f} ms not strictly below "
                    f"the fifo baseline's {fifo_row['p99_ms']:.2f} ms "
                    "— the SLO scheduler stopped protecting the "
                    "cheap class")
    for row in base_t.get("slo", ()):
        if "." in row["cls"]:
            continue        # timing-dependent sub-rows: informational
        key = (row["cls"], row["policy"])
        name = f"slo[cls={key[0]}, policy={key[1]}]"
        got = fresh_slo.get(key)
        if got is None:
            continue        # already reported above
        ceil = (1.0 + latency_tol) * row["p99_ms"]
        if got["p99_ms"] > ceil:
            out.append(
                f"{name}: p99 {got['p99_ms']:.2f} ms > {ceil:.2f} "
                f"(baseline {row['p99_ms']:.2f} ms "
                f"+ {latency_tol:.0%})")
        if check_throughput:
            floor = (1.0 - throughput_tol) * row["queries_per_s"]
            if got["queries_per_s"] < floor:
                out.append(
                    f"{name}: wall throughput "
                    f"{got['queries_per_s']:.0f} q/s < {floor:.0f} "
                    f"(baseline {row['queries_per_s']:.0f} "
                    f"- {throughput_tol:.0%})")

    fresh_lat = {r["mode"]: r for r in fresh_t.get("latency", ())}
    for row in base_t.get("latency", ()):
        name = f"latency[{row['mode']}]"
        got = fresh_lat.get(row["mode"])
        if got is None:
            out.append(f"{name}: row missing from fresh run")
            continue
        ceil = (1.0 + latency_tol) * row["p99_ms"]
        if got["p99_ms"] > ceil:
            out.append(
                f"{name}: p99 {got['p99_ms']:.2f} ms > {ceil:.2f} "
                f"(baseline {row['p99_ms']:.2f} ms "
                f"+ {latency_tol:.0%})")
    return out


#: argv flag dest → module default, for the three-layer tolerance
#: resolution in :func:`resolve_tolerances`.
_TOL_DEFAULTS = {
    "hit_rate_tol": HIT_RATE_TOL,
    "throughput_tol": THROUGHPUT_TOL,
    "bytes_tol": BYTES_TOL,
    "latency_tol": LATENCY_TOL,
}


def resolve_tolerances(args: argparse.Namespace) -> dict:
    """Tolerance knobs layered defaults < ``--config`` ``gate:``
    section < explicit argv flags (flags use ``argparse.SUPPRESS`` so
    only ones the caller actually passed are present on ``args``)."""
    tols = dict(_TOL_DEFAULTS)
    cfg_path = getattr(args, "config", None)
    if cfg_path:
        try:
            from repro.config import Config
        except ImportError as exc:
            raise SystemExit(
                f"--config needs repro on the path (PYTHONPATH=src): "
                f"{exc}")
        gate = Config(cfg_path).get("gate") or {}
        unknown = set(gate) - set(tols)
        if unknown:
            raise SystemExit(
                f"{cfg_path}: unknown gate key(s) {sorted(unknown)} — "
                f"expected {sorted(tols)}")
        for k, v in gate.items():
            tols[k] = float(v)
    for k in tols:
        if hasattr(args, k):
            tols[k] = getattr(args, k)
    return tols


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail (exit 1) when a fresh BENCH_serve run "
                    "regresses against the committed baseline")
    ap.add_argument("--baseline", default="BENCH_serve.json",
                    help="committed baseline BENCH_serve.json")
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH_serve.json")
    ap.add_argument("--config", default=None,
                    help="YAML whose `gate:` section sets the "
                         "tolerance knobs (configs/bench_serve.yaml); "
                         "explicit flags below still override it")
    ap.add_argument("--hit-rate-tol", type=float,
                    default=argparse.SUPPRESS,
                    help=f"max absolute hit-rate drop "
                         f"(default {HIT_RATE_TOL})")
    ap.add_argument("--throughput-tol", type=float,
                    default=argparse.SUPPRESS,
                    help=f"max relative throughput drop "
                         f"(default {THROUGHPUT_TOL})")
    ap.add_argument("--bytes-tol", type=float,
                    default=argparse.SUPPRESS,
                    help=f"max relative bytes-read growth "
                         f"(default {BYTES_TOL})")
    ap.add_argument("--latency-tol", type=float,
                    default=argparse.SUPPRESS,
                    help=f"max relative per-mode p99 latency growth "
                         f"(default {LATENCY_TOL}; wall-time — loosen "
                         f"on CI)")
    ap.add_argument("--no-throughput", action="store_true",
                    help="skip the machine-dependent throughput check")
    args = ap.parse_args(argv)
    tols = resolve_tolerances(args)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    violations = compare(baseline, fresh,
                         check_throughput=not args.no_throughput,
                         **tols)
    if violations:
        print(f"bench regression vs {args.baseline}:")
        for v in violations:
            print(f"  FAIL {v}")
        return 1
    base_sha = baseline.get("git_sha", "?")
    print(f"bench-regression gate OK: {args.fresh} within tolerance of "
          f"{args.baseline} (baseline sha {base_sha[:12]})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
